"""Layer-1 Pallas kernels: the GCN message-passing hot spot.

Two kernels, both tiled over the node axis with the full embedding matrix
resident (the paper's DAGs are ≤256 nodes; N·E floats ≤ 16 KiB — far under
VMEM):

* ``mgnet_layer`` — one forward message-passing iteration
  (Eq 5: ``out = g(A·e) + e0``, masked), fused aggregate + 2-layer MLP.
* ``agg_transpose`` — the backward aggregation ``Aᵀ·d_agg`` used by the
  custom VJP.

``mgnet_layer`` carries a ``jax.custom_vjp``: the forward *and* the heavy
part of the backward run as Pallas kernels; the small MLP parameter
gradients are plain jnp (they are O(E·H), negligible).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): on a real TPU the
BlockSpec below maps node tiles to the MXU's 128-lane systolic array; here
``interpret=True`` lowers to plain HLO so the CPU PJRT client (and the
rust runtime) can execute it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Node-axis tile. 32 divides both compiled variants (N=64, N=256).
BLOCK_N = 32


def _fwd_kernel(e_ref, e0_ref, adj_ref, mask_ref, g1_ref, bg1_ref, g2_ref, bg2_ref, out_ref):
    """One node-tile of: out = (tanh(tanh(A·e @ g1 + bg1) @ g2 + bg2) + e0) · mask."""
    agg = adj_ref[...] @ e_ref[...]  # [BN, E]  (adj tile row-block × full e)
    h = jnp.tanh(agg @ g1_ref[...] + bg1_ref[...])
    m = jnp.tanh(h @ g2_ref[...] + bg2_ref[...])
    out_ref[...] = (m + e0_ref[...]) * mask_ref[...][:, None]


def _fwd_pallas(e, e0, adj, mask, g1, bg1, g2, bg2):
    n, emb = e.shape
    h = g1.shape[1]
    block = min(BLOCK_N, n)
    assert n % block == 0, f"N={n} must be a multiple of {block}"
    grid = (n // block,)
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, emb), lambda i: (0, 0)),        # e: full
            pl.BlockSpec((block, emb), lambda i: (i, 0)),    # e0: row tile
            pl.BlockSpec((block, n), lambda i: (i, 0)),      # adj: row tile
            pl.BlockSpec((block,), lambda i: (i,)),          # mask: row tile
            pl.BlockSpec((emb, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, emb), lambda i: (0, 0)),
            pl.BlockSpec((emb,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block, emb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, emb), e.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(e, e0, adj, mask, g1, bg1, g2, bg2)


def _agg_t_kernel(adj_ref, d_ref, out_ref):
    """One node-tile of Aᵀ·d: out[tile] = (A[:, tile])ᵀ @ d = A_colsᵀ d."""
    # adj tile is the column block [N, BN]; transpose inside the tile.
    out_ref[...] = adj_ref[...].T @ d_ref[...]


def agg_transpose(adj, d_agg):
    """Pallas backward aggregation: returns adjᵀ @ d_agg, tiled over the
    output rows (= adj columns)."""
    n, emb = d_agg.shape
    block = min(BLOCK_N, n)
    assert n % block == 0
    grid = (n // block,)
    return pl.pallas_call(
        _agg_t_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block), lambda i: (0, i)),   # adj column block
            pl.BlockSpec((n, emb), lambda i: (0, 0)),     # d_agg: full
        ],
        out_specs=pl.BlockSpec((block, emb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, emb), d_agg.dtype),
        interpret=True,
    )(adj, d_agg)


@jax.custom_vjp
def mgnet_layer(e, e0, adj, mask, g1, bg1, g2, bg2):
    """One MGNet iteration (Eq 5) as a Pallas kernel with a custom VJP."""
    return _fwd_pallas(e, e0, adj, mask, g1, bg1, g2, bg2)


def _mgnet_fwd(e, e0, adj, mask, g1, bg1, g2, bg2):
    # Recompute the intermediates needed by the backward pass (agg, h, m).
    agg = adj @ e
    h = jnp.tanh(agg @ g1 + bg1)
    m = jnp.tanh(h @ g2 + bg2)
    out = _fwd_pallas(e, e0, adj, mask, g1, bg1, g2, bg2)
    return out, (adj, mask, g1, g2, agg, h, m)


def _mgnet_bwd(res, ct):
    adj, mask, g1, g2, agg, h, m = res
    # out = (m + e0) * mask[:, None]
    d_me0 = ct * mask[:, None]
    d_e0 = d_me0
    # m = tanh(pre2), pre2 = h @ g2 + bg2
    d_pre2 = d_me0 * (1.0 - m * m)
    d_h = d_pre2 @ g2.T
    d_g2 = h.T @ d_pre2
    d_bg2 = jnp.sum(d_pre2, axis=0)
    # h = tanh(pre1), pre1 = agg @ g1 + bg1
    d_pre1 = d_h * (1.0 - h * h)
    d_agg = d_pre1 @ g1.T
    d_g1 = agg.T @ d_pre1
    d_bg1 = jnp.sum(d_pre1, axis=0)
    # agg = adj @ e  →  d_e = adjᵀ @ d_agg (the heavy term — Pallas kernel)
    d_e = agg_transpose(adj, d_agg)
    # adjacency and masks are structural constants — zero cotangents.
    d_adj = jnp.zeros_like(adj)
    d_mask = jnp.zeros_like(mask)
    return (d_e, d_e0, d_adj, d_mask, d_g1, d_bg1, d_g2, d_bg2)


mgnet_layer.defvjp(_mgnet_fwd, _mgnet_bwd)


@functools.partial(jax.jit, static_argnames=())
def mgnet_layer_jit(e, e0, adj, mask, g1, bg1, g2, bg2):
    """Jitted wrapper for tests/benchmarks."""
    return mgnet_layer(e, e0, adj, mask, g1, bg1, g2, bg2)


__all__ = ["mgnet_layer", "agg_transpose", "mgnet_layer_jit", "ref", "BLOCK_N"]
