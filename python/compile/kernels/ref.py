"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal:
pytest asserts kernel == ref across shapes/dtypes, and the kernels'
backward pass is validated against jax.grad of these references)."""

import jax.numpy as jnp


def mgnet_layer_ref(e, e0, adj, mask, g1, bg1, g2, bg2):
    """One MGNet message-passing iteration (paper Eq 5):

        out = ( g(Σ_children e) + e0 ) · mask

    with g a two-layer tanh MLP. `adj[i, j] = 1` iff j is a child of i.

    Shapes: e,e0:[N,E]  adj:[N,N]  mask:[N]  g1:[E,H] bg1:[H] g2:[H,E] bg2:[E]
    """
    agg = adj @ e
    h = jnp.tanh(agg @ g1 + bg1)
    m = jnp.tanh(h @ g2 + bg2)
    return (m + e0) * mask[:, None]


def agg_transpose_ref(adj, d_agg):
    """Backward of the aggregation: cotangent flowing to `e` is adjᵀ·d_agg."""
    return adj.T @ d_agg


def masked_log_softmax_ref(logits, exec_mask):
    """Log-softmax over the executable set only (paper Eq 8).

    Non-executable slots get -inf logits; returns per-slot log-probs with
    zeros on masked slots (callers gather only executable actions).
    """
    neg = jnp.asarray(-1e9, logits.dtype)
    masked = jnp.where(exec_mask > 0, logits, neg)
    z = jnp.max(masked, axis=-1, keepdims=True)
    logsumexp = z + jnp.log(jnp.sum(jnp.exp(masked - z), axis=-1, keepdims=True))
    logp = masked - logsumexp
    return jnp.where(exec_mask > 0, logp, 0.0)
