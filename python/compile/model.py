"""Layer-2: the MGNet + policy/value network in JAX (paper §4.1–4.3),
operating on a single flat parameter vector whose layout is the shared
model contract with `rust/src/policy/net.rs`.

The forward pass calls the Layer-1 Pallas kernel (`kernels.gcn.mgnet_layer`)
for the K message-passing iterations, so the kernel lowers into the same
HLO module the rust runtime executes. `train_step` is the complete
actor–critic update — forward, backward (through the kernel's custom VJP)
and Adam — as one jittable function, AOT-exported by `aot.py`.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import shapes
from .kernels import gcn
from .kernels import ref as kref

S = shapes.param_slices()


def unpack(flat, name):
    """View one named tensor inside the flat parameter vector."""
    off, r, c = S[name]
    t = jax.lax.dynamic_slice(flat, (off,), (r * c,)).reshape(r, c)
    return t[0] if r == 1 else t  # biases as 1-D


def init_params(seed: int = 0) -> np.ndarray:
    """Glorot-uniform initialization of the flat vector (biases zero)."""
    rng = np.random.default_rng(seed)
    out = np.zeros(shapes.param_len(), dtype=np.float32)
    for name, r, c in shapes.LAYOUT:
        off, _, _ = S[name]
        if not name.startswith("b"):
            lim = np.sqrt(6.0 / (r + c))
            out[off : off + r * c] = rng.uniform(-lim, lim, r * c).astype(np.float32)
    return out


def _forward(flat, x, adj, jobmat, node_mask, use_kernel=True):
    """Shared forward: returns (logits [N], value scalar)."""
    layer = gcn.mgnet_layer if use_kernel else kref.mgnet_layer_ref
    e0 = jnp.tanh(x @ unpack(flat, "w_in") + unpack(flat, "b_in"))
    e0 = e0 * node_mask[:, None]
    e = e0
    g1, bg1 = unpack(flat, "g1"), unpack(flat, "bg1")
    g2, bg2 = unpack(flat, "g2"), unpack(flat, "bg2")
    for _ in range(shapes.K):
        e = layer(e, e0, adj, node_mask, g1, bg1, g2, bg2)

    # Per-job summaries.
    jobsum = jobmat @ e  # [J, E]
    jh = jnp.tanh(jobsum @ unpack(flat, "fj1") + unpack(flat, "bfj1"))
    y = jnp.tanh(jh @ unpack(flat, "fj2") + unpack(flat, "bfj2"))
    occupied = (jnp.sum(jobmat, axis=1) > 0).astype(y.dtype)  # [J]
    y = y * occupied[:, None]

    # Global summary.
    gsum = jnp.sum(y, axis=0)  # [E]
    gh = jnp.tanh(gsum @ unpack(flat, "fg1") + unpack(flat, "bfg1"))
    z = jnp.tanh(gh @ unpack(flat, "fg2") + unpack(flat, "bfg2"))  # [E]

    # Per-node scores over [e_n ; y_job(n) ; z] (Eq 8's q(·)).
    ybc = jobmat.T @ y  # [N, E] — each node's job summary (0 for padding)
    n = x.shape[0]
    cat = jnp.concatenate([e, ybc, jnp.broadcast_to(z, (n, shapes.E))], axis=1)
    q = jnp.tanh(cat @ unpack(flat, "q1") + unpack(flat, "bq1"))
    q = jnp.tanh(q @ unpack(flat, "q2") + unpack(flat, "bq2"))
    q = jnp.tanh(q @ unpack(flat, "q3") + unpack(flat, "bq3"))
    logits = (q @ unpack(flat, "q4") + unpack(flat, "bq4"))[:, 0]  # [N]

    # Value head on the global summary.
    v = jnp.tanh(z @ unpack(flat, "v1") + unpack(flat, "bv1"))
    v = jnp.tanh(v @ unpack(flat, "v2") + unpack(flat, "bv2"))
    value = (v @ unpack(flat, "v3") + unpack(flat, "bv3"))[0]
    return logits, value


def policy_forward(flat, x, adj, jobmat, node_mask):
    """Inference entrypoint (AOT-exported per shape variant).

    Returns (logits [N], value [1])."""
    logits, value = _forward(flat, x, adj, jobmat, node_mask, use_kernel=True)
    return logits, value.reshape(1)


def policy_forward_ref(flat, x, adj, jobmat, node_mask):
    """Oracle path (pure jnp, no Pallas) for correctness tests."""
    logits, value = _forward(flat, x, adj, jobmat, node_mask, use_kernel=False)
    return logits, value.reshape(1)


def _loss(flat, x, adj, jobmat, node_mask, exec_mask, action, adv, ret, sample_w, ew, vw):
    """Batched actor-critic loss (paper Eq 12 direction, with entropy
    regularization and a weighted value-regression term)."""

    def single(xi, ai, ji, mi, emi):
        return _forward(flat, xi, ai, ji, mi, use_kernel=True)

    logits, values = jax.vmap(single)(x, adj, jobmat, node_mask, exec_mask)
    logp = kref.masked_log_softmax_ref(logits, exec_mask)  # [B, N]
    b = logits.shape[0]
    logp_a = logp[jnp.arange(b), action]  # [B]
    wsum = jnp.sum(sample_w) + 1e-8
    pg = -jnp.sum(sample_w * adv * logp_a) / wsum
    # Entropy over the executable distribution.
    p = jnp.where(exec_mask > 0, jnp.exp(logp), 0.0)
    ent = -jnp.sum(jnp.where(exec_mask > 0, p * logp, 0.0), axis=-1)  # [B]
    entropy = jnp.sum(sample_w * ent) / wsum
    vloss = jnp.sum(sample_w * (values - ret) ** 2) / wsum
    total = pg + vw[0] * vloss - ew[0] * entropy
    return total, (pg, vloss, entropy)


def train_step(
    flat, m, v, step, x, adj, jobmat, node_mask, exec_mask, action, adv, ret, sample_w, lr, ew, vw
):
    """One synchronous actor-critic + Adam update (Algorithm 2 lines 9–13).

    All inputs/outputs are f32 except `action` (i32). Scalars arrive as
    shape-[1] tensors. Returns
    (new_flat, new_m, new_v, loss, pg_loss, value_loss, entropy) — each
    loss as shape [1].
    """
    (total, (pg, vloss, ent)), grads = jax.value_and_grad(_loss, has_aux=True)(
        flat, x, adj, jobmat, node_mask, exec_mask, action, adv, ret, sample_w, ew, vw
    )
    # Global-norm clipping keeps early high-variance episodes stable.
    gnorm = jnp.sqrt(jnp.sum(grads * grads) + 1e-12)
    clip = jnp.minimum(1.0, 5.0 / gnorm)
    grads = grads * clip
    # Adam (paper Appendix C; lr arrives as an input so imitation and RL
    # phases can differ without recompiling).
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = step[0]
    new_m = b1 * m + (1.0 - b1) * grads
    new_v = b2 * v + (1.0 - b2) * grads * grads
    mhat = new_m / (1.0 - jnp.power(b1, t))
    vhat = new_v / (1.0 - jnp.power(b2, t))
    new_flat = flat - lr[0] * mhat / (jnp.sqrt(vhat) + eps)
    one = lambda s: jnp.reshape(s, (1,))
    return new_flat, new_m, new_v, one(total), one(pg), one(vloss), one(ent)
