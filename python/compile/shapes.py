"""Model-contract constants, shared between the JAX model and the AOT
exporter. These MUST match `rust/src/policy/{mod,net,encode}.rs` — the
rust runtime validates them against `artifacts/meta.json` at load time.
"""

# Raw node feature count (rust: policy::features::NODE_FEATURES).
# 12 paper features + 3 data-locality features (rack-local parent-data
# fraction, cross-rack bytes pending, dominant rack id).
F = 15
# Embedding width.
E = 16
# Hidden width of the g/f MLPs.
H = 32
# Message-passing iterations (the paper's three-layer MGNet).
K = 3
# Policy head hidden sizes (paper §5.1: 32/16/8).
Q1, Q2, Q3 = 32, 16, 8
# Value head hidden sizes.
V1, V2 = 32, 16

# Policy-forward shape variants: (artifact stem, N nodes, J jobs).
VARIANTS = [
    ("policy_n64", 64, 8),
    ("policy_n256", 256, 32),
]

# Train-step shapes: (stem, batch B, N, J) — matches the small variant.
TRAIN = ("train_step", 16, 64, 8)

# Flat parameter layout: (name, rows, cols); biases are 1 x cols.
# Mirrors rust/src/policy/net.rs::LAYOUT exactly.
LAYOUT = [
    ("w_in", F, E),
    ("b_in", 1, E),
    ("g1", E, H),
    ("bg1", 1, H),
    ("g2", H, E),
    ("bg2", 1, E),
    ("fj1", E, H),
    ("bfj1", 1, H),
    ("fj2", H, E),
    ("bfj2", 1, E),
    ("fg1", E, H),
    ("bfg1", 1, H),
    ("fg2", H, E),
    ("bfg2", 1, E),
    ("q1", 3 * E, Q1),
    ("bq1", 1, Q1),
    ("q2", Q1, Q2),
    ("bq2", 1, Q2),
    ("q3", Q2, Q3),
    ("bq3", 1, Q3),
    ("q4", Q3, 1),
    ("bq4", 1, 1),
    ("v1", E, V1),
    ("bv1", 1, V1),
    ("v2", V1, V2),
    ("bv2", 1, V2),
    ("v3", V2, 1),
    ("bv3", 1, 1),
]


def param_len() -> int:
    """Total flat parameter count P."""
    return sum(r * c for _, r, c in LAYOUT)


def param_slices():
    """name -> (offset, rows, cols) mapping over the flat vector."""
    out = {}
    off = 0
    for name, r, c in LAYOUT:
        out[name] = (off, r, c)
        off += r * c
    return out
