"""AOT exporter: lowers the policy forward (per shape variant) and the
actor-critic train_step to HLO **text** and writes the artifact bundle:

    artifacts/
      policy_n64.hlo.txt    # inference, N=64 / J=8
      policy_n256.hlo.txt   # inference, N=256 / J=32
      train_step.hlo.txt    # fwd+bwd+Adam, B=16 / N=64 / J=8
      params_init.bin       # Glorot init, flat f32 LE
      meta.json             # shapes + param_len (the model contract)

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 rust crate binds) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, shapes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.float32)


def i32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.int32)


def lower_policy(n: int, j: int) -> str:
    p = shapes.param_len()
    lowered = jax.jit(model.policy_forward).lower(
        f32(p),            # flat params
        f32(n, shapes.F),  # x
        f32(n, n),         # adj
        f32(j, n),         # jobmat
        f32(n),            # node_mask
    )
    return to_hlo_text(lowered)


def lower_train(b: int, n: int, j: int) -> str:
    p = shapes.param_len()
    lowered = jax.jit(model.train_step).lower(
        f32(p), f32(p), f32(p), f32(1),          # params, m, v, step
        f32(b, n, shapes.F),                     # x
        f32(b, n, n),                            # adj
        f32(b, j, n),                            # jobmat
        f32(b, n),                               # node_mask
        f32(b, n),                               # exec_mask
        i32(b),                                  # action
        f32(b), f32(b), f32(b),                  # adv, ret, sample_w
        f32(1), f32(1), f32(1),                  # lr, entropy_w, value_w
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    variants_meta = []
    for name, n, j in shapes.VARIANTS:
        text = lower_policy(n, j)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
        variants_meta.append({"name": name, "n": n, "j": j})

    tname, b, tn, tj = shapes.TRAIN
    text = lower_train(b, tn, tj)
    tpath = os.path.join(args.out, f"{tname}.hlo.txt")
    with open(tpath, "w") as f:
        f.write(text)
    print(f"wrote {tpath} ({len(text)} chars)")

    params = model.init_params(args.seed)
    ppath = os.path.join(args.out, "params_init.bin")
    params.astype("<f4").tofile(ppath)
    print(f"wrote {ppath} ({params.size} params)")

    meta = {
        "format": "lachesis-artifacts-v1",
        "param_len": shapes.param_len(),
        "f": shapes.F,
        "e": shapes.E,
        "k": shapes.K,
        "variants": variants_meta,
        "train": {"name": tname, "b": b, "n": tn, "j": tj},
    }
    mpath = os.path.join(args.out, "meta.json")
    with open(mpath, "w") as f:
        json.dump(meta, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {mpath}")

    # Smoke check: numerics of the lowered fn match the python fn.
    n, j = shapes.VARIANTS[0][1], shapes.VARIANTS[0][2]
    rng = np.random.default_rng(1)
    x = rng.uniform(0, 1, (n, shapes.F)).astype(np.float32)
    adj = (rng.uniform(0, 1, (n, n)) < 0.05).astype(np.float32)
    jobmat = np.zeros((j, n), dtype=np.float32)
    jobmat[0, : n // 2] = 1.0
    jobmat[1, n // 2 :] = 1.0
    mask = np.ones(n, dtype=np.float32)
    logits, value = model.policy_forward(jnp.asarray(params), x, adj, jobmat, mask)
    assert np.isfinite(np.asarray(logits)).all() and np.isfinite(np.asarray(value)).all()
    print("smoke check OK")


if __name__ == "__main__":
    main()
