"""AOT artifact validation: shapes are lowered correctly, the HLO text is
self-consistent, meta.json matches the model contract, and the lowered
module's numerics match the python function when executed through
xla_client (the same engine the rust PJRT path binds)."""

import json
import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, "..")

from compile import aot, model, shapes  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def artifacts_present():
    return os.path.exists(os.path.join(ART, "meta.json"))


def test_lower_policy_produces_hlo_text():
    text = aot.lower_policy(64, 8)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Inputs: params, x, adj, jobmat, node_mask → 5 parameters.
    assert text.count("parameter(") >= 5


def test_lower_train_produces_hlo_text():
    text = aot.lower_train(4, 64, 8)  # small B to keep the test fast
    assert "HloModule" in text
    # Adam + grads means plenty of fusion-worthy ops.
    assert len(text) > 10_000


@pytest.mark.skipif(not artifacts_present(), reason="run `make artifacts` first")
def test_meta_json_matches_contract():
    with open(os.path.join(ART, "meta.json")) as f:
        meta = json.load(f)
    assert meta["param_len"] == shapes.param_len()
    assert meta["f"] == shapes.F
    assert meta["e"] == shapes.E
    assert meta["k"] == shapes.K
    names = {v["name"] for v in meta["variants"]}
    assert names == {n for n, _, _ in shapes.VARIANTS}
    assert meta["train"]["b"] == shapes.TRAIN[1]


@pytest.mark.skipif(not artifacts_present(), reason="run `make artifacts` first")
def test_params_init_length():
    p = np.fromfile(os.path.join(ART, "params_init.bin"), dtype="<f4")
    assert p.shape == (shapes.param_len(),)
    assert np.isfinite(p).all()


@pytest.mark.skipif(not artifacts_present(), reason="run `make artifacts` first")
@pytest.mark.parametrize("stem,n,j", [("policy_n64", 64, 8), ("policy_n256", 256, 32)])
def test_hlo_text_parses_with_expected_signature(stem, n, j):
    """The artifact text must round-trip through XLA's HLO parser (the
    exact entry point the rust runtime uses) and expose the agreed
    parameter signature. Numerical equivalence of the compiled module
    vs the rust reference forward is asserted end-to-end in
    rust/tests/integration_runtime.rs (jaxlib's in-process PJRT client
    API churns across versions, so the execution check lives rust-side).
    """
    from jax._src.lib import xla_client as xc

    with open(os.path.join(ART, f"{stem}.hlo.txt")) as f:
        text = f.read()
    mod = xc._xla.hlo_module_from_text(text)
    rendered = mod.to_string()
    # Entry signature: params[P], x[N,F], adj[N,N], jobmat[J,N], mask[N].
    assert f"f32[{shapes.param_len()}]" in rendered
    assert f"f32[{n},{shapes.F}]" in rendered
    assert f"f32[{n},{n}]" in rendered
    assert f"f32[{j},{n}]" in rendered
    # Proto round-trip is lossless enough to re-parse.
    assert mod.as_serialized_hlo_module_proto()


@pytest.mark.skipif(not artifacts_present(), reason="run `make artifacts` first")
def test_policy_forward_value_head_independent_of_exec_mask():
    """The value head reads only the global summary: perturbing features
    of one node changes the value, but logits of untouched nodes shift
    only through shared summaries — sanity of information routing."""
    n, j = 64, 8
    rng = np.random.default_rng(2)
    params = jnp.asarray(np.fromfile(os.path.join(ART, "params_init.bin"), dtype="<f4"))
    x = rng.uniform(0, 1, (n, shapes.F)).astype(np.float32)
    adj = np.zeros((n, n), dtype=np.float32)
    jobmat = np.zeros((j, n), dtype=np.float32)
    jobmat[0, :n] = 1.0
    mask = np.ones(n, dtype=np.float32)
    _, v1 = model.policy_forward(params, x, adj, jobmat, mask)
    x2 = x.copy()
    x2[0] = 1.0 - x2[0]
    _, v2 = model.policy_forward(params, x2, adj, jobmat, mask)
    assert not np.allclose(np.asarray(v1), np.asarray(v2)), "value must see node features"
