"""L2 correctness: model shapes, masking semantics, kernel-vs-ref forward
agreement, gradient sanity and the Adam train_step."""

import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, "..")

from compile import model, shapes  # noqa: E402


def make_state(rng, n=64, j=8, n_used=20, n_jobs=3):
    x = np.zeros((n, shapes.F), dtype=np.float32)
    x[:n_used] = rng.uniform(0, 1, (n_used, shapes.F)).astype(np.float32)
    adj = np.zeros((n, n), dtype=np.float32)
    for _ in range(n_used):
        a, b = rng.integers(0, n_used, 2)
        if a < b:
            adj[a, b] = 1.0
    jobmat = np.zeros((j, n), dtype=np.float32)
    for i in range(n_used):
        jobmat[i % n_jobs, i] = 1.0
    node_mask = np.zeros(n, dtype=np.float32)
    node_mask[:n_used] = 1.0
    exec_mask = np.zeros(n, dtype=np.float32)
    exec_mask[: n_used // 2] = 1.0
    return x, adj, jobmat, node_mask, exec_mask


def test_param_len_matches_layout():
    p = model.init_params(0)
    assert p.shape == (shapes.param_len(),)
    assert p.dtype == np.float32
    # Biases start at zero, weights don't.
    s = shapes.param_slices()
    off, r, c = s["b_in"]
    assert np.all(p[off : off + r * c] == 0.0)
    off, r, c = s["w_in"]
    assert np.any(p[off : off + r * c] != 0.0)


def test_forward_shapes_and_finite():
    rng = np.random.default_rng(0)
    params = jnp.asarray(model.init_params(0))
    x, adj, jobmat, node_mask, _ = make_state(rng)
    logits, value = model.policy_forward(params, x, adj, jobmat, node_mask)
    assert logits.shape == (64,)
    assert value.shape == (1,)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(np.asarray(value)).all()


def test_kernel_and_ref_forward_agree():
    rng = np.random.default_rng(1)
    params = jnp.asarray(model.init_params(1))
    x, adj, jobmat, node_mask, _ = make_state(rng)
    lk, vk = model.policy_forward(params, x, adj, jobmat, node_mask)
    lr_, vr = model.policy_forward_ref(params, x, adj, jobmat, node_mask)
    np.testing.assert_allclose(lk, lr_, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(vk, vr, rtol=1e-5, atol=1e-5)


def test_padding_slots_do_not_affect_used_logits():
    """Writing garbage features into masked-out slots must not change the
    logits of used slots (mask correctness end to end)."""
    rng = np.random.default_rng(2)
    params = jnp.asarray(model.init_params(2))
    x, adj, jobmat, node_mask, _ = make_state(rng, n_used=10)
    l1, _ = model.policy_forward(params, x, adj, jobmat, node_mask)
    x2 = x.copy()
    x2[10:] = 99.0  # garbage in padding
    l2, _ = model.policy_forward(params, x2, adj, jobmat, node_mask)
    np.testing.assert_allclose(np.asarray(l1)[:10], np.asarray(l2)[:10], rtol=1e-5)


def test_deeper_dag_changes_logits():
    """The GCN must actually use the adjacency: adding edges changes scores."""
    rng = np.random.default_rng(3)
    params = jnp.asarray(model.init_params(3))
    x, adj, jobmat, node_mask, _ = make_state(rng)
    l1, _ = model.policy_forward(params, x, adj, jobmat, node_mask)
    adj2 = adj.copy()
    adj2[0, 1] = 1.0
    adj2[1, 2] = 1.0
    l2, _ = model.policy_forward(params, x, adj2, jobmat, node_mask)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def make_batch(rng, b=4, n=64, j=8):
    xs, adjs, jobs, nms, ems = [], [], [], [], []
    for _ in range(b):
        x, adj, jobmat, nm, em = make_state(rng)
        xs.append(x)
        adjs.append(adj)
        jobs.append(jobmat)
        nms.append(nm)
        ems.append(em)
    action = rng.integers(0, 5, b).astype(np.int32)
    adv = rng.standard_normal(b).astype(np.float32)
    ret = rng.standard_normal(b).astype(np.float32)
    sw = np.ones(b, dtype=np.float32)
    return (
        np.stack(xs),
        np.stack(adjs),
        np.stack(jobs),
        np.stack(nms),
        np.stack(ems),
        action,
        adv,
        ret,
        sw,
    )


def test_train_step_updates_params_and_reduces_imitation_loss():
    rng = np.random.default_rng(4)
    params = jnp.asarray(model.init_params(4))
    p = shapes.param_len()
    m = jnp.zeros(p)
    v = jnp.zeros(p)
    batch = make_batch(rng)
    # Imitation setting: adv=1 toward fixed actions, value weight 0.
    x, adj, jobmat, nm, em, action, _, ret, sw = batch
    adv = np.ones_like(ret)
    lr = np.array([1e-3], dtype=np.float32)
    ew = np.array([0.0], dtype=np.float32)
    vw = np.array([0.0], dtype=np.float32)
    losses = []
    step = 0.0
    for i in range(12):
        step += 1.0
        params, m, v, total, pg, vl, ent = model.train_step(
            params, m, v, np.array([step], dtype=np.float32),
            x, adj, jobmat, nm, em, action, adv, ret, sw, lr, ew, vw,
        )
        losses.append(float(total[0]))
    assert losses[-1] < losses[0], f"imitation loss should fall: {losses}"
    assert np.isfinite(np.asarray(params)).all()


def test_train_step_respects_sample_weights():
    """Zero-weight rows must not influence the update."""
    rng = np.random.default_rng(5)
    params0 = jnp.asarray(model.init_params(5))
    p = shapes.param_len()
    x, adj, jobmat, nm, em, action, adv, ret, sw = make_batch(rng)
    lr = np.array([1e-3], dtype=np.float32)
    ew = np.array([0.01], dtype=np.float32)
    vw = np.array([0.5], dtype=np.float32)
    step = np.array([1.0], dtype=np.float32)
    z = jnp.zeros(p)
    # Run with all rows active.
    pa, *_ = model.train_step(
        params0, z, z, step, x, adj, jobmat, nm, em, action, adv, ret, sw, lr, ew, vw
    )
    # Corrupt the last row but zero its weight: same update expected.
    x2 = x.copy()
    x2[-1] = 1.0
    adv2 = adv.copy()
    adv2[-1] = 100.0
    sw2 = sw.copy()
    sw2[-1] = 0.0
    sw_ref = sw.copy()
    sw_ref[-1] = 0.0
    pb, *_ = model.train_step(
        params0, z, z, step, x2, adj, jobmat, nm, em, action, adv2, ret, sw2, lr, ew, vw
    )
    pc, *_ = model.train_step(
        params0, z, z, step, x, adj, jobmat, nm, em, action, adv, ret, sw_ref, lr, ew, vw
    )
    np.testing.assert_allclose(np.asarray(pb), np.asarray(pc), rtol=1e-5, atol=1e-6)


def test_masked_log_softmax_properties():
    from compile.kernels import ref as kref

    logits = np.array([[1.0, 2.0, 3.0, 4.0]], dtype=np.float32)
    em = np.array([[1.0, 1.0, 0.0, 1.0]], dtype=np.float32)
    logp = np.asarray(kref.masked_log_softmax_ref(logits, em))
    probs = np.exp(logp[0][em[0] > 0])
    assert abs(probs.sum() - 1.0) < 1e-5
    assert logp[0][2] == 0.0  # masked slot zeroed
    # Larger logit ⇒ larger prob among executables.
    assert logp[0][3] > logp[0][0]


def test_grad_flows_to_all_parameter_blocks():
    rng = np.random.default_rng(6)
    params = jnp.asarray(model.init_params(6))
    x, adj, jobmat, nm, em = make_state(rng)

    def loss(p):
        logits, value = model.policy_forward(p, x, adj, jobmat, nm)
        return jnp.sum(logits * np.asarray(em)) + value[0] ** 2

    g = np.asarray(jax.grad(loss)(params))
    s = shapes.param_slices()
    for name, _, _ in shapes.LAYOUT:
        off, r, c = s[name]
        block = g[off : off + r * c]
        assert np.any(block != 0.0), f"no gradient reached '{name}'"
