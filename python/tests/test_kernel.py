"""L1 correctness: the Pallas kernels against the pure-jnp oracles, and
the custom VJP against jax.grad of the reference — swept over shapes and
magnitudes with hypothesis."""

import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, "..")  # python/ on the path when run from python/

from compile.kernels import gcn, ref  # noqa: E402


def make_inputs(rng, n, e, h, density=0.1, scale=1.0):
    e_in = rng.standard_normal((n, e)).astype(np.float32) * scale
    e0 = rng.standard_normal((n, e)).astype(np.float32) * scale
    adj = (rng.uniform(size=(n, n)) < density).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    mask = (rng.uniform(size=n) < 0.9).astype(np.float32)
    g1 = rng.standard_normal((e, h)).astype(np.float32) * 0.3
    bg1 = rng.standard_normal(h).astype(np.float32) * 0.1
    g2 = rng.standard_normal((h, e)).astype(np.float32) * 0.3
    bg2 = rng.standard_normal(e).astype(np.float32) * 0.1
    return e_in, e0, adj, mask, g1, bg1, g2, bg2


@pytest.mark.parametrize("n", [32, 64, 128, 256])
@pytest.mark.parametrize("e,h", [(16, 32), (8, 16)])
def test_mgnet_layer_matches_ref(n, e, h):
    rng = np.random.default_rng(n + e)
    args = make_inputs(rng, n, e, h)
    out_kernel = gcn.mgnet_layer(*args)
    out_ref = ref.mgnet_layer_ref(*args)
    np.testing.assert_allclose(out_kernel, out_ref, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(min_value=1, max_value=8),
    density=st.floats(min_value=0.0, max_value=0.5),
    scale=st.floats(min_value=0.01, max_value=10.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_mgnet_layer_hypothesis_sweep(n_blocks, density, scale, seed):
    n = gcn.BLOCK_N * n_blocks
    rng = np.random.default_rng(seed)
    args = make_inputs(rng, n, 16, 32, density=density, scale=scale)
    out_kernel = gcn.mgnet_layer(*args)
    out_ref = ref.mgnet_layer_ref(*args)
    np.testing.assert_allclose(out_kernel, out_ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n", [32, 64])
def test_agg_transpose_matches_ref(n):
    rng = np.random.default_rng(n)
    adj = (rng.uniform(size=(n, n)) < 0.2).astype(np.float32)
    d = rng.standard_normal((n, 16)).astype(np.float32)
    np.testing.assert_allclose(
        gcn.agg_transpose(adj, d), ref.agg_transpose_ref(adj, d), rtol=1e-5, atol=1e-5
    )


def test_custom_vjp_matches_ref_grads():
    """d(kernel)/d(inputs) must equal jax.grad of the reference for every
    differentiable input (e, e0, g1, bg1, g2, bg2)."""
    rng = np.random.default_rng(7)
    args = make_inputs(rng, 64, 16, 32)

    def loss_kernel(e, e0, g1, bg1, g2, bg2):
        out = gcn.mgnet_layer(e, e0, args[2], args[3], g1, bg1, g2, bg2)
        return jnp.sum(out * out)

    def loss_ref(e, e0, g1, bg1, g2, bg2):
        out = ref.mgnet_layer_ref(e, e0, args[2], args[3], g1, bg1, g2, bg2)
        return jnp.sum(out * out)

    diff_args = (args[0], args[1], args[4], args[5], args[6], args[7])
    gk = jax.grad(loss_kernel, argnums=tuple(range(6)))(*diff_args)
    gr = jax.grad(loss_ref, argnums=tuple(range(6)))(*diff_args)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_masked_rows_are_zero():
    rng = np.random.default_rng(11)
    e, e0, adj, mask, g1, bg1, g2, bg2 = make_inputs(rng, 32, 16, 32)
    mask = np.zeros(32, dtype=np.float32)
    mask[:5] = 1.0
    out = np.asarray(gcn.mgnet_layer(e, e0, adj, mask, g1, bg1, g2, bg2))
    assert np.all(out[5:] == 0.0)
    assert np.any(out[:5] != 0.0)


def test_kernel_under_jit():
    rng = np.random.default_rng(13)
    args = make_inputs(rng, 64, 16, 32)
    out_eager = gcn.mgnet_layer(*args)
    out_jit = gcn.mgnet_layer_jit(*args)
    np.testing.assert_allclose(out_eager, out_jit, rtol=1e-6, atol=1e-6)


def test_empty_graph_reduces_to_mlp_of_zero():
    """With no edges, agg = 0 and out = (g(0) + e0) * mask."""
    rng = np.random.default_rng(17)
    e, e0, adj, mask, g1, bg1, g2, bg2 = make_inputs(rng, 32, 16, 32)
    adj = np.zeros_like(adj)
    out = np.asarray(gcn.mgnet_layer(e, e0, adj, mask, g1, bg1, g2, bg2))
    g0 = np.tanh(np.tanh(bg1) @ g2 + bg2)
    expected = (g0[None, :] + e0) * mask[:, None]
    np.testing.assert_allclose(out, expected, rtol=1e-5, atol=1e-5)
