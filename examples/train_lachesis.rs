//! END-TO-END DRIVER (DESIGN.md §End-to-end validation): trains the
//! Lachesis policy with the full three-layer stack —
//!
//!   rust simulator rollouts (parallel actors) → encoded transitions →
//!   gradient step (AOT `train_step` via PJRT when built with
//!   `--features pjrt` and artifacts exist, otherwise the native CPU
//!   backend — analytic backprop, no python anywhere) → updated flat
//!   parameters → next rollouts —
//!
//! then evaluates the trained policy against HEFT/FIFO/Decima-DEFT on
//! held-out workloads and prints the learning curve (the paper's Fig 4).
//!
//!     cargo run --release --example train_lachesis
//!     (options: -- --episodes 200 --agents 4 --seed 1 --threads auto)

use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, TrainConfig, WorkloadConfig};
use lachesis::policy::features::FeatureMode;
use lachesis::policy::{params, RustPolicy};
use lachesis::rl::cpu_backend::{CpuTrainBackend, CPU_TRAIN_BATCH};
use lachesis::rl::trainer::{TrainBackend, Trainer};
use lachesis::sched::{
    DecimaScheduler, FifoScheduler, HeftScheduler, LachesisScheduler, Scheduler,
};
use lachesis::sim::Simulator;
use lachesis::workload::WorkloadGenerator;

fn main() -> anyhow::Result<()> {
    let args = lachesis::util::cli::Args::from_env()?;
    let mut cfg = TrainConfig::default();
    cfg.episodes = args.usize_opt("episodes", 120)?;
    cfg.agents = args.usize_opt("agents", 4)?;
    cfg.seed = args.u64_opt("seed", 20210001)?;
    cfg.jobs_per_episode = args.usize_opt("jobs-per-episode", 4)?;
    cfg.executors = args.usize_opt("executors", 10)?;
    cfg.threads = args.threads_opt(0)?;

    let init = params::load_expected(
        "artifacts/params_init.bin",
        lachesis::policy::net::param_len(),
    )
    .unwrap_or_else(|_| RustPolicy::random_params(cfg.seed));

    #[cfg(feature = "pjrt")]
    {
        use lachesis::rl::trainer::PjrtTrainBackend;
        match PjrtTrainBackend::new("artifacts", init.clone()) {
            Ok(backend) => {
                let batch = backend.batch_size();
                return run(cfg, backend, batch);
            }
            Err(e) => eprintln!("PJRT backend unavailable ({e}); using the CPU backend"),
        }
    }
    run(cfg, CpuTrainBackend::new(init), CPU_TRAIN_BATCH)
}

fn run<B: TrainBackend>(cfg: TrainConfig, backend: B, batch: usize) -> anyhow::Result<()> {
    let mut trainer = Trainer::new(cfg.clone(), backend, FeatureMode::Full);
    println!(
        "training Lachesis [{} backend]: {} episodes × {} agents (imitation warm start: {} epochs)",
        trainer.backend.name(),
        cfg.episodes,
        cfg.agents,
        cfg.imitation_epochs
    );
    let t0 = std::time::Instant::now();
    let stats = trainer.train(batch)?;
    println!("training took {:.1}s\n", t0.elapsed().as_secs_f64());

    // Learning curve (Fig 4).
    println!("episode  jobs  avg-makespan     loss  entropy");
    let stride = (stats.len() / 15).max(1);
    for s in stats.iter().step_by(stride).chain(stats.last()) {
        println!(
            "{:>7} {:>5} {:>12.1}s {:>8.4} {:>8.3}",
            s.episode, s.n_jobs, s.makespan, s.loss, s.entropy
        );
    }
    std::fs::create_dir_all("results").ok();
    let mut csv = String::from(lachesis::rl::trainer::EpisodeStat::csv_header());
    csv.push('\n');
    for s in &stats {
        csv.push_str(&s.csv_row());
        csv.push('\n');
    }
    std::fs::write("results/fig4_learning_curve.csv", csv)?;
    std::fs::create_dir_all("checkpoints").ok();
    params::save_f32("checkpoints/lachesis.bin", trainer.backend.params())?;
    println!("\nlearning curve → results/fig4_learning_curve.csv");
    println!("trained weights → checkpoints/lachesis.bin");

    // ---- Evaluate on held-out workloads --------------------------------
    println!("\nheld-out evaluation ({} executors, 6-job batches):", cfg.executors);
    println!("{:<16} {:>12} {:>9}", "algorithm", "avg makespan", "speedup");
    let trained = trainer.backend.params().to_vec();
    let eval = |mut s: Box<dyn Scheduler>| -> anyhow::Result<(String, f64, f64)> {
        let mut ms = Vec::new();
        let mut sp = Vec::new();
        for seed in 9000..9006u64 {
            let cluster =
                Cluster::heterogeneous(&ClusterConfig::with_executors(cfg.executors), seed);
            let w = WorkloadGenerator::new(WorkloadConfig::small_batch(6), seed).generate();
            let r = Simulator::new(cluster, w).run(s.as_mut())?;
            ms.push(r.makespan);
            sp.push(r.speedup);
        }
        Ok((
            s.name(),
            lachesis::util::stats::mean(&ms),
            lachesis::util::stats::mean(&sp),
        ))
    };
    let contenders: Vec<Box<dyn Scheduler>> = vec![
        Box::new(FifoScheduler::new()),
        Box::new(HeftScheduler::new()),
        Box::new(DecimaScheduler::greedy_decima(Box::new(RustPolicy::random(1)))),
        Box::new(LachesisScheduler::greedy(Box::new(RustPolicy::new(
            trained,
        )))),
    ];
    for c in contenders {
        let (name, m, s) = eval(c)?;
        println!("{name:<16} {m:>11.1}s {s:>8.2}x");
    }
    Ok(())
}
