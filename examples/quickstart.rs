//! Quickstart: generate a TPC-H workload, schedule it on a heterogeneous
//! 50-executor cluster with several algorithms, and print the paper's
//! metrics for each.
//!
//!     cargo run --release --example quickstart

use lachesis::prelude::*;

fn main() -> anyhow::Result<()> {
    let seed = 42;
    let cluster_cfg = ClusterConfig::default(); // 50 executors, 2.1–3.6 GHz
    let workload = WorkloadGenerator::new(WorkloadConfig::small_batch(10), seed).generate();
    println!(
        "workload: {} jobs, {} tasks, {} edges, {:.0} GHz·s total work\n",
        workload.n_jobs(),
        workload.n_tasks(),
        workload.n_edges(),
        workload.total_work()
    );

    let mut schedulers: Vec<Box<dyn Scheduler>> = vec![
        Box::new(FifoScheduler::new()),
        Box::new(SjfScheduler::new()),
        Box::new(HeftScheduler::new()),
        Box::new(CpopScheduler::new()),
        Box::new(TdcaScheduler::new()),
        Box::new(HighRankUpScheduler::new()),
        Box::new(LachesisScheduler::greedy(Box::new(RustPolicy::random(7)))),
    ];

    println!(
        "{:<18} {:>10} {:>9} {:>7} {:>6} {:>12}",
        "algorithm", "makespan", "speedup", "SLR", "dups", "p98 decision"
    );
    for sched in schedulers.iter_mut() {
        let cluster = Cluster::heterogeneous(&cluster_cfg, seed);
        let mut sim = Simulator::new(cluster, workload.clone());
        let r = sim.run(sched.as_mut())?;
        sim.state.validate()?;
        println!(
            "{:<18} {:>9.1}s {:>8.2}x {:>7.3} {:>6} {:>10.3}ms",
            r.algo,
            r.makespan,
            r.speedup,
            r.avg_slr,
            r.n_duplicates,
            r.decision_ms.percentile(98.0)
        );
    }
    println!("\n(Lachesis here runs with untrained weights — see examples/train_lachesis.rs)");
    Ok(())
}
