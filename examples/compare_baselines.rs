//! Reproduce a slice of the paper's Fig 6 comparison (batch mode, large
//! scale): all batch baselines vs Lachesis over several seeds, printing
//! the same four panels (makespan / speedup / SLR / decision time).
//!
//!     cargo run --release --example compare_baselines [-- --seeds 5 --threads auto]

use lachesis::exp::{self, PolicySource};

fn main() -> anyhow::Result<()> {
    let args = lachesis::util::cli::Args::from_env()?;
    let seeds = args.usize_opt("seeds", 3)?;
    let threads = args.threads_opt(1)?;
    let quick = !args.flag("full");
    let src = PolicySource {
        // Uses checkpoints/lachesis.bin if present, else the AOT init,
        // else random weights; PJRT backend if artifacts exist.
        ..Default::default()
    };
    let out = exp::fig6(&src, quick, seeds, threads)?;
    println!("{out}");
    println!("CSV written to results/fig6.csv");
    Ok(())
}
