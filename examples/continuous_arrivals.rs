//! Continuous-mode study (paper §5.3.3): Poisson arrivals with mean 45 s,
//! comparing the online selectors the paper uses in Fig 7, plus a
//! sensitivity sweep over the arrival rate (an extension experiment the
//! paper motivates but does not plot).
//!
//!     cargo run --release --example continuous_arrivals [-- --net tree:5x10]
//!
//! `--net` selects the network topology (`flat` | `tree:RxW` |
//! `fat-tree:K`) so the continuous-mode comparison can be repeated on a
//! rack-structured cluster.

use lachesis::cluster::Cluster;
use lachesis::config::{Arrival, ClusterConfig, WorkloadConfig};
use lachesis::policy::RustPolicy;
use lachesis::sched::{
    HighRankUpScheduler, HrrnScheduler, LachesisScheduler, Scheduler, SjfScheduler,
};
use lachesis::sim::Simulator;
use lachesis::util::stats::mean;
use lachesis::workload::WorkloadGenerator;

/// Load the Lachesis weights once; every scheduler built from them
/// clones the vector instead of re-reading the checkpoint.
fn lachesis_params() -> Vec<f32> {
    lachesis::policy::params::load_expected(
        "checkpoints/lachesis.bin",
        lachesis::policy::net::param_len(),
    )
    .unwrap_or_else(|_| RustPolicy::random_params(3))
}

fn make_scheds(params: &[f32]) -> Vec<Box<dyn Scheduler>> {
    vec![
        Box::new(SjfScheduler::new()),
        Box::new(HrrnScheduler::new()),
        Box::new(HighRankUpScheduler::new()),
        Box::new(LachesisScheduler::greedy(Box::new(RustPolicy::new(
            params.to_vec(),
        )))),
    ]
}

fn main() -> anyhow::Result<()> {
    let args = lachesis::util::cli::Args::from_env()?;
    let mut cfg = ClusterConfig::default();
    cfg.net = lachesis::net::NetConfig::parse(args.opt_or("net", "flat"))?;
    cfg.validate()?;
    let seeds: Vec<u64> = (0..4).collect();
    let params = lachesis_params();

    println!("network topology: {}", cfg.net.topology_str());
    println!("== Fig 7a slice: makespan at mean inter-arrival 45 s ==");
    println!("{:<18} {:>12} {:>10}", "algorithm", "avg makespan", "avg JCT");
    for mut sched in make_scheds(&params) {
        let mut ms = Vec::new();
        let mut jct = Vec::new();
        for &seed in &seeds {
            let w = WorkloadGenerator::new(WorkloadConfig::continuous(20), 7000 + seed)
                .generate();
            let cluster = Cluster::heterogeneous(&cfg, seed);
            let r = Simulator::new(cluster, w).run(sched.as_mut())?;
            ms.push(r.makespan);
            jct.push(r.avg_jct);
        }
        println!(
            "{:<18} {:>11.1}s {:>9.1}s",
            sched.name(),
            mean(&ms),
            mean(&jct)
        );
    }

    println!("\n== extension: sensitivity to arrival rate (HighRankUp-DEFT vs Lachesis) ==");
    println!("{:<14} {:>16} {:>16}", "mean interval", "HighRankUp-DEFT", "Lachesis");
    // Exactly the two compared schedulers, built once for the whole
    // sweep — not all four (plus a checkpoint reload) per interval.
    let mut pair: [Box<dyn Scheduler>; 2] = [
        Box::new(HighRankUpScheduler::new()),
        Box::new(LachesisScheduler::greedy(Box::new(RustPolicy::new(
            params.clone(),
        )))),
    ];
    for &interval in &[15.0, 30.0, 45.0, 90.0] {
        let mut cols = Vec::new();
        for sched in pair.iter_mut() {
            let mut ms = Vec::new();
            for &seed in &seeds {
                let mut wc = WorkloadConfig::continuous(16);
                wc.arrival = Arrival::Poisson {
                    mean_interval: interval,
                };
                let w = WorkloadGenerator::new(wc, 8000 + seed).generate();
                let cluster = Cluster::heterogeneous(&cfg, seed);
                let r = Simulator::new(cluster, w).run(sched.as_mut())?;
                ms.push(r.avg_jct);
            }
            cols.push(mean(&ms));
        }
        println!(
            "{:>11.0} s {:>15.1}s {:>15.1}s",
            interval, cols[0], cols[1]
        );
    }
    println!("\n(avg JCT reported for the sensitivity sweep; lower is better)");
    Ok(())
}
