//! Continuous-mode study (paper §5.3.3): Poisson arrivals with mean 45 s,
//! comparing the online selectors the paper uses in Fig 7, plus a
//! sensitivity sweep over the arrival rate (an extension experiment the
//! paper motivates but does not plot).
//!
//!     cargo run --release --example continuous_arrivals

use lachesis::cluster::Cluster;
use lachesis::config::{Arrival, ClusterConfig, WorkloadConfig};
use lachesis::policy::RustPolicy;
use lachesis::sched::{
    HighRankUpScheduler, HrrnScheduler, LachesisScheduler, Scheduler, SjfScheduler,
};
use lachesis::sim::Simulator;
use lachesis::util::stats::mean;
use lachesis::workload::WorkloadGenerator;

fn make_scheds() -> Vec<Box<dyn Scheduler>> {
    let params = lachesis::policy::params::load_expected(
        "checkpoints/lachesis.bin",
        lachesis::policy::net::param_len(),
    )
    .unwrap_or_else(|_| RustPolicy::random_params(3));
    vec![
        Box::new(SjfScheduler::new()),
        Box::new(HrrnScheduler::new()),
        Box::new(HighRankUpScheduler::new()),
        Box::new(LachesisScheduler::greedy(Box::new(RustPolicy::new(params)))),
    ]
}

fn main() -> anyhow::Result<()> {
    let cfg = ClusterConfig::default();
    let seeds: Vec<u64> = (0..4).collect();

    println!("== Fig 7a slice: makespan at mean inter-arrival 45 s ==");
    println!("{:<18} {:>12} {:>10}", "algorithm", "avg makespan", "avg JCT");
    for mut sched in make_scheds() {
        let mut ms = Vec::new();
        let mut jct = Vec::new();
        for &seed in &seeds {
            let w = WorkloadGenerator::new(WorkloadConfig::continuous(20), 7000 + seed)
                .generate();
            let cluster = Cluster::heterogeneous(&cfg, seed);
            let r = Simulator::new(cluster, w).run(sched.as_mut())?;
            ms.push(r.makespan);
            jct.push(r.avg_jct);
        }
        println!(
            "{:<18} {:>11.1}s {:>9.1}s",
            sched.name(),
            mean(&ms),
            mean(&jct)
        );
    }

    println!("\n== extension: sensitivity to arrival rate (HighRankUp-DEFT vs Lachesis) ==");
    println!("{:<14} {:>16} {:>16}", "mean interval", "HighRankUp-DEFT", "Lachesis");
    for &interval in &[15.0, 30.0, 45.0, 90.0] {
        let mut cols = Vec::new();
        for mut sched in [
            Box::new(HighRankUpScheduler::new()) as Box<dyn Scheduler>,
            make_scheds().pop().unwrap(),
        ] {
            let mut ms = Vec::new();
            for &seed in &seeds {
                let mut wc = WorkloadConfig::continuous(16);
                wc.arrival = Arrival::Poisson {
                    mean_interval: interval,
                };
                let w = WorkloadGenerator::new(wc, 8000 + seed).generate();
                let cluster = Cluster::heterogeneous(&cfg, seed);
                let r = Simulator::new(cluster, w).run(sched.as_mut())?;
                ms.push(r.avg_jct);
            }
            cols.push(mean(&ms));
        }
        println!(
            "{:>11.0} s {:>15.1}s {:>15.1}s",
            interval, cols[0], cols[1]
        );
    }
    println!("\n(avg JCT reported for the sensitivity sweep; lower is better)");
    Ok(())
}
