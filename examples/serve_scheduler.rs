//! Plug-and-play service demo (paper Fig 3): starts the Lachesis agent on
//! an ephemeral TCP port, then plays the resource manager — submitting a
//! streaming TPC-H workload, asking for assignments at each arrival, and
//! reporting end-to-end request latency.
//!
//!     cargo run --release --example serve_scheduler

use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, WorkloadConfig};
use lachesis::policy::RustPolicy;
use lachesis::sched::LachesisScheduler;
use lachesis::service::{AgentServer, ClientConfig, Request, Response, ServiceClient};
use std::time::Duration;
use lachesis::util::stats::Recorder;
use lachesis::workload::WorkloadGenerator;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // Agent side: Lachesis policy (trained weights if present) + DEFT.
    let params = lachesis::policy::params::load_expected(
        "checkpoints/lachesis.bin",
        lachesis::policy::net::param_len(),
    )
    .or_else(|_| {
        lachesis::policy::params::load_expected(
            "artifacts/params_init.bin",
            lachesis::policy::net::param_len(),
        )
    })
    .unwrap_or_else(|_| RustPolicy::random_params(1));
    let sched = LachesisScheduler::greedy(Box::new(RustPolicy::new(params)));
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(20), 5);
    let agent = AgentServer::new(cluster, Box::new(sched));
    let (tx, rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        agent
            .serve("127.0.0.1:0", move |a| tx.send(a).unwrap())
            .unwrap()
    });
    let addr = rx.recv()?;
    println!("agent listening on {addr}");

    // Resource-manager side: stream jobs in arrival order. The client
    // carries explicit I/O deadlines and retries with request ids, so a
    // stalled or restarted agent never double-applies a submit.
    let mut client = ServiceClient::connect_with(
        &addr.to_string(),
        ClientConfig {
            connect_timeout: Duration::from_secs(2),
            read_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(2),
            ..ClientConfig::default()
        },
    )?;
    let workload = WorkloadGenerator::new(WorkloadConfig::continuous(12), 5).generate();
    let mut latency = Recorder::new();
    let mut total_assignments = 0;
    for (j, job) in workload.jobs.iter().enumerate() {
        let computes: Vec<f64> = job.tasks.iter().map(|t| t.compute).collect();
        let edges: Vec<(usize, usize, f64)> = (0..job.n_tasks())
            .flat_map(|u| {
                job.children[u]
                    .iter()
                    .map(move |e| (u, e.other, e.data))
                    .collect::<Vec<_>>()
            })
            .collect();
        let t0 = Instant::now();
        client.call_idempotent(
            &format!("rm-{j}-submit"),
            &Request::SubmitJob {
                name: job.name.clone(),
                arrival: job.arrival,
                computes,
                edges,
            },
        )?;
        let resp = client.call_idempotent(
            &format!("rm-{j}-sched"),
            &Request::Schedule { time: job.arrival },
        )?;
        latency.push(t0.elapsed().as_secs_f64() * 1e3);
        if let Response::Assignments(a) = resp {
            println!(
                "t={:>7.1}s  {}  → {} assignments",
                job.arrival,
                job.name,
                a.len()
            );
            total_assignments += a.len();
        }
    }
    match client.call(&Request::Status)? {
        Response::Status {
            jobs,
            assigned,
            horizon,
            ..
        } => println!(
            "\nfinal: {jobs} jobs, {assigned} tasks assigned, schedule horizon {horizon:.1}s"
        ),
        other => println!("unexpected status: {other:?}"),
    }
    println!(
        "assignments: {total_assignments}; request latency p50 {:.2}ms p98 {:.2}ms",
        latency.percentile(50.0),
        latency.percentile(98.0)
    );
    client.call(&Request::Shutdown)?;
    server.join().unwrap();
    Ok(())
}
