//! Fixed-shape state encoding for the AOT-compiled network.
//!
//! The AOT artifacts are compiled for static shapes, so the dynamic
//! scheduling state (arbitrary numbers of jobs and tasks) is packed into
//! one of two variants — N=64/J=8 for small states, N=256/J=32 for large —
//! with explicit node/job masks. Slots map back to tasks through
//! [`EncodedState::slot_task`].
//!
//! The graph structure is stored **sparsely**: a CSR adjacency
//! (`row_offsets`/`col_indices`, child slots per parent slot) and a
//! per-slot job-slot index (`slot_job`) instead of dense N×N / J×N
//! matrices. The pure-rust forward consumes the CSR directly — O(|E|)
//! message passing instead of O(N²) — while [`EncodedState::dense_adj`] /
//! [`EncodedState::dense_jobmat`] materialize the dense tensors on demand
//! for the PJRT artifact and the cross-validation oracle.
//!
//! Packing policy: unassigned tasks of arrived jobs, jobs in arrival
//! order. If the state exceeds the large variant (never at paper scales —
//! see DESIGN.md), the lowest-`rank_up` tasks are dropped from the
//! encoding; they remain schedulable later once the frontier drains.

use super::features::{node_features, FeatureMode};
use super::F;
use crate::dag::TaskRef;
use crate::sim::SimState;

/// A compiled shape variant (must match `python/compile/shapes.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShapeVariant {
    /// Max nodes N.
    pub n: usize,
    /// Max jobs J.
    pub j: usize,
    /// Artifact name stem, e.g. `policy_n64`.
    pub name: &'static str,
}

/// The two compiled variants, ascending capacity.
pub const VARIANTS: [ShapeVariant; 2] = [
    ShapeVariant {
        n: 64,
        j: 8,
        name: "policy_n64",
    },
    ShapeVariant {
        n: 256,
        j: 32,
        name: "policy_n256",
    },
];

/// Pick the smallest variant that fits `n_tasks` tasks over `n_jobs` jobs;
/// falls back to the largest.
pub fn pick_variant(n_tasks: usize, n_jobs: usize) -> ShapeVariant {
    for v in VARIANTS {
        if n_tasks <= v.n && n_jobs <= v.j {
            return v;
        }
    }
    VARIANTS[VARIANTS.len() - 1]
}

/// The encoded scheduling state: dense node features/masks plus the
/// sparse graph structure. Compact enough to clone per training
/// transition (the old dense form cloned 65k+8k f32 per decision at
/// N=256; the CSR form carries one u32 per edge plus one per slot).
#[derive(Debug, Clone, PartialEq)]
pub struct EncodedState {
    pub variant: ShapeVariant,
    /// Node features [N, F].
    pub x: Vec<f32>,
    /// 1.0 for occupied node slots.
    pub node_mask: Vec<f32>,
    /// 1.0 for slots whose task is currently executable (`A_t`).
    pub exec_mask: Vec<f32>,
    /// CSR row offsets (len `n_used()+1`): the children of slot `i` are
    /// `col_indices[row_offsets[i]..row_offsets[i+1]]`, sorted ascending
    /// and deduplicated (parallel DAG edges aggregate once, exactly like
    /// the saturated dense adjacency).
    pub row_offsets: Vec<u32>,
    /// CSR column indices: child slot per edge.
    pub col_indices: Vec<u32>,
    /// Job-slot index of each used slot (len `n_used()`).
    pub slot_job: Vec<u32>,
    /// Number of slots per used job slot (len = number of encoded jobs,
    /// every entry > 0). Replaces the O(J·N) occupied-row scan in the
    /// forward pass.
    pub job_counts: Vec<u32>,
    /// True if the state did not fit the variant and tasks/jobs were
    /// dropped (incremental patching is unsound then — see `EncoderCache`).
    pub truncated: bool,
    /// Slot → task mapping (len = used slots, sorted by (job, node)).
    pub(crate) slots: Vec<TaskRef>,
}

impl EncodedState {
    /// The task behind a slot index.
    pub fn slot_task(&self, slot: usize) -> Option<TaskRef> {
        self.slots.get(slot).copied()
    }

    /// The slot of a task, if encoded. Slots are sorted by (job, node),
    /// so this is a binary search, not a linear scan.
    pub fn task_slot(&self, t: TaskRef) -> Option<usize> {
        self.slots.binary_search(&t).ok()
    }

    pub fn n_used(&self) -> usize {
        self.slots.len()
    }

    /// Number of executable slots.
    pub fn n_executable(&self) -> usize {
        self.exec_mask.iter().filter(|&&m| m > 0.0).count()
    }

    /// Number of encoded jobs (used job slots).
    pub fn n_jobs_used(&self) -> usize {
        self.job_counts.len()
    }

    /// Number of CSR edges.
    pub fn n_edges(&self) -> usize {
        self.col_indices.len()
    }

    /// Child slots of slot `i` (ascending, deduplicated).
    pub fn children_of(&self, i: usize) -> &[u32] {
        &self.col_indices[self.row_offsets[i] as usize..self.row_offsets[i + 1] as usize]
    }

    /// Write the dense [N, N] adjacency into `out` (must be zeroed,
    /// len N²): `out[i*N+j] = 1` iff slot j is a *child* of slot i (Eq 5
    /// aggregates children embeddings into the parent).
    pub fn write_dense_adj(&self, out: &mut [f32]) {
        let n = self.variant.n;
        debug_assert_eq!(out.len(), n * n);
        for i in 0..self.n_used() {
            for &c in self.children_of(i) {
                out[i * n + c as usize] = 1.0;
            }
        }
    }

    /// Materialize the dense [N, N] adjacency (PJRT artifact input and
    /// dense-oracle cross-validation).
    pub fn dense_adj(&self) -> Vec<f32> {
        let n = self.variant.n;
        let mut out = vec![0.0; n * n];
        self.write_dense_adj(&mut out);
        out
    }

    /// Write the dense [J, N] job membership into `out` (must be zeroed,
    /// len J·N): `out[j*N+i] = 1` iff slot i belongs to job-slot j.
    pub fn write_dense_jobmat(&self, out: &mut [f32]) {
        let n = self.variant.n;
        debug_assert_eq!(out.len(), self.variant.j * n);
        for (i, &js) in self.slot_job.iter().enumerate() {
            out[js as usize * n + i] = 1.0;
        }
    }

    /// Materialize the dense [J, N] job membership matrix.
    pub fn dense_jobmat(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.variant.j * self.variant.n];
        self.write_dense_jobmat(&mut out);
        out
    }

    /// Remove the slot of `t`: shift the feature rows, masks, job index
    /// and CSR down by one, all in place. Returns the removed slot index,
    /// or `None` if `t` is not encoded. Used by the incremental
    /// `EncoderCache`; produces exactly what [`build_csr`] +
    /// [`build_job_index`] would rebuild from the shrunken slot list
    /// (sortedness and dedup survive deleting a column and decrementing
    /// the columns above it, so rows stay in dense-matmul order).
    pub(crate) fn remove_slot(&mut self, t: TaskRef) -> Option<usize> {
        let i = self.slots.binary_search(&t).ok()?;
        let m = self.slots.len();
        self.slots.remove(i);
        if i + 1 < m {
            self.x.copy_within((i + 1) * F..m * F, i * F);
            self.node_mask.copy_within(i + 1..m, i);
            self.exec_mask.copy_within(i + 1..m, i);
        }
        self.x[(m - 1) * F..m * F].fill(0.0);
        self.node_mask[m - 1] = 0.0;
        self.exec_mask[m - 1] = 0.0;
        // Job index: shrink the slot's job, dropping the job slot (and
        // shifting later job slots down) when it empties.
        let js = self.slot_job[i] as usize;
        self.slot_job.remove(i);
        self.job_counts[js] -= 1;
        if self.job_counts[js] == 0 {
            self.job_counts.remove(js);
            for sj in self.slot_job.iter_mut() {
                if *sj as usize > js {
                    *sj -= 1;
                }
            }
        }
        // CSR: one compacting pass — drop row i, drop references to slot
        // i, renumber slots above it. O(|E|) with no sorting or searches.
        let mut write = 0usize;
        let mut out_row = 0usize;
        let mut lo = 0usize;
        for r in 0..m {
            let hi = self.row_offsets[r + 1] as usize;
            if r != i {
                for k in lo..hi {
                    let c = self.col_indices[k] as usize;
                    if c != i {
                        self.col_indices[write] = if c > i { (c - 1) as u32 } else { c as u32 };
                        write += 1;
                    }
                }
                out_row += 1;
                self.row_offsets[out_row] = write as u32;
            }
            lo = hi;
        }
        self.row_offsets.truncate(out_row + 1);
        self.col_indices.truncate(write);
        Some(i)
    }
}

/// Fill slot `i`'s feature row and masks from the live state. Shared by
/// [`encode`] and the incremental `EncoderCache` so a patched slot is
/// bitwise identical to a freshly encoded one.
pub(crate) fn fill_slot(state: &SimState, mode: FeatureMode, enc: &mut EncodedState, i: usize) {
    let t = enc.slots[i];
    node_features(state, t, mode, &mut enc.x[i * F..(i + 1) * F]);
    enc.node_mask[i] = 1.0;
    enc.exec_mask[i] = if state.is_executable(t) { 1.0 } else { 0.0 };
}

/// Rebuild `slot_job`/`job_counts` from the sorted slot list: job slots
/// are assigned in order of first appearance, i.e. ascending job id.
pub(crate) fn build_job_index(enc: &mut EncodedState) {
    enc.slot_job.clear();
    enc.job_counts.clear();
    let mut last_job = usize::MAX;
    for i in 0..enc.slots.len() {
        let job = enc.slots[i].job;
        if job != last_job || enc.job_counts.is_empty() {
            enc.job_counts.push(0);
            last_job = job;
        }
        let js = enc.job_counts.len() - 1;
        enc.job_counts[js] += 1;
        enc.slot_job.push(js as u32);
    }
}

/// Rebuild the CSR adjacency from the sorted slot list. Edges to tasks
/// outside the encoding (assigned or truncated away) vanish — their
/// influence is already summarized in the features. Each row is sorted
/// and deduplicated so sparse aggregation visits children in exactly the
/// order the dense matmul does.
pub(crate) fn build_csr(state: &SimState, enc: &mut EncodedState) {
    enc.row_offsets.clear();
    enc.col_indices.clear();
    enc.row_offsets.push(0);
    let mut row: Vec<u32> = Vec::new();
    for &t in &enc.slots {
        row.clear();
        for e in &state.jobs[t.job].children[t.node] {
            let c = TaskRef::new(t.job, e.other);
            if let Ok(ci) = enc.slots.binary_search(&c) {
                row.push(ci as u32);
            }
        }
        row.sort_unstable();
        row.dedup();
        enc.col_indices.extend_from_slice(&row);
        enc.row_offsets.push(enc.col_indices.len() as u32);
    }
}

/// Encode the current scheduling state.
pub fn encode(state: &SimState, mode: FeatureMode) -> EncodedState {
    // Gather candidate tasks: unassigned tasks of arrived jobs, jobs in
    // arrival order (ids are arrival-ordered by Workload::new).
    // `job_left_tasks` is an O(1) counter, so this filter is O(jobs).
    let mut jobs: Vec<usize> = (0..state.jobs.len())
        .filter(|&j| state.arrived[j] && state.job_left_tasks(j) > 0)
        .collect();
    jobs.sort_unstable(); // arrival order == id order

    let mut tasks: Vec<TaskRef> = Vec::new();
    for &j in &jobs {
        for node in 0..state.jobs[j].n_tasks() {
            if !state.assigned[j][node] {
                tasks.push(TaskRef::new(j, node));
            }
        }
    }
    let variant = pick_variant(tasks.len(), jobs.len());
    let mut truncated = false;

    // Truncate if needed: drop lowest-rank_up tasks first, then re-gather
    // per-job. Executable tasks are always kept in preference.
    if tasks.len() > variant.n || jobs.len() > variant.j {
        truncated = true;
        if jobs.len() > variant.j {
            jobs.truncate(variant.j);
        }
        // Job-membership bool-vec: O(tasks + jobs) instead of the old
        // O(tasks·jobs) `jobs.contains` scan.
        let mut in_jobs = vec![false; state.jobs.len()];
        for &j in &jobs {
            in_jobs[j] = true;
        }
        let mut kept: Vec<TaskRef> = tasks.into_iter().filter(|t| in_jobs[t.job]).collect();
        kept.sort_by(|a, b| {
            let ea = state.is_executable(*a);
            let eb = state.is_executable(*b);
            eb.cmp(&ea).then(
                state.rank_up[b.job][b.node]
                    .partial_cmp(&state.rank_up[a.job][a.node])
                    .unwrap(),
            )
        });
        kept.truncate(variant.n);
        kept.sort_unstable();
        tasks = kept;
    }

    let n = variant.n;
    let mut enc = EncodedState {
        variant,
        x: vec![0.0; n * F],
        node_mask: vec![0.0; n],
        exec_mask: vec![0.0; n],
        row_offsets: Vec::with_capacity(tasks.len() + 1),
        col_indices: Vec::new(),
        slot_job: Vec::with_capacity(tasks.len()),
        job_counts: Vec::new(),
        truncated,
        slots: tasks,
    };

    build_job_index(&mut enc);
    for i in 0..enc.slots.len() {
        fill_slot(state, mode, &mut enc, i);
    }
    build_csr(state, &mut enc);
    enc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::WorkloadConfig;
    use crate::sim::{Allocation, SimState};
    use crate::workload::WorkloadGenerator;

    fn state(n_jobs: usize, seed: u64) -> SimState {
        let cluster = Cluster::homogeneous(4, 2.5, 100.0);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(n_jobs), seed).generate();
        let mut st = SimState::new(cluster, w);
        for j in 0..n_jobs {
            st.mark_arrived(j);
        }
        st
    }

    #[test]
    fn encodes_all_tasks_small() {
        let st = state(3, 1);
        let enc = encode(&st, FeatureMode::Full);
        assert_eq!(enc.variant.n, 64);
        assert_eq!(enc.n_used(), st.n_tasks_total());
        assert_eq!(enc.n_executable(), st.executable().len());
        assert!(!enc.truncated);
        // Masks consistent.
        let used = enc.node_mask.iter().filter(|&&m| m > 0.0).count();
        assert_eq!(used, enc.n_used());
    }

    #[test]
    fn picks_large_variant_for_many_jobs() {
        let st = state(15, 2);
        let enc = encode(&st, FeatureMode::Full);
        assert_eq!(enc.variant.n, 256);
    }

    #[test]
    fn slot_mapping_roundtrips() {
        let st = state(2, 3);
        let enc = encode(&st, FeatureMode::Full);
        for slot in 0..enc.n_used() {
            let t = enc.slot_task(slot).unwrap();
            assert_eq!(enc.task_slot(t), Some(slot));
        }
        assert!(enc.slot_task(enc.n_used()).is_none());
    }

    #[test]
    fn adjacency_matches_dag() {
        let st = state(1, 4);
        let enc = encode(&st, FeatureMode::Full);
        let n = enc.variant.n;
        let adj = enc.dense_adj();
        let mut edge_count = 0;
        for i in 0..enc.n_used() {
            for j in 0..enc.n_used() {
                if adj[i * n + j] > 0.0 {
                    edge_count += 1;
                    let ti = enc.slot_task(i).unwrap();
                    let tj = enc.slot_task(j).unwrap();
                    assert_eq!(ti.job, tj.job);
                    assert!(st.jobs[ti.job].edge_data(ti.node, tj.node) > 0.0);
                }
            }
        }
        assert_eq!(edge_count, st.jobs[0].n_edges());
    }

    #[test]
    fn csr_rows_sorted_and_bounded() {
        let st = state(3, 8);
        let enc = encode(&st, FeatureMode::Full);
        assert_eq!(enc.row_offsets.len(), enc.n_used() + 1);
        assert_eq!(enc.row_offsets[0], 0);
        assert_eq!(*enc.row_offsets.last().unwrap() as usize, enc.n_edges());
        for i in 0..enc.n_used() {
            let row = enc.children_of(i);
            for w in row.windows(2) {
                assert!(w[0] < w[1], "row {i} not strictly ascending");
            }
            for &c in row {
                assert!((c as usize) < enc.n_used());
            }
        }
    }

    #[test]
    fn assigned_tasks_leave_encoding() {
        let mut st = state(1, 5);
        let before = encode(&st, FeatureMode::Full).n_used();
        let t = st.executable()[0];
        st.apply(t, Allocation::Direct { exec: 0 });
        let after = encode(&st, FeatureMode::Full).n_used();
        assert_eq!(after, before - 1);
    }

    #[test]
    fn jobmat_partitions_nodes() {
        let st = state(3, 6);
        let enc = encode(&st, FeatureMode::Full);
        let n = enc.variant.n;
        let jobmat = enc.dense_jobmat();
        for i in 0..enc.n_used() {
            let memberships: usize = (0..enc.variant.j)
                .filter(|&j| jobmat[j * n + i] > 0.0)
                .count();
            assert_eq!(memberships, 1, "slot {i} in {memberships} jobs");
        }
        // job_counts sums to the used slots and matches slot_job.
        let total: u32 = enc.job_counts.iter().sum();
        assert_eq!(total as usize, enc.n_used());
        for (i, &js) in enc.slot_job.iter().enumerate() {
            assert!((js as usize) < enc.n_jobs_used(), "slot {i}");
        }
    }

    #[test]
    fn remove_slot_matches_reencode() {
        let mut st = state(2, 9);
        let mut enc = encode(&st, FeatureMode::Full);
        let t = st.executable()[0];
        st.apply(t, Allocation::Direct { exec: 0 });
        // Patch: remove (features, masks, job index and CSR all shift in
        // place) + re-featurize the touched job. No rebuild.
        enc.remove_slot(t).unwrap();
        for i in 0..enc.n_used() {
            if enc.slots[i].job == t.job {
                fill_slot(&st, FeatureMode::Full, &mut enc, i);
            }
        }
        let fresh = encode(&st, FeatureMode::Full);
        assert_eq!(enc, fresh);
    }

    #[test]
    fn remove_slot_drains_to_empty() {
        let mut st = state(1, 10);
        let mut enc = encode(&st, FeatureMode::Full);
        while !st.executable().is_empty() {
            let t = st.executable()[0];
            st.apply(t, Allocation::Direct { exec: 0 });
            enc.remove_slot(t).unwrap();
            for i in 0..enc.n_used() {
                fill_slot(&st, FeatureMode::Full, &mut enc, i);
            }
            assert_eq!(enc, encode(&st, FeatureMode::Full));
        }
        assert_eq!(enc.n_used(), 0);
        assert_eq!(enc.row_offsets, vec![0]);
        assert!(enc.col_indices.is_empty());
    }

    #[test]
    fn truncation_keeps_executable_tasks() {
        // Build a state larger than the big variant by using many jobs.
        let cluster = Cluster::homogeneous(4, 2.5, 100.0);
        let w = WorkloadGenerator::new(WorkloadConfig::large_batch(40), 7).generate();
        let mut st = SimState::new(cluster, w);
        for j in 0..40 {
            st.mark_arrived(j);
        }
        let enc = encode(&st, FeatureMode::Full);
        assert_eq!(enc.variant.n, 256);
        assert!(enc.n_used() <= 256);
        assert!(enc.truncated);
        // Every encoded executable slot must be genuinely executable.
        for i in 0..enc.n_used() {
            let t = enc.slot_task(i).unwrap();
            assert_eq!(enc.exec_mask[i] > 0.0, st.is_executable(t));
        }
        // At least one executable task survives truncation.
        assert!(enc.n_executable() > 0);
    }
}
