//! Fixed-shape state encoding for the AOT-compiled network.
//!
//! The AOT artifacts are compiled for static shapes, so the dynamic
//! scheduling state (arbitrary numbers of jobs and tasks) is packed into
//! one of two variants — N=64/J=8 for small states, N=256/J=32 for large —
//! with explicit node/job masks. Slots map back to tasks through
//! [`EncodedState::slot_task`].
//!
//! Packing policy: unassigned tasks of arrived jobs, jobs in arrival
//! order. If the state exceeds the large variant (never at paper scales —
//! see DESIGN.md), the lowest-`rank_up` tasks are dropped from the
//! encoding; they remain schedulable later once the frontier drains.

use super::features::{node_features, FeatureMode};
use super::F;
use crate::dag::TaskRef;
use crate::sim::SimState;

/// A compiled shape variant (must match `python/compile/shapes.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShapeVariant {
    /// Max nodes N.
    pub n: usize,
    /// Max jobs J.
    pub j: usize,
    /// Artifact name stem, e.g. `policy_n64`.
    pub name: &'static str,
}

/// The two compiled variants, ascending capacity.
pub const VARIANTS: [ShapeVariant; 2] = [
    ShapeVariant {
        n: 64,
        j: 8,
        name: "policy_n64",
    },
    ShapeVariant {
        n: 256,
        j: 32,
        name: "policy_n256",
    },
];

/// Pick the smallest variant that fits `n_tasks` tasks over `n_jobs` jobs;
/// falls back to the largest.
pub fn pick_variant(n_tasks: usize, n_jobs: usize) -> ShapeVariant {
    for v in VARIANTS {
        if n_tasks <= v.n && n_jobs <= v.j {
            return v;
        }
    }
    VARIANTS[VARIANTS.len() - 1]
}

/// The dense tensors the network consumes (row-major, f32 — exactly what
/// both the rust forward and the PJRT artifact take).
#[derive(Debug, Clone)]
pub struct EncodedState {
    pub variant: ShapeVariant,
    /// Node features [N, F].
    pub x: Vec<f32>,
    /// Adjacency [N, N]: `adj[i*N+j] = 1` iff slot j is a *child* of slot
    /// i (Eq 5 aggregates children embeddings into the parent).
    pub adj: Vec<f32>,
    /// Job membership [J, N]: `jobmat[j*N+i] = 1` iff slot i belongs to
    /// job-slot j.
    pub jobmat: Vec<f32>,
    /// 1.0 for occupied node slots.
    pub node_mask: Vec<f32>,
    /// 1.0 for slots whose task is currently executable (`A_t`).
    pub exec_mask: Vec<f32>,
    /// Slot → task mapping (len = used slots).
    slots: Vec<TaskRef>,
}

impl EncodedState {
    /// The task behind a slot index.
    pub fn slot_task(&self, slot: usize) -> Option<TaskRef> {
        self.slots.get(slot).copied()
    }

    /// The slot of a task, if encoded.
    pub fn task_slot(&self, t: TaskRef) -> Option<usize> {
        self.slots.iter().position(|&s| s == t)
    }

    pub fn n_used(&self) -> usize {
        self.slots.len()
    }

    /// Number of executable slots.
    pub fn n_executable(&self) -> usize {
        self.exec_mask.iter().filter(|&&m| m > 0.0).count()
    }
}

/// Encode the current scheduling state.
pub fn encode(state: &SimState, mode: FeatureMode) -> EncodedState {
    // Gather candidate tasks: unassigned tasks of arrived jobs, jobs in
    // arrival order (ids are arrival-ordered by Workload::new).
    // `job_left_tasks` is an O(1) counter, so this filter is O(jobs).
    let mut jobs: Vec<usize> = (0..state.jobs.len())
        .filter(|&j| state.arrived[j] && state.job_left_tasks(j) > 0)
        .collect();
    jobs.sort_unstable(); // arrival order == id order

    let mut tasks: Vec<TaskRef> = Vec::new();
    for &j in &jobs {
        for node in 0..state.jobs[j].n_tasks() {
            if !state.assigned[j][node] {
                tasks.push(TaskRef::new(j, node));
            }
        }
    }
    let variant = pick_variant(tasks.len(), jobs.len());

    // Truncate if needed: drop lowest-rank_up tasks first, then re-gather
    // per-job. Executable tasks are always kept in preference.
    if tasks.len() > variant.n || jobs.len() > variant.j {
        if jobs.len() > variant.j {
            jobs.truncate(variant.j);
        }
        let mut kept: Vec<TaskRef> = tasks
            .into_iter()
            .filter(|t| jobs.contains(&t.job))
            .collect();
        kept.sort_by(|a, b| {
            let ea = state.is_executable(*a);
            let eb = state.is_executable(*b);
            eb.cmp(&ea).then(
                state.rank_up[b.job][b.node]
                    .partial_cmp(&state.rank_up[a.job][a.node])
                    .unwrap(),
            )
        });
        kept.truncate(variant.n);
        kept.sort_unstable();
        tasks = kept;
    }

    let n = variant.n;
    let jcap = variant.j;
    let mut enc = EncodedState {
        variant,
        x: vec![0.0; n * F],
        adj: vec![0.0; n * n],
        jobmat: vec![0.0; jcap * n],
        node_mask: vec![0.0; n],
        exec_mask: vec![0.0; n],
        slots: tasks,
    };

    // Job slot assignment in arrival order.
    let mut job_slot: std::collections::BTreeMap<usize, usize> = Default::default();
    for t in &enc.slots {
        let next = job_slot.len();
        job_slot.entry(t.job).or_insert(next);
    }

    for (i, &t) in enc.slots.iter().enumerate() {
        node_features(state, t, mode, &mut enc.x[i * F..(i + 1) * F]);
        enc.node_mask[i] = 1.0;
        if state.is_executable(t) {
            enc.exec_mask[i] = 1.0;
        }
        let js = job_slot[&t.job];
        enc.jobmat[js * n + i] = 1.0;
    }
    // Adjacency between encoded slots (edges to assigned tasks vanish —
    // their influence is already summarized in the features).
    for (i, &t) in enc.slots.iter().enumerate() {
        for e in &state.jobs[t.job].children[t.node] {
            let c = TaskRef::new(t.job, e.other);
            // Children are unassigned if t is unassigned, but may have been
            // truncated out.
            if let Some(ci) = enc.slots.binary_search(&c).ok() {
                enc.adj[i * n + ci] = 1.0;
            }
        }
    }
    enc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::WorkloadConfig;
    use crate::sim::{Allocation, SimState};
    use crate::workload::WorkloadGenerator;

    fn state(n_jobs: usize, seed: u64) -> SimState {
        let cluster = Cluster::homogeneous(4, 2.5, 100.0);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(n_jobs), seed).generate();
        let mut st = SimState::new(cluster, w);
        for j in 0..n_jobs {
            st.mark_arrived(j);
        }
        st
    }

    #[test]
    fn encodes_all_tasks_small() {
        let st = state(3, 1);
        let enc = encode(&st, FeatureMode::Full);
        assert_eq!(enc.variant.n, 64);
        assert_eq!(enc.n_used(), st.n_tasks_total());
        assert_eq!(enc.n_executable(), st.executable().len());
        // Masks consistent.
        let used = enc.node_mask.iter().filter(|&&m| m > 0.0).count();
        assert_eq!(used, enc.n_used());
    }

    #[test]
    fn picks_large_variant_for_many_jobs() {
        let st = state(15, 2);
        let enc = encode(&st, FeatureMode::Full);
        assert_eq!(enc.variant.n, 256);
    }

    #[test]
    fn slot_mapping_roundtrips() {
        let st = state(2, 3);
        let enc = encode(&st, FeatureMode::Full);
        for slot in 0..enc.n_used() {
            let t = enc.slot_task(slot).unwrap();
            assert_eq!(enc.task_slot(t), Some(slot));
        }
        assert!(enc.slot_task(enc.n_used()).is_none());
    }

    #[test]
    fn adjacency_matches_dag() {
        let st = state(1, 4);
        let enc = encode(&st, FeatureMode::Full);
        let n = enc.variant.n;
        let mut edge_count = 0;
        for i in 0..enc.n_used() {
            for j in 0..enc.n_used() {
                if enc.adj[i * n + j] > 0.0 {
                    edge_count += 1;
                    let ti = enc.slot_task(i).unwrap();
                    let tj = enc.slot_task(j).unwrap();
                    assert_eq!(ti.job, tj.job);
                    assert!(st.jobs[ti.job].edge_data(ti.node, tj.node) > 0.0);
                }
            }
        }
        assert_eq!(edge_count, st.jobs[0].n_edges());
    }

    #[test]
    fn assigned_tasks_leave_encoding() {
        let mut st = state(1, 5);
        let before = encode(&st, FeatureMode::Full).n_used();
        let t = st.executable()[0];
        st.apply(t, Allocation::Direct { exec: 0 });
        let after = encode(&st, FeatureMode::Full).n_used();
        assert_eq!(after, before - 1);
    }

    #[test]
    fn jobmat_partitions_nodes() {
        let st = state(3, 6);
        let enc = encode(&st, FeatureMode::Full);
        let n = enc.variant.n;
        for i in 0..enc.n_used() {
            let memberships: usize = (0..enc.variant.j)
                .filter(|&j| enc.jobmat[j * n + i] > 0.0)
                .count();
            assert_eq!(memberships, 1, "slot {i} in {memberships} jobs");
        }
    }

    #[test]
    fn truncation_keeps_executable_tasks() {
        // Build a state larger than the big variant by using many jobs.
        let cluster = Cluster::homogeneous(4, 2.5, 100.0);
        let w = WorkloadGenerator::new(WorkloadConfig::large_batch(40), 7).generate();
        let mut st = SimState::new(cluster, w);
        for j in 0..40 {
            st.mark_arrived(j);
        }
        let enc = encode(&st, FeatureMode::Full);
        assert_eq!(enc.variant.n, 256);
        assert!(enc.n_used() <= 256);
        // Every encoded executable slot must be genuinely executable.
        for i in 0..enc.n_used() {
            let t = enc.slot_task(i).unwrap();
            assert_eq!(enc.exec_mask[i] > 0.0, st.is_executable(t));
        }
        // At least one executable task survives truncation.
        assert!(enc.n_executable() > 0);
    }
}
