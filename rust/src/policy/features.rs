//! Node feature extraction (paper §4.1): per-task features combining task,
//! DAG-position and job-level information, all computed in rust on the
//! request path (python only ever sees the resulting tensors at training
//! time, through the AOT train_step).
//!
//! All features are squashed to [0, 1) with `x / (x + c)` saturation so the
//! network sees bounded inputs regardless of workload scale; the constants
//! are part of the model contract (changing them invalidates trained
//! parameters).

use crate::dag::TaskRef;
use crate::sim::SimState;

/// Number of features per node. Must match `python/compile/shapes.py::F`.
pub const NODE_FEATURES: usize = 15;

/// Saturating normalization to [0, 1).
#[inline]
pub fn squash(x: f64, c: f64) -> f32 {
    (x / (x + c)) as f32
}

/// Which executor-awareness the features carry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatureMode {
    /// Lachesis: heterogeneity- and communication-aware features.
    Full,
    /// Decima-DEFT: Decima models a homogeneous cluster and ignores data
    /// transmission (paper §2) — speed features use a unit executor and
    /// communication features are zeroed.
    HomogeneousBlind,
}

/// Time-scale constants for squashing (seconds).
const T_EXEC: f64 = 60.0;
const T_RANK: f64 = 300.0;
const T_DATA: f64 = 30.0;
const T_WAIT: f64 = 120.0;
const N_TASKS: f64 = 10.0;

/// Index of the job-wait feature — the only feature that moves with the
/// wall clock alone (the finished-parent fraction also depends on the
/// wall, but only flips when a copy's finish time is crossed).
pub const WAIT_FEATURE: usize = 11;

/// Feature [`WAIT_FEATURE`]: job wait time since arrival. Shared by
/// [`node_features`] and the incremental `EncoderCache` wall patch so
/// both produce bitwise-identical values.
#[inline]
pub fn job_wait_feature(state: &SimState, job: usize) -> f32 {
    squash((state.wall - state.jobs[job].arrival).max(0.0), T_WAIT)
}

/// Compute the feature vector of one task. `out` must have length
/// [`NODE_FEATURES`]; the function overwrites it (allocation-free hot
/// path).
pub fn node_features(state: &SimState, t: TaskRef, mode: FeatureMode, out: &mut [f32]) {
    debug_assert_eq!(out.len(), NODE_FEATURES);
    let job = &state.jobs[t.job];
    // Cluster averages are memoized on the state — no per-feature scan.
    let (v_avg, c_avg) = match mode {
        FeatureMode::Full => (state.v_avg(), state.c_avg()),
        FeatureMode::HomogeneousBlind => (1.0, f64::INFINITY),
    };

    // 0: average execution time of the task.
    out[0] = squash(job.tasks[t.node].compute / v_avg, T_EXEC);
    // 1: rank_up — remaining critical path below this node (Eq 6).
    out[1] = squash(state.rank_up[t.job][t.node], T_RANK);
    // 2: rank_down — longest path from the entry (Eq 7).
    out[2] = squash(state.rank_down[t.job][t.node], T_RANK);
    // 3: average incoming data time.
    let in_data: f64 = job.parents[t.node].iter().map(|e| e.data).sum();
    out[3] = if c_avg.is_finite() {
        squash(in_data / c_avg, T_DATA)
    } else {
        0.0
    };
    // 4: average outgoing data time.
    let out_data: f64 = job.children[t.node].iter().map(|e| e.data).sum();
    out[4] = if c_avg.is_finite() {
        squash(out_data / c_avg, T_DATA)
    } else {
        0.0
    };
    // 5: number of parents (DAG in-degree).
    out[5] = squash(job.parents[t.node].len() as f64, 4.0);
    // 6: number of children (DAG out-degree).
    out[6] = squash(job.children[t.node].len() as f64, 4.0);
    // 7: job's remaining task count (O(1) incremental counter).
    out[7] = squash(state.job_left_tasks(t.job) as f64, N_TASKS);
    // 8: job's remaining work (average execution time of left tasks ×
    //    count ≈ total, paper's "sum of average execution time"); O(1)
    //    incremental counter instead of a per-feature task scan.
    out[8] = squash(state.job_left_work(t.job) / v_avg, T_RANK);
    // 9: executable right now?
    out[9] = if state.is_executable(t) { 1.0 } else { 0.0 };
    // 10: fraction of parents whose earliest copy has finished.
    let n_par = job.parents[t.node].len();
    if n_par == 0 {
        out[10] = 1.0;
    } else {
        let fin = job.parents[t.node]
            .iter()
            .filter(|e| state.is_finished(TaskRef::new(t.job, e.other)))
            .count();
        out[10] = fin as f32 / n_par as f32;
    }
    // 11: job wait time since arrival.
    out[WAIT_FEATURE] = job_wait_feature(state, t.job);
    // 12–14: data locality (zero-information defaults under flat
    // topologies and for Decima's network-blind mode, so pre-topology
    // behavior is preserved). Placement-dependent: sound to cache
    // because every placement change re-featurizes the touched job
    // (apply → Assigned) or rebuilds outright (faults → Invalidated).
    let n_racks = state.cluster.n_racks();
    if n_racks <= 1 || mode == FeatureMode::HomogeneousBlind {
        out[12] = 1.0; // all parent data is "rack-local" in a flat world
        out[13] = 0.0; // no cross-rack bytes pending
        out[14] = 0.0; // dominant rack id (degenerate)
    } else {
        let (dominant, local_mb, total_mb) = state.parent_locality(t);
        // 12: fraction of placed-parent data with a rack-local copy in
        //     the dominant rack (1.0 when nothing is placed yet).
        out[12] = if total_mb > 0.0 {
            (local_mb / total_mb) as f32
        } else {
            1.0
        };
        // 13: cross-rack bytes still pending, as a transfer time at c̄.
        let cross_mb = total_mb - local_mb;
        out[13] = if c_avg.is_finite() {
            squash(cross_mb / c_avg, T_DATA)
        } else {
            0.0
        };
        // 14: dominant rack id, normalized (which rack pulls this task).
        out[14] = dominant as f32 / n_racks as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::dag::Job;
    use crate::workload::Workload;

    fn state() -> SimState {
        let cluster = Cluster::homogeneous(2, 2.0, 100.0);
        let job = Job::new(
            0,
            "diamond",
            0.0,
            vec![1.0, 2.0, 3.0, 4.0],
            &[(0, 1, 10.0), (0, 2, 20.0), (1, 3, 30.0), (2, 3, 40.0)],
        );
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st
    }

    #[test]
    fn features_bounded() {
        let st = state();
        let mut f = [0.0f32; NODE_FEATURES];
        for node in 0..4 {
            node_features(&st, TaskRef::new(0, node), FeatureMode::Full, &mut f);
            for (i, &x) in f.iter().enumerate() {
                assert!((0.0..=1.0).contains(&x), "feature {i} = {x}");
            }
        }
    }

    #[test]
    fn executable_flag_tracks_frontier() {
        let st = state();
        let mut f = [0.0f32; NODE_FEATURES];
        node_features(&st, TaskRef::new(0, 0), FeatureMode::Full, &mut f);
        assert_eq!(f[9], 1.0);
        node_features(&st, TaskRef::new(0, 3), FeatureMode::Full, &mut f);
        assert_eq!(f[9], 0.0);
    }

    #[test]
    fn blind_mode_zeroes_comm() {
        let st = state();
        let mut f = [0.0f32; NODE_FEATURES];
        node_features(&st, TaskRef::new(0, 0), FeatureMode::HomogeneousBlind, &mut f);
        assert_eq!(f[3], 0.0);
        assert_eq!(f[4], 0.0);
        let mut ff = [0.0f32; NODE_FEATURES];
        node_features(&st, TaskRef::new(0, 0), FeatureMode::Full, &mut ff);
        assert!(ff[4] > 0.0, "full mode sees outgoing data");
    }

    #[test]
    fn rank_features_order_nodes() {
        let st = state();
        let mut f0 = [0.0f32; NODE_FEATURES];
        let mut f3 = [0.0f32; NODE_FEATURES];
        node_features(&st, TaskRef::new(0, 0), FeatureMode::Full, &mut f0);
        node_features(&st, TaskRef::new(0, 3), FeatureMode::Full, &mut f3);
        assert!(f0[1] > f3[1], "entry has larger rank_up");
        assert!(f3[2] > f0[2], "exit has larger rank_down");
    }

    #[test]
    fn locality_features_flat_defaults() {
        let st = state();
        let mut f = [0.0f32; NODE_FEATURES];
        for node in 0..4 {
            node_features(&st, TaskRef::new(0, node), FeatureMode::Full, &mut f);
            assert_eq!(f[12], 1.0, "flat: everything is rack-local");
            assert_eq!(f[13], 0.0);
            assert_eq!(f[14], 0.0);
        }
    }

    #[test]
    fn locality_features_track_parent_placement() {
        use crate::net::NetConfig;
        use crate::sim::Allocation;
        let cluster = Cluster::homogeneous(4, 2.0, 100.0).with_net(&NetConfig::tree(2, 2));
        let job = Job::new(
            0,
            "diamond",
            0.0,
            vec![1.0, 2.0, 3.0, 4.0],
            &[(0, 1, 10.0), (0, 2, 20.0), (1, 3, 30.0), (2, 3, 40.0)],
        );
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        let mut f = [0.0f32; NODE_FEATURES];
        // No parent placed yet: neutral defaults.
        node_features(&st, TaskRef::new(0, 3), FeatureMode::Full, &mut f);
        assert_eq!(f[12], 1.0);
        assert_eq!(f[13], 0.0);
        // Place the entry on rack 0, then both middles split across
        // racks: task 3's parents (1, 2) land in racks 0 and 1.
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 1 }); // rack 0
        st.apply(TaskRef::new(0, 2), Allocation::Direct { exec: 2 }); // rack 1
        node_features(&st, TaskRef::new(0, 3), FeatureMode::Full, &mut f);
        // Dominant rack is 1 (40 MB from parent 2 beats 30 MB), so a
        // fraction of the 70 MB total is rack-local and the rest pends.
        assert!(f[12] > 0.0 && f[12] < 1.0, "split parents: f12 = {}", f[12]);
        assert!(f[13] > 0.0, "cross-rack bytes pending");
        assert_eq!(f[14], 0.5, "dominant rack 1 of 2");
        // Blind mode ignores the topology entirely.
        node_features(&st, TaskRef::new(0, 3), FeatureMode::HomogeneousBlind, &mut f);
        assert_eq!(f[12], 1.0);
        assert_eq!(f[13], 0.0);
        assert_eq!(f[14], 0.0);
    }

    #[test]
    fn squash_monotone_and_bounded() {
        let mut prev = -1.0f32;
        for i in 0..100 {
            let v = squash(i as f64, 10.0);
            assert!(v >= prev);
            assert!((0.0..1.0).contains(&v));
            prev = v;
        }
    }
}
