//! Pure-rust reference implementation of the MGNet + policy/value forward
//! pass.
//!
//! This mirrors `python/compile/model.py` *exactly* (same flat parameter
//! layout, same ops, same activation functions) and is cross-validated
//! against the AOT artifact in `rust/tests/integration_runtime.rs`. It
//! serves three purposes: a test oracle for the JAX model, a no-PJRT
//! fallback backend, and the decision-latency baseline for §Perf.
//!
//! Two forward paths exist:
//!
//! * [`RustPolicy::forward_into`] — the production path: CSR-sparse
//!   message passing and job pooling, O(K·|E|·E) instead of O(K·N²·E),
//!   allocation-free after warmup (all buffers live in `Scratch`, logits
//!   are written into a caller-owned buffer).
//! * [`RustPolicy::forward_dense`] — the oracle: materializes the dense
//!   adjacency/jobmat on demand and runs dense matmuls, exactly what the
//!   PJRT artifact computes. The sparse path accumulates in the same
//!   order (CSR rows are sorted ascending), so the two agree bitwise;
//!   tests pin them within 1e-5.

use super::encode::EncodedState;
use super::{PolicyEval, E, F, H, K, Q1, Q2, Q3, V1, V2};
use anyhow::Result;
use std::sync::Arc;

/// The flat parameter layout: (name, rows, cols). Biases are 1×cols.
/// THIS IS THE MODEL CONTRACT — `python/compile/model.py::LAYOUT` must
/// list identical shapes in identical order.
pub const LAYOUT: &[(&str, usize, usize)] = &[
    ("w_in", F, E),
    ("b_in", 1, E),
    ("g1", E, H),
    ("bg1", 1, H),
    ("g2", H, E),
    ("bg2", 1, E),
    ("fj1", E, H),
    ("bfj1", 1, H),
    ("fj2", H, E),
    ("bfj2", 1, E),
    ("fg1", E, H),
    ("bfg1", 1, H),
    ("fg2", H, E),
    ("bfg2", 1, E),
    ("q1", 3 * E, Q1),
    ("bq1", 1, Q1),
    ("q2", Q1, Q2),
    ("bq2", 1, Q2),
    ("q3", Q2, Q3),
    ("bq3", 1, Q3),
    ("q4", Q3, 1),
    ("bq4", 1, 1),
    ("v1", E, V1),
    ("bv1", 1, V1),
    ("v2", V1, V2),
    ("bv2", 1, V2),
    ("v3", V2, 1),
    ("bv3", 1, 1),
];

/// Total flat parameter count P.
pub fn param_len() -> usize {
    LAYOUT.iter().map(|(_, r, c)| r * c).sum()
}

/// Offset of a named tensor within the flat vector.
pub fn param_offset(name: &str) -> usize {
    let mut off = 0;
    for (n, r, c) in LAYOUT {
        if *n == name {
            return off;
        }
        off += r * c;
    }
    panic!("unknown parameter '{name}'");
}

/// out[m,n] += a[m,k] · b[k,n] — row-major, allocation-free.
pub(crate) fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // adjacency/jobmat rows are sparse
            }
            let brow = &b[kk * n..(kk + 1) * n];
            // zip elides bounds checks → autovectorizes.
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// Dense layer: out = act(x·w + b) for a batch of m rows.
pub(crate) fn dense(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    din: usize,
    dout: usize,
    tanh: bool,
) {
    out[..m * dout].fill(0.0);
    matmul_into(&x[..m * din], w, &mut out[..m * dout], m, din, dout);
    for row in out[..m * dout].chunks_exact_mut(dout) {
        for (o, &bv) in row.iter_mut().zip(b) {
            let v = *o + bv;
            *o = if tanh { v.tanh() } else { v };
        }
    }
}

/// A pure-rust policy: flat parameters + scratch buffers. Parameters sit
/// behind an `Arc` so one trained snapshot can be shared across parallel
/// rollout actors (and the trainer's eval runs) without cloning the full
/// vector per policy instance.
pub struct RustPolicy {
    pub params: Arc<Vec<f32>>,
    // Scratch (sized lazily for the variant in use).
    scratch: Scratch,
    // Batched-forward scratch (sized lazily per packed batch).
    pub(crate) batch_scratch: super::batch::BatchScratch,
}

#[derive(Default)]
struct Scratch {
    n: usize,
    j: usize,
    e0: Vec<f32>,
    e: Vec<f32>,
    agg: Vec<f32>,
    h: Vec<f32>,
    m: Vec<f32>,
    jobsum: Vec<f32>,
    jh: Vec<f32>,
    y: Vec<f32>,
    gsum: Vec<f32>,
    gh: Vec<f32>,
    z: Vec<f32>,
    cat: Vec<f32>,
    q_h1: Vec<f32>,
    q_h2: Vec<f32>,
    q_h3: Vec<f32>,
    logits: Vec<f32>,
    // Value-head buffers (moved out of `forward` so the hot path does not
    // allocate per decision).
    vh1: Vec<f32>,
    vh2: Vec<f32>,
    vout: Vec<f32>,
    // Dense-oracle staging for adj/jobmat (only sized by forward_dense).
    dense_adj: Vec<f32>,
    dense_jobmat: Vec<f32>,
}

impl Scratch {
    fn ensure(&mut self, n: usize, j: usize) {
        if self.n == n && self.j == j {
            return;
        }
        self.n = n;
        self.j = j;
        self.e0 = vec![0.0; n * E];
        self.e = vec![0.0; n * E];
        self.agg = vec![0.0; n * E];
        self.h = vec![0.0; n * H];
        self.m = vec![0.0; n * E];
        self.jobsum = vec![0.0; j * E];
        self.jh = vec![0.0; j * H];
        self.y = vec![0.0; j * E];
        self.gsum = vec![0.0; E];
        self.gh = vec![0.0; H];
        self.z = vec![0.0; E];
        self.cat = vec![0.0; n * 3 * E];
        self.q_h1 = vec![0.0; n * Q1];
        self.q_h2 = vec![0.0; n * Q2];
        self.q_h3 = vec![0.0; n * Q3];
        self.logits = vec![0.0; n];
        self.vh1 = vec![0.0; V1];
        self.vh2 = vec![0.0; V2];
        self.vout = vec![0.0; 1];
        // Dense staging keeps its old capacity; forward_dense resizes.
        self.dense_adj.clear();
        self.dense_jobmat.clear();
    }
}

impl RustPolicy {
    pub fn new(params: Vec<f32>) -> RustPolicy {
        RustPolicy::shared(Arc::new(params))
    }

    /// Build a policy over an existing shared parameter snapshot — no
    /// copy; every actor holding the same `Arc` reads the same weights.
    pub fn shared(params: Arc<Vec<f32>>) -> RustPolicy {
        assert_eq!(
            params.len(),
            param_len(),
            "parameter vector length mismatch: got {}, layout wants {}",
            params.len(),
            param_len()
        );
        RustPolicy {
            params,
            scratch: Scratch::default(),
            batch_scratch: super::batch::BatchScratch::default(),
        }
    }

    /// Glorot-uniform random initialization — same scheme as the python
    /// side's `init_params` (not bit-identical, used when artifacts are
    /// unavailable, e.g. pure-rust tests).
    pub fn random(seed: u64) -> RustPolicy {
        RustPolicy::new(RustPolicy::random_params(seed))
    }

    /// The flat parameter vector [`RustPolicy::random`] wraps — for
    /// callers that need owned weights (backends, checkpoints) rather
    /// than a policy instance.
    pub fn random_params(seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::new(seed ^ 0x9017_11E7);
        let mut params = vec![0.0f32; param_len()];
        let mut off = 0;
        for (name, r, c) in LAYOUT {
            let fan = (*r + *c) as f64;
            let lim = (6.0 / fan).sqrt();
            for p in params[off..off + r * c].iter_mut() {
                *p = if name.starts_with('b') {
                    0.0
                } else {
                    rng.range_f(-lim, lim) as f32
                };
            }
            off += r * c;
        }
        params
    }

    pub(crate) fn p(&self, name: &str) -> &[f32] {
        let off = param_offset(name);
        let (_, r, c) = LAYOUT
            .iter()
            .find(|(n, _, _)| *n == name)
            .expect("known name");
        &self.params[off..off + r * c]
    }

    /// Shared epilogue of both forward paths: node embeddings `s.e` and
    /// job summaries `s.y` → global summary, per-node scores, value head.
    /// `sparse_gather` controls how y_job(n) is looked up (slot→job index
    /// vs dense row scan — identical results, kept separate so the oracle
    /// exercises the dense layout end to end).
    fn heads(&self, s: &mut Scratch, enc: &EncodedState, m: usize, sparse_gather: bool) -> f32 {
        let n = enc.variant.n;
        let jcap = enc.variant.j;

        // Global summary: z = f(Σ_j y_j).
        s.gsum.fill(0.0);
        for j in 0..jcap {
            for d in 0..E {
                s.gsum[d] += s.y[j * E + d];
            }
        }
        dense(&s.gsum, self.p("fg1"), self.p("bfg1"), &mut s.gh, 1, E, H, true);
        dense(&s.gh, self.p("fg2"), self.p("bfg2"), &mut s.z, 1, H, E, true);

        // Per-node score over [e_n ; y_job(n) ; z] (Eq 8's q).
        for i in 0..m {
            let cat = &mut s.cat[i * 3 * E..(i + 1) * 3 * E];
            cat[..E].copy_from_slice(&s.e[i * E..(i + 1) * E]);
            cat[E..2 * E].fill(0.0);
            if sparse_gather {
                if let Some(&js) = enc.slot_job.get(i) {
                    let js = js as usize;
                    cat[E..2 * E].copy_from_slice(&s.y[js * E..(js + 1) * E]);
                }
            } else {
                for j in 0..jcap {
                    if s.dense_jobmat[j * n + i] > 0.0 {
                        cat[E..2 * E].copy_from_slice(&s.y[j * E..(j + 1) * E]);
                        break;
                    }
                }
            }
            cat[2 * E..].copy_from_slice(&s.z);
        }
        dense(&s.cat, self.p("q1"), self.p("bq1"), &mut s.q_h1, m, 3 * E, Q1, true);
        dense(&s.q_h1, self.p("q2"), self.p("bq2"), &mut s.q_h2, m, Q1, Q2, true);
        dense(&s.q_h2, self.p("q3"), self.p("bq3"), &mut s.q_h3, m, Q2, Q3, true);
        s.logits.fill(0.0);
        dense(&s.q_h3, self.p("q4"), self.p("bq4"), &mut s.logits, m, Q3, 1, false);

        // Value head over z.
        dense(&s.z, self.p("v1"), self.p("bv1"), &mut s.vh1, 1, E, V1, true);
        dense(&s.vh1, self.p("v2"), self.p("bv2"), &mut s.vh2, 1, V1, V2, true);
        dense(&s.vh2, self.p("v3"), self.p("bv3"), &mut s.vout, 1, V2, 1, false);
        s.vout[0]
    }

    /// Input embedding shared by both paths: e0 = tanh(x·W_in + b_in),
    /// masked, copied into the working embedding `e`.
    fn embed(&self, s: &mut Scratch, enc: &EncodedState, m: usize) {
        s.e0.fill(0.0);
        dense(&enc.x, self.p("w_in"), self.p("b_in"), &mut s.e0, m, F, E, true);
        for i in 0..m {
            if enc.node_mask[i] == 0.0 {
                s.e0[i * E..(i + 1) * E].fill(0.0);
            }
        }
        s.e.copy_from_slice(&s.e0);
    }

    /// Sparse forward pass — the production path. Writes the per-slot
    /// logits (all N, padding slots meaningless — mask before use) into
    /// `logits` and returns the critic's value estimate. Allocation-free
    /// once the scratch is warm for the variant.
    pub fn forward_into(&mut self, enc: &EncodedState, logits: &mut Vec<f32>) -> f32 {
        let n = enc.variant.n;
        let jcap = enc.variant.j;
        // Slots are packed [0, n_used): all row-wise work can stop there
        // (padding rows are identically zero by construction).
        let m = enc.n_used().max(1);
        let mut s = std::mem::take(&mut self.scratch);
        s.ensure(n, jcap);

        self.embed(&mut s, enc, m);

        // K message-passing iterations with shared g (Eq 5): CSR gather —
        // O(|E|·E) per round. Children per row are sorted ascending, the
        // same order the dense matmul visits nonzero columns, so the
        // accumulation is bitwise identical to the dense oracle.
        for _ in 0..K {
            s.agg[..m * E].fill(0.0);
            for i in 0..enc.n_used() {
                for &c in enc.children_of(i) {
                    let c = c as usize;
                    let erow = &s.e[c * E..(c + 1) * E];
                    let arow = &mut s.agg[i * E..(i + 1) * E];
                    for (o, &ev) in arow.iter_mut().zip(erow) {
                        *o += ev;
                    }
                }
            }
            dense(&s.agg, self.p("g1"), self.p("bg1"), &mut s.h, m, E, H, true);
            dense(&s.h, self.p("g2"), self.p("bg2"), &mut s.m, m, H, E, true);
            for i in 0..m {
                let mask = enc.node_mask[i];
                for d in 0..E {
                    s.e[i * E + d] = (s.m[i * E + d] + s.e0[i * E + d]) * mask;
                }
            }
        }

        // Per-job summaries via the slot→job index (slots ascend, so each
        // job row accumulates in the same order as the dense jobmat·e).
        s.jobsum.fill(0.0);
        for (i, &js) in enc.slot_job.iter().enumerate() {
            let js = js as usize;
            let erow = &s.e[i * E..(i + 1) * E];
            let jrow = &mut s.jobsum[js * E..(js + 1) * E];
            for (o, &ev) in jrow.iter_mut().zip(erow) {
                *o += ev;
            }
        }
        dense(&s.jobsum, self.p("fj1"), self.p("bfj1"), &mut s.jh, jcap, E, H, true);
        dense(&s.jh, self.p("fj2"), self.p("bfj2"), &mut s.y, jcap, H, E, true);
        // Zero-out empty job slots (tanh(bias) could leak). Per-job slot
        // counts from the encoder replace the old O(J·N) occupancy scan.
        for j in 0..jcap {
            if j >= enc.job_counts.len() {
                s.y[j * E..(j + 1) * E].fill(0.0);
            }
        }

        let value = self.heads(&mut s, enc, m, true);
        logits.clear();
        logits.extend_from_slice(&s.logits);
        self.scratch = s;
        value
    }

    /// Full sparse forward pass returning freshly allocated logits.
    /// Convenience wrapper over [`RustPolicy::forward_into`].
    pub fn forward(&mut self, enc: &EncodedState) -> (Vec<f32>, f32) {
        let mut logits = Vec::new();
        let value = self.forward_into(enc, &mut logits);
        (logits, value)
    }

    /// Dense-oracle forward pass: materializes the dense adjacency and
    /// job matrix from the CSR and runs the original O(K·N²·E) pipeline —
    /// exactly the computation the PJRT artifact performs. Used for
    /// cross-validation; the sparse path must match it within 1e-5.
    pub fn forward_dense(&mut self, enc: &EncodedState) -> (Vec<f32>, f32) {
        let n = enc.variant.n;
        let jcap = enc.variant.j;
        let m = enc.n_used().max(1);
        let mut s = std::mem::take(&mut self.scratch);
        s.ensure(n, jcap);
        s.dense_adj.clear();
        s.dense_adj.resize(n * n, 0.0);
        enc.write_dense_adj(&mut s.dense_adj);
        s.dense_jobmat.clear();
        s.dense_jobmat.resize(jcap * n, 0.0);
        enc.write_dense_jobmat(&mut s.dense_jobmat);

        self.embed(&mut s, enc, m);

        // K message-passing iterations — dense matmul against adj.
        for _ in 0..K {
            s.agg[..m * E].fill(0.0);
            matmul_into(&s.dense_adj[..m * n], &s.e, &mut s.agg[..m * E], m, n, E);
            dense(&s.agg, self.p("g1"), self.p("bg1"), &mut s.h, m, E, H, true);
            dense(&s.h, self.p("g2"), self.p("bg2"), &mut s.m, m, H, E, true);
            for i in 0..m {
                let mask = enc.node_mask[i];
                for d in 0..E {
                    s.e[i * E + d] = (s.m[i * E + d] + s.e0[i * E + d]) * mask;
                }
            }
        }

        // Per-job summaries: jobsum = jobmat · e, y = f(jobsum).
        s.jobsum.fill(0.0);
        matmul_into(&s.dense_jobmat, &s.e, &mut s.jobsum, jcap, n, E);
        dense(&s.jobsum, self.p("fj1"), self.p("bfj1"), &mut s.jh, jcap, E, H, true);
        dense(&s.jh, self.p("fj2"), self.p("bfj2"), &mut s.y, jcap, H, E, true);
        for j in 0..jcap {
            let occupied = (0..n).any(|i| s.dense_jobmat[j * n + i] > 0.0);
            if !occupied {
                s.y[j * E..(j + 1) * E].fill(0.0);
            }
        }

        let value = self.heads(&mut s, enc, m, false);
        let logits = s.logits.clone();
        self.scratch = s;
        (logits, value)
    }
}

impl PolicyEval for RustPolicy {
    fn logits_value_into(&mut self, enc: &EncodedState, logits: &mut Vec<f32>) -> Result<f32> {
        Ok(self.forward_into(enc, logits))
    }

    fn backend_name(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::WorkloadConfig;
    use crate::policy::encode::encode;
    use crate::policy::features::FeatureMode;
    use crate::sim::SimState;
    use crate::workload::WorkloadGenerator;

    fn enc(n_jobs: usize, seed: u64) -> EncodedState {
        let cluster = Cluster::homogeneous(4, 2.5, 100.0);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(n_jobs), seed).generate();
        let mut st = SimState::new(cluster, w);
        for j in 0..n_jobs {
            st.mark_arrived(j);
        }
        encode(&st, FeatureMode::Full)
    }

    #[test]
    fn layout_is_consistent() {
        assert!(param_len() > 1000);
        assert_eq!(param_offset("w_in"), 0);
        assert_eq!(param_offset("b_in"), F * E);
        // Offsets strictly increase and the last block ends at param_len.
        let mut off = 0;
        for (name, r, c) in LAYOUT {
            assert_eq!(param_offset(name), off);
            off += r * c;
        }
        assert_eq!(off, param_len());
    }

    #[test]
    fn forward_produces_finite_outputs() {
        let mut net = RustPolicy::random(1);
        let e = enc(3, 1);
        let (logits, value) = net.forward(&e);
        assert_eq!(logits.len(), e.variant.n);
        assert!(value.is_finite());
        for i in 0..e.n_used() {
            assert!(logits[i].is_finite());
        }
    }

    #[test]
    fn forward_is_deterministic() {
        let mut net = RustPolicy::random(2);
        let e = enc(2, 2);
        let (l1, v1) = net.forward(&e);
        let (l2, v2) = net.forward(&e);
        assert_eq!(l1, l2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn sparse_forward_matches_dense_oracle() {
        for seed in 0..4u64 {
            let mut net = RustPolicy::random(20 + seed);
            // 2 jobs → N=64 variant; 12 jobs → N=256 variant.
            for jobs in [2usize, 12] {
                let e = enc(jobs, seed + 1);
                let (ls, vs) = net.forward(&e);
                let (ld, vd) = net.forward_dense(&e);
                assert!((vs - vd).abs() <= 1e-5, "value {vs} vs {vd}");
                for i in 0..e.n_used() {
                    assert!(
                        (ls[i] - ld[i]).abs() <= 1e-5,
                        "slot {i}: sparse {} dense {}",
                        ls[i],
                        ld[i]
                    );
                }
            }
        }
    }

    #[test]
    fn forward_into_reuses_buffer() {
        let mut net = RustPolicy::random(3);
        let e = enc(2, 5);
        let mut buf = Vec::new();
        let v1 = net.forward_into(&e, &mut buf);
        let cap = buf.capacity();
        let first = buf.clone();
        let v2 = net.forward_into(&e, &mut buf);
        assert_eq!(buf, first);
        assert_eq!(v1, v2);
        assert_eq!(buf.capacity(), cap, "steady state must not reallocate");
    }

    #[test]
    fn different_params_different_logits() {
        let e = enc(2, 3);
        let (l1, _) = RustPolicy::random(10).forward(&e);
        let (l2, _) = RustPolicy::random(11).forward(&e);
        let used = e.n_used();
        assert!(
            l1[..used] != l2[..used],
            "different params must change logits"
        );
    }

    #[test]
    fn node_order_permutation_equivariance_of_padding() {
        // Padding slots must not affect used slots: compare a small state
        // against itself (the padded tail is already zero; this guards the
        // masking logic by ensuring logits don't depend on scratch resize).
        let mut net = RustPolicy::random(4);
        let e_small = enc(1, 4);
        let (l1, _) = net.forward(&e_small);
        let e_big = enc(12, 4); // forces the 256-variant, resizing scratch
        let _ = net.forward(&e_big);
        let (l2, _) = net.forward(&e_small);
        assert_eq!(l1, l2, "scratch reuse must not leak state");
    }

    #[test]
    fn dense_oracle_does_not_poison_sparse_scratch() {
        let mut net = RustPolicy::random(5);
        let e = enc(2, 6);
        let (l1, v1) = net.forward(&e);
        let _ = net.forward_dense(&e);
        let (l2, v2) = net.forward(&e);
        assert_eq!(l1, l2);
        assert_eq!(v1, v2);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn rejects_wrong_param_len() {
        RustPolicy::new(vec![0.0; 10]);
    }
}
