//! Incremental encoder cache: persists one [`EncodedState`] across the
//! decisions of an episode and patches it instead of re-running the full
//! `encode()` rebuild per decision.
//!
//! `SimState::apply` knows exactly which tasks changed, and publishes that
//! knowledge through the [`EncEvent`] log: the assigned task leaves the
//! encoding (one slot removal), its children's `executable` feature may
//! flip, one job's `left_tasks`/`left_work` counters move (features 7/8
//! of every slot of that job), and bookings schedule a future
//! finished-parent flip for their children. The wall clock alone moves
//! only the per-job wait feature plus whichever finished-parent fractions
//! it crosses — tracked by a min-heap of pending copy-finish times.
//!
//! The cache's contract, pinned by proptests: after any replayable event
//! sequence (monotone wall), [`EncoderCache::refresh`] returns an
//! encoding **bitwise identical** to a fresh `encode()` of the same
//! state. Whenever a patch would be unsound — a job arrival (slots get
//! inserted), an active truncation (dropped tasks can re-enter), or a
//! shape-variant change — the cache falls back to the full rebuild, so
//! correctness never depends on the patch fast-path being reachable.

use super::encode::{self, encode, pick_variant, EncodedState};
use super::features::{job_wait_feature, FeatureMode, WAIT_FEATURE};
use super::F;
use crate::dag::TaskRef;
use crate::sim::{EncEvent, SimState};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A future copy-finish: when the wall clock passes `finish`, the
/// children of `task` flip their finished-parent fraction.
#[derive(Debug, Clone, Copy)]
struct PendingFinish {
    finish: f64,
    task: TaskRef,
}

impl PartialEq for PendingFinish {
    fn eq(&self, other: &Self) -> bool {
        self.finish == other.finish && self.task == other.task
    }
}
impl Eq for PendingFinish {}
impl PartialOrd for PendingFinish {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for PendingFinish {
    // Reverse ordering: BinaryHeap is a max-heap, we want earliest first.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .finish
            .total_cmp(&self.finish)
            .then_with(|| other.task.cmp(&self.task))
    }
}

/// The incremental encoder. One cache per episode/state lifecycle: call
/// [`EncoderCache::reset`] when switching to a fresh `SimState`. Swapped
/// or compacted-past states are detected defensively when
/// `enc_events_since` cannot serve the replay cursor (the cache then
/// rebuilds and reseeds its pending heap from live placements), but a
/// foreign state whose log happens to cover the cursor cannot be told
/// apart — the selector resets explicitly instead.
pub struct EncoderCache {
    mode: FeatureMode,
    enc: Option<EncodedState>,
    /// Absolute position in the state's event log up to which events
    /// have been replayed (see `SimState::enc_events_since`).
    cursor: u64,
    /// Wall clock the cached encoding reflects.
    wall: f64,
    /// Min-heap of future copy finishes (may contain stale, superseded
    /// entries — popping one re-featurizes from live state, which is
    /// idempotent, so duplicates are harmless).
    pending: BinaryHeap<PendingFinish>,
    /// Diagnostics: full rebuilds vs incremental patches served.
    pub rebuilds: usize,
    pub patches: usize,
}

impl EncoderCache {
    pub fn new(mode: FeatureMode) -> EncoderCache {
        EncoderCache {
            mode,
            enc: None,
            cursor: 0,
            wall: 0.0,
            pending: BinaryHeap::new(),
            rebuilds: 0,
            patches: 0,
        }
    }

    pub fn mode(&self) -> FeatureMode {
        self.mode
    }

    /// Forget everything (start of a new episode/state).
    pub fn reset(&mut self) {
        self.enc = None;
        self.cursor = 0;
        self.wall = 0.0;
        self.pending.clear();
    }

    /// Bring the cached encoding up to date with `state` and return it.
    /// Equivalent to `encode(state, mode)` — bitwise. The patch path
    /// re-featurizes only dirty slots (the touched job, flipped
    /// finished-parent children); the remaining work is memmove/renumber
    /// passes (slot shift, CSR compaction, per-job wait fanout) that are
    /// O(N + |E|) with tiny constants, versus the rebuild's full
    /// per-slot feature extraction, allocation and edge re-gather.
    pub fn refresh(&mut self, state: &SimState) -> &EncodedState {
        let events: &[EncEvent] = match state.enc_events_since(self.cursor) {
            Some(evs) => evs,
            None => {
                // Our cursor predates the state's compacted log window
                // (or the state was swapped under us): the replay gap is
                // unrecoverable, so rebuild and reseed the pending
                // finish-heap from the live placements.
                self.reset();
                self.cursor = state.enc_log_end();
                self.reseed_pending(state);
                self.rebuild(state);
                return self.enc.as_ref().expect("encoding present after rebuild");
            }
        };
        debug_assert!(
            state.wall >= self.wall || self.enc.is_none(),
            "EncoderCache requires a monotone wall clock"
        );

        // Replay the event log: collect slot removals, schedule pending
        // finishes, detect structural growth.
        let mut removals: Vec<TaskRef> = Vec::new();
        let mut rebuild =
            self.enc.is_none() || self.enc.as_ref().map_or(false, |e| e.truncated);
        let mut reseed = false;
        for ev in events {
            match *ev {
                EncEvent::Assigned { task } => removals.push(task),
                EncEvent::Booked { task, finish } => {
                    self.pending.push(PendingFinish { finish, task })
                }
                EncEvent::Arrived { .. } => rebuild = true,
                EncEvent::Invalidated => {
                    // A fault-recovery pass cancelled or re-timed
                    // bookings: both the encoding and the pending
                    // finish-heap may reference copies that no longer
                    // exist (or finishes that moved). Re-derive both
                    // from live state.
                    rebuild = true;
                    reseed = true;
                }
            }
        }
        self.cursor = state.enc_log_end();

        if reseed {
            self.reseed_pending(state);
        }
        if !rebuild {
            rebuild = !self.patch(state, &removals);
        }
        if rebuild {
            self.rebuild(state);
        }
        self.enc.as_ref().expect("encoding present after refresh")
    }

    /// Reconstruct the pending finish-heap from the live placements: every
    /// copy finishing after the current wall may still flip its children's
    /// finished-parent fraction. Only needed when the event log cannot be
    /// replayed (compaction gap / foreign state).
    fn reseed_pending(&mut self, state: &SimState) {
        self.pending.clear();
        for (ji, per_task) in state.placements.iter().enumerate() {
            for (node, copies) in per_task.iter().enumerate() {
                for pl in copies {
                    if pl.finish > state.wall {
                        self.pending.push(PendingFinish {
                            finish: pl.finish,
                            task: TaskRef::new(ji, node),
                        });
                    }
                }
            }
        }
    }

    /// Try to patch the cached encoding in place; returns false if a full
    /// rebuild is required after all (missing slot, variant change).
    fn patch(&mut self, state: &SimState, removals: &[TaskRef]) -> bool {
        let enc = self.enc.as_mut().expect("patch requires a cached encoding");

        // 1. Structural removals (assigned tasks leave the encoding;
        // features, masks, job index and CSR all shift in place).
        for &t in removals {
            if enc.remove_slot(t).is_none() {
                return false; // unknown slot — be safe, rebuild
            }
        }
        // Fewer tasks/jobs can shrink the shape variant; fresh `encode`
        // would pick the smaller one, so follow it.
        if pick_variant(enc.n_used(), enc.n_jobs_used()) != enc.variant {
            return false;
        }

        // 2. Re-featurize every slot of each touched job: the assignment
        // moved the job's left_tasks/left_work (features 7/8 of all its
        // slots) and possibly its children's executable flag/mask.
        let mut dirty_jobs: Vec<usize> = removals.iter().map(|t| t.job).collect();
        dirty_jobs.sort_unstable();
        dirty_jobs.dedup();
        for job in dirty_jobs {
            let lo = enc.slots.partition_point(|s| s.job < job);
            let hi = enc.slots.partition_point(|s| s.job <= job);
            for i in lo..hi {
                encode::fill_slot(state, self.mode, enc, i);
            }
        }

        // 3. Wall-clock advance: the per-job wait feature moves for every
        // encoded job (one squash per job, fanned out to its slots), and
        // copies finishing inside (cached_wall, wall] flip their
        // children's finished-parent fraction.
        if state.wall != self.wall {
            let mut i = 0;
            while i < enc.n_used() {
                let job = enc.slots[i].job;
                let wait = job_wait_feature(state, job);
                let hi = enc.slots.partition_point(|s| s.job <= job);
                for k in i..hi {
                    enc.x[k * F + WAIT_FEATURE] = wait;
                }
                i = hi;
            }
            while let Some(p) = self.pending.peek() {
                if p.finish > state.wall {
                    break;
                }
                let p = self.pending.pop().expect("peeked entry");
                for e in &state.jobs[p.task.job].children[p.task.node] {
                    let c = TaskRef::new(p.task.job, e.other);
                    if let Ok(ci) = enc.slots.binary_search(&c) {
                        encode::fill_slot(state, self.mode, enc, ci);
                    }
                }
            }
            self.wall = state.wall;
        }
        self.patches += 1;
        true
    }

    /// Full rebuild: delegate to `encode` and drop pending entries the
    /// fresh features already reflect.
    fn rebuild(&mut self, state: &SimState) {
        self.enc = Some(encode(state, self.mode));
        self.wall = state.wall;
        self.rebuilds += 1;
        while let Some(p) = self.pending.peek() {
            if p.finish > state.wall {
                break;
            }
            self.pending.pop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::WorkloadConfig;
    use crate::sim::{Allocation, SimState};
    use crate::workload::WorkloadGenerator;

    fn state(n_jobs: usize, seed: u64) -> SimState {
        let cluster = Cluster::homogeneous(4, 2.5, 100.0);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(n_jobs), seed).generate();
        let mut st = SimState::new(cluster, w);
        for j in 0..n_jobs {
            st.mark_arrived(j);
        }
        st
    }

    fn assert_matches_fresh(cache: &mut EncoderCache, st: &SimState) {
        let fresh = encode(st, cache.mode());
        let cached = cache.refresh(st);
        assert_eq!(cached, &fresh);
    }

    #[test]
    fn first_refresh_rebuilds_then_patches() {
        let mut st = state(2, 1);
        let mut cache = EncoderCache::new(FeatureMode::Full);
        assert_matches_fresh(&mut cache, &st);
        assert_eq!(cache.rebuilds, 1);
        let t = st.executable()[0];
        st.apply(t, Allocation::Direct { exec: 0 });
        assert_matches_fresh(&mut cache, &st);
        assert_eq!(cache.rebuilds, 1, "apply must patch, not rebuild");
        assert_eq!(cache.patches, 1);
    }

    #[test]
    fn tracks_full_episode_with_wall_advances() {
        let mut st = state(3, 2);
        let mut cache = EncoderCache::new(FeatureMode::Full);
        let mut step = 0usize;
        while !st.executable().is_empty() {
            let t = st.executable()[step % st.executable().len()];
            let exec = step % st.cluster.len();
            let finish = st.apply(t, Allocation::Direct { exec });
            if step % 3 == 0 {
                st.wall = st.wall.max(finish); // engine-style monotone advance
            }
            assert_matches_fresh(&mut cache, &st);
            step += 1;
        }
        assert!(cache.patches > 0);
    }

    #[test]
    fn arrival_triggers_rebuild() {
        let cluster = Cluster::homogeneous(4, 2.5, 100.0);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(3), 3).generate();
        let mut st = SimState::new(cluster, w);
        st.mark_arrived(0);
        let mut cache = EncoderCache::new(FeatureMode::Full);
        assert_matches_fresh(&mut cache, &st);
        let before = cache.rebuilds;
        st.mark_arrived(1);
        assert_matches_fresh(&mut cache, &st);
        assert_eq!(cache.rebuilds, before + 1);
        st.mark_arrived(2);
        assert_matches_fresh(&mut cache, &st);
    }

    #[test]
    fn duplicate_allocations_stay_bitwise() {
        let mut st = state(2, 4);
        let mut cache = EncoderCache::new(FeatureMode::Full);
        assert_matches_fresh(&mut cache, &st);
        // Drain one entry task first so some task has an assigned parent.
        let t0 = st.executable()[0];
        let f0 = st.apply(t0, Allocation::Direct { exec: 0 });
        assert_matches_fresh(&mut cache, &st);
        // Find an executable task with a parent and duplicate it.
        let cand = st
            .executable()
            .iter()
            .copied()
            .find(|t| !st.jobs[t.job].parents[t.node].is_empty());
        if let Some(t) = cand {
            let parent = st.jobs[t.job].parents[t.node][0].other;
            st.apply(t, Allocation::Duplicate { exec: 1, parent });
            assert_matches_fresh(&mut cache, &st);
        }
        // Cross the first finish boundary: finished-parent fractions flip.
        st.wall = st.wall.max(f0 + 1e-6);
        assert_matches_fresh(&mut cache, &st);
    }

    #[test]
    fn reset_recovers_from_state_swap() {
        let mut st = state(2, 5);
        let mut cache = EncoderCache::new(FeatureMode::Full);
        for _ in 0..3 {
            let t = st.executable()[0];
            st.apply(t, Allocation::Direct { exec: 0 });
            cache.refresh(&st);
        }
        // New, shorter-logged state: detected and replayed from scratch.
        let st2 = state(3, 6);
        assert_matches_fresh(&mut cache, &st2);
        // Explicit reset also works.
        cache.reset();
        assert_matches_fresh(&mut cache, &st2);
    }

    #[test]
    fn compaction_gap_falls_back_to_rebuild() {
        let mut st = state(2, 8);
        let mut cache = EncoderCache::new(FeatureMode::Full);
        // Generate events the cache never saw, then compact them away:
        // the replay gap must trigger a rebuild + pending reseed.
        let t = st.executable()[0];
        let f = st.apply(t, Allocation::Direct { exec: 0 });
        st.compact_enc_log();
        assert_matches_fresh(&mut cache, &st);
        // The reseeded heap still flips finished parents later on.
        st.wall = f + 1e-6;
        assert_matches_fresh(&mut cache, &st);
    }

    #[test]
    fn variant_shrink_falls_back_to_rebuild() {
        // 14 small jobs → N=256; drain jobs until the state fits N=64.
        let mut st = state(14, 7);
        let mut cache = EncoderCache::new(FeatureMode::Full);
        assert_eq!(cache.refresh(&st).variant.n, 256);
        while !st.executable().is_empty() {
            let t = st.executable()[0];
            st.apply(t, Allocation::Direct { exec: 0 });
            assert_matches_fresh(&mut cache, &st);
        }
        assert_eq!(cache.refresh(&st).variant.n, 64, "empty state fits small");
    }
}
