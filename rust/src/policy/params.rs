//! Flat parameter vector I/O: raw little-endian f32 files.
//!
//! Rust treats network weights as an opaque `Vec<f32>` — the layout is
//! owned jointly by `net::LAYOUT` and `python/compile/model.py`. The AOT
//! build writes `artifacts/params_init.bin`; training checkpoints go to
//! `checkpoints/*.bin` with a sidecar JSON of training metadata.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Load a raw f32-LE parameter file.
pub fn load_f32(path: &str) -> Result<Vec<f32>> {
    let mut file = std::fs::File::open(path).with_context(|| format!("opening {path}"))?;
    let mut bytes = Vec::new();
    file.read_to_end(&mut bytes)
        .with_context(|| format!("reading {path}"))?;
    if bytes.len() % 4 != 0 {
        bail!("{path}: length {} is not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Save a raw f32-LE parameter file.
pub fn save_f32(path: &str, params: &[f32]) -> Result<()> {
    let mut file = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    let mut bytes = Vec::with_capacity(params.len() * 4);
    for &p in params {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    file.write_all(&bytes)
        .with_context(|| format!("writing {path}"))?;
    Ok(())
}

/// Save a checkpoint: parameters + JSON sidecar with training metadata.
pub fn save_checkpoint(
    dir: &str,
    tag: &str,
    params: &[f32],
    episode: usize,
    avg_return: f64,
) -> Result<String> {
    std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir}"))?;
    let bin = format!("{dir}/{tag}.bin");
    save_f32(&bin, params)?;
    let meta = Json::from_pairs(vec![
        ("tag", Json::from(tag)),
        ("episode", Json::from(episode)),
        ("avg_return", Json::from(avg_return)),
        ("param_len", Json::from(params.len())),
    ]);
    std::fs::write(format!("{dir}/{tag}.json"), meta.to_pretty())?;
    Ok(bin)
}

/// Load parameters validated against the expected length.
pub fn load_expected(path: &str, expected_len: usize) -> Result<Vec<f32>> {
    let p = load_f32(path)?;
    if p.len() != expected_len {
        bail!(
            "{path}: has {} parameters, model wants {expected_len} \
             (stale checkpoint from an older model layout?)",
            p.len()
        );
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let params: Vec<f32> = (0..100).map(|i| i as f32 * 0.5 - 3.0).collect();
        let path = "/tmp/lachesis_params_test.bin";
        save_f32(path, &params).unwrap();
        let back = load_f32(path).unwrap();
        assert_eq!(params, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_expected_validates() {
        let path = "/tmp/lachesis_params_test2.bin";
        save_f32(path, &[1.0, 2.0]).unwrap();
        assert!(load_expected(path, 2).is_ok());
        assert!(load_expected(path, 3).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn checkpoint_writes_sidecar() {
        let dir = "/tmp/lachesis_ckpt_test";
        let bin = save_checkpoint(dir, "ep10", &[1.0; 8], 10, -42.0).unwrap();
        assert!(std::path::Path::new(&bin).exists());
        let meta = std::fs::read_to_string(format!("{dir}/ep10.json")).unwrap();
        assert!(meta.contains("avg_return"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_truncated_file() {
        let path = "/tmp/lachesis_params_bad.bin";
        std::fs::write(path, [0u8, 1, 2]).unwrap();
        assert!(load_f32(path).is_err());
        std::fs::remove_file(path).ok();
    }
}
