//! Batched CSR forward pass: pack B encoded states into one block-CSR
//! graph and run the whole MGNet pipeline over the concatenated rows.
//!
//! The single-state path ([`RustPolicy::forward_into`]) pays the fixed
//! cost of every dense layer once per state — at training batch sizes
//! that is B passes over the same weight matrices with tiny row counts.
//! [`PackedBatch`] concatenates only the *used* rows of each state
//! (padding slots are dropped entirely, M = Σ n_used) with the CSR
//! column indices rebased into the global row space, so one K-step
//! propagation loop and one trip through each MLP covers the batch.
//! Per-row math is identical to the per-state path — same accumulation
//! order everywhere — so batched and single-state outputs agree
//! bitwise; tests pin them within 1e-5.
//!
//! States of *different shape variants* can share a batch: nothing here
//! depends on the N/J capacities, only on used rows. (The PJRT
//! `train_step` artifact keeps its single-variant restriction — that is
//! a property of the compiled dense shapes, not of this packing.)

use super::encode::EncodedState;
use super::net::{dense, RustPolicy};
use super::{E, F, H, K, Q1, Q2, Q3, V1, V2};
use anyhow::{bail, Result};

/// B encoded states packed into one graph over M = Σ n_used rows.
#[derive(Debug, Clone, Default)]
pub struct PackedBatch {
    /// Number of packed states B.
    pub n_states: usize,
    /// Per-state node-row offsets (len B+1): state `b` owns packed rows
    /// `row_base[b]..row_base[b+1]`, in its own slot order.
    pub row_base: Vec<usize>,
    /// Per-state job-row offsets (len B+1), same convention.
    pub job_base: Vec<usize>,
    /// Concatenated used-row features [M, F].
    pub x: Vec<f32>,
    /// Block CSR over all M rows (len M+1): children of global row `i`
    /// are `col_indices[row_offsets[i]..row_offsets[i+1]]`, already
    /// rebased into global row indices.
    pub row_offsets: Vec<u32>,
    pub col_indices: Vec<u32>,
    /// Global job row of each packed node row (len M).
    pub slot_job: Vec<u32>,
    /// Executable mask over packed rows (len M).
    pub exec_mask: Vec<f32>,
}

impl PackedBatch {
    /// Pack a batch of encoded states. States may mix shape variants;
    /// per-state padding never enters the packed buffers.
    pub fn pack(encs: &[&EncodedState]) -> PackedBatch {
        let b = encs.len();
        let m: usize = encs.iter().map(|e| e.n_used()).sum();
        let edges: usize = encs.iter().map(|e| e.n_edges()).sum();
        let jobs: usize = encs.iter().map(|e| e.n_jobs_used()).sum();
        let mut out = PackedBatch {
            n_states: b,
            row_base: Vec::with_capacity(b + 1),
            job_base: Vec::with_capacity(b + 1),
            x: Vec::with_capacity(m * F),
            row_offsets: Vec::with_capacity(m + 1),
            col_indices: Vec::with_capacity(edges),
            slot_job: Vec::with_capacity(m),
            exec_mask: Vec::with_capacity(m),
        };
        out.row_offsets.push(0);
        let mut row0 = 0u32;
        let mut job0 = 0u32;
        for enc in encs {
            let used = enc.n_used();
            out.row_base.push(row0 as usize);
            out.job_base.push(job0 as usize);
            out.x.extend_from_slice(&enc.x[..used * F]);
            out.exec_mask.extend_from_slice(&enc.exec_mask[..used]);
            for i in 0..used {
                for &c in enc.children_of(i) {
                    out.col_indices.push(row0 + c);
                }
                out.row_offsets.push(out.col_indices.len() as u32);
            }
            out.slot_job.extend(enc.slot_job.iter().map(|&j| job0 + j));
            row0 += used as u32;
            job0 += enc.n_jobs_used() as u32;
        }
        out.row_base.push(row0 as usize);
        out.job_base.push(job0 as usize);
        debug_assert_eq!(row0 as usize, m);
        debug_assert_eq!(job0 as usize, jobs);
        out
    }

    /// Total packed node rows M.
    pub fn n_rows(&self) -> usize {
        self.slot_job.len()
    }

    /// Total packed job rows.
    pub fn n_job_rows(&self) -> usize {
        *self.job_base.last().unwrap_or(&0)
    }

    /// State `b`'s slice of a per-row vector (its logits segment).
    pub fn state_rows<'a, T>(&self, xs: &'a [T], b: usize) -> &'a [T] {
        &xs[self.row_base[b]..self.row_base[b + 1]]
    }
}

/// Write B encoded states into the dense `train_step` batch tensors in
/// one pass — the PJRT path's batch packer (buffers are the artifact's
/// B-major layouts and must be pre-zeroed). All states must match the
/// compiled variant (N, J).
pub fn write_dense_batch(
    encs: &[&EncodedState],
    n: usize,
    j: usize,
    x: &mut [f32],
    adj: &mut [f32],
    jobmat: &mut [f32],
    node_mask: &mut [f32],
    exec_mask: &mut [f32],
) -> Result<()> {
    debug_assert_eq!(x.len(), encs.len() * n * F);
    debug_assert_eq!(adj.len(), encs.len() * n * n);
    debug_assert_eq!(jobmat.len(), encs.len() * j * n);
    for (i, enc) in encs.iter().enumerate() {
        if enc.variant.n != n || enc.variant.j != j {
            bail!(
                "transition encoded at variant N={} J={}, train_step wants N={n} J={j} \
                 (train with workloads that fit the training variant)",
                enc.variant.n,
                enc.variant.j
            );
        }
        x[i * n * F..(i + 1) * n * F].copy_from_slice(&enc.x);
        enc.write_dense_adj(&mut adj[i * n * n..(i + 1) * n * n]);
        enc.write_dense_jobmat(&mut jobmat[i * j * n..(i + 1) * j * n]);
        node_mask[i * n..(i + 1) * n].copy_from_slice(&enc.node_mask);
        exec_mask[i * n..(i + 1) * n].copy_from_slice(&enc.exec_mask);
    }
    Ok(())
}

/// Reusable buffers for [`RustPolicy::forward_batch`] (sized lazily per
/// packed batch; `Vec::resize` keeps capacity, so steady-state training
/// batches stop allocating after warmup).
#[derive(Default)]
pub(crate) struct BatchScratch {
    pub e0: Vec<f32>,
    pub e: Vec<f32>,
    pub agg: Vec<f32>,
    pub h: Vec<f32>,
    pub msg: Vec<f32>,
    pub jobsum: Vec<f32>,
    pub jh: Vec<f32>,
    pub y: Vec<f32>,
    pub gsum: Vec<f32>,
    pub gh: Vec<f32>,
    pub z: Vec<f32>,
    pub cat: Vec<f32>,
    pub q_h1: Vec<f32>,
    pub q_h2: Vec<f32>,
    pub q_h3: Vec<f32>,
    pub logits: Vec<f32>,
    pub vh1: Vec<f32>,
    pub vh2: Vec<f32>,
    pub vout: Vec<f32>,
}

impl BatchScratch {
    pub(crate) fn ensure(&mut self, m: usize, jobs: usize, b: usize) {
        self.e0.resize(m * E, 0.0);
        self.e.resize(m * E, 0.0);
        self.agg.resize(m * E, 0.0);
        self.h.resize(m * H, 0.0);
        self.msg.resize(m * E, 0.0);
        self.jobsum.resize(jobs * E, 0.0);
        self.jh.resize(jobs * H, 0.0);
        self.y.resize(jobs * E, 0.0);
        self.gsum.resize(b * E, 0.0);
        self.gh.resize(b * H, 0.0);
        self.z.resize(b * E, 0.0);
        self.cat.resize(m * 3 * E, 0.0);
        self.q_h1.resize(m * Q1, 0.0);
        self.q_h2.resize(m * Q2, 0.0);
        self.q_h3.resize(m * Q3, 0.0);
        self.logits.resize(m, 0.0);
        self.vh1.resize(b * V1, 0.0);
        self.vh2.resize(b * V2, 0.0);
        self.vout.resize(b, 0.0);
    }
}

impl RustPolicy {
    /// Batched forward pass over a [`PackedBatch`]. Writes the M packed
    /// per-slot logits into `logits` (state `b`'s segment is
    /// `batch.state_rows(&logits, b)`, in its own slot order — only used
    /// slots, no padding) and the B critic values into `values`.
    /// Per-row accumulation order matches [`RustPolicy::forward_into`]
    /// exactly, so outputs agree with per-state forwards bitwise.
    pub fn forward_batch(
        &mut self,
        batch: &PackedBatch,
        logits: &mut Vec<f32>,
        values: &mut Vec<f32>,
    ) {
        let m = batch.n_rows();
        let jobs = batch.n_job_rows();
        let b = batch.n_states;
        let mut s = std::mem::take(&mut self.batch_scratch);
        s.ensure(m, jobs, b);

        // e0 = tanh(x·W_in + b_in). Every packed row is a real slot
        // (node_mask ≡ 1 on used slots), so no masking is needed.
        dense(&batch.x, self.p("w_in"), self.p("b_in"), &mut s.e0, m, F, E, true);
        s.e[..m * E].copy_from_slice(&s.e0[..m * E]);

        // K message-passing iterations over the block CSR — one shared
        // loop for the whole batch; cross-state edges cannot exist by
        // construction (column indices are rebased per state block).
        for _ in 0..K {
            s.agg[..m * E].fill(0.0);
            for i in 0..m {
                let lo = batch.row_offsets[i] as usize;
                let hi = batch.row_offsets[i + 1] as usize;
                for &c in &batch.col_indices[lo..hi] {
                    let c = c as usize;
                    let erow = &s.e[c * E..(c + 1) * E];
                    let arow = &mut s.agg[i * E..(i + 1) * E];
                    for (o, &ev) in arow.iter_mut().zip(erow) {
                        *o += ev;
                    }
                }
            }
            dense(&s.agg, self.p("g1"), self.p("bg1"), &mut s.h, m, E, H, true);
            dense(&s.h, self.p("g2"), self.p("bg2"), &mut s.msg, m, H, E, true);
            for d in 0..m * E {
                s.e[d] = s.msg[d] + s.e0[d];
            }
        }

        // Per-job summaries over global job rows (all occupied — empty
        // job slots never enter the packing, so no zeroing either).
        s.jobsum[..jobs * E].fill(0.0);
        for (i, &js) in batch.slot_job.iter().enumerate() {
            let js = js as usize;
            let erow = &s.e[i * E..(i + 1) * E];
            let jrow = &mut s.jobsum[js * E..(js + 1) * E];
            for (o, &ev) in jrow.iter_mut().zip(erow) {
                *o += ev;
            }
        }
        dense(&s.jobsum, self.p("fj1"), self.p("bfj1"), &mut s.jh, jobs, E, H, true);
        dense(&s.jh, self.p("fj2"), self.p("bfj2"), &mut s.y, jobs, H, E, true);

        // Global summaries: one z row per state from its job segment.
        s.gsum[..b * E].fill(0.0);
        for bi in 0..b {
            let grow = &mut s.gsum[bi * E..(bi + 1) * E];
            for j in batch.job_base[bi]..batch.job_base[bi + 1] {
                let yrow = &s.y[j * E..(j + 1) * E];
                for (o, &yv) in grow.iter_mut().zip(yrow) {
                    *o += yv;
                }
            }
        }
        dense(&s.gsum, self.p("fg1"), self.p("bfg1"), &mut s.gh, b, E, H, true);
        dense(&s.gh, self.p("fg2"), self.p("bfg2"), &mut s.z, b, H, E, true);

        // Per-node score input [e_i ; y_job(i) ; z_state(i)].
        for bi in 0..b {
            let zrow = &s.z[bi * E..(bi + 1) * E];
            for i in batch.row_base[bi]..batch.row_base[bi + 1] {
                let js = batch.slot_job[i] as usize;
                let cat = &mut s.cat[i * 3 * E..(i + 1) * 3 * E];
                cat[..E].copy_from_slice(&s.e[i * E..(i + 1) * E]);
                cat[E..2 * E].copy_from_slice(&s.y[js * E..(js + 1) * E]);
                cat[2 * E..].copy_from_slice(zrow);
            }
        }
        dense(&s.cat, self.p("q1"), self.p("bq1"), &mut s.q_h1, m, 3 * E, Q1, true);
        dense(&s.q_h1, self.p("q2"), self.p("bq2"), &mut s.q_h2, m, Q1, Q2, true);
        dense(&s.q_h2, self.p("q3"), self.p("bq3"), &mut s.q_h3, m, Q2, Q3, true);
        dense(&s.q_h3, self.p("q4"), self.p("bq4"), &mut s.logits, m, Q3, 1, false);

        // Value head, batched over the B z rows.
        dense(&s.z, self.p("v1"), self.p("bv1"), &mut s.vh1, b, E, V1, true);
        dense(&s.vh1, self.p("v2"), self.p("bv2"), &mut s.vh2, b, V1, V2, true);
        dense(&s.vh2, self.p("v3"), self.p("bv3"), &mut s.vout, b, V2, 1, false);

        logits.clear();
        logits.extend_from_slice(&s.logits[..m]);
        values.clear();
        values.extend_from_slice(&s.vout[..b]);
        self.batch_scratch = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::WorkloadConfig;
    use crate::policy::encode::encode;
    use crate::policy::features::FeatureMode;
    use crate::sim::SimState;
    use crate::workload::WorkloadGenerator;

    fn enc(n_jobs: usize, seed: u64) -> EncodedState {
        let cluster = Cluster::homogeneous(4, 2.5, 100.0);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(n_jobs), seed).generate();
        let mut st = SimState::new(cluster, w);
        for j in 0..n_jobs {
            st.mark_arrived(j);
        }
        encode(&st, FeatureMode::Full)
    }

    #[test]
    fn pack_shape_invariants() {
        let encs = [enc(2, 1), enc(3, 2), enc(1, 3)];
        let refs: Vec<&EncodedState> = encs.iter().collect();
        let p = PackedBatch::pack(&refs);
        assert_eq!(p.n_states, 3);
        let m: usize = encs.iter().map(|e| e.n_used()).sum();
        assert_eq!(p.n_rows(), m);
        assert_eq!(p.x.len(), m * F);
        assert_eq!(p.row_offsets.len(), m + 1);
        assert_eq!(p.slot_job.len(), m);
        assert_eq!(p.exec_mask.len(), m);
        assert_eq!(p.row_base, {
            let mut rb = vec![0usize];
            for e in &encs {
                rb.push(rb.last().unwrap() + e.n_used());
            }
            rb
        });
        // Every CSR column stays inside its owning state's block.
        for b in 0..3 {
            for i in p.row_base[b]..p.row_base[b + 1] {
                let lo = p.row_offsets[i] as usize;
                let hi = p.row_offsets[i + 1] as usize;
                for &c in &p.col_indices[lo..hi] {
                    assert!((c as usize) >= p.row_base[b] && (c as usize) < p.row_base[b + 1]);
                }
            }
        }
    }

    #[test]
    fn forward_batch_matches_forward_into() {
        let encs = [enc(2, 5), enc(3, 6), enc(2, 7)];
        let refs: Vec<&EncodedState> = encs.iter().collect();
        let batch = PackedBatch::pack(&refs);
        let mut net = RustPolicy::random(42);
        let (mut blogits, mut bvalues) = (Vec::new(), Vec::new());
        net.forward_batch(&batch, &mut blogits, &mut bvalues);
        assert_eq!(bvalues.len(), 3);
        let mut single = Vec::new();
        for (b, e) in encs.iter().enumerate() {
            let v = net.forward_into(e, &mut single);
            assert!(
                (v - bvalues[b]).abs() <= 1e-5,
                "state {b} value {v} vs batched {}",
                bvalues[b]
            );
            let seg = batch.state_rows(&blogits, b);
            assert_eq!(seg.len(), e.n_used());
            for (i, (&bl, &sl)) in seg.iter().zip(single.iter()).enumerate() {
                assert!((bl - sl).abs() <= 1e-5, "state {b} slot {i}: {bl} vs {sl}");
            }
        }
    }

    #[test]
    fn mixed_variant_batch_works() {
        let small = enc(2, 8); // N=64 variant
        let big = enc(12, 9); // N=256 variant
        assert_ne!(small.variant.n, big.variant.n);
        let refs = [&small, &big];
        let batch = PackedBatch::pack(&refs);
        let mut net = RustPolicy::random(4);
        let (mut l, mut v) = (Vec::new(), Vec::new());
        net.forward_batch(&batch, &mut l, &mut v);
        assert_eq!(v.len(), 2);
        let mut single = Vec::new();
        for (b, e) in refs.iter().enumerate() {
            let sv = net.forward_into(e, &mut single);
            assert!((sv - v[b]).abs() <= 1e-5);
            for (i, (&bl, &sl)) in batch.state_rows(&l, b).iter().zip(&single).enumerate() {
                assert!((bl - sl).abs() <= 1e-5, "state {b} slot {i}");
            }
        }
    }

    #[test]
    fn empty_batch_and_scratch_reuse() {
        let mut net = RustPolicy::random(5);
        let (mut l, mut v) = (Vec::new(), Vec::new());
        net.forward_batch(&PackedBatch::pack(&[]), &mut l, &mut v);
        assert!(l.is_empty() && v.is_empty());
        // A big batch then a small one: stale buffer tails must not leak.
        let encs = [enc(3, 10), enc(3, 11)];
        let refs: Vec<&EncodedState> = encs.iter().collect();
        net.forward_batch(&PackedBatch::pack(&refs), &mut l, &mut v);
        let both = (l.clone(), v.clone());
        let one = PackedBatch::pack(&refs[..1]);
        net.forward_batch(&one, &mut l, &mut v);
        assert_eq!(v[0], both.1[0]);
        assert_eq!(l[..one.n_rows()], both.0[..one.n_rows()]);
    }

    #[test]
    fn write_dense_batch_matches_row_writers() {
        let encs = [enc(2, 12), enc(2, 13)];
        let refs: Vec<&EncodedState> = encs.iter().collect();
        let (n, j) = (encs[0].variant.n, encs[0].variant.j);
        let b = refs.len();
        let mut x = vec![0.0; b * n * F];
        let mut adj = vec![0.0; b * n * n];
        let mut jobmat = vec![0.0; b * j * n];
        let mut nm = vec![0.0; b * n];
        let mut em = vec![0.0; b * n];
        write_dense_batch(&refs, n, j, &mut x, &mut adj, &mut jobmat, &mut nm, &mut em)
            .unwrap();
        for (i, e) in encs.iter().enumerate() {
            assert_eq!(x[i * n * F..(i + 1) * n * F], e.x[..]);
            assert_eq!(adj[i * n * n..(i + 1) * n * n], e.dense_adj()[..]);
            assert_eq!(jobmat[i * j * n..(i + 1) * j * n], e.dense_jobmat()[..]);
            assert_eq!(nm[i * n..(i + 1) * n], e.node_mask[..]);
            assert_eq!(em[i * n..(i + 1) * n], e.exec_mask[..]);
        }
        // Variant mismatch is rejected.
        let big = enc(12, 14);
        assert!(write_dense_batch(
            &[&big],
            n,
            j,
            &mut x[..n * F],
            &mut adj[..n * n],
            &mut jobmat[..j * n],
            &mut nm[..n],
            &mut em[..n],
        )
        .is_err());
    }
}
