//! The learned node-selection policy (paper §4.1): feature extraction,
//! fixed-shape state encoding for the AOT-compiled network, a pure-rust
//! reference implementation of the MGNet forward pass, an incremental
//! per-episode encoder cache, and parameter I/O.
//!
//! Network architecture (mirrored exactly by `python/compile/model.py` —
//! the flat parameter layout is defined once in [`net::LAYOUT`] and
//! asserted equal to the python side's `meta.json` at artifact load):
//!
//! ```text
//! x[N,F] ──W_in──▶ e0[N,E]
//! repeat K:  e ← g2(tanh(g1(A·e))) + e0          (Eq 5, shared params)
//! y[J,E] = f(Σ_{n∈job} e_n)                      (per-job summary)
//! z[E]   = f(Σ_j y_j)                            (global summary)
//! q_n    = MLP([e_n ; y_job(n) ; z]) → score      (Eq 8 softmax outside)
//! v      = MLP(z) → scalar value (critic baseline)
//! ```
//!
//! The serving hot path is sparse and incremental: `A` lives as a CSR
//! edge list inside [`EncodedState`] (the rust forward never touches an
//! N×N matrix), and [`EncoderCache`] patches the previous decision's
//! encoding instead of re-featurizing the whole state. The dense tensors
//! remain producible on demand ([`EncodedState::dense_adj`] /
//! [`EncodedState::dense_jobmat`]) for the PJRT artifact and the
//! dense-oracle cross-validation tests.

pub mod batch;
pub mod cache;
pub mod encode;
pub mod features;
pub mod net;
pub mod params;

pub use batch::PackedBatch;
pub use cache::EncoderCache;
pub use encode::{EncodedState, ShapeVariant};
pub use features::{FeatureMode, NODE_FEATURES};
pub use net::RustPolicy;

use anyhow::Result;

/// Number of raw node features F.
pub const F: usize = NODE_FEATURES;
/// Embedding width E.
pub const E: usize = 16;
/// Hidden width H of the g/f MLPs.
pub const H: usize = 32;
/// Message-passing iterations K (the paper's three-layer MGNet).
pub const K: usize = 3;
/// Policy head hidden sizes (paper §5.1: 32/16/8).
pub const Q1: usize = 32;
pub const Q2: usize = 16;
pub const Q3: usize = 8;
/// Value head hidden sizes.
pub const V1: usize = 32;
pub const V2: usize = 16;

/// Anything that can score an encoded state: the pure-rust forward or the
/// PJRT-loaded AOT artifact ([`crate::runtime::PjrtPolicy`]).
pub trait PolicyEval: Send {
    /// Write the per-slot logits into `logits` (cleared and refilled to
    /// the variant's N; padding slots get arbitrary values — mask before
    /// use) and return the critic's value estimate. Implementations
    /// should reuse internal buffers so the serving hot path stays
    /// allocation-free.
    fn logits_value_into(&mut self, enc: &EncodedState, logits: &mut Vec<f32>) -> Result<f32>;

    /// Convenience wrapper allocating fresh logits (tests, one-shots).
    fn logits_value(&mut self, enc: &EncodedState) -> Result<(Vec<f32>, f32)> {
        let mut logits = Vec::new();
        let value = self.logits_value_into(enc, &mut logits)?;
        Ok((logits, value))
    }

    fn backend_name(&self) -> &'static str;
}

/// A boxed policy evaluator plus sampling behaviour — what the Lachesis
/// scheduler owns. Keeps reusable logits/mask buffers so per-decision
/// evaluation does not allocate.
pub struct PolicyNet {
    pub eval: Box<dyn PolicyEval>,
    logits: Vec<f32>,
    mask: Vec<bool>,
}

impl PolicyNet {
    pub fn new(eval: Box<dyn PolicyEval>) -> PolicyNet {
        PolicyNet {
            eval,
            logits: Vec::new(),
            mask: Vec::new(),
        }
    }

    /// Greedy argmax over executable slots.
    pub fn argmax(&mut self, enc: &EncodedState) -> Result<Option<usize>> {
        self.eval.logits_value_into(enc, &mut self.logits)?;
        let mut best: Option<(f32, usize)> = None;
        for i in 0..enc.variant.n {
            if enc.exec_mask[i] == 0.0 {
                continue;
            }
            if best.map(|(b, _)| self.logits[i] > b).unwrap_or(true) {
                best = Some((self.logits[i], i));
            }
        }
        Ok(best.map(|(_, i)| i))
    }

    /// Softmax-sample over executable slots (exploration during training).
    pub fn sample(
        &mut self,
        enc: &EncodedState,
        rng: &mut crate::util::rng::Rng,
        temperature: f64,
    ) -> Result<Option<(usize, f32)>> {
        let value = self.eval.logits_value_into(enc, &mut self.logits)?;
        self.mask.clear();
        self.mask.extend(enc.exec_mask.iter().map(|&m| m > 0.0));
        if !self.mask.iter().any(|&m| m) {
            return Ok(None);
        }
        let n = enc.variant.n;
        let slot = rng.softmax_sample(&self.logits[..n], &self.mask[..n], temperature);
        Ok(Some((slot, value)))
    }
}
