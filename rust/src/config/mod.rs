//! Typed configuration system with JSON load/save.
//!
//! Every experiment in the harness is fully described by a config +
//! seed, so runs are reproducible from the command line or from a JSON
//! file (`lachesis ... --config exp.json`). Defaults mirror the paper's
//! settings (50 executors, Intel 2.1–3.6 GHz frequency table, TPC-H
//! workloads at 2/5/10/50/80/100 GB, Poisson arrivals with 45 s mean).

use crate::net::NetConfig;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// How executor time is booked when a task is placed.
///
/// `Append` reproduces the paper's timing equations exactly: each executor
/// is a single growing tail and tasks queue behind it (Eq 2–3). `GapAware`
/// additionally lets the allocator backfill a task into an earlier idle
/// window of the executor timeline when the task (and its data) fit — the
/// insertion-based HEFT variant, opening the backfilling scenario family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedMode {
    /// Paper-faithful append-only executor timelines (the default).
    #[default]
    Append,
    /// Insertion-based booking into the earliest feasible idle gap.
    GapAware,
}

impl SchedMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            SchedMode::Append => "append",
            SchedMode::GapAware => "gap",
        }
    }
}

/// How jobs arrive at the system (paper §5.3.2 vs §5.3.3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// All jobs present at t = 0 ("batch mode").
    Batch,
    /// First job at t = 0, subsequent inter-arrival times are exponential
    /// with the given mean in seconds ("continuous mode", paper uses 45 s).
    Poisson { mean_interval: f64 },
}

/// Heterogeneous cluster description (paper §5.2).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of executors (paper: 50).
    pub n_executors: usize,
    /// Executor speed table in GHz; speeds are sampled uniformly from this
    /// grid (paper: Intel CPU frequencies 2.1–3.6 GHz).
    pub freq_table: Vec<f64>,
    /// Base data transmission speed between distinct executors, MB/s
    /// (the uniform speed under the paper's `flat` topology; the
    /// reference link rate other topologies scale from).
    pub comm_mbps: f64,
    /// Executor-time booking mode (append-compat vs gap-aware insertion).
    pub sched_mode: SchedMode,
    /// Network topology (`flat` | `tree:RxW` | `fat-tree:K`); `flat`
    /// reproduces the paper's scalar comm model bit-identically.
    pub net: NetConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        // 2.1, 2.2, ..., 3.6 GHz
        let freq_table = (0..=15).map(|i| 2.1 + 0.1 * i as f64).collect();
        ClusterConfig {
            n_executors: 50,
            freq_table,
            comm_mbps: 100.0,
            sched_mode: SchedMode::Append,
            net: NetConfig::flat(),
        }
    }
}

impl ClusterConfig {
    pub fn with_executors(n: usize) -> Self {
        ClusterConfig {
            n_executors: n,
            ..Default::default()
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_executors == 0 {
            bail!("cluster must have at least one executor");
        }
        if self.freq_table.is_empty() {
            bail!("frequency table is empty");
        }
        if self.freq_table.iter().any(|&f| f <= 0.0) {
            bail!("executor frequencies must be positive");
        }
        if self.comm_mbps <= 0.0 {
            bail!("communication speed must be positive");
        }
        self.net.validate(self.n_executors)?;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("n_executors", Json::from(self.n_executors)),
            ("freq_table", Json::from(self.freq_table.clone())),
            ("comm_mbps", Json::from(self.comm_mbps)),
            ("sched_mode", Json::from(self.sched_mode.as_str())),
            (
                "net",
                Json::from_pairs(vec![
                    ("topology", Json::from(self.net.topology_str())),
                    ("rack_mult", Json::from(self.net.rack_mult)),
                    ("oversub", Json::from(self.net.oversub)),
                    ("hop_latency", Json::from(self.net.hop_latency)),
                ]),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let freq_table = v
            .req("freq_table")?
            .as_arr()
            .ok_or_else(|| anyhow!("freq_table must be an array"))?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow!("bad frequency")))
            .collect::<Result<Vec<_>>>()?;
        // Absent in pre-gap-aware configs: default to the paper-faithful
        // append mode so old experiment files stay reproducible.
        let sched_mode = match v.get("sched_mode").and_then(Json::as_str) {
            None | Some("append") => SchedMode::Append,
            Some("gap") | Some("gap_aware") => SchedMode::GapAware,
            Some(other) => bail!("unknown sched_mode '{other}' (append|gap)"),
        };
        // Absent in pre-topology configs: default to the paper's flat
        // (scalar) network so old experiment files stay reproducible.
        // Accepted as either a bare topology string ("tree:4x8") or an
        // object with explicit knobs.
        let net = match v.get("net") {
            None => NetConfig::flat(),
            Some(Json::Str(s)) => NetConfig::parse(s)?,
            Some(obj) => {
                let mut net = NetConfig::parse(obj.req_str("topology")?)?;
                if let Some(x) = obj.get("rack_mult").and_then(Json::as_f64) {
                    net.rack_mult = x;
                }
                if let Some(x) = obj.get("oversub").and_then(Json::as_f64) {
                    net.oversub = x;
                }
                if let Some(x) = obj.get("hop_latency").and_then(Json::as_f64) {
                    net.hop_latency = x;
                }
                net
            }
        };
        let cfg = ClusterConfig {
            n_executors: v.req_usize("n_executors")?,
            freq_table,
            comm_mbps: v.req_f64("comm_mbps")?,
            sched_mode,
            net,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Workload description (paper §5.2: TPC-H, 22 shapes × 6 sizes).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Number of jobs to generate.
    pub n_jobs: usize,
    /// TPC-H scale factors in GB to sample from (paper: 2,5,10,50,80,100).
    pub sizes_gb: Vec<f64>,
    /// Arrival process.
    pub arrival: Arrival,
    /// Restrict to a subset of the 22 query shapes (1-based ids); empty
    /// means all 22.
    pub query_ids: Vec<usize>,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            n_jobs: 10,
            sizes_gb: vec![2.0, 5.0, 10.0, 50.0, 80.0, 100.0],
            arrival: Arrival::Batch,
            query_ids: Vec::new(),
        }
    }
}

impl WorkloadConfig {
    /// Paper's small-scale batch experiments (Fig 5): 1–20 jobs at t=0.
    pub fn small_batch(n_jobs: usize) -> Self {
        WorkloadConfig {
            n_jobs,
            sizes_gb: vec![2.0, 5.0, 10.0],
            ..Default::default()
        }
    }

    /// Paper's large-scale batch experiments (Fig 6): bigger jobs.
    pub fn large_batch(n_jobs: usize) -> Self {
        WorkloadConfig {
            n_jobs,
            sizes_gb: vec![50.0, 80.0, 100.0],
            ..Default::default()
        }
    }

    /// Paper's continuous mode (Fig 7): Poisson arrivals, mean 45 s.
    pub fn continuous(n_jobs: usize) -> Self {
        WorkloadConfig {
            n_jobs,
            arrival: Arrival::Poisson {
                mean_interval: 45.0,
            },
            ..Default::default()
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_jobs == 0 {
            bail!("workload must contain at least one job");
        }
        if self.sizes_gb.is_empty() || self.sizes_gb.iter().any(|&s| s <= 0.0) {
            bail!("sizes_gb must be non-empty and positive");
        }
        if let Arrival::Poisson { mean_interval } = self.arrival {
            if mean_interval <= 0.0 {
                bail!("mean_interval must be positive");
            }
        }
        for &q in &self.query_ids {
            if q == 0 || q > 22 {
                bail!("query_ids must be in 1..=22, got {q}");
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let arrival = match self.arrival {
            Arrival::Batch => Json::from_pairs(vec![("mode", Json::from("batch"))]),
            Arrival::Poisson { mean_interval } => Json::from_pairs(vec![
                ("mode", Json::from("poisson")),
                ("mean_interval", Json::from(mean_interval)),
            ]),
        };
        Json::from_pairs(vec![
            ("n_jobs", Json::from(self.n_jobs)),
            ("sizes_gb", Json::from(self.sizes_gb.clone())),
            ("arrival", arrival),
            ("query_ids", Json::from(self.query_ids.clone())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let arr = v.req("arrival")?;
        let arrival = match arr.req_str("mode")? {
            "batch" => Arrival::Batch,
            "poisson" => Arrival::Poisson {
                mean_interval: arr.req_f64("mean_interval")?,
            },
            other => bail!("unknown arrival mode '{other}'"),
        };
        let sizes_gb = v
            .req("sizes_gb")?
            .as_arr()
            .ok_or_else(|| anyhow!("sizes_gb must be an array"))?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow!("bad size")))
            .collect::<Result<Vec<_>>>()?;
        let query_ids = match v.get("query_ids") {
            Some(Json::Arr(xs)) => xs
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad query id")))
                .collect::<Result<Vec<_>>>()?,
            _ => Vec::new(),
        };
        let cfg = WorkloadConfig {
            n_jobs: v.req_usize("n_jobs")?,
            sizes_gb,
            arrival,
            query_ids,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Fault-injection knobs (see `rust/src/fault/`). A `(FaultConfig, seed,
/// n_executors)` triple fully determines a [`crate::fault::FaultPlan`],
/// so fault runs are exactly as reproducible as fault-free ones.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Per-executor incident rate (incidents per simulated second,
    /// exponential inter-incident times). `0.0` disables faults entirely
    /// — the plan is empty and schedules are bit-identical to a run with
    /// no plan at all.
    pub crash_rate: f64,
    /// Mean time to recovery for transient crashes, seconds
    /// (exponential outage durations).
    pub mttr: f64,
    /// Probability a crash is permanent (the executor never recovers).
    pub p_permanent: f64,
    /// Probability an incident is a straggle rather than a crash.
    pub straggler_prob: f64,
    /// Straggle stretch factor (> 1): in-flight work on the executor
    /// takes `slowdown ×` its remaining time.
    pub slowdown: f64,
    /// Incidents are pre-generated over `[0, horizon]` simulated seconds;
    /// a schedule extending past the horizon sees no further faults.
    pub horizon: f64,
    /// Per-rack correlated-failure rate (incidents per simulated second
    /// per rack): each incident downs *every* executor in the rack at
    /// once (ToR switch / PDU failure). `0.0` (the default) disables the
    /// mode and keeps plans bit-identical to pre-topology ones. Rack
    /// incidents are always transient — a permanent whole-rack loss
    /// would leave single-rack topologies unschedulable.
    pub rack_rate: f64,
}

impl Default for FaultConfig {
    /// Defaults describe a *moderately* unreliable cluster; use
    /// [`FaultConfig::none`] for the reliable baseline.
    fn default() -> Self {
        FaultConfig {
            crash_rate: 1e-3,
            mttr: 30.0,
            p_permanent: 0.1,
            straggler_prob: 0.25,
            slowdown: 3.0,
            horizon: 10_000.0,
            rack_rate: 0.0,
        }
    }
}

impl FaultConfig {
    /// The reliable cluster: no incidents, empty plan, schedules
    /// bit-identical to a simulator with no fault plan attached.
    pub fn none() -> Self {
        FaultConfig {
            crash_rate: 0.0,
            ..Default::default()
        }
    }

    /// A config differing from the defaults only in the incident rate —
    /// the x-axis of the robustness sweep.
    pub fn with_rate(crash_rate: f64) -> Self {
        FaultConfig {
            crash_rate,
            ..Default::default()
        }
    }

    /// True when the plan this config generates is always empty.
    pub fn is_none(&self) -> bool {
        self.crash_rate <= 0.0 && self.rack_rate <= 0.0
    }

    pub fn validate(&self) -> Result<()> {
        if !self.crash_rate.is_finite() || self.crash_rate < 0.0 {
            bail!("crash_rate must be finite and non-negative");
        }
        if self.mttr <= 0.0 || !self.mttr.is_finite() {
            bail!("mttr must be positive and finite");
        }
        if !(0.0..=1.0).contains(&self.p_permanent) {
            bail!("p_permanent must be in [0, 1]");
        }
        if !(0.0..=1.0).contains(&self.straggler_prob) {
            bail!("straggler_prob must be in [0, 1]");
        }
        if self.slowdown < 1.0 || !self.slowdown.is_finite() {
            bail!("slowdown must be a finite factor >= 1");
        }
        if self.horizon <= 0.0 || !self.horizon.is_finite() {
            bail!("horizon must be positive and finite");
        }
        if !self.rack_rate.is_finite() || self.rack_rate < 0.0 {
            bail!("rack_rate must be finite and non-negative");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("crash_rate", Json::from(self.crash_rate)),
            ("mttr", Json::from(self.mttr)),
            ("p_permanent", Json::from(self.p_permanent)),
            ("straggler_prob", Json::from(self.straggler_prob)),
            ("slowdown", Json::from(self.slowdown)),
            ("horizon", Json::from(self.horizon)),
            ("rack_rate", Json::from(self.rack_rate)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let cfg = FaultConfig {
            crash_rate: v.req_f64("crash_rate")?,
            mttr: v.req_f64("mttr")?,
            p_permanent: v.req_f64("p_permanent")?,
            straggler_prob: v.req_f64("straggler_prob")?,
            slowdown: v.req_f64("slowdown")?,
            horizon: v.req_f64("horizon")?,
            // Absent in pre-topology fault configs.
            rack_rate: v.req_f64("rack_rate").unwrap_or(0.0),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// RL training configuration (paper §4.3 / Appendix C).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Number of training episodes (paper converges by ~800).
    pub episodes: usize,
    /// Parallel reward-collection agents (paper: 8).
    pub agents: usize,
    /// Discount factor for returns.
    pub gamma: f64,
    /// Initial curriculum episode-length mean τ_mean (Algorithm 2 line 4).
    pub tau_mean0: f64,
    /// Curriculum growth ε per iteration (Algorithm 2 line 14).
    pub tau_eps: f64,
    /// Softmax sampling temperature during exploration.
    pub temperature: f64,
    /// Jobs per training episode.
    pub jobs_per_episode: usize,
    /// Executors in the training cluster.
    pub executors: usize,
    /// Imitation warm-start epochs toward HEFT's choices before RL
    /// fine-tuning (0 disables; our addition — see DESIGN.md).
    pub imitation_epochs: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Worker threads for rollout actors and evaluation (0 = all cores).
    /// The training trajectory is identical for every value.
    pub threads: usize,
    /// Append one JSON line of telemetry per episode (loss, entropy,
    /// reward, rollout/update wall time) to this path. `None` disables.
    /// Monitoring only — never read back, never affects the trajectory.
    pub metrics_jsonl: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            episodes: 400,
            agents: 8,
            gamma: 0.99,
            tau_mean0: 50.0,
            tau_eps: 2.0,
            temperature: 1.0,
            jobs_per_episode: 4,
            executors: 10,
            imitation_epochs: 2,
            seed: 20210001,
            threads: 0,
            metrics_jsonl: None,
        }
    }
}

impl TrainConfig {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("episodes", Json::from(self.episodes)),
            ("agents", Json::from(self.agents)),
            ("gamma", Json::from(self.gamma)),
            ("tau_mean0", Json::from(self.tau_mean0)),
            ("tau_eps", Json::from(self.tau_eps)),
            ("temperature", Json::from(self.temperature)),
            ("jobs_per_episode", Json::from(self.jobs_per_episode)),
            ("executors", Json::from(self.executors)),
            ("imitation_epochs", Json::from(self.imitation_epochs)),
            ("seed", Json::from(self.seed)),
            ("threads", Json::from(self.threads)),
            (
                "metrics_jsonl",
                match &self.metrics_jsonl {
                    Some(p) => Json::from(p.as_str()),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(TrainConfig {
            episodes: v.req_usize("episodes")?,
            agents: v.req_usize("agents")?,
            gamma: v.req_f64("gamma")?,
            tau_mean0: v.req_f64("tau_mean0")?,
            tau_eps: v.req_f64("tau_eps")?,
            temperature: v.req_f64("temperature")?,
            jobs_per_episode: v.req_usize("jobs_per_episode")?,
            executors: v.req_usize("executors")?,
            imitation_epochs: v.req_usize("imitation_epochs")?,
            seed: v.req("seed")?.as_u64().context("seed")?,
            // Absent in configs written before the threaded engine.
            threads: v.req_usize("threads").unwrap_or(0),
            // Absent in configs written before the telemetry subsystem.
            metrics_jsonl: v
                .get("metrics_jsonl")
                .and_then(Json::as_str)
                .map(str::to_string),
        })
    }
}

/// One experiment sweep (a figure panel): job counts × seeds × algorithms.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub cluster: ClusterConfig,
    pub workload_base: WorkloadConfig,
    /// Sweep over these job counts (x-axis of Figs 5–7).
    pub job_counts: Vec<usize>,
    /// Independent workload seeds per point (paper: 10).
    pub seeds: Vec<u64>,
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("cluster", self.cluster.to_json()),
            ("workload_base", self.workload_base.to_json()),
            ("job_counts", Json::from(self.job_counts.clone())),
            (
                "seeds",
                Json::Arr(self.seeds.iter().map(|&s| Json::from(s)).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let job_counts = v
            .req("job_counts")?
            .as_arr()
            .ok_or_else(|| anyhow!("job_counts must be an array"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad job count")))
            .collect::<Result<Vec<_>>>()?;
        let seeds = v
            .req("seeds")?
            .as_arr()
            .ok_or_else(|| anyhow!("seeds must be an array"))?
            .iter()
            .map(|x| x.as_u64().ok_or_else(|| anyhow!("bad seed")))
            .collect::<Result<Vec<_>>>()?;
        Ok(ExperimentConfig {
            cluster: ClusterConfig::from_json(v.req("cluster")?)?,
            workload_base: WorkloadConfig::from_json(v.req("workload_base")?)?,
            job_counts,
            seeds,
        })
    }

    /// Load from a JSON file.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        Self::from_json(&v)
    }

    /// Save to a JSON file (pretty).
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing {path}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_roundtrip() {
        let c = ClusterConfig::default();
        let j = c.to_json();
        let c2 = ClusterConfig::from_json(&j).unwrap();
        assert_eq!(c2.n_executors, 50);
        assert_eq!(c2.freq_table.len(), 16);
        assert!((c2.freq_table[0] - 2.1).abs() < 1e-9);
        assert!((c2.freq_table[15] - 3.6).abs() < 1e-9);
        assert_eq!(c2.sched_mode, SchedMode::Append);
    }

    #[test]
    fn sched_mode_roundtrip_and_default() {
        let mut c = ClusterConfig::with_executors(4);
        c.sched_mode = SchedMode::GapAware;
        let c2 = ClusterConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.sched_mode, SchedMode::GapAware);
        // Pre-gap-aware config files (no sched_mode key) default to append.
        let legacy = Json::from_pairs(vec![
            ("n_executors", Json::from(2usize)),
            ("freq_table", Json::from(vec![2.0])),
            ("comm_mbps", Json::from(10.0)),
        ]);
        let c3 = ClusterConfig::from_json(&legacy).unwrap();
        assert_eq!(c3.sched_mode, SchedMode::Append);
    }

    #[test]
    fn net_roundtrip_and_legacy_default() {
        use crate::net::NetTopology;
        let mut c = ClusterConfig::with_executors(8);
        c.net = NetConfig::tree(2, 4);
        c.net.oversub = 3.0;
        let c2 = ClusterConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.net, c.net);
        // Pre-topology config files (no net key) default to flat.
        let legacy = Json::from_pairs(vec![
            ("n_executors", Json::from(2usize)),
            ("freq_table", Json::from(vec![2.0])),
            ("comm_mbps", Json::from(10.0)),
        ]);
        assert!(ClusterConfig::from_json(&legacy).unwrap().net.is_flat());
        // A bare topology string is accepted for hand-written configs.
        let terse = Json::from_pairs(vec![
            ("n_executors", Json::from(8usize)),
            ("freq_table", Json::from(vec![2.0])),
            ("comm_mbps", Json::from(10.0)),
            ("net", Json::from("fat-tree:4")),
        ]);
        let c3 = ClusterConfig::from_json(&terse).unwrap();
        assert_eq!(c3.net.topology, NetTopology::FatTree { k: 4 });
        // Over-capacity topologies are rejected by validate().
        let mut bad = ClusterConfig::with_executors(9);
        bad.net = NetConfig::tree(2, 4);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn fault_rack_rate_roundtrip_and_legacy() {
        let mut f = FaultConfig::none();
        f.rack_rate = 2e-3;
        assert!(!f.is_none(), "rack-only faults still produce a plan");
        let f2 = FaultConfig::from_json(&f.to_json()).unwrap();
        assert_eq!(f, f2);
        // Pre-topology fault JSON (no rack_rate key) defaults to 0.
        let mut j = FaultConfig::default().to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("rack_rate");
        }
        assert_eq!(FaultConfig::from_json(&j).unwrap().rack_rate, 0.0);
        let mut bad = FaultConfig::default();
        bad.rack_rate = -1.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn fault_roundtrip_and_validation() {
        let f = FaultConfig::with_rate(2e-3);
        let f2 = FaultConfig::from_json(&f.to_json()).unwrap();
        assert_eq!(f, f2);
        assert!(!f.is_none());
        assert!(FaultConfig::none().is_none());
        let mut bad = FaultConfig::default();
        bad.p_permanent = 1.5;
        assert!(bad.validate().is_err());
        let mut bad = FaultConfig::default();
        bad.slowdown = 0.5;
        assert!(bad.validate().is_err());
        let mut bad = FaultConfig::default();
        bad.mttr = 0.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn workload_roundtrip_poisson() {
        let w = WorkloadConfig::continuous(30);
        let w2 = WorkloadConfig::from_json(&w.to_json()).unwrap();
        assert_eq!(w2.n_jobs, 30);
        assert_eq!(
            w2.arrival,
            Arrival::Poisson {
                mean_interval: 45.0
            }
        );
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = ClusterConfig::default();
        c.n_executors = 0;
        assert!(c.validate().is_err());
        let mut w = WorkloadConfig::default();
        w.sizes_gb = vec![-1.0];
        assert!(w.validate().is_err());
        let mut w = WorkloadConfig::default();
        w.query_ids = vec![23];
        assert!(w.validate().is_err());
    }

    #[test]
    fn experiment_roundtrip() {
        let e = ExperimentConfig {
            cluster: ClusterConfig::with_executors(10),
            workload_base: WorkloadConfig::small_batch(5),
            job_counts: vec![1, 5, 10],
            seeds: vec![1, 2, 3],
        };
        let e2 = ExperimentConfig::from_json(&e.to_json()).unwrap();
        assert_eq!(e2.job_counts, vec![1, 5, 10]);
        assert_eq!(e2.seeds, vec![1, 2, 3]);
        assert_eq!(e2.cluster.n_executors, 10);
    }

    #[test]
    fn train_roundtrip() {
        let t = TrainConfig::default();
        let t2 = TrainConfig::from_json(&t.to_json()).unwrap();
        assert_eq!(t2.episodes, t.episodes);
        assert_eq!(t2.agents, 8);
        assert!((t2.gamma - 0.99).abs() < 1e-12);
    }
}
