//! Sustained-load soak harness for the scheduling service: an open-loop
//! Poisson arrival process (Decima's continuous evaluation regime, §5.3.3)
//! over streaming TPC-H jobs, driven by N concurrent master connections
//! against a live [`AgentServer`] — once per [`ServiceMode`], so the
//! batched engine's throughput is measured against the single-lock
//! baseline in the same run, on the same machine.
//!
//! Each master walks its own simulated clock (`t += Exp(mean_interval)`),
//! submits the next TPC-H job at that arrival, heartbeats the previous
//! job, and asks for a schedule — recording wall-clock submit/decision
//! latency per request into the obs registry's log-scale [`Histogram`]
//! (fixed 274-bucket memory no matter how long the soak runs; a
//! `Recorder` keeping every sample grows without bound under sustained
//! arrivals and is kept only for short sweeps). Every mutating request
//! carries a `request_id` (exercising the dedup window at full load)
//! and goes through the retrying client, so the soak measures the
//! production request path. Dedicated monitor threads hammer `status`
//! concurrently (the read path the batched engine serves lock-free).
//! A third leg repeats the batched run with a write-ahead journal
//! attached, yielding the journaling overhead ratio CI gates on. Each
//! leg also binds the same plain-HTTP Prometheus listener that
//! `lachesis serve --metrics-addr` exposes and scrapes it once mid-run,
//! so the soak doubles as an end-to-end check of the live metrics
//! surface. Results land in `results/soak.md` and a
//! `BENCH_service.json` with the same shape as the other committed
//! bench snapshots.
//!
//! `lachesis soak --chaos` runs the [`chaos`] harness instead: a
//! journaled child server process is SIGKILLed mid-stream, restarted
//! with `--restore`, re-driven by a retrying client through torn lines
//! and duplicate requests — and the final status must be byte-identical
//! to an in-process run of the same stream that never crashed.
//!
//! [`AgentServer`]: crate::service::AgentServer
//! [`ServiceMode`]: crate::service::ServiceMode

use super::{build_send_scheduler, write_results, PolicySource};
use crate::cluster::Cluster;
use crate::config::ClusterConfig;
use crate::obs::metrics::Histogram;
use crate::service::{
    AgentCore, AgentServer, ClientConfig, Durability, Request, Response, ServiceClient,
    ServiceMode,
};
use crate::util::json::Json;
use crate::util::rng::{Rng, STREAM_SOAK};
use crate::workload::tpch;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Soak profile. Defaults are the CI smoke scale; `lachesis soak` flags
/// override each field.
#[derive(Debug, Clone)]
pub struct SoakConfig {
    /// Concurrent master connections.
    pub masters: usize,
    /// Total jobs across all masters.
    pub jobs: usize,
    /// Mean simulated inter-arrival time per master (seconds, Poisson).
    pub mean_interval: f64,
    /// Cluster size (heterogeneous, seeded).
    pub executors: usize,
    /// Scheduler under load (any zoo name).
    pub algo: String,
    pub seed: u64,
    /// Issue a timed `status` every this many jobs per master (0 = never).
    pub status_every: usize,
    /// Dedicated threads polling `status` for the whole run.
    pub monitors: usize,
    /// Directory for the journaled leg's write-ahead journal. `None`
    /// uses (and cleans up) a per-process temp directory.
    pub journal: Option<PathBuf>,
    /// Snapshot cadence for the journaled leg (records between
    /// snapshots; 0 = journal only, never snapshot).
    pub snapshot_every: u64,
    /// Mailbox bound for all legs (0 = unbounded).
    pub max_queue: usize,
}

impl Default for SoakConfig {
    fn default() -> Self {
        SoakConfig {
            masters: 8,
            jobs: 200,
            mean_interval: 5.0,
            executors: 50,
            algo: "HighRankUp-DEFT".to_string(),
            seed: 7,
            status_every: 1,
            monitors: 2,
            journal: None,
            snapshot_every: 256,
            max_queue: 0,
        }
    }
}

/// Aggregated measurements of one soak run (one service mode).
pub struct SoakReport {
    pub mode: ServiceMode,
    /// Row label: the mode name, with `+journal` when a write-ahead
    /// journal was attached.
    pub label: String,
    /// `schedule` round-trip latency, ms — a bounded log-scale
    /// histogram, so memory stays O(1) over arbitrarily long soaks.
    pub decision: Histogram,
    /// `submit_job` round-trip latency, ms.
    pub submit: Histogram,
    /// `status` round-trip latency, ms (masters + monitors).
    pub status: Histogram,
    pub jobs: usize,
    pub assignments: usize,
    pub wall_secs: f64,
    pub jobs_per_sec: f64,
    /// (batches, requests through batches, coalesced heartbeats) — zeros
    /// in serial mode.
    pub batches: u64,
    pub batched_requests: u64,
    pub coalesced_heartbeats: u64,
    /// Requests refused with `overloaded` (every one was retried to
    /// completion by the client).
    pub shed: u64,
    /// Duplicate `request_id`s answered from the dedup window.
    pub deduped: usize,
    /// The mid-run scrape of this leg's Prometheus listener parsed as
    /// text exposition and carried `lachesis_requests_total`.
    pub metrics_scrape_ok: bool,
}

#[derive(Default)]
struct MasterStats {
    submit: Histogram,
    decision: Histogram,
    status: Histogram,
    jobs: usize,
    assignments: usize,
}

fn ms_since(t0: Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e3
}

/// One master connection: stream `jobs_m` TPC-H jobs along a private
/// simulated Poisson clock, timing every submit/schedule round trip.
/// Mutating requests carry `m{m}-{k}-*` request ids and go through the
/// retrying path, so a shed (`overloaded`) or dropped connection is
/// retried without ever double-applying.
fn run_master(m: usize, addr: &str, cfg: &SoakConfig) -> Result<MasterStats> {
    let mut client = ServiceClient::connect_with(addr, ClientConfig::default())
        .with_context(|| format!("master {m} connecting"))?;
    let shapes = tpch::all_shapes();
    let mut rng = Rng::stream_n(cfg.seed, STREAM_SOAK, m as u64);
    let jobs_m = cfg.jobs / cfg.masters + usize::from(m < cfg.jobs % cfg.masters);
    let mut stats = MasterStats::default();
    let mut sim_t = 0.0;
    let mut prev_job: Option<usize> = None;
    for k in 0..jobs_m {
        sim_t += rng.exponential(cfg.mean_interval);
        // Round-robin the 22 query shapes, offset per master; input
        // scale drawn from the paper's 10/50/100 GB set.
        let shape = &shapes[(m + k) % shapes.len()];
        let size = [10.0, 50.0, 100.0][rng.below(3)];
        let job = shape.instantiate(0, size, sim_t);
        let computes: Vec<f64> = job.tasks.iter().map(|t| t.compute).collect();
        let edges: Vec<(usize, usize, f64)> = (0..job.n_tasks())
            .flat_map(|u| {
                job.children[u]
                    .iter()
                    .map(move |e| (u, e.other, e.data))
                    .collect::<Vec<_>>()
            })
            .collect();
        let t0 = Instant::now();
        let resp = client.call_idempotent(
            &format!("m{m}-{k}-submit"),
            &Request::SubmitJob {
                name: job.name.clone(),
                arrival: job.arrival,
                computes,
                edges,
            },
        )?;
        stats.submit.record(ms_since(t0));
        let job_id = match resp {
            Response::Ok { job_id: Some(id) } => id,
            other => bail!("master {m}: unexpected submit response {other:?}"),
        };
        // Heartbeat the previous job: advances the agent's wall clock the
        // way a live resource manager's completion reports would.
        if let Some(prev) = prev_job {
            client.call_idempotent(
                &format!("m{m}-{k}-hb"),
                &Request::TaskComplete {
                    job: prev,
                    node: 0,
                    time: sim_t,
                },
            )?;
        }
        prev_job = Some(job_id);
        let t0 = Instant::now();
        let resp =
            client.call_idempotent(&format!("m{m}-{k}-sched"), &Request::Schedule { time: sim_t })?;
        stats.decision.record(ms_since(t0));
        match resp {
            Response::Assignments(a) => stats.assignments += a.len(),
            other => bail!("master {m}: unexpected schedule response {other:?}"),
        }
        if cfg.status_every > 0 && k % cfg.status_every == 0 {
            let t0 = Instant::now();
            client.call(&Request::Status)?;
            stats.status.record(ms_since(t0));
        }
        stats.jobs += 1;
    }
    Ok(stats)
}

/// Run one soak profile against a fresh server in `mode`.
pub fn run_soak_mode(
    cfg: &SoakConfig,
    src: &PolicySource,
    mode: ServiceMode,
) -> Result<SoakReport> {
    if cfg.masters == 0 || cfg.jobs == 0 {
        bail!("soak needs at least one master and one job");
    }
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(cfg.executors), cfg.seed);
    let scheduler = build_send_scheduler(&cfg.algo, src, cfg.seed)?;
    let mut server = AgentServer::with_mode(cluster, scheduler, mode);
    if cfg.max_queue > 0 {
        // Shed + retrying clients: the overload path the service runs in
        // production, so its cost shows up in the measured latencies.
        server = server.with_admission(cfg.max_queue, crate::service::AdmissionPolicy::Shed);
    }
    if let Some(dir) = &cfg.journal {
        server = server.with_durability(Durability {
            dir: dir.clone(),
            snapshot_every: cfg.snapshot_every,
            restore: false,
        })?;
    }
    let server = Arc::new(server);
    let (tx, rx) = std::sync::mpsc::channel();
    let srv = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |a| {
                let _ = tx.send(a);
            })
        })
    };
    let addr = rx
        .recv_timeout(Duration::from_secs(10))
        .context("soak server did not bind")?
        .to_string();

    // The same plain-HTTP Prometheus surface `lachesis serve
    // --metrics-addr` exposes, on an ephemeral port; scraped once after
    // the masters drain so the soak exercises the live metrics path.
    let (mtx, mrx) = std::sync::mpsc::channel();
    let msrv = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || {
            server.serve_metrics_http("127.0.0.1:0", move |a| {
                let _ = mtx.send(a);
            })
        })
    };
    let metrics_addr = mrx
        .recv_timeout(Duration::from_secs(10))
        .context("soak metrics listener did not bind")?
        .to_string();

    let stop = AtomicBool::new(false);
    let mut master_results: Vec<std::thread::Result<Result<MasterStats>>> = Vec::new();
    let status = Histogram::new();
    let t_start = Instant::now();
    let mut wall_secs = 0.0;
    std::thread::scope(|s| {
        let monitors: Vec<_> = (0..cfg.monitors)
            .map(|_| {
                let addr = addr.clone();
                let stop = &stop;
                s.spawn(move || -> Result<Histogram> {
                    let mut client = ServiceClient::connect(&addr)?;
                    let rec = Histogram::new();
                    while !stop.load(Ordering::SeqCst) {
                        let t0 = Instant::now();
                        match client.call(&Request::Status)? {
                            Response::Status { .. } => rec.record(ms_since(t0)),
                            other => bail!("unexpected status response {other:?}"),
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Ok(rec)
                })
            })
            .collect();
        let masters: Vec<_> = (0..cfg.masters)
            .map(|m| {
                let addr = addr.clone();
                s.spawn(move || run_master(m, &addr, cfg))
            })
            .collect();
        for h in masters {
            master_results.push(h.join());
        }
        // Only the request-serving window counts toward throughput; the
        // monitor drain and shutdown below are bookkeeping.
        wall_secs = t_start.elapsed().as_secs_f64();
        stop.store(true, Ordering::SeqCst);
        for h in monitors {
            match h.join() {
                Ok(Ok(rec)) => status.merge_from(&rec),
                Ok(Err(e)) => crate::log_warn!("status monitor failed: {e:#}"),
                Err(_) => crate::log_warn!("status monitor panicked"),
            }
        }
    });

    // Acceptance scrape: hit the leg's metrics listener the way a
    // Prometheus agent would. A failed scrape is reported (and gated in
    // CI via the bench note), not fatal to the latency measurement.
    let metrics_scrape_ok = match scrape_metrics(&metrics_addr)
        .and_then(|body| check_prometheus_payload(&body))
    {
        Ok(()) => true,
        Err(e) => {
            crate::log_warn!("metrics scrape failed: {e:#}");
            false
        }
    };

    // Stop the server before surfacing any master error, so a failed run
    // never leaks a bound listener thread. The final status carries the
    // run's operational counters (shed, deduped).
    let mut client = ServiceClient::connect(&addr).context("connecting for shutdown")?;
    let (shed, deduped) = match client.call(&Request::Status)? {
        Response::Status { shed, deduped, .. } => (shed as u64, deduped),
        other => bail!("unexpected final status response {other:?}"),
    };
    client.call(&Request::Shutdown)?;
    srv.join().map_err(|_| anyhow!("server thread panicked"))??;
    msrv.join()
        .map_err(|_| anyhow!("metrics listener thread panicked"))??;

    let label = if cfg.journal.is_some() {
        format!("{}+journal", mode.name())
    } else {
        mode.name().to_string()
    };
    let mut report = SoakReport {
        mode,
        label,
        decision: Histogram::new(),
        submit: Histogram::new(),
        status,
        jobs: 0,
        assignments: 0,
        wall_secs,
        jobs_per_sec: 0.0,
        batches: 0,
        batched_requests: 0,
        coalesced_heartbeats: 0,
        shed,
        deduped,
        metrics_scrape_ok,
    };
    for r in master_results {
        let stats = r.map_err(|_| anyhow!("master thread panicked"))??;
        report.decision.merge_from(&stats.decision);
        report.submit.merge_from(&stats.submit);
        report.status.merge_from(&stats.status);
        report.jobs += stats.jobs;
        report.assignments += stats.assignments;
    }
    report.jobs_per_sec = report.jobs as f64 / wall_secs.max(1e-9);
    let (batches, batched_requests, coalesced) = server.batch_stats();
    report.batches = batches;
    report.batched_requests = batched_requests;
    report.coalesced_heartbeats = coalesced;
    crate::log_info!(
        "soak [{}]: {} jobs in {:.2}s ({:.1} jobs/s), {} assignments, {} shed, {} deduped",
        report.label,
        report.jobs,
        wall_secs,
        report.jobs_per_sec,
        report.assignments,
        report.shed,
        report.deduped
    );
    Ok(report)
}

/// GET a leg's Prometheus listener once over a plain TCP socket (the
/// repo carries no HTTP client) and return the response body.
fn scrape_metrics(addr: &str) -> Result<String> {
    let mut s = std::net::TcpStream::connect(addr).context("connecting to the metrics listener")?;
    s.set_read_timeout(Some(Duration::from_secs(5)))?;
    s.set_write_timeout(Some(Duration::from_secs(5)))?;
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: lachesis\r\nConnection: close\r\n\r\n")?;
    let mut raw = String::new();
    s.read_to_string(&mut raw).context("reading the scrape response")?;
    let (head, body) = raw
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow!("scrape response has no header/body separator"))?;
    if !head.starts_with("HTTP/1.1 200") {
        bail!(
            "metrics listener answered {:?}",
            head.lines().next().unwrap_or("")
        );
    }
    Ok(body.to_string())
}

/// Minimal exposition-format check: every non-comment, non-blank line
/// must end in a finite numeric sample value, at least one sample must
/// be present, and the payload must carry the request counter family —
/// the invariant the CI soak smoke gates on.
fn check_prometheus_payload(body: &str) -> Result<()> {
    let mut samples = 0usize;
    for line in body.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (_series, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| anyhow!("malformed exposition line {line:?}"))?;
        let v: f64 = value
            .parse()
            .map_err(|_| anyhow!("non-numeric sample value in {line:?}"))?;
        if !v.is_finite() {
            bail!("non-finite sample value in {line:?}");
        }
        samples += 1;
    }
    if samples == 0 {
        bail!("scrape returned no samples");
    }
    if !body.contains("lachesis_requests_total") {
        bail!("scrape is missing lachesis_requests_total");
    }
    Ok(())
}

fn latency_row(name: &str, rec: &Histogram) -> String {
    let ps = rec.percentiles(&[50.0, 95.0, 99.0]);
    format!(
        "| {name} | {} | {:.3} | {:.3} | {:.3} | {:.3} |\n",
        rec.count(),
        rec.mean(),
        ps[0],
        ps[1],
        ps[2]
    )
}

fn bench_case(name: &str, rec: &Histogram) -> Json {
    // ms → ns, matching the other BENCH_*.json snapshots. Percentiles
    // are bucket upper edges (≤ 13% above exact by construction); the
    // histogram carries no per-sample data, so no std_ns here.
    let ps = rec.percentiles(&[50.0, 95.0, 99.0]);
    Json::from_pairs(vec![
        ("name", Json::from(name)),
        ("iters", Json::from(rec.count() as usize)),
        ("mean_ns", Json::from(rec.mean() * 1e6)),
        ("p50_ns", Json::from(ps[0] * 1e6)),
        ("p95_ns", Json::from(ps[1] * 1e6)),
        ("p99_ns", Json::from(ps[2] * 1e6)),
    ])
}

/// Run the full soak comparison — serial, batched, and batched with a
/// write-ahead journal attached — write `results/soak.md` + the bench
/// JSON at `out_json`, and return the rendered markdown. The journaled
/// leg yields `journal_overhead_ratio` (journal-off / journal-on
/// jobs/sec), which CI gates at ≤ 1.10.
pub fn soak(cfg: &SoakConfig, src: &PolicySource, out_json: &str) -> Result<String> {
    let serial = run_soak_mode(cfg, src, ServiceMode::Serial)?;
    let batched = run_soak_mode(cfg, src, ServiceMode::Batched)?;
    let jdir = cfg.journal.clone().unwrap_or_else(|| {
        std::env::temp_dir().join(format!("lachesis-soak-journal-{}", std::process::id()))
    });
    let _ = std::fs::remove_dir_all(&jdir);
    let mut jcfg = cfg.clone();
    jcfg.journal = Some(jdir.clone());
    let journaled = run_soak_mode(&jcfg, src, ServiceMode::Batched)?;
    if cfg.journal.is_none() {
        let _ = std::fs::remove_dir_all(&jdir);
    }

    let mut out = String::from("## Service soak: serial vs batched vs journaled engine\n\n");
    out.push_str(&format!(
        "{} masters x {} jobs total, mean inter-arrival {}s, {} executors, \
         algo {}, seed {}, {} status monitors, max queue {}\n\n",
        cfg.masters,
        cfg.jobs,
        cfg.mean_interval,
        cfg.executors,
        cfg.algo,
        cfg.seed,
        cfg.monitors,
        cfg.max_queue
    ));
    out.push_str("| metric | samples | mean ms | p50 | p95 | p99 |\n|---|---|---|---|---|---|\n");
    for rep in [&serial, &batched, &journaled] {
        let m = &rep.label;
        out.push_str(&latency_row(&format!("decision/{m}"), &rep.decision));
        out.push_str(&latency_row(&format!("submit/{m}"), &rep.submit));
        out.push_str(&latency_row(&format!("status/{m}"), &rep.status));
    }
    let journal_overhead = batched.jobs_per_sec / journaled.jobs_per_sec.max(1e-9);
    out.push_str(&format!(
        "\njobs/sec: serial {:.1}, batched {:.1} ({:.2}x), batched+journal {:.1} \
         (journal overhead {:.3}x); batched engine formed {} batches over {} requests \
         (avg {:.2}/batch), coalesced {} heartbeats; shed {} requests, \
         suppressed {} duplicates\n",
        serial.jobs_per_sec,
        batched.jobs_per_sec,
        batched.jobs_per_sec / serial.jobs_per_sec.max(1e-9),
        journaled.jobs_per_sec,
        journal_overhead,
        batched.batches,
        batched.batched_requests,
        batched.batched_requests as f64 / batched.batches.max(1) as f64,
        batched.coalesced_heartbeats,
        serial.shed + batched.shed + journaled.shed,
        serial.deduped + batched.deduped + journaled.deduped
    ));
    write_results("soak.md", &out)?;

    let mut cases = Vec::new();
    for rep in [&serial, &batched, &journaled] {
        let m = &rep.label;
        cases.push(bench_case(&format!("decision/{m}"), &rep.decision));
        cases.push(bench_case(&format!("submit/{m}"), &rep.submit));
        cases.push(bench_case(&format!("status/{m}"), &rep.status));
    }
    let decision_s = serial.decision.percentiles(&[50.0, 95.0, 99.0]);
    let decision_b = batched.decision.percentiles(&[50.0, 95.0, 99.0]);
    let json = Json::from_pairs(vec![
        ("bench", Json::from("service_soak")),
        (
            "config",
            Json::from_pairs(vec![
                ("masters", Json::from(cfg.masters)),
                ("jobs", Json::from(cfg.jobs)),
                ("mean_interval", Json::from(cfg.mean_interval)),
                ("executors", Json::from(cfg.executors)),
                ("algo", Json::from(cfg.algo.clone())),
                ("seed", Json::from(cfg.seed as usize)),
                ("status_every", Json::from(cfg.status_every)),
                ("monitors", Json::from(cfg.monitors)),
            ]),
        ),
        ("cases", Json::Arr(cases)),
        (
            "notes",
            Json::from_pairs(vec![
                ("jobs_per_sec_serial", Json::from(serial.jobs_per_sec)),
                ("jobs_per_sec_batched", Json::from(batched.jobs_per_sec)),
                (
                    "batched_speedup",
                    Json::from(batched.jobs_per_sec / serial.jobs_per_sec.max(1e-9)),
                ),
                ("decision_p50_ms_serial", Json::from(decision_s[0])),
                ("decision_p95_ms_serial", Json::from(decision_s[1])),
                ("decision_p99_ms_serial", Json::from(decision_s[2])),
                ("decision_p50_ms_batched", Json::from(decision_b[0])),
                ("decision_p95_ms_batched", Json::from(decision_b[1])),
                ("decision_p99_ms_batched", Json::from(decision_b[2])),
                (
                    "avg_batch_size",
                    Json::from(
                        batched.batched_requests as f64 / batched.batches.max(1) as f64,
                    ),
                ),
                (
                    "coalesced_heartbeats",
                    Json::from(batched.coalesced_heartbeats as f64),
                ),
                ("jobs_per_sec_journal", Json::from(journaled.jobs_per_sec)),
                ("journal_overhead_ratio", Json::from(journal_overhead)),
                (
                    "shed_total",
                    Json::from((serial.shed + batched.shed + journaled.shed) as f64),
                ),
                (
                    "deduped_total",
                    Json::from(serial.deduped + batched.deduped + journaled.deduped),
                ),
                (
                    "metrics_scrape_ok",
                    Json::from(
                        serial.metrics_scrape_ok
                            && batched.metrics_scrape_ok
                            && journaled.metrics_scrape_ok,
                    ),
                ),
            ]),
        ),
    ]);
    std::fs::write(out_json, format!("{}\n", json.to_string()))
        .with_context(|| format!("writing {out_json}"))?;
    crate::log_info!("wrote {out_json}");
    Ok(out)
}

// ------------------------------------------------------------------ chaos

/// Profile for the kill-and-restore chaos drill (`lachesis soak --chaos`).
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Jobs in the deterministic driver stream (each contributes a
    /// submit, a heartbeat for its predecessor, and a schedule request).
    pub jobs: usize,
    /// SIGKILL the server after this many acknowledged requests; must
    /// fall strictly mid-stream.
    pub kill_after: usize,
    pub executors: usize,
    pub algo: String,
    pub seed: u64,
    /// Journal directory for the child servers (wiped at the start).
    pub dir: PathBuf,
    pub snapshot_every: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            jobs: 40,
            kill_after: 60,
            executors: 12,
            algo: "HighRankUp-DEFT".to_string(),
            seed: 7,
            dir: std::env::temp_dir().join(format!("lachesis-chaos-{}", std::process::id())),
            snapshot_every: 16,
        }
    }
}

/// The single deterministic request stream both the chaos run and the
/// uninterrupted reference replay. One driver, fixed ids — concurrent
/// masters would interleave nondeterministically and make the
/// byte-identical final-status comparison meaningless.
fn chaos_stream(cfg: &ChaosConfig) -> Vec<(String, Request)> {
    let shapes = tpch::all_shapes();
    let mut rng = Rng::stream_n(cfg.seed, STREAM_SOAK, 0);
    let mut reqs = Vec::new();
    let mut sim_t = 0.0;
    for k in 0..cfg.jobs {
        sim_t += rng.exponential(1.0);
        let shape = &shapes[k % shapes.len()];
        let size = [10.0, 50.0, 100.0][rng.below(3)];
        let job = shape.instantiate(0, size, sim_t);
        let computes: Vec<f64> = job.tasks.iter().map(|t| t.compute).collect();
        let edges: Vec<(usize, usize, f64)> = (0..job.n_tasks())
            .flat_map(|u| {
                job.children[u]
                    .iter()
                    .map(move |e| (u, e.other, e.data))
                    .collect::<Vec<_>>()
            })
            .collect();
        reqs.push((
            format!("c{k}-submit"),
            Request::SubmitJob {
                name: job.name.clone(),
                arrival: job.arrival,
                computes,
                edges,
            },
        ));
        if k > 0 {
            // Job ids are assigned densely in submit order by the server,
            // so the predecessor's id is statically k-1.
            reqs.push((
                format!("c{k}-hb"),
                Request::TaskComplete {
                    job: k - 1,
                    node: 0,
                    time: sim_t,
                },
            ));
        }
        reqs.push((format!("c{k}-sched"), Request::Schedule { time: sim_t }));
    }
    reqs
}

/// Start a `lachesis serve` child on an ephemeral port with the chaos
/// journal attached, and parse the bound address off its stdout.
fn spawn_server(
    cfg: &ChaosConfig,
    src: &PolicySource,
    restore: bool,
) -> Result<(std::process::Child, String)> {
    let exe = std::env::current_exe().context("locating the lachesis binary")?;
    let mut cmd = std::process::Command::new(exe);
    cmd.arg("serve")
        .arg("--addr")
        .arg("127.0.0.1:0")
        .arg("--algo")
        .arg(&cfg.algo)
        .arg("--executors")
        .arg(cfg.executors.to_string())
        .arg("--seed")
        .arg(cfg.seed.to_string())
        .arg("--artifacts")
        .arg(&src.artifact_dir)
        .arg("--backend")
        .arg(&src.backend)
        .arg("--journal")
        .arg(&cfg.dir)
        .arg("--snapshot-every")
        .arg(cfg.snapshot_every.to_string());
    if let Some(p) = &src.lachesis_params {
        cmd.arg("--lachesis-params").arg(p);
    }
    if let Some(p) = &src.decima_params {
        cmd.arg("--decima-params").arg(p);
    }
    if restore {
        cmd.arg("--restore");
    }
    cmd.stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null());
    let mut child = cmd.spawn().context("spawning `lachesis serve`")?;
    let stdout = child.stdout.take().expect("stdout was piped");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).context("reading server stdout")?;
        if n == 0 {
            let _ = child.kill();
            let _ = child.wait();
            bail!("server child exited before reporting its bound address");
        }
        if let Some(addr) = line.trim().strip_prefix("bound ") {
            let addr = addr.to_string();
            // Keep draining stdout so the child never blocks on a full pipe.
            std::thread::spawn(move || {
                let mut sink = String::new();
                while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
                    sink.clear();
                }
            });
            return Ok((child, addr));
        }
    }
}

/// Hostile-client interference: a request torn mid-line, invalid UTF-8,
/// a garbage JSON line (must be answered with an error, not kill the
/// server), and a silent stalled connection. None of these mutate state.
fn interfere(addr: &str) -> Result<()> {
    use std::net::TcpStream;
    {
        let mut s = TcpStream::connect(addr).context("torn-line connect")?;
        s.write_all(b"{\"type\":\"submit_job\",\"name\":\"torn")?;
        // Dropped without the newline: the server sees EOF mid-line.
    }
    {
        let mut s = TcpStream::connect(addr).context("bad-utf8 connect")?;
        s.write_all(b"\xff\xfe\x01garbage\n")?;
    }
    {
        let s = TcpStream::connect(addr).context("garbage-line connect")?;
        let mut w = s.try_clone()?;
        w.write_all(b"this is not json\n")?;
        let mut line = String::new();
        BufReader::new(s).read_line(&mut line)?;
        if !line.contains("\"error\"") {
            bail!("garbage line answered with {line:?}, expected an error response");
        }
    }
    {
        let _s = TcpStream::connect(addr).context("stall connect")?;
        std::thread::sleep(Duration::from_millis(50));
    }
    Ok(())
}

/// The same stream into an in-process core that never crashes and never
/// journals — the oracle the restored run must match byte-for-byte.
fn run_reference(
    cfg: &ChaosConfig,
    src: &PolicySource,
    stream: &[(String, Request)],
) -> Result<Response> {
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(cfg.executors), cfg.seed);
    let scheduler = build_send_scheduler(&cfg.algo, src, cfg.seed)?;
    let mut core = AgentCore::new(cluster, scheduler);
    for (id, req) in stream {
        core.handle_tagged(Some(id.as_str()), req.clone());
    }
    Ok(core.status_snapshot().to_response())
}

/// Render only the schedule-state fields of a `status` response. The
/// operational counters (queue depth, shed, deduped) legitimately differ
/// between a crashed-and-restored run and the uninterrupted reference;
/// everything the scheduler's decisions depend on must be identical,
/// with the float horizon compared by bit pattern.
fn schedule_state_key(resp: &Response) -> Result<String> {
    match resp {
        Response::Status {
            jobs,
            assigned,
            executors,
            horizon,
            executable,
            pending,
            down,
            ..
        } => Ok(format!(
            "jobs={jobs} assigned={assigned} executors={executors} \
             horizon_bits={:016x} executable={executable} pending={pending} down={down}",
            horizon.to_bits()
        )),
        other => bail!("expected a status response, got {other:?}"),
    }
}

/// Kill-and-restore chaos drill: drive a journaled child server with a
/// retrying client, SIGKILL it mid-stream, restart it with `--restore`,
/// re-send the last acknowledged request (must be deduplicated
/// byte-identically), run interference connections, finish the stream —
/// and require the final status to match an uninterrupted in-process
/// reference byte-for-byte. Writes a `## Chaos soak` section into
/// `results/soak.md` and a `service_chaos` bench JSON at `out_json`.
pub fn chaos(cfg: &ChaosConfig, src: &PolicySource, out_json: &str) -> Result<String> {
    let stream = chaos_stream(cfg);
    let n_requests = stream.len();
    if cfg.kill_after == 0 || cfg.kill_after >= n_requests {
        bail!(
            "--kill-after must fall mid-stream (1..{n_requests} for {} jobs)",
            cfg.jobs
        );
    }
    let _ = std::fs::remove_dir_all(&cfg.dir);
    let ccfg = ClientConfig {
        read_timeout: Duration::from_secs(10),
        retries: 8,
        backoff: Duration::from_millis(100),
        ..ClientConfig::default()
    };

    // Phase 1: journaled server, drive the stream up to the kill point.
    let (mut child, addr) = spawn_server(cfg, src, false)?;
    let mut client = ServiceClient::connect_with(&addr, ccfg.clone())?;
    let mut acks: Vec<String> = Vec::with_capacity(n_requests);
    for (id, req) in &stream[..cfg.kill_after] {
        acks.push(client.call_idempotent(id, req)?.to_json().to_string());
    }

    // SIGKILL: no flush, no goodbye — exactly the crash the journal's
    // fsync-before-ack contract covers.
    child.kill().context("killing the server child")?;
    child.wait().context("reaping the killed child")?;
    let t_down = Instant::now();

    // Phase 2: restart from disk; recovery time covers exec + snapshot
    // load + journal replay + the first successfully answered status.
    let (mut child, addr) = spawn_server(cfg, src, true)?;
    let mut client = ServiceClient::connect_with(&addr, ccfg)?;
    client.call(&Request::Status).context("first post-restore status")?;
    let recovery_ms = t_down.elapsed().as_secs_f64() * 1e3;

    interfere(&addr)?;

    // A client that never saw the last pre-crash ack retries it: the
    // restored dedup window must answer byte-identically, not re-apply.
    let (dup_id, dup_req) = &stream[cfg.kill_after - 1];
    let dup = client.call_idempotent(dup_id, dup_req)?.to_json().to_string();
    if dup != acks[cfg.kill_after - 1] {
        bail!(
            "duplicate of '{dup_id}' not served from the restored dedup window:\n  \
             pre-crash    {}\n  post-restore {dup}",
            acks[cfg.kill_after - 1]
        );
    }

    // Finish the stream on the restored server.
    for (id, req) in &stream[cfg.kill_after..] {
        acks.push(client.call_idempotent(id, req)?.to_json().to_string());
    }
    let final_status = client.call(&Request::Status)?;
    let (shed, deduped) = match &final_status {
        Response::Status { shed, deduped, .. } => (*shed, *deduped),
        other => bail!("unexpected final status {other:?}"),
    };
    if deduped == 0 {
        bail!("the deliberate duplicate was not counted by the dedup window");
    }
    client.call(&Request::Shutdown)?;
    child.wait().context("reaping the restored child")?;

    let reference = run_reference(cfg, src, &stream)?;
    let got = schedule_state_key(&final_status)?;
    let want = schedule_state_key(&reference)?;
    if got != want {
        bail!(
            "restored run diverged from the uninterrupted reference:\n  \
             restored  {got}\n  reference {want}"
        );
    }
    let _ = std::fs::remove_dir_all(&cfg.dir);

    let mut out = String::from("## Chaos soak: SIGKILL + restore\n\n");
    out.push_str(&format!(
        "{} jobs ({n_requests} requests), killed after {} acked requests, \
         {} executors, algo {}, seed {}, snapshot every {} records\n\n",
        cfg.jobs, cfg.kill_after, cfg.executors, cfg.algo, cfg.seed, cfg.snapshot_every
    ));
    out.push_str(&format!(
        "- recovery (restart + restore + first status): {recovery_ms:.1} ms\n\
         - duplicates suppressed by the restored dedup window: {deduped}\n\
         - requests shed: {shed}\n\
         - final status byte-identical to the never-crashed reference\n"
    ));

    // Append after (or replace) any previous chaos section so `soak` and
    // `soak --chaos` can share results/soak.md in either order.
    let path = std::path::Path::new("results").join("soak.md");
    let mut doc = std::fs::read_to_string(&path).unwrap_or_default();
    if let Some(i) = doc.find("## Chaos soak") {
        doc.truncate(i);
    }
    if !doc.is_empty() && !doc.ends_with("\n\n") {
        doc.push('\n');
    }
    doc.push_str(&out);
    write_results("soak.md", &doc)?;

    let json = Json::from_pairs(vec![
        ("bench", Json::from("service_chaos")),
        (
            "config",
            Json::from_pairs(vec![
                ("jobs", Json::from(cfg.jobs)),
                ("requests", Json::from(n_requests)),
                ("kill_after", Json::from(cfg.kill_after)),
                ("executors", Json::from(cfg.executors)),
                ("algo", Json::from(cfg.algo.clone())),
                ("seed", Json::from(cfg.seed as usize)),
                ("snapshot_every", Json::from(cfg.snapshot_every)),
            ]),
        ),
        (
            "notes",
            Json::from_pairs(vec![
                ("recovery_ms", Json::from(recovery_ms)),
                ("duplicates_suppressed", Json::from(deduped)),
                ("requests_shed", Json::from(shed)),
                ("status_byte_identical", Json::from(true)),
            ]),
        ),
    ]);
    std::fs::write(out_json, format!("{}\n", json.to_string()))
        .with_context(|| format!("writing {out_json}"))?;
    crate::log_info!("wrote {out_json}");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end smoke at toy scale: both modes complete, every job is
    /// acknowledged, latencies are recorded, and the bench JSON lands.
    #[test]
    fn soak_smoke_both_modes() {
        let cfg = SoakConfig {
            masters: 2,
            jobs: 8,
            mean_interval: 1.0,
            executors: 6,
            algo: "FIFO-DEFT".to_string(),
            seed: 11,
            status_every: 1,
            monitors: 1,
            ..SoakConfig::default()
        };
        let src = PolicySource {
            backend: "rust".to_string(),
            ..PolicySource::default()
        };
        let out = std::env::temp_dir().join(format!(
            "lachesis_soak_test_{}.json",
            std::process::id()
        ));
        let out_path = out.to_str().unwrap().to_string();
        let md = soak(&cfg, &src, &out_path).unwrap();
        assert!(md.contains("decision/serial"));
        assert!(md.contains("decision/batched"));
        assert!(md.contains("decision/batched+journal"));
        let raw = std::fs::read_to_string(&out_path).unwrap();
        assert!(raw.contains("jobs_per_sec_serial"));
        assert!(raw.contains("jobs_per_sec_batched"));
        assert!(raw.contains("journal_overhead_ratio"));
        let parsed = Json::parse(&raw).unwrap();
        assert_eq!(
            parsed.get("notes").and_then(|n| n.get("metrics_scrape_ok")).and_then(Json::as_bool),
            Some(true),
            "every soak leg must serve a parseable Prometheus scrape"
        );
        std::fs::remove_file(&out_path).ok();
    }

    /// The per-mode runner reports every submitted job and a decision
    /// sample per job.
    #[test]
    fn soak_mode_accounts_every_job() {
        let cfg = SoakConfig {
            masters: 3,
            jobs: 7, // deliberately not divisible by masters
            mean_interval: 1.0,
            executors: 4,
            algo: "FIFO-DEFT".to_string(),
            seed: 5,
            status_every: 2,
            monitors: 0,
            ..SoakConfig::default()
        };
        let src = PolicySource {
            backend: "rust".to_string(),
            ..PolicySource::default()
        };
        let rep = run_soak_mode(&cfg, &src, ServiceMode::Batched).unwrap();
        assert_eq!(rep.jobs, 7);
        assert_eq!(rep.decision.count(), 7);
        assert_eq!(rep.submit.count(), 7);
        assert!(rep.assignments > 0);
        assert!(rep.batches > 0);
        assert!(rep.jobs_per_sec > 0.0);
        assert_eq!(rep.label, "batched");
        assert_eq!(rep.deduped, 0, "unique ids must never count as duplicates");
        assert!(
            rep.metrics_scrape_ok,
            "the in-run Prometheus scrape must parse and carry lachesis_requests_total"
        );
    }

    /// The journaled leg lands every job through the write-ahead journal,
    /// and a tight mailbox bound with retrying clients loses nothing.
    #[test]
    fn soak_mode_journals_and_bounds_queue() {
        let dir = std::env::temp_dir().join(format!(
            "lachesis-soak-journal-test-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SoakConfig {
            masters: 2,
            jobs: 6,
            mean_interval: 1.0,
            executors: 4,
            algo: "FIFO-DEFT".to_string(),
            seed: 9,
            status_every: 0,
            monitors: 0,
            journal: Some(dir.clone()),
            snapshot_every: 4,
            max_queue: 1,
        };
        let src = PolicySource {
            backend: "rust".to_string(),
            ..PolicySource::default()
        };
        let rep = run_soak_mode(&cfg, &src, ServiceMode::Batched).unwrap();
        assert_eq!(rep.jobs, 6, "shed requests must be retried to completion");
        assert_eq!(rep.label, "batched+journal");
        assert!(
            dir.join(crate::service::journal::JOURNAL_FILE).exists(),
            "journaled leg must leave a journal on disk"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
