//! Sustained-load soak harness for the scheduling service: an open-loop
//! Poisson arrival process (Decima's continuous evaluation regime, §5.3.3)
//! over streaming TPC-H jobs, driven by N concurrent master connections
//! against a live [`AgentServer`] — once per [`ServiceMode`], so the
//! batched engine's throughput is measured against the single-lock
//! baseline in the same run, on the same machine.
//!
//! Each master walks its own simulated clock (`t += Exp(mean_interval)`),
//! submits the next TPC-H job at that arrival, heartbeats the previous
//! job, and asks for a schedule — recording wall-clock submit/decision
//! latency per request. Dedicated monitor threads hammer `status`
//! concurrently (the read path the batched engine serves lock-free).
//! Results land in `results/soak.md` and a `BENCH_service.json` with the
//! same shape as the other committed bench snapshots.
//!
//! [`AgentServer`]: crate::service::AgentServer
//! [`ServiceMode`]: crate::service::ServiceMode

use super::{build_send_scheduler, write_results, PolicySource};
use crate::cluster::Cluster;
use crate::config::ClusterConfig;
use crate::service::{AgentServer, Request, Response, ServiceClient, ServiceMode};
use crate::util::json::Json;
use crate::util::rng::{Rng, STREAM_SOAK};
use crate::util::stats::Recorder;
use crate::workload::tpch;
use anyhow::{anyhow, bail, Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Soak profile. Defaults are the CI smoke scale; `lachesis soak` flags
/// override each field.
#[derive(Debug, Clone)]
pub struct SoakConfig {
    /// Concurrent master connections.
    pub masters: usize,
    /// Total jobs across all masters.
    pub jobs: usize,
    /// Mean simulated inter-arrival time per master (seconds, Poisson).
    pub mean_interval: f64,
    /// Cluster size (heterogeneous, seeded).
    pub executors: usize,
    /// Scheduler under load (any zoo name).
    pub algo: String,
    pub seed: u64,
    /// Issue a timed `status` every this many jobs per master (0 = never).
    pub status_every: usize,
    /// Dedicated threads polling `status` for the whole run.
    pub monitors: usize,
}

impl Default for SoakConfig {
    fn default() -> Self {
        SoakConfig {
            masters: 8,
            jobs: 200,
            mean_interval: 5.0,
            executors: 50,
            algo: "HighRankUp-DEFT".to_string(),
            seed: 7,
            status_every: 1,
            monitors: 2,
        }
    }
}

/// Aggregated measurements of one soak run (one service mode).
pub struct SoakReport {
    pub mode: ServiceMode,
    /// `schedule` round-trip latency, ms.
    pub decision: Recorder,
    /// `submit_job` round-trip latency, ms.
    pub submit: Recorder,
    /// `status` round-trip latency, ms (masters + monitors).
    pub status: Recorder,
    pub jobs: usize,
    pub assignments: usize,
    pub wall_secs: f64,
    pub jobs_per_sec: f64,
    /// (batches, requests through batches, coalesced heartbeats) — zeros
    /// in serial mode.
    pub batches: u64,
    pub batched_requests: u64,
    pub coalesced_heartbeats: u64,
}

#[derive(Default)]
struct MasterStats {
    submit: Recorder,
    decision: Recorder,
    status: Recorder,
    jobs: usize,
    assignments: usize,
}

fn ms_since(t0: Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e3
}

/// One master connection: stream `jobs_m` TPC-H jobs along a private
/// simulated Poisson clock, timing every submit/schedule round trip.
fn run_master(m: usize, addr: &str, cfg: &SoakConfig) -> Result<MasterStats> {
    let mut client =
        ServiceClient::connect(addr).with_context(|| format!("master {m} connecting"))?;
    let shapes = tpch::all_shapes();
    let mut rng = Rng::stream_n(cfg.seed, STREAM_SOAK, m as u64);
    let jobs_m = cfg.jobs / cfg.masters + usize::from(m < cfg.jobs % cfg.masters);
    let mut stats = MasterStats::default();
    let mut sim_t = 0.0;
    let mut prev_job: Option<usize> = None;
    for k in 0..jobs_m {
        sim_t += rng.exponential(cfg.mean_interval);
        // Round-robin the 22 query shapes, offset per master; input
        // scale drawn from the paper's 10/50/100 GB set.
        let shape = &shapes[(m + k) % shapes.len()];
        let size = [10.0, 50.0, 100.0][rng.below(3)];
        let job = shape.instantiate(0, size, sim_t);
        let computes: Vec<f64> = job.tasks.iter().map(|t| t.compute).collect();
        let edges: Vec<(usize, usize, f64)> = (0..job.n_tasks())
            .flat_map(|u| {
                job.children[u]
                    .iter()
                    .map(move |e| (u, e.other, e.data))
                    .collect::<Vec<_>>()
            })
            .collect();
        let t0 = Instant::now();
        let resp = client.call(&Request::SubmitJob {
            name: job.name.clone(),
            arrival: job.arrival,
            computes,
            edges,
        })?;
        stats.submit.push(ms_since(t0));
        let job_id = match resp {
            Response::Ok { job_id: Some(id) } => id,
            other => bail!("master {m}: unexpected submit response {other:?}"),
        };
        // Heartbeat the previous job: advances the agent's wall clock the
        // way a live resource manager's completion reports would.
        if let Some(prev) = prev_job {
            client.call(&Request::TaskComplete {
                job: prev,
                node: 0,
                time: sim_t,
            })?;
        }
        prev_job = Some(job_id);
        let t0 = Instant::now();
        let resp = client.call(&Request::Schedule { time: sim_t })?;
        stats.decision.push(ms_since(t0));
        match resp {
            Response::Assignments(a) => stats.assignments += a.len(),
            other => bail!("master {m}: unexpected schedule response {other:?}"),
        }
        if cfg.status_every > 0 && k % cfg.status_every == 0 {
            let t0 = Instant::now();
            client.call(&Request::Status)?;
            stats.status.push(ms_since(t0));
        }
        stats.jobs += 1;
    }
    Ok(stats)
}

/// Run one soak profile against a fresh server in `mode`.
pub fn run_soak_mode(
    cfg: &SoakConfig,
    src: &PolicySource,
    mode: ServiceMode,
) -> Result<SoakReport> {
    if cfg.masters == 0 || cfg.jobs == 0 {
        bail!("soak needs at least one master and one job");
    }
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(cfg.executors), cfg.seed);
    let scheduler = build_send_scheduler(&cfg.algo, src, cfg.seed)?;
    let server = Arc::new(AgentServer::with_mode(cluster, scheduler, mode));
    let (tx, rx) = std::sync::mpsc::channel();
    let srv = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |a| {
                let _ = tx.send(a);
            })
        })
    };
    let addr = rx
        .recv_timeout(Duration::from_secs(10))
        .context("soak server did not bind")?
        .to_string();

    let stop = AtomicBool::new(false);
    let mut master_results: Vec<std::thread::Result<Result<MasterStats>>> = Vec::new();
    let mut status = Recorder::new();
    let t_start = Instant::now();
    let mut wall_secs = 0.0;
    std::thread::scope(|s| {
        let monitors: Vec<_> = (0..cfg.monitors)
            .map(|_| {
                let addr = addr.clone();
                let stop = &stop;
                s.spawn(move || -> Result<Recorder> {
                    let mut client = ServiceClient::connect(&addr)?;
                    let mut rec = Recorder::new();
                    while !stop.load(Ordering::SeqCst) {
                        let t0 = Instant::now();
                        match client.call(&Request::Status)? {
                            Response::Status { .. } => rec.push(ms_since(t0)),
                            other => bail!("unexpected status response {other:?}"),
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Ok(rec)
                })
            })
            .collect();
        let masters: Vec<_> = (0..cfg.masters)
            .map(|m| {
                let addr = addr.clone();
                s.spawn(move || run_master(m, &addr, cfg))
            })
            .collect();
        for h in masters {
            master_results.push(h.join());
        }
        // Only the request-serving window counts toward throughput; the
        // monitor drain and shutdown below are bookkeeping.
        wall_secs = t_start.elapsed().as_secs_f64();
        stop.store(true, Ordering::SeqCst);
        for h in monitors {
            match h.join() {
                Ok(Ok(rec)) => status.extend_from(&rec),
                Ok(Err(e)) => crate::log_warn!("status monitor failed: {e:#}"),
                Err(_) => crate::log_warn!("status monitor panicked"),
            }
        }
    });

    // Stop the server before surfacing any master error, so a failed run
    // never leaks a bound listener thread.
    let mut client = ServiceClient::connect(&addr).context("connecting for shutdown")?;
    client.call(&Request::Shutdown)?;
    srv.join().map_err(|_| anyhow!("server thread panicked"))??;

    let mut report = SoakReport {
        mode,
        decision: Recorder::new(),
        submit: Recorder::new(),
        status,
        jobs: 0,
        assignments: 0,
        wall_secs,
        jobs_per_sec: 0.0,
        batches: 0,
        batched_requests: 0,
        coalesced_heartbeats: 0,
    };
    for r in master_results {
        let stats = r.map_err(|_| anyhow!("master thread panicked"))??;
        report.decision.extend_from(&stats.decision);
        report.submit.extend_from(&stats.submit);
        report.status.extend_from(&stats.status);
        report.jobs += stats.jobs;
        report.assignments += stats.assignments;
    }
    report.jobs_per_sec = report.jobs as f64 / wall_secs.max(1e-9);
    let (batches, batched_requests, coalesced) = server.batch_stats();
    report.batches = batches;
    report.batched_requests = batched_requests;
    report.coalesced_heartbeats = coalesced;
    crate::log_info!(
        "soak [{}]: {} jobs in {:.2}s ({:.1} jobs/s), {} assignments",
        mode.name(),
        report.jobs,
        wall_secs,
        report.jobs_per_sec,
        report.assignments
    );
    Ok(report)
}

fn latency_row(name: &str, rec: &Recorder) -> String {
    let ps = rec.percentiles(&[50.0, 95.0, 99.0]);
    format!(
        "| {name} | {} | {:.3} | {:.3} | {:.3} | {:.3} |\n",
        rec.len(),
        rec.mean(),
        ps[0],
        ps[1],
        ps[2]
    )
}

fn bench_case(name: &str, rec: &Recorder) -> Json {
    // ms → ns, matching the other BENCH_*.json snapshots.
    let ps = rec.percentiles(&[50.0, 95.0, 99.0]);
    Json::from_pairs(vec![
        ("name", Json::from(name)),
        ("iters", Json::from(rec.len())),
        ("mean_ns", Json::from(rec.mean() * 1e6)),
        ("std_ns", Json::from(rec.std_dev() * 1e6)),
        ("p50_ns", Json::from(ps[0] * 1e6)),
        ("p95_ns", Json::from(ps[1] * 1e6)),
        ("p99_ns", Json::from(ps[2] * 1e6)),
    ])
}

/// Run the full serial-vs-batched soak comparison, write
/// `results/soak.md` + the bench JSON at `out_json`, and return the
/// rendered markdown.
pub fn soak(cfg: &SoakConfig, src: &PolicySource, out_json: &str) -> Result<String> {
    let serial = run_soak_mode(cfg, src, ServiceMode::Serial)?;
    let batched = run_soak_mode(cfg, src, ServiceMode::Batched)?;

    let mut out = String::from("## Service soak: serial vs batched engine\n\n");
    out.push_str(&format!(
        "{} masters x {} jobs total, mean inter-arrival {}s, {} executors, \
         algo {}, seed {}, {} status monitors\n\n",
        cfg.masters,
        cfg.jobs,
        cfg.mean_interval,
        cfg.executors,
        cfg.algo,
        cfg.seed,
        cfg.monitors
    ));
    out.push_str("| metric | samples | mean ms | p50 | p95 | p99 |\n|---|---|---|---|---|---|\n");
    for rep in [&serial, &batched] {
        let m = rep.mode.name();
        out.push_str(&latency_row(&format!("decision/{m}"), &rep.decision));
        out.push_str(&latency_row(&format!("submit/{m}"), &rep.submit));
        out.push_str(&latency_row(&format!("status/{m}"), &rep.status));
    }
    out.push_str(&format!(
        "\njobs/sec: serial {:.1}, batched {:.1} ({:.2}x); \
         batched engine formed {} batches over {} requests \
         (avg {:.2}/batch), coalesced {} heartbeats\n",
        serial.jobs_per_sec,
        batched.jobs_per_sec,
        batched.jobs_per_sec / serial.jobs_per_sec.max(1e-9),
        batched.batches,
        batched.batched_requests,
        batched.batched_requests as f64 / batched.batches.max(1) as f64,
        batched.coalesced_heartbeats
    ));
    write_results("soak.md", &out)?;

    let mut cases = Vec::new();
    for rep in [&serial, &batched] {
        let m = rep.mode.name();
        cases.push(bench_case(&format!("decision/{m}"), &rep.decision));
        cases.push(bench_case(&format!("submit/{m}"), &rep.submit));
        cases.push(bench_case(&format!("status/{m}"), &rep.status));
    }
    let decision_s = serial.decision.percentiles(&[50.0, 95.0, 99.0]);
    let decision_b = batched.decision.percentiles(&[50.0, 95.0, 99.0]);
    let json = Json::from_pairs(vec![
        ("bench", Json::from("service_soak")),
        (
            "config",
            Json::from_pairs(vec![
                ("masters", Json::from(cfg.masters)),
                ("jobs", Json::from(cfg.jobs)),
                ("mean_interval", Json::from(cfg.mean_interval)),
                ("executors", Json::from(cfg.executors)),
                ("algo", Json::from(cfg.algo.clone())),
                ("seed", Json::from(cfg.seed as usize)),
                ("status_every", Json::from(cfg.status_every)),
                ("monitors", Json::from(cfg.monitors)),
            ]),
        ),
        ("cases", Json::Arr(cases)),
        (
            "notes",
            Json::from_pairs(vec![
                ("jobs_per_sec_serial", Json::from(serial.jobs_per_sec)),
                ("jobs_per_sec_batched", Json::from(batched.jobs_per_sec)),
                (
                    "batched_speedup",
                    Json::from(batched.jobs_per_sec / serial.jobs_per_sec.max(1e-9)),
                ),
                ("decision_p50_ms_serial", Json::from(decision_s[0])),
                ("decision_p95_ms_serial", Json::from(decision_s[1])),
                ("decision_p99_ms_serial", Json::from(decision_s[2])),
                ("decision_p50_ms_batched", Json::from(decision_b[0])),
                ("decision_p95_ms_batched", Json::from(decision_b[1])),
                ("decision_p99_ms_batched", Json::from(decision_b[2])),
                (
                    "avg_batch_size",
                    Json::from(
                        batched.batched_requests as f64 / batched.batches.max(1) as f64,
                    ),
                ),
                (
                    "coalesced_heartbeats",
                    Json::from(batched.coalesced_heartbeats as f64),
                ),
            ]),
        ),
    ]);
    std::fs::write(out_json, format!("{}\n", json.to_string()))
        .with_context(|| format!("writing {out_json}"))?;
    crate::log_info!("wrote {out_json}");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end smoke at toy scale: both modes complete, every job is
    /// acknowledged, latencies are recorded, and the bench JSON lands.
    #[test]
    fn soak_smoke_both_modes() {
        let cfg = SoakConfig {
            masters: 2,
            jobs: 8,
            mean_interval: 1.0,
            executors: 6,
            algo: "FIFO-DEFT".to_string(),
            seed: 11,
            status_every: 1,
            monitors: 1,
        };
        let src = PolicySource {
            backend: "rust".to_string(),
            ..PolicySource::default()
        };
        let out = std::env::temp_dir().join(format!(
            "lachesis_soak_test_{}.json",
            std::process::id()
        ));
        let out_path = out.to_str().unwrap().to_string();
        let md = soak(&cfg, &src, &out_path).unwrap();
        assert!(md.contains("decision/serial"));
        assert!(md.contains("decision/batched"));
        let raw = std::fs::read_to_string(&out_path).unwrap();
        assert!(raw.contains("jobs_per_sec_serial"));
        assert!(raw.contains("jobs_per_sec_batched"));
        std::fs::remove_file(&out_path).ok();
    }

    /// The per-mode runner reports every submitted job and a decision
    /// sample per job.
    #[test]
    fn soak_mode_accounts_every_job() {
        let cfg = SoakConfig {
            masters: 3,
            jobs: 7, // deliberately not divisible by masters
            mean_interval: 1.0,
            executors: 4,
            algo: "FIFO-DEFT".to_string(),
            seed: 5,
            status_every: 2,
            monitors: 0,
        };
        let src = PolicySource {
            backend: "rust".to_string(),
            ..PolicySource::default()
        };
        let rep = run_soak_mode(&cfg, &src, ServiceMode::Batched).unwrap();
        assert_eq!(rep.jobs, 7);
        assert_eq!(rep.decision.len(), 7);
        assert_eq!(rep.submit.len(), 7);
        assert!(rep.assignments > 0);
        assert!(rep.batches > 0);
        assert!(rep.jobs_per_sec > 0.0);
    }
}
