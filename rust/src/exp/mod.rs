//! Experiment harness: regenerates every figure of the paper's evaluation
//! (§5.3) as printed series + CSV files under `results/`.
//!
//! * Fig 4 — learning curve (loss/return vs episode): [`fig4`]
//! * Fig 5a–d — batch mode, small scale (1–20 jobs): [`fig5`]
//! * Fig 6a–d — batch mode, large scale (20–100 jobs): [`fig6`]
//! * Fig 7a–b — continuous mode (Poisson 45 s arrivals): [`fig7`]
//! * Ablations (DESIGN.md §Per-experiment index): [`ablate`]
//! * Service soak (sustained Poisson arrivals over TCP): [`soak`]

pub mod soak;

use crate::cluster::Cluster;
use crate::config::{ClusterConfig, ExperimentConfig, FaultConfig, TrainConfig, WorkloadConfig};
use crate::fault::FaultPlan;
use crate::metrics::{ScheduleReport, SuiteReport};
use crate::policy::features::FeatureMode;
use crate::policy::{params, PolicyEval, RustPolicy};
use crate::rl::cpu_backend::{CpuTrainBackend, CPU_TRAIN_BATCH};
#[cfg(feature = "pjrt")]
use crate::rl::trainer::PjrtTrainBackend;
use crate::rl::trainer::{TrainBackend, Trainer};
#[cfg(feature = "pjrt")]
use crate::runtime::PjrtPolicy;
use crate::util::par::par_indexed;
use crate::sched::{
    CpopScheduler, DecimaScheduler, DlsScheduler, FifoScheduler, HeftScheduler,
    HighRankUpScheduler, HrrnScheduler, LachesisScheduler, RandomScheduler, Scheduler,
    SjfScheduler, TdcaScheduler,
};
use crate::sim::Simulator;
use crate::workload::WorkloadGenerator;
use anyhow::{bail, Context, Result};

/// Where learned-policy weights come from for the evaluation runs.
#[derive(Debug, Clone)]
pub struct PolicySource {
    pub artifact_dir: String,
    /// Trained Lachesis weights; falls back to `params_init.bin`, then to
    /// a random rust-side init (with a warning) so sweeps never block.
    pub lachesis_params: Option<String>,
    pub decima_params: Option<String>,
    /// `pjrt` (the AOT artifact — production path) or `rust` (reference
    /// forward; used when artifacts are unavailable).
    pub backend: String,
}

impl Default for PolicySource {
    fn default() -> Self {
        PolicySource {
            artifact_dir: "artifacts".to_string(),
            lachesis_params: None,
            decima_params: None,
            backend: "pjrt".to_string(),
        }
    }
}

impl PolicySource {
    /// Resolve the parameter vector for one policy flavor. Preference
    /// order: explicit checkpoint → trained default location →
    /// `params_init.bin` → random init (with a warning) so runs never
    /// block on a missing file.
    fn load_params(&self, which: FeatureMode) -> Vec<f32> {
        let explicit = match which {
            FeatureMode::Full => self.lachesis_params.as_deref(),
            FeatureMode::HomogeneousBlind => self.decima_params.as_deref(),
        };
        let default_ckpt = match which {
            FeatureMode::Full => "checkpoints/lachesis.bin",
            FeatureMode::HomogeneousBlind => "checkpoints/decima.bin",
        };
        let init = format!("{}/params_init.bin", self.artifact_dir);
        let candidates: Vec<&str> = match explicit {
            Some(p) => vec![p],
            None => vec![default_ckpt, &init],
        };
        let params = candidates.iter().find_map(|p| {
            params::load_expected(p, crate::policy::net::param_len()).ok()
        });
        match params {
            Some(p) => p,
            None => {
                crate::log_warn!(
                    "no parameter file found (tried {:?}); using random init",
                    candidates
                );
                RustPolicy::random_params(12345)
            }
        }
    }

    fn eval_for(&self, which: FeatureMode) -> Box<dyn PolicyEval> {
        let params = self.load_params(which);
        if self.backend == "pjrt" {
            #[cfg(feature = "pjrt")]
            match PjrtPolicy::with_params(&self.artifact_dir, params.clone()) {
                Ok(p) => return Box::new(p),
                Err(e) => {
                    crate::log_warn!("PJRT backend unavailable ({e}); using rust forward");
                }
            }
            #[cfg(not(feature = "pjrt"))]
            crate::log_warn!("built without the `pjrt` feature; using rust forward");
        }
        Box::new(RustPolicy::new(params))
    }

    /// The rust-side forward for `which`, regardless of the configured
    /// backend — what the long-lived service uses (the PJRT runtime is
    /// not `Send`, and a server moves its scheduler across threads).
    pub fn rust_eval_for(&self, which: FeatureMode) -> RustPolicy {
        RustPolicy::new(self.load_params(which))
    }
}

/// Build a scheduler by name. Names match the paper's figure legends.
pub fn build_scheduler(name: &str, src: &PolicySource, seed: u64) -> Result<Box<dyn Scheduler>> {
    Ok(match name {
        "FIFO-DEFT" => Box::new(FifoScheduler::new()),
        "SJF-DEFT" => Box::new(SjfScheduler::new()),
        "HRRN-DEFT" => Box::new(HrrnScheduler::new()),
        "HighRankUp-DEFT" => Box::new(HighRankUpScheduler::new()),
        "HEFT" => Box::new(HeftScheduler::new()),
        "CPOP" => Box::new(CpopScheduler::new()),
        "DLS" => Box::new(DlsScheduler::new()),
        "TDCA" => Box::new(TdcaScheduler::new()),
        "Random-DEFT" => Box::new(RandomScheduler::new(seed)),
        "Decima-DEFT" => Box::new(DecimaScheduler::greedy_decima(
            src.eval_for(FeatureMode::HomogeneousBlind),
        )),
        "Lachesis" => Box::new(LachesisScheduler::greedy(src.eval_for(FeatureMode::Full))),
        other => bail!("unknown scheduler '{other}'"),
    })
}

/// Build a scheduler by name as a `Send` box — what the service and the
/// soak harness need (the scheduler lives behind the server's mutex and
/// moves across threads). Learned policies always use the rust forward:
/// the PJRT runtime is not `Send`.
pub fn build_send_scheduler(
    name: &str,
    src: &PolicySource,
    seed: u64,
) -> Result<Box<dyn Scheduler + Send>> {
    Ok(match name {
        "FIFO-DEFT" => Box::new(FifoScheduler::new()),
        "SJF-DEFT" => Box::new(SjfScheduler::new()),
        "HRRN-DEFT" => Box::new(HrrnScheduler::new()),
        "HighRankUp-DEFT" => Box::new(HighRankUpScheduler::new()),
        "HEFT" => Box::new(HeftScheduler::new()),
        "CPOP" => Box::new(CpopScheduler::new()),
        "DLS" => Box::new(DlsScheduler::new()),
        "TDCA" => Box::new(TdcaScheduler::new()),
        "Random-DEFT" => Box::new(RandomScheduler::new(seed)),
        "Decima-DEFT" => Box::new(DecimaScheduler::greedy_decima(Box::new(
            src.rust_eval_for(FeatureMode::HomogeneousBlind),
        ))),
        "Lachesis" => Box::new(LachesisScheduler::greedy(Box::new(
            src.rust_eval_for(FeatureMode::Full),
        ))),
        other => bail!("unknown scheduler '{other}'"),
    })
}

/// One (job_count, seed, algo) cell of a sweep — the unit of
/// parallelism. Every cell owns its cluster, scheduler and simulator
/// and clones its workload, so cells are embarrassingly parallel; only
/// report collection is shared.
struct SweepCell<'a> {
    x: usize,
    seed: u64,
    algo: &'a str,
    /// Index into the per-(x, seed) shared workload table (workloads are
    /// generated once per (x, seed), not once per algorithm).
    workload: usize,
}

/// Run one sweep cell in isolation. Fully deterministic in (x, seed,
/// algo): the workload and cluster derive from the seed alone, so a
/// cell computes the same schedule no matter which worker runs it.
fn run_cell(
    cfg: &ExperimentConfig,
    x: usize,
    seed: u64,
    algo: &str,
    workload: &crate::workload::Workload,
    src: &PolicySource,
) -> Result<(usize, ScheduleReport)> {
    let cluster = Cluster::heterogeneous(&cfg.cluster, seed);
    let mut sched = build_scheduler(algo, src, seed)?;
    let mut sim = Simulator::new(cluster, workload.clone());
    let report = sim
        .run(sched.as_mut())
        .with_context(|| format!("{algo} on {x} jobs, seed {seed}"))?;
    crate::log_debug!("cell {algo} x={x} seed={seed} done");
    Ok((x, report))
}

/// Run one figure sweep: job_counts × seeds × algorithms, sequentially.
pub fn sweep(cfg: &ExperimentConfig, algos: &[&str], src: &PolicySource) -> Result<SuiteReport> {
    sweep_threaded(cfg, algos, src, 1)
}

/// Run one figure sweep with `threads` workers fanning out over the
/// (job_count, seed, algo) cells. Results are collected into
/// pre-indexed slots, so the returned `SuiteReport` has exactly the
/// sequential insertion order regardless of worker interleaving — every
/// schedule-derived metric (and the CSV/table rendering) is identical to
/// the `threads == 1` run. Only the measured decision *latencies*
/// differ, since those are wall-clock timings.
pub fn sweep_threaded(
    cfg: &ExperimentConfig,
    algos: &[&str],
    src: &PolicySource,
    threads: usize,
) -> Result<SuiteReport> {
    let threads = threads.max(1);
    let n_cells = cfg.job_counts.len() * cfg.seeds.len() * algos.len();
    let mut suite = SuiteReport::new();
    if threads <= 1 || n_cells <= 1 {
        // Sequential: one live workload at a time (generated per
        // (x, seed), shared across algos), failing at the first error.
        for &x in &cfg.job_counts {
            for &seed in &cfg.seeds {
                let mut wcfg = cfg.workload_base.clone();
                wcfg.n_jobs = x;
                let workload = WorkloadGenerator::new(wcfg, seed).generate();
                for &algo in algos {
                    let (x, report) = run_cell(cfg, x, seed, algo, &workload, src)?;
                    suite.push(x, report);
                }
            }
        }
    } else {
        // Pregenerate the shared per-(x, seed) workload table so worker
        // threads only clone, never regenerate.
        let mut workloads = Vec::new();
        let mut cells = Vec::new();
        for &x in &cfg.job_counts {
            for &seed in &cfg.seeds {
                let mut wcfg = cfg.workload_base.clone();
                wcfg.n_jobs = x;
                workloads.push(WorkloadGenerator::new(wcfg, seed).generate());
                let workload = workloads.len() - 1;
                for &algo in algos {
                    cells.push(SweepCell { x, seed, algo, workload });
                }
            }
        }
        let workloads = &workloads[..];
        let results = par_indexed(&cells, threads, |c| {
            run_cell(cfg, c.x, c.seed, c.algo, &workloads[c.workload], src)
        })?;
        for (x, report) in results {
            suite.push(x, report);
        }
    }
    crate::log_info!("sweep complete: {n_cells} cells on {threads} thread(s)");
    Ok(suite)
}

pub(crate) fn write_results(name: &str, content: &str) -> Result<()> {
    std::fs::create_dir_all("results").context("mkdir results")?;
    let path = format!("results/{name}");
    std::fs::write(&path, content).with_context(|| format!("writing {path}"))?;
    crate::log_info!("wrote {path}");
    Ok(())
}

/// The batch-mode algorithm set of Figs 5–6.
pub const BATCH_ALGOS: [&str; 5] = ["FIFO-DEFT", "TDCA", "HEFT", "Decima-DEFT", "Lachesis"];
/// The continuous-mode algorithm set of Fig 7.
pub const CONT_ALGOS: [&str; 5] = [
    "SJF-DEFT",
    "HRRN-DEFT",
    "HighRankUp-DEFT",
    "Decima-DEFT",
    "Lachesis",
];

/// Fig 5: batch mode, small scale. `quick` shrinks the sweep for CI;
/// `threads` fans the sweep cells out over that many workers.
pub fn fig5(src: &PolicySource, quick: bool, seeds: usize, threads: usize) -> Result<String> {
    let cfg = ExperimentConfig {
        cluster: ClusterConfig::default(),
        workload_base: WorkloadConfig::small_batch(1),
        job_counts: if quick {
            vec![2, 6]
        } else {
            vec![1, 2, 4, 6, 8, 10, 12, 14, 16, 18, 20]
        },
        seeds: (0..seeds as u64).map(|s| 1000 + s).collect(),
    };
    let suite = sweep_threaded(&cfg, &BATCH_ALGOS, src, threads)?;
    let mut out = String::from("# Fig 5 — batch mode, small scale\n\n");
    out.push_str(&suite.table("makespan", "Fig 5a: average makespan (s)"));
    out.push_str(&suite.table("speedup", "Fig 5b: speedup (Eq 13)"));
    out.push_str(&suite.table("slr", "Fig 5c: SLR (Eq 14)"));
    out.push_str(&suite.table("p98", "Fig 5d: p98 decision time (ms)"));
    out.push_str(&decision_cdf_section(&suite, &BATCH_ALGOS));
    write_results("fig5.md", &out)?;
    write_results("fig5.csv", &suite.to_csv())?;
    Ok(out)
}

/// Fig 6: batch mode, large scale (the −26.7% makespan / +35.2% speedup
/// headline setting).
pub fn fig6(src: &PolicySource, quick: bool, seeds: usize, threads: usize) -> Result<String> {
    let cfg = ExperimentConfig {
        cluster: ClusterConfig::default(),
        workload_base: WorkloadConfig::large_batch(1),
        job_counts: if quick {
            vec![20, 40]
        } else {
            vec![20, 30, 40, 50, 60, 70, 80, 90, 100]
        },
        seeds: (0..seeds as u64).map(|s| 2000 + s).collect(),
    };
    let suite = sweep_threaded(&cfg, &BATCH_ALGOS, src, threads)?;
    let mut out = String::from("# Fig 6 — batch mode, large scale\n\n");
    out.push_str(&suite.table("makespan", "Fig 6a: average makespan (s)"));
    out.push_str(&suite.table("speedup", "Fig 6b: speedup (Eq 13)"));
    out.push_str(&suite.table("slr", "Fig 6c: SLR (Eq 14)"));
    out.push_str(&suite.table("p98", "Fig 6d: p98 decision time (ms)"));
    out.push_str(&decision_cdf_section(&suite, &BATCH_ALGOS));
    out.push_str(&headline_section(&suite));
    write_results("fig6.md", &out)?;
    write_results("fig6.csv", &suite.to_csv())?;
    Ok(out)
}

/// Fig 7: continuous mode (Poisson arrivals, mean 45 s).
pub fn fig7(src: &PolicySource, quick: bool, seeds: usize, threads: usize) -> Result<String> {
    let cfg = ExperimentConfig {
        cluster: ClusterConfig::default(),
        workload_base: WorkloadConfig::continuous(1),
        job_counts: if quick {
            vec![5, 15]
        } else {
            vec![10, 20, 30, 40, 50, 60, 70, 80]
        },
        seeds: (0..seeds as u64).map(|s| 3000 + s).collect(),
    };
    let suite = sweep_threaded(&cfg, &CONT_ALGOS, src, threads)?;
    let mut out = String::from("# Fig 7 — continuous mode (Poisson, mean 45 s)\n\n");
    out.push_str(&suite.table("makespan", "Fig 7a: average makespan (s)"));
    out.push_str(&suite.table(
        "jct",
        "Fig 7a′ (supplementary): average job completion time (s) — at the \
paper's 45 s mean inter-arrival our simulated cluster is underloaded, so \
total makespan is arrival-dominated and JCT is the discriminating metric",
    ));
    out.push_str(&suite.table("p98", "Fig 7b: p98 decision time (ms)"));
    out.push_str(&decision_cdf_section(&suite, &CONT_ALGOS));
    write_results("fig7.md", &out)?;
    write_results("fig7.csv", &suite.to_csv())?;
    Ok(out)
}

/// Fig 4: the learning curve. Prefers the AOT `train_step` artifact
/// (`pjrt` feature + artifacts on disk); otherwise trains through the
/// native CPU gradient backend — same loss, clip and Adam numerics — so
/// the figure reproduces on a plain `cargo build`. Initial parameters
/// come from `params_init.bin` when present, else a seeded random init.
pub fn fig4(cfg: &TrainConfig, artifact_dir: &str, out_params: &str) -> Result<String> {
    let init_path = format!("{artifact_dir}/params_init.bin");
    #[cfg(feature = "pjrt")]
    {
        let pjrt = params::load_expected(&init_path, crate::policy::net::param_len())
            .and_then(|init| PjrtTrainBackend::new(artifact_dir, init));
        match pjrt {
            Ok(backend) => {
                let batch = backend.batch_size();
                return fig4_run(cfg, backend, batch, out_params);
            }
            Err(e) => {
                crate::log_warn!("PJRT train backend unavailable ({e}); using the CPU backend");
            }
        }
    }
    let init = params::load_expected(&init_path, crate::policy::net::param_len())
        .unwrap_or_else(|_| RustPolicy::random_params(cfg.seed));
    fig4_run(cfg, CpuTrainBackend::new(init), CPU_TRAIN_BATCH, out_params)
}

/// The backend-generic fig4 body: train, dump the per-episode CSV, save
/// the trained parameters, render the text chart.
fn fig4_run<B: TrainBackend>(
    cfg: &TrainConfig,
    backend: B,
    batch: usize,
    out_params: &str,
) -> Result<String> {
    let mut trainer = Trainer::new(cfg.clone(), backend, FeatureMode::Full);
    let stats = trainer.train(batch)?;
    let mut csv = String::from(crate::rl::trainer::EpisodeStat::csv_header());
    csv.push('\n');
    for s in &stats {
        csv.push_str(&s.csv_row());
        csv.push('\n');
    }
    write_results("fig4_learning_curve.csv", &csv)?;
    if let Some(dir) = std::path::Path::new(out_params).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    params::save_f32(out_params, trainer.backend.params())?;
    crate::log_info!("saved trained parameters to {out_params}");
    // Render a compact textual learning curve + ASCII chart of the
    // held-out greedy eval makespan.
    let eval_series: Vec<(f64, f64)> = stats
        .iter()
        .filter(|s| s.eval_makespan.is_finite())
        .map(|s| (s.episode as f64, s.eval_makespan))
        .collect();
    let chart = crate::metrics::chart::line_chart(
        "greedy eval makespan (s) vs episode",
        &[("eval", eval_series)],
        70,
        14,
    );
    let mut out = format!(
        "# Fig 4 — learning curve ({} backend)\n\nepisode  avg-makespan  loss\n",
        trainer.backend.name()
    );
    let stride = (stats.len() / 20).max(1);
    for s in stats.iter().step_by(stride) {
        out.push_str(&format!(
            "{:>7}  {:>12.1}  {:>8.4}\n",
            s.episode, s.makespan, s.loss
        ));
    }
    if let (Some(first), Some(last)) = (stats.first(), stats.last()) {
        out.push_str(&format!(
            "\nfirst-episode makespan {:.1}s → last {:.1}s\n\n",
            first.makespan, last.makespan
        ));
    }
    out.push_str(&chart);
    write_results("fig4.md", &out)?;
    Ok(out)
}

/// Ablations over the design choices DESIGN.md calls out: DEFT vs EFT in
/// phase 2, and the value of duplication across network speeds.
pub fn ablate(src: &PolicySource, seeds: usize, threads: usize) -> Result<String> {
    use crate::sched::selectors::RankUpSelector;
    use crate::sched::{EftAllocator, TwoPhase};
    let mut out = String::from("# Ablations\n\n");

    // (a) phase-2 allocator: rank_up selector with EFT vs DEFT, across
    // communication speeds. The (comm, seed) cells are embarrassingly
    // parallel, exactly like sweep cells; results reduce in input order
    // so the table is identical at any thread count.
    out.push_str("## DEFT vs EFT (phase-2 allocator) across network speeds\n\n");
    out.push_str("| comm MB/s | EFT makespan | DEFT makespan | DEFT dup count | gain |\n|---|---|---|---|---|\n");
    const COMMS: [f64; 4] = [10.0, 50.0, 100.0, 500.0];
    let cells: Vec<(f64, u64)> = COMMS
        .iter()
        .flat_map(|&comm| (0..seeds as u64).map(move |seed| (comm, seed)))
        .collect();
    let results = par_indexed(&cells, threads, |&(comm, seed)| {
        let mut ccfg = ClusterConfig::default();
        ccfg.comm_mbps = comm;
        let w = WorkloadGenerator::new(WorkloadConfig::large_batch(20), 4000 + seed).generate();
        let r1 = Simulator::new(Cluster::heterogeneous(&ccfg, seed), w.clone())
            .run(&mut TwoPhase::named(RankUpSelector, EftAllocator::new(), "rankup-eft"))?;
        let r2 = Simulator::new(Cluster::heterogeneous(&ccfg, seed), w)
            .run(&mut HighRankUpScheduler::new())?;
        Ok((r1.makespan, r2.makespan, r2.n_duplicates))
    })?;
    for (ci, &comm) in COMMS.iter().enumerate() {
        let cell = &results[ci * seeds..(ci + 1) * seeds];
        let eft_ms: Vec<f64> = cell.iter().map(|r| r.0).collect();
        let deft_ms: Vec<f64> = cell.iter().map(|r| r.1).collect();
        let dups: usize = cell.iter().map(|r| r.2).sum();
        let (e, d) = (
            crate::util::stats::mean(&eft_ms),
            crate::util::stats::mean(&deft_ms),
        );
        out.push_str(&format!(
            "| {comm} | {e:.1} | {d:.1} | {:.1} | {:.1}% |\n",
            // Mean duplicate count across seeds; integer division would
            // truncate (e.g. 5 dups over 3 seeds reported as 1).
            dups as f64 / seeds.max(1) as f64,
            100.0 * (e - d) / e
        ));
    }

    // (b) selector ablation at fixed allocator (all DEFT).
    out.push_str("\n## Phase-1 selector (all with DEFT)\n\n");
    let cfg = ExperimentConfig {
        cluster: ClusterConfig::default(),
        workload_base: WorkloadConfig::large_batch(1),
        job_counts: vec![30],
        seeds: (0..seeds as u64).map(|s| 5000 + s).collect(),
    };
    let suite = sweep_threaded(
        &cfg,
        &[
            "Random-DEFT",
            "FIFO-DEFT",
            "SJF-DEFT",
            "HRRN-DEFT",
            "HighRankUp-DEFT",
            "Lachesis",
        ],
        src,
        threads,
    )?;
    out.push_str(&suite.table("makespan", "makespan at 30 jobs"));
    write_results("ablations.md", &out)?;
    Ok(out)
}

/// The robustness-sweep scheduler set: the zoo families that matter for
/// fault tolerance (with and without duplication, learned and heuristic).
pub const FAULT_ALGOS: [&str; 5] = [
    "FIFO-DEFT",
    "HighRankUp-DEFT",
    "HEFT",
    "TDCA",
    "Lachesis",
];

/// The robustness sweep's default failure rates (per-executor incidents
/// per second): a reliable baseline plus three escalating regimes.
pub const FAULT_RATES: [f64; 4] = [0.0, 2e-4, 1e-3, 5e-3];

/// Robustness sweep: run each scheduler under escalating failure rates
/// and report makespan degradation plus recovery counters. Rides the
/// same threaded cell fan-out as the figure sweeps; every cell is
/// deterministic in `(rate, seed, algo)` (the fault plan derives from
/// the config and seed alone), so the CSV is byte-identical at any
/// thread count. Each cell also runs `validate()`, pinning the blackout
/// and rollback invariants on every schedule the sweep produces.
pub fn fault_sweep(
    src: &PolicySource,
    rates: &[f64],
    jobs: usize,
    seeds: usize,
    threads: usize,
) -> Result<String> {
    if rates.is_empty() {
        bail!("fault sweep needs at least one failure rate");
    }
    // Sort + dedup: a repeated rate would double-count every aggregate
    // (same agg key, twice the cells) and print the inflated row twice.
    let mut rates: Vec<f64> = rates.to_vec();
    rates.sort_by(|a, b| a.total_cmp(b));
    rates.dedup_by(|a, b| a.to_bits() == b.to_bits());
    let rates = &rates[..];
    let ccfg = ClusterConfig::default();
    let seed_list: Vec<u64> = (0..seeds as u64).map(|s| 6000 + s).collect();
    // Workloads are shared per seed (the failure rate must not change
    // the workload, or the degradation column would be confounded).
    let workloads: Vec<crate::workload::Workload> = seed_list
        .iter()
        .map(|&seed| WorkloadGenerator::new(WorkloadConfig::large_batch(jobs), seed).generate())
        .collect();
    struct FaultCell<'a> {
        rate: f64,
        seed: u64,
        algo: &'a str,
        workload: usize,
    }
    let mut cells: Vec<FaultCell> = Vec::new();
    for &rate in rates {
        for (wi, &seed) in seed_list.iter().enumerate() {
            for &algo in &FAULT_ALGOS {
                cells.push(FaultCell {
                    rate,
                    seed,
                    algo,
                    workload: wi,
                });
            }
        }
    }
    let workloads = &workloads[..];
    let results = par_indexed(&cells, threads, |c| {
        let cluster = Cluster::heterogeneous(&ccfg, c.seed);
        let plan = FaultPlan::generate(&FaultConfig::with_rate(c.rate), cluster.len(), c.seed);
        let mut sched = build_scheduler(c.algo, src, c.seed)?;
        let mut sim = Simulator::with_faults(cluster, workloads[c.workload].clone(), &plan);
        let report = sim
            .run(sched.as_mut())
            .with_context(|| format!("{} at rate {} seed {}", c.algo, c.rate, c.seed))?;
        sim.state
            .validate()
            .with_context(|| format!("{} at rate {} seed {}", c.algo, c.rate, c.seed))?;
        Ok(report)
    })?;

    // Aggregate per (algo, rate) in input order.
    struct Agg {
        makespan: Vec<f64>,
        crashes: usize,
        straggles: usize,
        cancelled: usize,
        requeued: usize,
        dup_survived: usize,
    }
    let mut agg: Vec<((String, u64), Agg)> = Vec::new(); // rate keyed by bits for exact lookup
    for (c, r) in cells.iter().zip(&results) {
        let key = (c.algo.to_string(), c.rate.to_bits());
        let idx = match agg.iter().position(|(k, _)| *k == key) {
            Some(i) => i,
            None => {
                agg.push((
                    key,
                    Agg {
                        makespan: Vec::new(),
                        crashes: 0,
                        straggles: 0,
                        cancelled: 0,
                        requeued: 0,
                        dup_survived: 0,
                    },
                ));
                agg.len() - 1
            }
        };
        let slot = &mut agg[idx].1;
        slot.makespan.push(r.makespan);
        slot.crashes += r.faults.n_crashes;
        slot.straggles += r.faults.n_straggles;
        slot.cancelled += r.faults.n_cancelled;
        slot.requeued += r.faults.n_requeued;
        slot.dup_survived += r.faults.n_dup_survived;
    }
    let mean_of = |algo: &str, rate: f64| -> Option<f64> {
        agg.iter()
            .find(|(k, _)| k.0 == algo && k.1 == rate.to_bits())
            .map(|(_, a)| crate::util::stats::mean(&a.makespan))
    };
    let baseline_rate = rates
        .iter()
        .copied()
        .fold(f64::INFINITY, f64::min);

    let mut out = String::from(
        "# Fault robustness — makespan degradation & recovery vs failure rate\n\n",
    );
    out.push_str(&format!(
        "{jobs} jobs (large-batch TPC-H), {} executors, {} seeds; rates are \
         per-executor incidents/second\n\n",
        ccfg.n_executors, seeds
    ));
    out.push_str("### Mean makespan (s)\n\n| rate |");
    for a in FAULT_ALGOS {
        out.push_str(&format!(" {a} |"));
    }
    out.push_str("\n|---|");
    out.push_str(&"---|".repeat(FAULT_ALGOS.len()));
    out.push('\n');
    for &rate in rates {
        out.push_str(&format!("| {rate} |"));
        for a in FAULT_ALGOS {
            match mean_of(a, rate) {
                Some(m) => out.push_str(&format!(" {m:.1} |")),
                None => out.push_str(" - |"),
            }
        }
        out.push('\n');
    }
    out.push_str("\n### Degradation vs the most reliable rate (%)\n\n| rate |");
    for a in FAULT_ALGOS {
        out.push_str(&format!(" {a} |"));
    }
    out.push_str("\n|---|");
    out.push_str(&"---|".repeat(FAULT_ALGOS.len()));
    out.push('\n');
    for &rate in rates {
        out.push_str(&format!("| {rate} |"));
        for a in FAULT_ALGOS {
            match (mean_of(a, rate), mean_of(a, baseline_rate)) {
                (Some(m), Some(b)) if b > 0.0 => {
                    out.push_str(&format!(" {:+.1}% |", 100.0 * (m - b) / b))
                }
                _ => out.push_str(" - |"),
            }
        }
        out.push('\n');
    }
    out.push_str(
        "\n### Recovery counters (totals across seeds)\n\n\
         | algo | rate | crashes | straggles | cancelled | requeued | saved-by-dup |\n\
         |---|---|---|---|---|---|---|\n",
    );
    let mut csv = String::from(
        "algo,rate,n_seeds,makespan,degradation_pct,crashes,straggles,cancelled,\
         requeued,dup_survived\n",
    );
    for a in FAULT_ALGOS {
        for &rate in rates {
            let Some((_, s)) = agg
                .iter()
                .find(|(k, _)| k.0 == a && k.1 == rate.to_bits())
            else {
                continue;
            };
            let m = crate::util::stats::mean(&s.makespan);
            let b = mean_of(a, baseline_rate).unwrap_or(m);
            let deg = if b > 0.0 { 100.0 * (m - b) / b } else { 0.0 };
            out.push_str(&format!(
                "| {a} | {rate} | {} | {} | {} | {} | {} |\n",
                s.crashes, s.straggles, s.cancelled, s.requeued, s.dup_survived
            ));
            csv.push_str(&format!(
                "{a},{rate},{},{m:.6},{deg:.6},{},{},{},{},{}\n",
                s.makespan.len(),
                s.crashes,
                s.straggles,
                s.cancelled,
                s.requeued,
                s.dup_survived
            ));
        }
    }
    out.push('\n');
    write_results("fault_robustness.md", &out)?;
    write_results("fault_robustness.csv", &csv)?;
    Ok(out)
}

/// The locality-sweep scheduler set: list-scheduling heuristics with and
/// without duplication plus the learned policy.
pub const LOCALITY_ALGOS: [&str; 5] = [
    "FIFO-DEFT",
    "HighRankUp-DEFT",
    "HEFT",
    "TDCA",
    "Lachesis",
];

/// The topologies the locality sweep compares on the default 50-executor
/// cluster: the paper's uniform model, a 5-rack tree, and an 8-ary
/// fat-tree (capacity 128).
pub const LOCALITY_NETS: [&str; 3] = ["flat", "tree:5x10", "fat-tree:8"];

/// Cross-rack traffic of a finished schedule: for every parent→child
/// edge whose child has a primary placement, the edge's bytes count as
/// cross-rack when *no* copy of the parent (primary or duplicate) shares
/// the child's rack — the transfer must cross an uplink. Zero under
/// `flat` (one rack).
fn cross_rack_mb(state: &crate::sim::SimState) -> f64 {
    let mut mb = 0.0f64;
    for (ji, job) in state.jobs.iter().enumerate() {
        for node in 0..job.n_tasks() {
            let Some(pl) = state.placements[ji][node].iter().find(|p| !p.duplicate) else {
                continue;
            };
            for e in &job.parents[node] {
                let copies = &state.placements[ji][e.other];
                if !copies.is_empty()
                    && !copies
                        .iter()
                        .any(|pc| state.cluster.same_rack(pc.exec, pl.exec))
                {
                    mb += e.data;
                }
            }
        }
    }
    mb
}

/// Topology-locality sweep: every scheduler runs the same workloads on
/// the same cluster (speeds depend on the seed alone, so they are
/// identical across topologies) under each of [`LOCALITY_NETS`], and the
/// figure reports mean makespan, duplicate count, cross-rack traffic,
/// and how many primary placements moved relative to the flat run —
/// the direct evidence that topology awareness changes decisions.
pub fn locality(
    src: &PolicySource,
    jobs: usize,
    seeds: usize,
    threads: usize,
) -> Result<String> {
    let nets: Vec<crate::net::NetConfig> = LOCALITY_NETS
        .iter()
        .map(|s| crate::net::NetConfig::parse(s))
        .collect::<Result<Vec<_>>>()?;
    let ccfg_base = ClusterConfig::default();
    let seed_list: Vec<u64> = (0..seeds as u64).map(|s| 7000 + s).collect();
    // Workloads are shared per seed: the topology must not change the
    // workload, or the comparison would be confounded.
    let workloads: Vec<crate::workload::Workload> = seed_list
        .iter()
        .map(|&seed| WorkloadGenerator::new(WorkloadConfig::large_batch(jobs), seed).generate())
        .collect();
    struct LocCell<'a> {
        net: usize,
        seed: u64,
        algo: &'a str,
        workload: usize,
    }
    let mut cells: Vec<LocCell> = Vec::new();
    for net in 0..nets.len() {
        for (wi, &seed) in seed_list.iter().enumerate() {
            for &algo in &LOCALITY_ALGOS {
                cells.push(LocCell {
                    net,
                    seed,
                    algo,
                    workload: wi,
                });
            }
        }
    }
    struct LocResult {
        makespan: f64,
        duplicates: usize,
        cross_mb: f64,
        /// Primary executor per task, in (job, node) scan order — the
        /// placement signature compared across topologies.
        primaries: Vec<usize>,
    }
    let workloads = &workloads[..];
    let nets_ref = &nets[..];
    let results = par_indexed(&cells, threads, |c| {
        let mut ccfg = ccfg_base.clone();
        ccfg.net = nets_ref[c.net].clone();
        let cluster = Cluster::heterogeneous(&ccfg, c.seed);
        let mut sched = build_scheduler(c.algo, src, c.seed)?;
        let mut sim = Simulator::new(cluster, workloads[c.workload].clone());
        let report = sim
            .run(sched.as_mut())
            .with_context(|| format!("{} on {} seed {}", c.algo, LOCALITY_NETS[c.net], c.seed))?;
        sim.state
            .validate()
            .with_context(|| format!("{} on {} seed {}", c.algo, LOCALITY_NETS[c.net], c.seed))?;
        let mut primaries = Vec::new();
        for (ji, job) in sim.state.jobs.iter().enumerate() {
            for node in 0..job.n_tasks() {
                let exec = sim.state.placements[ji][node]
                    .iter()
                    .find(|p| !p.duplicate)
                    .map(|p| p.exec)
                    .unwrap_or(usize::MAX);
                primaries.push(exec);
            }
        }
        Ok(LocResult {
            makespan: report.makespan,
            duplicates: report.n_duplicates,
            cross_mb: cross_rack_mb(&sim.state),
            primaries,
        })
    })?;

    // Aggregate per (algo, net); placement diffs compare each topology
    // cell to the flat cell of the same (algo, seed).
    let cell_at = |net: usize, seed: u64, algo: &str| -> Option<&LocResult> {
        cells
            .iter()
            .position(|c| c.net == net && c.seed == seed && c.algo == algo)
            .map(|i| &results[i])
    };
    struct Agg {
        makespan: Vec<f64>,
        duplicates: usize,
        cross_mb: f64,
        moved: usize,
    }
    let mut agg: Vec<((String, usize), Agg)> = Vec::new();
    for (c, r) in cells.iter().zip(&results) {
        let key = (c.algo.to_string(), c.net);
        let idx = match agg.iter().position(|(k, _)| *k == key) {
            Some(i) => i,
            None => {
                agg.push((
                    key,
                    Agg {
                        makespan: Vec::new(),
                        duplicates: 0,
                        cross_mb: 0.0,
                        moved: 0,
                    },
                ));
                agg.len() - 1
            }
        };
        let slot = &mut agg[idx].1;
        slot.makespan.push(r.makespan);
        slot.duplicates += r.duplicates;
        slot.cross_mb += r.cross_mb;
        if c.net != 0 {
            if let Some(flat) = cell_at(0, c.seed, c.algo) {
                slot.moved += flat
                    .primaries
                    .iter()
                    .zip(&r.primaries)
                    .filter(|(a, b)| a != b)
                    .count();
            }
        }
    }
    let get = |algo: &str, net: usize| -> Option<&Agg> {
        agg.iter()
            .find(|(k, _)| k.0 == algo && k.1 == net)
            .map(|(_, a)| a)
    };

    let mut out = String::from(
        "# Data locality — schedulers across network topologies\n\n",
    );
    out.push_str(&format!(
        "{jobs} jobs (large-batch TPC-H), {} executors, {} seeds; identical \
         workloads and executor speeds per seed across topologies\n\n",
        ccfg_base.n_executors, seeds
    ));
    let mut csv = String::from(
        "algo,net,n_seeds,makespan,duplicates,cross_rack_mb,placements_moved_vs_flat\n",
    );
    for (title, col) in [
        ("Mean makespan (s)", 0usize),
        ("Duplicates (total across seeds)", 1),
        ("Cross-rack traffic (MB, total)", 2),
        ("Primary placements moved vs flat (total)", 3),
    ] {
        out.push_str(&format!("### {title}\n\n| net |"));
        for a in LOCALITY_ALGOS {
            out.push_str(&format!(" {a} |"));
        }
        out.push_str("\n|---|");
        out.push_str(&"---|".repeat(LOCALITY_ALGOS.len()));
        out.push('\n');
        for (ni, net) in LOCALITY_NETS.iter().enumerate() {
            out.push_str(&format!("| {net} |"));
            for a in LOCALITY_ALGOS {
                match get(a, ni) {
                    Some(s) => match col {
                        0 => out.push_str(&format!(
                            " {:.1} |",
                            crate::util::stats::mean(&s.makespan)
                        )),
                        1 => out.push_str(&format!(" {} |", s.duplicates)),
                        2 => out.push_str(&format!(" {:.0} |", s.cross_mb)),
                        _ => out.push_str(&format!(" {} |", s.moved)),
                    },
                    None => out.push_str(" - |"),
                }
            }
            out.push('\n');
        }
        out.push('\n');
    }
    for a in LOCALITY_ALGOS {
        for (ni, net) in LOCALITY_NETS.iter().enumerate() {
            if let Some(s) = get(a, ni) {
                csv.push_str(&format!(
                    "{a},{net},{},{:.6},{},{:.3},{}\n",
                    s.makespan.len(),
                    crate::util::stats::mean(&s.makespan),
                    s.duplicates,
                    s.cross_mb,
                    s.moved
                ));
            }
        }
    }
    let total_moved: usize = agg
        .iter()
        .filter(|(k, _)| k.1 != 0)
        .map(|(_, a)| a.moved)
        .sum();
    out.push_str(&format!(
        "Placements moved on non-flat topologies (all schedulers): {total_moved}\n",
    ));
    write_results("locality.md", &out)?;
    write_results("locality.csv", &csv)?;
    Ok(out)
}

/// The decision-time CDF series the paper plots (Figs 5d/6d/7b).
fn decision_cdf_section(suite: &SuiteReport, algos: &[&str]) -> String {
    let mut out = String::from("### Decision-time CDF (ms)\n\n| algo | p50 | p90 | p98 | p99.9 | max |\n|---|---|---|---|---|---|\n");
    for &a in algos {
        let rec = suite.decision_recorder(a);
        if rec.is_empty() {
            continue;
        }
        let ps = rec.percentiles(&[50.0, 90.0, 98.0, 99.9]);
        out.push_str(&format!(
            "| {a} | {:.3} | {:.3} | {:.3} | {:.3} | {:.3} |\n",
            ps[0],
            ps[1],
            ps[2],
            ps[3],
            rec.max()
        ));
    }
    out.push('\n');
    out
}

/// The paper's headline claims, recomputed from the sweep: Lachesis'
/// makespan reduction and speedup improvement vs the best baseline.
fn headline_section(suite: &SuiteReport) -> String {
    let mut best_red = f64::NEG_INFINITY;
    let mut best_spd = f64::NEG_INFINITY;
    for x in suite.xs() {
        let Some(lach) = suite.summarize("Lachesis", x) else {
            continue;
        };
        let mut best_base_ms = f64::INFINITY;
        let mut best_base_spd = f64::NEG_INFINITY;
        for a in suite.algos() {
            if a == "Lachesis" {
                continue;
            }
            if let Some(s) = suite.summarize(&a, x) {
                best_base_ms = best_base_ms.min(s.makespan);
                best_base_spd = best_base_spd.max(s.speedup);
            }
        }
        // A sweep with no baseline cells at this x would otherwise leak
        // ±inf into the headline percentages.
        if !best_base_ms.is_finite() || !best_base_spd.is_finite() {
            continue;
        }
        best_red = best_red.max(100.0 * (best_base_ms - lach.makespan) / best_base_ms);
        best_spd = best_spd.max(100.0 * (lach.speedup - best_base_spd) / best_base_spd);
    }
    let pct = |v: f64| {
        if v.is_finite() {
            format!("{v:.1}%")
        } else {
            "n/a (no baseline cells)".to_string()
        }
    };
    format!(
        "### Headline (paper: ≤26.7% makespan reduction, ≤35.2% speedup gain)\n\n\
         max makespan reduction vs best baseline: {}\n\
         max speedup improvement vs best baseline: {}\n\n",
        pct(best_red),
        pct(best_spd)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_all_heuristic_schedulers() {
        let src = PolicySource {
            backend: "rust".into(),
            ..Default::default()
        };
        for name in [
            "FIFO-DEFT",
            "SJF-DEFT",
            "HRRN-DEFT",
            "HighRankUp-DEFT",
            "HEFT",
            "CPOP",
            "DLS",
            "TDCA",
            "Random-DEFT",
            "Decima-DEFT",
            "Lachesis",
        ] {
            let s = build_scheduler(name, &src, 1).unwrap();
            assert!(!s.name().is_empty());
        }
        assert!(build_scheduler("nope", &src, 1).is_err());
    }

    #[test]
    fn tiny_sweep_produces_all_cells() {
        let src = PolicySource {
            backend: "rust".into(),
            ..Default::default()
        };
        let cfg = ExperimentConfig {
            cluster: ClusterConfig::with_executors(6),
            workload_base: WorkloadConfig::small_batch(1),
            job_counts: vec![2, 3],
            seeds: vec![1, 2],
        };
        let suite = sweep(&cfg, &["FIFO-DEFT", "HEFT"], &src).unwrap();
        for algo in ["FIFO-DEFT", "HEFT"] {
            for x in [2, 3] {
                let s = suite.summarize(algo, x).unwrap();
                assert_eq!(s.n_seeds, 2);
                assert!(s.makespan > 0.0);
            }
        }
    }

    /// Strip the trailing decision-latency column: it is wall-clock
    /// measured, so it is the one CSV field that legitimately differs
    /// between otherwise identical runs.
    fn csv_without_timing(csv: &str) -> String {
        csv.lines()
            .map(|l| l.rsplit_once(',').map(|(head, _)| head).unwrap_or(l))
            .collect::<Vec<_>>()
            .join("\n")
    }

    #[test]
    fn threaded_sweep_matches_sequential_bitwise() {
        let src = PolicySource {
            backend: "rust".into(),
            ..Default::default()
        };
        let cfg = ExperimentConfig {
            cluster: ClusterConfig::with_executors(6),
            workload_base: WorkloadConfig::small_batch(1),
            job_counts: vec![2, 3],
            seeds: vec![1, 2, 3],
        };
        let algos = ["FIFO-DEFT", "HEFT", "HighRankUp-DEFT"];
        let seq = sweep_threaded(&cfg, &algos, &src, 1).unwrap();
        let par = sweep_threaded(&cfg, &algos, &src, 4).unwrap();
        assert_eq!(seq.algos(), par.algos(), "insertion order must match");
        for algo in algos {
            for x in [2, 3] {
                let a = seq.summarize(algo, x).unwrap();
                let b = par.summarize(algo, x).unwrap();
                assert_eq!(a.n_seeds, b.n_seeds);
                assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{algo} x={x}");
                assert_eq!(a.speedup.to_bits(), b.speedup.to_bits(), "{algo} x={x}");
                assert_eq!(a.slr.to_bits(), b.slr.to_bits(), "{algo} x={x}");
                assert_eq!(a.jct.to_bits(), b.jct.to_bits(), "{algo} x={x}");
            }
        }
        assert_eq!(
            csv_without_timing(&seq.to_csv()),
            csv_without_timing(&par.to_csv()),
            "CSV must be byte-identical modulo the wall-clock timing column"
        );
    }

    #[test]
    fn fault_sweep_smoke() {
        let src = PolicySource {
            backend: "rust".into(),
            ..Default::default()
        };
        // Tiny but real: a reliable baseline plus one faulty rate, one
        // seed, 2 jobs — exercises plan generation, recovery, validation
        // and the degradation table end to end.
        let out = fault_sweep(&src, &[0.0, 2e-3], 2, 1, 2).unwrap();
        assert!(out.contains("Mean makespan"), "{out}");
        assert!(out.contains("Degradation"), "{out}");
        for a in FAULT_ALGOS {
            assert!(out.contains(a), "missing {a} in:\n{out}");
        }
    }

    #[test]
    fn threaded_sweep_surfaces_cell_errors() {
        let src = PolicySource {
            backend: "rust".into(),
            ..Default::default()
        };
        let cfg = ExperimentConfig {
            cluster: ClusterConfig::with_executors(4),
            workload_base: WorkloadConfig::small_batch(1),
            job_counts: vec![2],
            seeds: vec![1, 2],
        };
        assert!(sweep_threaded(&cfg, &["no-such-algo"], &src, 3).is_err());
    }

    #[test]
    fn headline_without_baselines_reports_na() {
        // A suite holding only Lachesis cells has no baseline to compare
        // against; the headline must say so instead of printing -inf%.
        let mut suite = SuiteReport::new();
        suite.push(
            20,
            ScheduleReport {
                algo: "Lachesis".into(),
                n_jobs: 20,
                n_tasks: 100,
                makespan: 50.0,
                speedup: 2.0,
                avg_slr: 1.5,
                avg_jct: 40.0,
                n_duplicates: 0,
                utilization: 0.5,
                decision_ms: crate::util::stats::Recorder::new(),
                faults: Default::default(),
            },
        );
        let out = headline_section(&suite);
        assert!(out.contains("n/a"), "{out}");
        assert!(!out.contains("inf"), "{out}");
    }
}
