//! Write-ahead journal for the scheduling service.
//!
//! Every mutating request is appended here — sequence-numbered,
//! checksummed, one JSON line per record — *before* it is applied to
//! the [`super::AgentCore`], so a crashed server can rebuild its exact
//! state by replaying the journal (optionally from a
//! [`super::snapshot`] checkpoint). The file format:
//!
//! ```text
//! {"lachesis_journal":1}                          <- versioned header
//! {"seq":1,"crc":3735928559,"req":{...}}          <- one record per line
//! {"seq":2,"crc":1234,"id":"m0-7","req":{...}}    <- optional request_id
//! ```
//!
//! * `seq` starts at 1 and increases by exactly 1 per record; a gap or
//!   regression marks the rest of the file untrustworthy.
//! * `crc` is the CRC-32 (IEEE) of `"<seq>:<id>:<request-json>"`, so a
//!   bit flip anywhere in a record is caught before replay.
//! * Durability: appends go through a buffered writer;
//!   [`Journal::sync`] flushes and `fsync`s **once per applied batch,
//!   before any of the batch's responses are released** — an
//!   acknowledged request is therefore always on disk, while the
//!   per-request cost is amortized across the batch.
//!
//! Recovery tolerates exactly the damage a hard kill can cause:
//! [`Journal::open`] validates the existing file record by record and
//! truncates at the first torn line (no trailing newline), checksum
//! mismatch, parse failure, or sequence break — everything before the
//! cut replays; everything after it was never acknowledged (its fsync
//! never completed) and is discarded with a warning.

use super::protocol::{request_id, Request};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// First line of every journal file.
pub const JOURNAL_HEADER: &str = "{\"lachesis_journal\":1}";
/// Journal file name inside the `--journal` directory.
pub const JOURNAL_FILE: &str = "journal.log";

/// One validated journal record.
#[derive(Debug, Clone)]
pub struct JournalRecord {
    pub seq: u64,
    /// Client-assigned idempotency id, if the request carried one.
    pub id: Option<String>,
    pub req: Request,
}

/// CRC-32 (IEEE 802.3, reflected, init/xorout `0xFFFFFFFF`) — the
/// `cksum`-family polynomial every other implementation agrees on.
/// Bitwise, no table: journal records are short and appends are
/// batched, so simplicity wins over throughput here.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// The string a record's checksum covers. The id is included (an empty
/// id and an absent id hash differently is not a concern — absent
/// encodes as the empty string and empty-string ids are rejected at
/// the protocol layer by no one, but they also round-trip fine).
fn crc_payload(seq: u64, id: Option<&str>, req_json: &str) -> String {
    format!("{seq}:{}:{req_json}", id.unwrap_or(""))
}

/// Append-side handle to an open journal file.
pub struct Journal {
    writer: BufWriter<File>,
    path: PathBuf,
    next_seq: u64,
    /// Appends since the last [`Journal::sync`].
    dirty: bool,
}

impl Journal {
    /// Open (or create) the journal in `dir`, validating any existing
    /// records. Returns the handle positioned for appending plus every
    /// record that survived validation, in order. A torn or corrupt
    /// tail is truncated in place; a file that does not start with the
    /// journal header is an error (refusing to clobber whatever it is).
    pub fn open(dir: &Path) -> Result<(Journal, Vec<JournalRecord>)> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating journal dir {}", dir.display()))?;
        let path = dir.join(JOURNAL_FILE);
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;

        let mut records = Vec::new();
        let mut valid_len: u64;
        let mut next_seq = 1u64;
        if bytes.is_empty() {
            file.write_all(JOURNAL_HEADER.as_bytes())?;
            file.write_all(b"\n")?;
            file.sync_data()?;
            valid_len = file.stream_position()?;
        } else {
            let header_end = match bytes.iter().position(|&b| b == b'\n') {
                Some(i) if &bytes[..i] == JOURNAL_HEADER.as_bytes() => i + 1,
                _ => bail!(
                    "{} does not start with the journal header — not a journal \
                     (or a journal from an incompatible version); refusing to touch it",
                    path.display()
                ),
            };
            valid_len = header_end as u64;
            let mut offset = header_end;
            while offset < bytes.len() {
                let rest = &bytes[offset..];
                let Some(nl) = rest.iter().position(|&b| b == b'\n') else {
                    crate::log_warn!(
                        "journal: torn tail ({} bytes) truncated at offset {offset}",
                        rest.len()
                    );
                    break;
                };
                let line = &rest[..nl];
                match parse_record(line, next_seq) {
                    Ok(rec) => {
                        records.push(rec);
                        next_seq += 1;
                        offset += nl + 1;
                        valid_len = offset as u64;
                    }
                    Err(e) => {
                        crate::log_warn!(
                            "journal: invalid record at offset {offset} ({e:#}); \
                             truncating the remaining {} bytes",
                            bytes.len() - offset
                        );
                        break;
                    }
                }
            }
            if valid_len < bytes.len() as u64 {
                file.set_len(valid_len)?;
                file.sync_data()?;
            }
        }
        file.seek(SeekFrom::Start(valid_len))?;
        Ok((
            Journal {
                writer: BufWriter::new(file),
                path,
                next_seq,
                dirty: false,
            },
            records,
        ))
    }

    /// The sequence number the next append will get.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Append one mutating request. The record is buffered — it is
    /// durable only after the next [`Journal::sync`]; the server syncs
    /// before releasing the batch's responses. Returns the record's
    /// sequence number.
    pub fn append(&mut self, id: Option<&str>, req: &Request) -> Result<u64> {
        let seq = self.next_seq;
        let req_json = req.to_json().to_string();
        let crc = crc32(crc_payload(seq, id, &req_json).as_bytes());
        let mut line = format!("{{\"seq\":{seq},\"crc\":{crc}");
        if let Some(id) = id {
            line.push_str(",\"id\":");
            line.push_str(&Json::from(id).to_string());
        }
        line.push_str(",\"req\":");
        line.push_str(&req_json);
        line.push_str("}\n");
        self.writer
            .write_all(line.as_bytes())
            .with_context(|| format!("appending to {}", self.path.display()))?;
        self.next_seq += 1;
        self.dirty = true;
        Ok(seq)
    }

    /// Flush buffered appends and `fsync` them to disk. No-op when
    /// nothing was appended since the last sync.
    pub fn sync(&mut self) -> Result<()> {
        if !self.dirty {
            return Ok(());
        }
        self.writer.flush()?;
        self.writer.get_ref().sync_data()?;
        self.dirty = false;
        Ok(())
    }
}

fn parse_record(line: &[u8], expect_seq: u64) -> Result<JournalRecord> {
    let text = std::str::from_utf8(line).map_err(|_| anyhow!("not UTF-8"))?;
    let v = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
    let seq = v
        .get("seq")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("missing seq"))?;
    if seq != expect_seq {
        bail!("sequence break: expected {expect_seq}, found {seq}");
    }
    let crc = v
        .get("crc")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("missing crc"))?;
    let req_json = v.req("req").map_err(|e| anyhow!("{e}"))?;
    let id = request_id(&{
        // The id is stored as a top-level field; reuse the protocol's
        // validation by probing a tiny wrapper object.
        let mut o = Json::obj();
        if let Some(i) = v.get("id") {
            o.set("request_id", i.clone());
        }
        o
    })?;
    let req_text = req_json.to_string();
    let want = crc32(crc_payload(seq, id.as_deref(), &req_text).as_bytes());
    if crc != want as u64 {
        bail!("checksum mismatch (stored {crc}, computed {want})");
    }
    let req = Request::from_json(req_json)?;
    Ok(JournalRecord { seq, id, req })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "lachesis-journal-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_requests() -> Vec<(Option<String>, Request)> {
        vec![
            (
                Some("m0-1".into()),
                Request::SubmitJob {
                    name: "q1".into(),
                    arrival: 1.5,
                    computes: vec![1.0, 2.5],
                    edges: vec![(0, 1, 3.0)],
                },
            ),
            (None, Request::Schedule { time: 2.0 }),
            (
                Some("m1-1".into()),
                Request::TaskComplete {
                    job: 0,
                    node: 0,
                    time: 3.25,
                },
            ),
            (
                None,
                Request::ReportFailure {
                    exec: 1,
                    time: 4.0,
                    recovery: Some(9.0),
                },
            ),
        ]
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE CRC-32 of "123456789" is the classic check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn appends_then_reopens_with_same_records() {
        let dir = tmpdir("roundtrip");
        let (mut j, recs) = Journal::open(&dir).unwrap();
        assert!(recs.is_empty());
        assert_eq!(j.next_seq(), 1);
        for (id, req) in sample_requests() {
            j.append(id.as_deref(), &req).unwrap();
        }
        j.sync().unwrap();
        drop(j);
        let (j2, recs) = Journal::open(&dir).unwrap();
        assert_eq!(recs.len(), 4);
        assert_eq!(j2.next_seq(), 5);
        for (i, rec) in recs.iter().enumerate() {
            assert_eq!(rec.seq as usize, i + 1);
            let (id, req) = &sample_requests()[i];
            assert_eq!(&rec.id, id);
            assert_eq!(rec.req.to_json().to_string(), req.to_json().to_string());
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_is_truncated() {
        let dir = tmpdir("torn");
        let (mut j, _) = Journal::open(&dir).unwrap();
        for (id, req) in sample_requests() {
            j.append(id.as_deref(), &req).unwrap();
        }
        j.sync().unwrap();
        drop(j);
        let path = dir.join(JOURNAL_FILE);
        // Chop mid-way through the last line: a torn write.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
        let (j2, recs) = Journal::open(&dir).unwrap();
        assert_eq!(recs.len(), 3, "last record dropped");
        assert_eq!(j2.next_seq(), 4);
        drop(j2);
        // The truncation is persistent and the file stays appendable.
        let (mut j3, recs) = Journal::open(&dir).unwrap();
        assert_eq!(recs.len(), 3);
        j3.append(None, &Request::Schedule { time: 9.0 }).unwrap();
        j3.sync().unwrap();
        drop(j3);
        let (_, recs) = Journal::open(&dir).unwrap();
        assert_eq!(recs.len(), 4);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_record_cuts_everything_after_it() {
        let dir = tmpdir("corrupt");
        let (mut j, _) = Journal::open(&dir).unwrap();
        for (id, req) in sample_requests() {
            j.append(id.as_deref(), &req).unwrap();
        }
        j.sync().unwrap();
        drop(j);
        let path = dir.join(JOURNAL_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines: Vec<String> = text.lines().map(String::from).collect();
        // Flip a byte inside record 2's request body.
        lines[2] = lines[2].replace("\"time\":", "\"tyme\":");
        std::fs::write(&path, lines.join("\n") + "\n").unwrap();
        let (_, recs) = Journal::open(&dir).unwrap();
        assert_eq!(recs.len(), 1, "records after the corrupt one distrusted");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sequence_break_is_rejected() {
        let dir = tmpdir("seqbreak");
        let (mut j, _) = Journal::open(&dir).unwrap();
        for (id, req) in sample_requests() {
            j.append(id.as_deref(), &req).unwrap();
        }
        j.sync().unwrap();
        drop(j);
        let path = dir.join(JOURNAL_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines: Vec<String> = text.lines().map(String::from).collect();
        lines.remove(2); // drop record 2: 1, 3, 4 remain
        std::fs::write(&path, lines.join("\n") + "\n").unwrap();
        let (_, recs) = Journal::open(&dir).unwrap();
        assert_eq!(recs.len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn foreign_file_is_not_clobbered() {
        let dir = tmpdir("foreign");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(JOURNAL_FILE), "important data\n").unwrap();
        assert!(Journal::open(&dir).is_err());
        let kept = std::fs::read_to_string(dir.join(JOURNAL_FILE)).unwrap();
        assert_eq!(kept, "important data\n");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
