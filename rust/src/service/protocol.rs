//! JSON-line wire protocol between the platform's resource manager (master
//! node) and the Lachesis agent.
//!
//! One JSON object per line. Requests:
//!
//! * `{"type":"submit_job", "job": {name, arrival, computes, edges}}` — a
//!   job whose `arrival` lies in the future is queued, not activated: it
//!   becomes schedulable only once a `schedule`/`task_complete` advances
//!   the agent's wall clock past its arrival (the simulator's
//!   event-driven semantics).
//! * `{"type":"task_complete", "job": j, "node": n, "time": t}`  (heartbeat)
//! * `{"type":"schedule", "time": t}` — ask for assignments at wall time t
//! * `{"type":"report_failure", "exec": k, "time": t[, "recovery": tr]}` —
//!   the master observed executor `k` crash at `t`; unfinished
//!   assignments on it are rolled back (tasks with a surviving duplicate
//!   copy are promoted in place, the rest re-enter the frontier for the
//!   next `schedule`). With `recovery` the executor rejoins once the
//!   wall clock passes `tr`; without it the crash is permanent.
//! * `{"type":"status"}` / `{"type":"shutdown"}`
//! * `{"type":"metrics"}` — telemetry snapshot: Prometheus text plus
//!   structured JSON series, answered off the lock-free path (never
//!   touches the core lock or the mailbox).
//!
//! Responses mirror them with `"ok"` / `"assignments"` / `"status"`;
//! `report_failure` answers `"recovery"` with the rollback counts
//! (`cancelled`/`requeued`/`survived`). The status response reports
//! `"pending"`: the number of submitted jobs still waiting for their
//! arrival time, and `"down"`: executors currently unavailable.
//! `shutdown` stops the whole server — every master connection, not just
//! the requesting one. See `docs/protocol.md` for the full wire contract.
//!
//! Pipelining: a master may send many request lines without waiting for
//! responses; the agent answers every line, strictly in the order sent.
//! In the server's batched mode, mutating requests pipelined this way
//! are applied as one batch under a single core-lock acquisition, and
//! `status` is answered from a lock-free snapshot refreshed after every
//! batch — at most one batch stale, never torn, and always at least as
//! fresh as the last response the same connection has already received.
//!
//! Idempotency: any request object may carry an optional top-level
//! `"request_id"` string. The server remembers the response produced
//! for each mutating request id in a bounded window, so a client that
//! times out and retries the same line never double-applies it — the
//! retry is answered with the remembered response. Ids on
//! non-mutating requests (`status`, `shutdown`) are accepted and
//! ignored: those are safe to repeat. When the server's mailbox is
//! full and the admission policy is `shed`, mutating requests are
//! answered with `{"type":"overloaded","queue":N}` without being
//! applied — the client should back off and retry.

use crate::dag::Job;
use crate::sim::Allocation;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// A request from the resource manager.
#[derive(Debug, Clone)]
pub enum Request {
    SubmitJob {
        name: String,
        arrival: f64,
        computes: Vec<f64>,
        edges: Vec<(usize, usize, f64)>,
    },
    TaskComplete {
        job: usize,
        node: usize,
        time: f64,
    },
    Schedule {
        time: f64,
    },
    /// Executor `exec` crashed at `time`; `recovery` is when it rejoins
    /// (`None` = permanent).
    ReportFailure {
        exec: usize,
        time: f64,
        recovery: Option<f64>,
    },
    Status,
    Shutdown,
    /// Fetch a telemetry snapshot (Prometheus text + JSON series).
    /// Non-mutating: answered off the lock-free path like `status`.
    Metrics,
}

/// One task assignment in a schedule response.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    pub job: usize,
    pub node: usize,
    pub exec: usize,
    /// Parent duplicated onto `exec` first, if any.
    pub dup_parent: Option<usize>,
    pub start: f64,
    pub finish: f64,
}

/// A response to the resource manager.
#[derive(Debug, Clone)]
pub enum Response {
    Ok {
        job_id: Option<usize>,
    },
    Assignments(Vec<Assignment>),
    Status {
        jobs: usize,
        assigned: usize,
        executors: usize,
        horizon: f64,
        /// Size of the executable frontier (tasks ready to be scheduled).
        executable: usize,
        /// Jobs submitted with a future arrival, not yet activated.
        pending: usize,
        /// Executors currently down (crashed, not yet recovered).
        down: usize,
        /// Racks in the cluster's network topology (1 under `flat`).
        racks: usize,
        /// Mailbox depth when this snapshot was published (batched
        /// engine; 0 in serial mode). Clients use it to back off
        /// before the admission policy starts shedding.
        queue: usize,
        /// Mutating requests rejected with `Overloaded` so far.
        shed: usize,
        /// Retried requests suppressed by the `request_id` dedup
        /// window so far (each was applied exactly once).
        deduped: usize,
    },
    /// Rollback counts answering a `report_failure`.
    Recovery {
        /// Booked copies cancelled by the rollback.
        cancelled: usize,
        /// Tasks returned to the frontier for rescheduling.
        requeued: usize,
        /// Tasks saved by promoting a surviving duplicate copy.
        survived: usize,
    },
    /// The mailbox is full and the admission policy is `shed`: the
    /// request was *not* applied. `queue` is the depth observed at
    /// rejection time — a hint for client backoff.
    Overloaded {
        queue: usize,
    },
    /// Telemetry snapshot answering a `metrics` request: the Prometheus
    /// text exposition plus the same registry as structured JSON series.
    Metrics {
        prometheus: String,
        series: Json,
    },
    Error(String),
}

impl Request {
    /// Whether this request may change the agent's state. Mutating
    /// requests go through the batched core loop; `status` is answered
    /// from the lock-free snapshot and `shutdown` by the connection
    /// thread itself.
    pub fn is_mutating(&self) -> bool {
        !matches!(
            self,
            Request::Status | Request::Shutdown | Request::Metrics
        )
    }

    /// Wire name of this request's type — the `type` label on service
    /// metric series (index-aligned with
    /// [`crate::obs::metrics::REQUEST_KINDS`]).
    pub fn kind(&self) -> &'static str {
        crate::obs::metrics::REQUEST_KINDS[self.kind_index()]
    }

    /// Dense index of this request's type, for per-type handle arrays.
    pub fn kind_index(&self) -> usize {
        match self {
            Request::SubmitJob { .. } => 0,
            Request::TaskComplete { .. } => 1,
            Request::Schedule { .. } => 2,
            Request::ReportFailure { .. } => 3,
            Request::Status => 4,
            Request::Shutdown => 5,
            Request::Metrics => 6,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Request::SubmitJob {
                name,
                arrival,
                computes,
                edges,
            } => {
                let edges_json: Vec<Json> = edges
                    .iter()
                    .map(|&(u, v, d)| {
                        Json::Arr(vec![Json::from(u), Json::from(v), Json::from(d)])
                    })
                    .collect();
                Json::from_pairs(vec![
                    ("type", Json::from("submit_job")),
                    ("name", Json::from(name.clone())),
                    ("arrival", Json::from(*arrival)),
                    ("computes", Json::from(computes.clone())),
                    ("edges", Json::Arr(edges_json)),
                ])
            }
            Request::TaskComplete { job, node, time } => Json::from_pairs(vec![
                ("type", Json::from("task_complete")),
                ("job", Json::from(*job)),
                ("node", Json::from(*node)),
                ("time", Json::from(*time)),
            ]),
            Request::Schedule { time } => Json::from_pairs(vec![
                ("type", Json::from("schedule")),
                ("time", Json::from(*time)),
            ]),
            Request::ReportFailure {
                exec,
                time,
                recovery,
            } => {
                let mut o = Json::from_pairs(vec![
                    ("type", Json::from("report_failure")),
                    ("exec", Json::from(*exec)),
                    ("time", Json::from(*time)),
                ]);
                if let Some(r) = recovery {
                    o.set("recovery", Json::from(*r));
                }
                o
            }
            Request::Status => Json::from_pairs(vec![("type", Json::from("status"))]),
            Request::Shutdown => Json::from_pairs(vec![("type", Json::from("shutdown"))]),
            Request::Metrics => Json::from_pairs(vec![("type", Json::from("metrics"))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Request> {
        match v.req_str("type").map_err(|e| anyhow!("{e}"))? {
            "submit_job" => {
                let computes = v
                    .req("computes")
                    .map_err(|e| anyhow!("{e}"))?
                    .as_arr()
                    .ok_or_else(|| anyhow!("computes must be an array"))?
                    .iter()
                    .map(|x| x.as_f64().ok_or_else(|| anyhow!("bad compute")))
                    .collect::<Result<Vec<_>>>()?;
                let edges = v
                    .req("edges")
                    .map_err(|e| anyhow!("{e}"))?
                    .as_arr()
                    .ok_or_else(|| anyhow!("edges must be an array"))?
                    .iter()
                    .map(|e| {
                        let u = e.at(0).and_then(Json::as_usize);
                        let w = e.at(1).and_then(Json::as_usize);
                        let d = e.at(2).and_then(Json::as_f64);
                        match (u, w, d) {
                            (Some(u), Some(w), Some(d)) => Ok((u, w, d)),
                            _ => Err(anyhow!("bad edge")),
                        }
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(Request::SubmitJob {
                    name: v.req_str("name").map_err(|e| anyhow!("{e}"))?.to_string(),
                    arrival: v.req_f64("arrival").map_err(|e| anyhow!("{e}"))?,
                    computes,
                    edges,
                })
            }
            "task_complete" => Ok(Request::TaskComplete {
                job: v.req_usize("job").map_err(|e| anyhow!("{e}"))?,
                node: v.req_usize("node").map_err(|e| anyhow!("{e}"))?,
                time: v.req_f64("time").map_err(|e| anyhow!("{e}"))?,
            }),
            "schedule" => Ok(Request::Schedule {
                time: v.req_f64("time").map_err(|e| anyhow!("{e}"))?,
            }),
            "report_failure" => {
                // Absent (or explicit null) means permanent; a present
                // non-numeric value is a malformed request, not a
                // permanent crash — silently dropping it would kill the
                // executor forever on a client serialization bug.
                let recovery = match v.get("recovery") {
                    None | Some(Json::Null) => None,
                    Some(r) => Some(
                        r.as_f64()
                            .ok_or_else(|| anyhow!("recovery must be a number"))?,
                    ),
                };
                Ok(Request::ReportFailure {
                    exec: v.req_usize("exec").map_err(|e| anyhow!("{e}"))?,
                    time: v.req_f64("time").map_err(|e| anyhow!("{e}"))?,
                    recovery,
                })
            }
            "status" => Ok(Request::Status),
            "shutdown" => Ok(Request::Shutdown),
            "metrics" => Ok(Request::Metrics),
            other => bail!("unknown request type '{other}'"),
        }
    }

    /// Build the Job object for a submit request.
    pub fn build_job(&self, id: usize) -> Result<Job> {
        match self {
            Request::SubmitJob {
                name,
                arrival,
                computes,
                edges,
            } => Job::try_new(id, name.clone(), *arrival, computes.clone(), edges),
            _ => bail!("not a submit_job request"),
        }
    }
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Ok { job_id } => {
                let mut o = Json::from_pairs(vec![("type", Json::from("ok"))]);
                if let Some(id) = job_id {
                    o.set("job_id", Json::from(*id));
                }
                o
            }
            Response::Assignments(asgs) => {
                let items: Vec<Json> = asgs
                    .iter()
                    .map(|a| {
                        let mut o = Json::from_pairs(vec![
                            ("job", Json::from(a.job)),
                            ("node", Json::from(a.node)),
                            ("exec", Json::from(a.exec)),
                            ("start", Json::from(a.start)),
                            ("finish", Json::from(a.finish)),
                        ]);
                        if let Some(p) = a.dup_parent {
                            o.set("dup_parent", Json::from(p));
                        }
                        o
                    })
                    .collect();
                Json::from_pairs(vec![
                    ("type", Json::from("assignments")),
                    ("items", Json::Arr(items)),
                ])
            }
            Response::Status {
                jobs,
                assigned,
                executors,
                horizon,
                executable,
                pending,
                down,
                racks,
                queue,
                shed,
                deduped,
            } => Json::from_pairs(vec![
                ("type", Json::from("status")),
                ("jobs", Json::from(*jobs)),
                ("assigned", Json::from(*assigned)),
                ("executors", Json::from(*executors)),
                ("horizon", Json::from(*horizon)),
                ("executable", Json::from(*executable)),
                ("pending", Json::from(*pending)),
                ("down", Json::from(*down)),
                ("racks", Json::from(*racks)),
                ("queue", Json::from(*queue)),
                ("shed", Json::from(*shed)),
                ("deduped", Json::from(*deduped)),
            ]),
            Response::Recovery {
                cancelled,
                requeued,
                survived,
            } => Json::from_pairs(vec![
                ("type", Json::from("recovery")),
                ("cancelled", Json::from(*cancelled)),
                ("requeued", Json::from(*requeued)),
                ("survived", Json::from(*survived)),
            ]),
            Response::Overloaded { queue } => Json::from_pairs(vec![
                ("type", Json::from("overloaded")),
                ("queue", Json::from(*queue)),
            ]),
            Response::Metrics { prometheus, series } => Json::from_pairs(vec![
                ("type", Json::from("metrics")),
                ("prometheus", Json::from(prometheus.clone())),
                ("series", series.clone()),
            ]),
            Response::Error(msg) => Json::from_pairs(vec![
                ("type", Json::from("error")),
                ("message", Json::from(msg.clone())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Response> {
        match v.req_str("type").map_err(|e| anyhow!("{e}"))? {
            "ok" => Ok(Response::Ok {
                job_id: v.get("job_id").and_then(Json::as_usize),
            }),
            "assignments" => {
                let items = v
                    .req("items")
                    .map_err(|e| anyhow!("{e}"))?
                    .as_arr()
                    .ok_or_else(|| anyhow!("items must be an array"))?
                    .iter()
                    .map(|a| {
                        Ok(Assignment {
                            job: a.req_usize("job").map_err(|e| anyhow!("{e}"))?,
                            node: a.req_usize("node").map_err(|e| anyhow!("{e}"))?,
                            exec: a.req_usize("exec").map_err(|e| anyhow!("{e}"))?,
                            dup_parent: a.get("dup_parent").and_then(Json::as_usize),
                            start: a.req_f64("start").map_err(|e| anyhow!("{e}"))?,
                            finish: a.req_f64("finish").map_err(|e| anyhow!("{e}"))?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(Response::Assignments(items))
            }
            "status" => Ok(Response::Status {
                jobs: v.req_usize("jobs").map_err(|e| anyhow!("{e}"))?,
                assigned: v.req_usize("assigned").map_err(|e| anyhow!("{e}"))?,
                executors: v.req_usize("executors").map_err(|e| anyhow!("{e}"))?,
                horizon: v.req_f64("horizon").map_err(|e| anyhow!("{e}"))?,
                // Absent in pre-frontier peers: default 0 for compatibility.
                executable: v.get("executable").and_then(Json::as_usize).unwrap_or(0),
                // Absent in pre-deferred-arrival peers: default 0.
                pending: v.get("pending").and_then(Json::as_usize).unwrap_or(0),
                // Absent in pre-fault peers: default 0 (all executors up).
                down: v.get("down").and_then(Json::as_usize).unwrap_or(0),
                // Absent in pre-topology peers: default 1 (flat = one rack).
                racks: v.get("racks").and_then(Json::as_usize).unwrap_or(1),
                // Absent in pre-admission-control peers: default 0.
                queue: v.get("queue").and_then(Json::as_usize).unwrap_or(0),
                shed: v.get("shed").and_then(Json::as_usize).unwrap_or(0),
                deduped: v.get("deduped").and_then(Json::as_usize).unwrap_or(0),
            }),
            "overloaded" => Ok(Response::Overloaded {
                // Absent from a terse peer: depth hint defaults to 0.
                queue: v.get("queue").and_then(Json::as_usize).unwrap_or(0),
            }),
            "metrics" => Ok(Response::Metrics {
                prometheus: v
                    .req_str("prometheus")
                    .map_err(|e| anyhow!("{e}"))?
                    .to_string(),
                // Structured series are optional on the wire (a terse
                // peer may send only the text exposition).
                series: v.get("series").cloned().unwrap_or(Json::Arr(Vec::new())),
            }),
            "recovery" => Ok(Response::Recovery {
                cancelled: v.req_usize("cancelled").map_err(|e| anyhow!("{e}"))?,
                requeued: v.req_usize("requeued").map_err(|e| anyhow!("{e}"))?,
                survived: v.req_usize("survived").map_err(|e| anyhow!("{e}"))?,
            }),
            "error" => Ok(Response::Error(
                v.req_str("message").map_err(|e| anyhow!("{e}"))?.to_string(),
            )),
            other => bail!("unknown response type '{other}'"),
        }
    }
}

/// Extract the optional client-assigned `request_id` from a parsed
/// request object. Absent (or explicit null) means untagged; a present
/// non-string value is a malformed request — silently ignoring it
/// would defeat the idempotency the client asked for.
pub fn request_id(v: &Json) -> Result<Option<String>> {
    match v.get("request_id") {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Str(s)) => Ok(Some(s.clone())),
        Some(_) => bail!("request_id must be a string"),
    }
}

/// Encode a request tagged with a client-assigned id.
pub fn with_request_id(req: &Request, id: &str) -> Json {
    let mut j = req.to_json();
    j.set("request_id", Json::from(id));
    j
}

/// Translate an applied allocation into a wire assignment.
pub fn assignment_from(
    job: usize,
    node: usize,
    alloc: Allocation,
    start: f64,
    finish: f64,
) -> Assignment {
    match alloc {
        Allocation::Direct { exec } => Assignment {
            job,
            node,
            exec,
            dup_parent: None,
            start,
            finish,
        },
        Allocation::Duplicate { exec, parent } => Assignment {
            job,
            node,
            exec,
            dup_parent: Some(parent),
            start,
            finish,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let reqs = vec![
            Request::SubmitJob {
                name: "q1".into(),
                arrival: 1.5,
                computes: vec![1.0, 2.0],
                edges: vec![(0, 1, 3.0)],
            },
            Request::TaskComplete {
                job: 1,
                node: 2,
                time: 9.0,
            },
            Request::Schedule { time: 10.0 },
            Request::ReportFailure {
                exec: 3,
                time: 12.5,
                recovery: Some(40.0),
            },
            Request::ReportFailure {
                exec: 1,
                time: 2.0,
                recovery: None,
            },
            Request::Status,
            Request::Shutdown,
            Request::Metrics,
        ];
        for r in reqs {
            let j = r.to_json();
            let r2 = Request::from_json(&j).unwrap();
            assert_eq!(j.to_string(), r2.to_json().to_string());
        }
    }

    #[test]
    fn response_roundtrip() {
        let resps = vec![
            Response::Ok { job_id: Some(3) },
            Response::Assignments(vec![Assignment {
                job: 0,
                node: 1,
                exec: 2,
                dup_parent: Some(0),
                start: 1.0,
                finish: 2.0,
            }]),
            Response::Status {
                jobs: 2,
                assigned: 5,
                executors: 8,
                horizon: 42.0,
                executable: 3,
                pending: 1,
                down: 2,
                racks: 3,
                queue: 7,
                shed: 4,
                deduped: 9,
            },
            Response::Recovery {
                cancelled: 4,
                requeued: 2,
                survived: 1,
            },
            Response::Overloaded { queue: 640 },
            Response::Metrics {
                prometheus: "# TYPE lachesis_requests_total counter\n".into(),
                series: Json::parse(r#"[{"name":"lachesis_requests_total"}]"#).unwrap(),
            },
            Response::Error("boom".into()),
        ];
        for r in resps {
            let j = r.to_json();
            let r2 = Response::from_json(&j).unwrap();
            assert_eq!(j.to_string(), r2.to_json().to_string());
        }
    }

    #[test]
    fn build_job_validates() {
        let r = Request::SubmitJob {
            name: "bad".into(),
            arrival: 0.0,
            computes: vec![1.0, 1.0],
            edges: vec![(0, 1, 1.0), (1, 0, 1.0)],
        };
        assert!(r.build_job(0).is_err());
    }

    #[test]
    fn rejects_unknown_types() {
        let v = Json::parse(r#"{"type": "nope"}"#).unwrap();
        assert!(Request::from_json(&v).is_err());
        assert!(Response::from_json(&v).is_err());
    }

    #[test]
    fn request_id_parses_and_tags() {
        let plain = Json::parse(r#"{"type":"status"}"#).unwrap();
        assert_eq!(request_id(&plain).unwrap(), None);
        let null = Json::parse(r#"{"type":"status","request_id":null}"#).unwrap();
        assert_eq!(request_id(&null).unwrap(), None);
        let tagged = with_request_id(&Request::Schedule { time: 4.0 }, "m1-17");
        assert_eq!(request_id(&tagged).unwrap().as_deref(), Some("m1-17"));
        // The tag must not disturb the request body itself.
        let back = Request::from_json(&tagged).unwrap();
        assert!(matches!(back, Request::Schedule { time } if time == 4.0));
        // Non-string ids are malformed, not silently untagged.
        let bad = Json::parse(r#"{"type":"status","request_id":7}"#).unwrap();
        assert!(request_id(&bad).is_err());
    }

    #[test]
    fn status_compat_defaults_admission_fields_to_zero() {
        let old = Json::parse(
            r#"{"type":"status","jobs":1,"assigned":2,"executors":3,"horizon":4.0}"#,
        )
        .unwrap();
        match Response::from_json(&old).unwrap() {
            Response::Status {
                queue,
                shed,
                deduped,
                racks,
                ..
            } => {
                assert_eq!((queue, shed, deduped), (0, 0, 0));
                assert_eq!(racks, 1, "pre-topology peer defaults to one rack");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn metrics_request_is_non_mutating_and_kinds_align() {
        assert!(!Request::Metrics.is_mutating());
        assert!(!Request::Status.is_mutating());
        assert!(Request::Schedule { time: 0.0 }.is_mutating());
        // kind()/kind_index() stay aligned with the metrics label table.
        let reqs = [
            Request::SubmitJob {
                name: "j".into(),
                arrival: 0.0,
                computes: vec![1.0],
                edges: vec![],
            },
            Request::TaskComplete {
                job: 0,
                node: 0,
                time: 0.0,
            },
            Request::Schedule { time: 0.0 },
            Request::ReportFailure {
                exec: 0,
                time: 0.0,
                recovery: None,
            },
            Request::Status,
            Request::Shutdown,
            Request::Metrics,
        ];
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.kind_index(), i);
            assert_eq!(r.kind(), crate::obs::metrics::REQUEST_KINDS[i]);
        }
    }

    #[test]
    fn metrics_response_tolerates_missing_series() {
        let terse = Json::parse(r#"{"type":"metrics","prometheus":"x 1\n"}"#).unwrap();
        match Response::from_json(&terse).unwrap() {
            Response::Metrics { prometheus, series } => {
                assert_eq!(prometheus, "x 1\n");
                assert_eq!(series, Json::Arr(Vec::new()));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn report_failure_recovery_must_be_numeric_or_null() {
        let bad = Json::parse(
            r#"{"type":"report_failure","exec":3,"time":42.0,"recovery":"72.0"}"#,
        )
        .unwrap();
        assert!(
            Request::from_json(&bad).is_err(),
            "stringly-typed recovery must not decode as permanent"
        );
        let null = Json::parse(
            r#"{"type":"report_failure","exec":3,"time":42.0,"recovery":null}"#,
        )
        .unwrap();
        match Request::from_json(&null).unwrap() {
            Request::ReportFailure { recovery: None, .. } => {}
            other => panic!("unexpected {other:?}"),
        }
    }
}
