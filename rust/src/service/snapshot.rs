//! Atomic on-disk snapshots of the agent core.
//!
//! A snapshot is one versioned JSON document, written as
//! `snap-<seq>.json` where `<seq>` is the last journal sequence number
//! it covers: restore loads the highest-`seq` snapshot and replays
//! only the journal records with `seq > snapshot.seq`. Writes are
//! crash-atomic — the document goes to a `.tmp` file first, is
//! `fsync`ed, and only then renamed into place (a kill mid-write
//! leaves at worst a stale `.tmp`, never a half-written snapshot
//! under the real name). Old snapshots beyond the most recent
//! [`KEEP_SNAPSHOTS`] are pruned after each successful write; pruning
//! failures are warnings, not errors.
//!
//! The document body is built by the server
//! ([`super::AgentCore::snapshot_json`]) and contains the full
//! [`crate::sim::SimState`] serialization plus the pending-arrival
//! heap, the recovery heap, and the request-id dedup window.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Snapshots retained after a successful write (the newest plus one
/// predecessor, in case the newest is lost with its directory entry).
pub const KEEP_SNAPSHOTS: usize = 2;

/// Version stamp checked by [`load_latest`].
pub const SNAPSHOT_VERSION: u64 = 1;

fn snap_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("snap-{seq}.json"))
}

/// Parse a `snap-<seq>.json` file name back to its sequence number.
fn parse_snap_name(name: &str) -> Option<u64> {
    name.strip_prefix("snap-")?
        .strip_suffix(".json")?
        .parse()
        .ok()
}

/// Atomically persist `body` as the snapshot covering journal sequence
/// `seq`. `body` is wrapped with the version stamp and `seq`; callers
/// pass the core-state document only.
pub fn write(dir: &Path, seq: u64, body: Json) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating snapshot dir {}", dir.display()))?;
    let doc = Json::from_pairs(vec![
        ("lachesis_snapshot", Json::from(SNAPSHOT_VERSION)),
        ("seq", Json::from(seq)),
        ("core", body),
    ]);
    let path = snap_path(dir, seq);
    let tmp = dir.join(format!("snap-{seq}.json.tmp"));
    {
        use std::io::Write as _;
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(doc.to_string().as_bytes())?;
        f.write_all(b"\n")?;
        f.sync_data()?;
    }
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    prune(dir, seq);
    Ok(path)
}

/// Delete snapshots older than the `KEEP_SNAPSHOTS` most recent ones
/// (and any orphaned `.tmp` from a previous crash-mid-write).
fn prune(dir: &Path, newest: u64) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    let mut seqs: Vec<u64> = Vec::new();
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if name.ends_with(".json.tmp") && name.starts_with("snap-") {
            // A crash between create and rename left this behind; the
            // newest real snapshot supersedes it.
            let _ = std::fs::remove_file(entry.path());
            continue;
        }
        if let Some(seq) = parse_snap_name(name) {
            seqs.push(seq);
        }
    }
    seqs.sort_unstable();
    let cut = seqs.len().saturating_sub(KEEP_SNAPSHOTS);
    for &seq in &seqs[..cut] {
        if seq == newest {
            continue;
        }
        if let Err(e) = std::fs::remove_file(snap_path(dir, seq)) {
            crate::log_warn!("snapshot prune failed for seq {seq}: {e}");
        }
    }
}

/// Load the highest-sequence snapshot in `dir`, if any. Returns the
/// covered journal sequence and the core-state document. A snapshot
/// that fails to parse is skipped with a warning and the next-newest
/// is tried — recovery prefers an older consistent checkpoint (plus a
/// longer journal replay) over refusing to start.
pub fn load_latest(dir: &Path) -> Result<Option<(u64, Json)>> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(anyhow!("reading snapshot dir {}: {e}", dir.display())),
    };
    let mut seqs: Vec<u64> = entries
        .flatten()
        .filter_map(|e| e.file_name().to_str().and_then(parse_snap_name))
        .collect();
    seqs.sort_unstable();
    for &seq in seqs.iter().rev() {
        let path = snap_path(dir, seq);
        match try_load(&path, seq) {
            Ok(core) => return Ok(Some((seq, core))),
            Err(e) => {
                crate::log_warn!("skipping unreadable snapshot {}: {e:#}", path.display());
            }
        }
    }
    Ok(None)
}

fn try_load(path: &Path, expect_seq: u64) -> Result<Json> {
    let text = std::fs::read_to_string(path)?;
    let doc = Json::parse(text.trim_end()).map_err(|e| anyhow!("{e}"))?;
    let version = doc
        .get("lachesis_snapshot")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("missing snapshot version stamp"))?;
    if version != SNAPSHOT_VERSION {
        return Err(anyhow!("unsupported snapshot version {version}"));
    }
    let seq = doc
        .get("seq")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("missing seq"))?;
    if seq != expect_seq {
        return Err(anyhow!(
            "file name says seq {expect_seq} but the document says {seq}"
        ));
    }
    doc.get("core")
        .cloned()
        .ok_or_else(|| anyhow!("missing core document"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "lachesis-snapshot-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn body(x: usize) -> Json {
        Json::from_pairs(vec![("x", Json::from(x))])
    }

    #[test]
    fn write_then_load_latest() {
        let dir = tmpdir("rw");
        assert!(load_latest(&dir).unwrap().is_none(), "no dir yet");
        write(&dir, 10, body(1)).unwrap();
        write(&dir, 25, body(2)).unwrap();
        let (seq, core) = load_latest(&dir).unwrap().unwrap();
        assert_eq!(seq, 25);
        assert_eq!(core.get("x").and_then(Json::as_usize), Some(2));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pruning_keeps_two_and_clears_tmp_orphans() {
        let dir = tmpdir("prune");
        for (i, seq) in [3u64, 8, 15, 21].into_iter().enumerate() {
            write(&dir, seq, body(i)).unwrap();
        }
        std::fs::write(dir.join("snap-99.json.tmp"), "half-written").unwrap();
        write(&dir, 30, body(9)).unwrap();
        let mut names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        names.sort();
        assert_eq!(names, vec!["snap-21.json", "snap-30.json"]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_newest_falls_back_to_predecessor() {
        let dir = tmpdir("fallback");
        write(&dir, 5, body(1)).unwrap();
        write(&dir, 9, body(2)).unwrap();
        std::fs::write(snap_path(&dir, 9), "{\"torn").unwrap();
        let (seq, core) = load_latest(&dir).unwrap().unwrap();
        assert_eq!(seq, 5);
        assert_eq!(core.get("x").and_then(Json::as_usize), Some(1));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn name_and_document_seq_must_agree() {
        let dir = tmpdir("rename");
        write(&dir, 4, body(1)).unwrap();
        // An adversarially renamed snapshot is skipped.
        std::fs::rename(snap_path(&dir, 4), snap_path(&dir, 7)).unwrap();
        assert!(load_latest(&dir).unwrap().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
