//! The Lachesis agent as a network service, plus the resource-manager
//! client used by examples and tests. std::net + threads (the offline
//! registry has no tokio; the protocol is line-oriented and blocking I/O
//! per connection is the right tool).
//!
//! Concurrency model: every accepted master connection gets its own
//! thread, and all of them share one [`AgentCore`] — the live `SimState`
//! plus the scheduler — behind a mutex. The server runs in one of two
//! [`ServiceMode`]s:
//!
//! * **Serial** — every request (including `status`) acquires the core
//!   lock, is applied, and is answered before the lock is released. One
//!   lock acquisition per request; the original single-lock engine, kept
//!   as the correctness reference and throughput baseline.
//! * **Batched** (default) — connection threads enqueue mutating
//!   requests into a mailbox drained by a dedicated core-loop thread
//!   that applies a whole batch per lock acquisition, coalescing
//!   consecutive `task_complete` heartbeats into a single wall-clock
//!   advance. `status` never touches the core lock at all: it is
//!   answered from a seqlock-published [`StatusSnapshot`] refreshed
//!   after every batch (bounded staleness, never torn). Batch
//!   application preserves mailbox FIFO order, so an identical request
//!   stream produces the byte-identical schedule the serial engine
//!   would — golden tests pin this.
//!
//! In both modes requests are processed in a single total order, so
//! decisions are exactly as deterministic as a single-connection session
//! interleaved the same way; concurrency buys connection-level
//! parallelism (parsing, I/O, slow peers) without ever racing the
//! scheduler.
//!
//! Arrival semantics match the simulator's event loop (Algorithm 3): a
//! `submit_job` whose `arrival` lies in the future is *queued*, not
//! activated — it becomes schedulable only once a `schedule` or
//! `task_complete` advances the agent's wall clock past its arrival time.

use super::journal::Journal;
use super::protocol::{assignment_from, request_id, Request, Response};
use super::snapshot;
use crate::cluster::Cluster;
use crate::sched::Scheduler;
use crate::sim::SimState;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::Workload;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How often the accept loop polls the shutdown flag.
const ACCEPT_POLL: Duration = Duration::from_millis(5);
/// Read timeout per connection, so blocked readers notice shutdown.
const READ_POLL: Duration = Duration::from_millis(25);
/// Write timeout per connection: a peer that stops draining its socket
/// must not pin its thread in `flush()` forever (that would block
/// `serve()`'s scope join at shutdown). Generous enough that only a
/// genuinely stalled peer gets dropped.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);
/// Largest accepted request line. A peer streaming bytes with no
/// newline must not grow a connection buffer without bound; generous
/// enough for very large submitted DAGs.
const MAX_LINE_BYTES: usize = 8 << 20;
/// Largest number of pipelined requests pulled into one burst per
/// connection read (bounds the responses held in flight per burst).
const MAX_BURST: usize = 128;

/// An id waiting for the wall clock to reach `time` — a deferred job
/// arrival (`id` = job) or a crashed executor's recovery (`id` = exec).
/// Min-heap by `(time, id)`.
#[derive(Debug, Clone, Copy)]
struct Pending {
    time: f64,
    id: usize,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.id == other.id
    }
}
impl Eq for Pending {}
impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Pending {
    // Reversed: BinaryHeap is a max-heap, we pop the earliest time.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then(other.id.cmp(&self.id))
    }
}

/// How the server applies requests to the shared core. See the module
/// docs for the two engines' contracts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceMode {
    /// One core-lock acquisition per request; `status` also locks.
    Serial,
    /// Mailbox + dedicated core loop: one lock acquisition per *batch*,
    /// heartbeat coalescing, and lock-free snapshot `status`.
    Batched,
}

impl ServiceMode {
    pub fn parse(s: &str) -> Result<ServiceMode> {
        match s {
            "serial" => Ok(ServiceMode::Serial),
            "batched" => Ok(ServiceMode::Batched),
            other => bail!("unknown service mode '{other}' (serial|batched)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ServiceMode::Serial => "serial",
            ServiceMode::Batched => "batched",
        }
    }
}

/// What the batched engine does with a mutating request that arrives
/// while the mailbox already holds `--max-queue` envelopes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Refuse it immediately with an `Overloaded` response carrying the
    /// queue depth — the client backs off and retries (load shedding).
    Shed,
    /// Park the connection thread until the core loop drains space —
    /// backpressure propagates to the peer's socket instead.
    Block,
}

impl AdmissionPolicy {
    pub fn parse(s: &str) -> Result<AdmissionPolicy> {
        match s {
            "shed" => Ok(AdmissionPolicy::Shed),
            "block" => Ok(AdmissionPolicy::Block),
            other => bail!("unknown admission policy '{other}' (shed|block)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Shed => "shed",
            AdmissionPolicy::Block => "block",
        }
    }
}

/// Durability configuration for [`AgentServer::with_durability`]: where
/// the write-ahead journal and snapshots live, how often to checkpoint,
/// and whether to rebuild the core from disk before serving.
#[derive(Debug, Clone)]
pub struct Durability {
    /// Directory holding `journal.log` and `snap-<seq>.json` files.
    pub dir: PathBuf,
    /// Journal records between snapshots (0 = journal only, never
    /// snapshot — recovery replays the whole journal).
    pub snapshot_every: u64,
    /// Load the newest snapshot and replay the journal suffix instead
    /// of requiring the directory to be fresh.
    pub restore: bool,
}

/// Requests whose cached responses the dedup window retains. Bounded so
/// a long-lived server's memory stays flat; clients that retry within
/// the window get the original response back, byte for byte.
const DEDUP_WINDOW: usize = 4096;

/// Bounded FIFO map from client-assigned `request_id` to the response
/// the first application produced. Insertion order is the eviction
/// order *and* the snapshot serialization order, so a restored window
/// evicts identically to the uninterrupted run.
#[derive(Default)]
struct DedupWindow {
    order: VecDeque<String>,
    map: HashMap<String, Response>,
}

impl DedupWindow {
    fn get(&self, id: &str) -> Option<&Response> {
        self.map.get(id)
    }

    fn insert(&mut self, id: String, resp: Response) {
        if self.map.contains_key(&id) {
            // Only reachable by re-storing under a cached id (the dedup
            // check runs first); keep the original response and its slot.
            return;
        }
        if self.order.len() >= DEDUP_WINDOW {
            if let Some(evicted) = self.order.pop_front() {
                self.map.remove(&evicted);
            }
        }
        self.order.push_back(id.clone());
        self.map.insert(id, resp);
    }

    /// `(id, response)` pairs oldest-first — the order `insert` must be
    /// replayed in to rebuild an identical window.
    fn iter_in_order(&self) -> impl Iterator<Item = (&String, &Response)> {
        self.order
            .iter()
            .map(move |id| (id, self.map.get(id).expect("ordered id is mapped")))
    }

    fn len(&self) -> usize {
        self.order.len()
    }
}

/// The journal/snapshot machinery carried by a durable [`AgentCore`].
struct DurabilityState {
    journal: Journal,
    dir: PathBuf,
    /// Journal records between snapshots (0 = never snapshot).
    snapshot_every: u64,
    /// Records appended since the last successful snapshot write.
    since_snapshot: u64,
}

/// The status fields as a plain value: what a `status` request reports,
/// and what the batched server publishes into its lock-free cell.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StatusSnapshot {
    pub jobs: usize,
    pub assigned: usize,
    pub executors: usize,
    pub horizon: f64,
    /// Size of the executable frontier (tasks ready to be scheduled).
    pub executable: usize,
    /// Jobs submitted with a future arrival, not yet activated.
    pub pending: usize,
    /// Executors currently down (crashed, not yet recovered).
    pub down: usize,
    /// Racks in the network topology (1 under `flat`).
    pub racks: usize,
    /// Mailbox depth at publish time (batched engine; 0 in serial mode).
    pub queue: usize,
    /// Mutating requests refused with `Overloaded` so far.
    pub shed: usize,
    /// Retries answered from the request-id dedup window so far.
    pub deduped: usize,
}

impl StatusSnapshot {
    pub fn to_response(&self) -> Response {
        Response::Status {
            jobs: self.jobs,
            assigned: self.assigned,
            executors: self.executors,
            horizon: self.horizon,
            executable: self.executable,
            pending: self.pending,
            down: self.down,
            racks: self.racks,
            queue: self.queue,
            shed: self.shed,
            deduped: self.deduped,
        }
    }
}

/// Seqlock-published [`StatusSnapshot`]: a single writer (the core loop)
/// bumps `seq` to odd, stores the fields, bumps back to even; readers
/// retry until they observe the same even `seq` on both sides of their
/// field loads. Readers therefore never block on the writer, never see a
/// torn snapshot, and never touch the core mutex — the whole point of
/// the batched `status` path. Every field is an individual atomic, so
/// the retry loop is a consistency protocol, not a safety requirement.
struct StatusCell {
    seq: AtomicU64,
    jobs: AtomicUsize,
    assigned: AtomicUsize,
    executors: AtomicUsize,
    /// `f64` horizon stored as raw bits (atomics are integer-only).
    horizon_bits: AtomicU64,
    executable: AtomicUsize,
    pending: AtomicUsize,
    down: AtomicUsize,
    racks: AtomicUsize,
    queue: AtomicUsize,
    shed: AtomicUsize,
    deduped: AtomicUsize,
}

impl StatusCell {
    fn new() -> StatusCell {
        StatusCell {
            seq: AtomicU64::new(0),
            jobs: AtomicUsize::new(0),
            assigned: AtomicUsize::new(0),
            executors: AtomicUsize::new(0),
            horizon_bits: AtomicU64::new(0f64.to_bits()),
            executable: AtomicUsize::new(0),
            pending: AtomicUsize::new(0),
            down: AtomicUsize::new(0),
            racks: AtomicUsize::new(1),
            queue: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
            deduped: AtomicUsize::new(0),
        }
    }

    /// Publish a new snapshot. Single-writer: only the core loop (and
    /// `serve()` once, before the core loop starts) may call this.
    fn publish(&self, s: &StatusSnapshot) {
        // Odd = write in progress. The acquire ordering on the RMW keeps
        // the field stores below it; the closing release keeps them above
        // the final (even) value readers validate against.
        self.seq.fetch_add(1, Ordering::Acquire);
        self.jobs.store(s.jobs, Ordering::Relaxed);
        self.assigned.store(s.assigned, Ordering::Relaxed);
        self.executors.store(s.executors, Ordering::Relaxed);
        self.horizon_bits.store(s.horizon.to_bits(), Ordering::Relaxed);
        self.executable.store(s.executable, Ordering::Relaxed);
        self.pending.store(s.pending, Ordering::Relaxed);
        self.down.store(s.down, Ordering::Relaxed);
        self.racks.store(s.racks, Ordering::Relaxed);
        self.queue.store(s.queue, Ordering::Relaxed);
        self.shed.store(s.shed, Ordering::Relaxed);
        self.deduped.store(s.deduped, Ordering::Relaxed);
        self.seq.fetch_add(1, Ordering::Release);
    }

    /// Read a consistent snapshot without ever blocking the writer.
    fn read(&self) -> StatusSnapshot {
        let mut spins = 0u32;
        loop {
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 & 1 == 0 {
                let snap = StatusSnapshot {
                    jobs: self.jobs.load(Ordering::Relaxed),
                    assigned: self.assigned.load(Ordering::Relaxed),
                    executors: self.executors.load(Ordering::Relaxed),
                    horizon: f64::from_bits(self.horizon_bits.load(Ordering::Relaxed)),
                    executable: self.executable.load(Ordering::Relaxed),
                    pending: self.pending.load(Ordering::Relaxed),
                    down: self.down.load(Ordering::Relaxed),
                    racks: self.racks.load(Ordering::Relaxed),
                    queue: self.queue.load(Ordering::Relaxed),
                    shed: self.shed.load(Ordering::Relaxed),
                    deduped: self.deduped.load(Ordering::Relaxed),
                };
                std::sync::atomic::fence(Ordering::Acquire);
                if self.seq.load(Ordering::Relaxed) == s1 {
                    return snap;
                }
            }
            // Publishes are a handful of stores; a reader only spins
            // here if it raced one. Yield periodically so a preempted
            // writer on a loaded box can finish.
            spins += 1;
            if spins % 64 == 0 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }
}

/// One mutating request parked in the mailbox, with the channel its
/// connection thread is blocked on. Dropping an envelope unanswered
/// disconnects the channel, which the waiter surfaces as an error — so
/// a panicking core loop can never strand a connection forever.
struct Envelope {
    /// Client-assigned idempotency id, if the request carried one.
    id: Option<String>,
    req: Request,
    resp_tx: mpsc::Sender<Response>,
}

#[derive(Default)]
struct MailboxQueue {
    queue: VecDeque<Envelope>,
    /// Set when the core loop exits (cleanly or by panic): no envelope
    /// will ever be drained again, so enqueues must be refused.
    closed: bool,
}

/// The connection-threads → core-loop handoff: a FIFO of envelopes plus
/// the condvar the core loop sleeps on.
struct Mailbox {
    q: Mutex<MailboxQueue>,
    cv: Condvar,
}

impl Mailbox {
    fn new() -> Mailbox {
        Mailbox {
            q: Mutex::new(MailboxQueue::default()),
            cv: Condvar::new(),
        }
    }

    /// The mailbox mutex guards a plain queue with no invariants a
    /// panic could break, so a poisoned guard is still usable.
    fn lock(&self) -> std::sync::MutexGuard<'_, MailboxQueue> {
        self.q.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Outcome of [`AgentServer::enqueue`] under the admission bound.
enum Enqueued {
    /// Parked; await the response on this channel.
    Queued(mpsc::Receiver<Response>),
    /// Refused by the `Shed` policy at this queue depth.
    Overloaded(usize),
    /// The core loop is gone (shutdown or panic).
    Closed,
}

/// The scheduling agent's shared core: live state, the scheduler, and
/// the deferred-arrival queue. One of these sits behind the server's
/// mutex; it is also usable directly (no networking) in tests and
/// embedding scenarios.
pub struct AgentCore {
    /// Private so the pending-heap invariant (every unarrived job has
    /// exactly one heap entry) can't be broken from outside; read via
    /// [`AgentCore::state`].
    state: SimState,
    scheduler: Box<dyn Scheduler + Send>,
    pending: BinaryHeap<Pending>,
    /// Transient crashes reported via `report_failure`, waiting for the
    /// wall clock to reach their recovery time (`id` = executor).
    recoveries: BinaryHeap<Pending>,
    /// Cached responses keyed by client-assigned `request_id`.
    dedup: DedupWindow,
    /// Retries answered from the window instead of re-applied.
    n_deduped: u64,
    /// Write-ahead journal + snapshot machinery (None = in-memory only).
    durability: Option<DurabilityState>,
}

impl AgentCore {
    pub fn new(cluster: Cluster, scheduler: Box<dyn Scheduler + Send>) -> AgentCore {
        AgentCore {
            state: SimState::new(cluster, Workload::new_empty()),
            scheduler,
            pending: BinaryHeap::new(),
            recoveries: BinaryHeap::new(),
            dedup: DedupWindow::default(),
            n_deduped: 0,
            durability: None,
        }
    }

    /// Advance the wall clock monotonically, bring recovered executors
    /// back up, and activate every deferred job whose arrival time has
    /// come — the service-side equivalent of the simulator popping
    /// recovery and arrival events.
    pub fn advance_to(&mut self, time: f64) {
        self.state.advance_wall(time);
        while let Some(r) = self.recoveries.peek() {
            if r.time > self.state.wall {
                break;
            }
            let r = self.recoveries.pop().expect("peeked entry exists");
            self.state.mark_executor_up(r.id);
        }
        while let Some(p) = self.pending.peek() {
            if p.time > self.state.wall {
                break;
            }
            let p = self.pending.pop().expect("peeked entry exists");
            self.state.mark_arrived(p.id);
        }
    }

    /// Jobs submitted but still waiting for their arrival time.
    pub fn pending_jobs(&self) -> usize {
        self.pending.len()
    }

    /// Read-only view of the live scheduling state.
    pub fn state(&self) -> &SimState {
        &self.state
    }

    /// The status fields as a value — what a `status` request answers,
    /// and what the batched server publishes after each batch. `pending`
    /// is O(1) from the heap; every unarrived job is exactly one entry
    /// (submit either marks arrived or pushes; `advance_to` pops and
    /// marks in lockstep).
    pub fn status_snapshot(&self) -> StatusSnapshot {
        StatusSnapshot {
            jobs: self.state.jobs.len(),
            assigned: self.state.n_assigned,
            executors: self.state.cluster.len(),
            horizon: self.state.horizon,
            executable: self.state.executable().len(),
            pending: self.pending.len(),
            down: self.state.cluster.len() - self.state.cluster.n_available(),
            racks: self.state.cluster.n_racks(),
            // queue/shed are engine-level; the server overrides them
            // when it publishes.
            queue: 0,
            shed: 0,
            deduped: self.n_deduped as usize,
        }
    }

    /// Handle one request against the live state (no idempotency id).
    pub fn handle(&mut self, req: Request) -> Response {
        self.handle_tagged(None, req)
    }

    /// Handle one request carrying an optional client-assigned
    /// idempotency id. Mutating requests go through the full durable
    /// path: a retry whose id is still in the dedup window gets the
    /// original response back without re-applying; a fresh request is
    /// appended to the journal *before* it touches the state (an append
    /// failure refuses the request outright), applied, and its response
    /// cached under the id. The journal record is durable only after
    /// the next [`AgentCore::sync_durability`] — the server syncs once
    /// per batch before releasing responses.
    pub fn handle_tagged(&mut self, id: Option<&str>, req: Request) -> Response {
        if !req.is_mutating() {
            return self.dispatch(req);
        }
        if let Some(cached) = self.dedup_cached(id) {
            return cached;
        }
        if let Err(e) = self.journal_append(id, &req) {
            crate::log_warn!("journal append failed: {e:#}");
            return Response::Error(format!("journal append failed; request not applied: {e:#}"));
        }
        let resp = self.dispatch(req);
        self.dedup_store(id, &resp);
        resp
    }

    /// The dedup-window lookup: a hit means this exact request was
    /// already applied — hand back the original response.
    fn dedup_cached(&mut self, id: Option<&str>) -> Option<Response> {
        let cached = self.dedup.get(id?)?.clone();
        self.n_deduped += 1;
        crate::obs::metrics::service_metrics()
            .requests_deduped_total
            .inc();
        Some(cached)
    }

    fn dedup_store(&mut self, id: Option<&str>, resp: &Response) {
        if let Some(id) = id {
            self.dedup.insert(id.to_string(), resp.clone());
        }
    }

    /// Append a mutating request to the write-ahead journal (no-op when
    /// durability is off). Must run before the request is applied.
    fn journal_append(&mut self, id: Option<&str>, req: &Request) -> Result<()> {
        let Some(d) = self.durability.as_mut() else {
            return Ok(());
        };
        let _sp = crate::obs::trace::span("service", "journal_append");
        let t0 = Instant::now();
        d.journal.append(id, req)?;
        crate::obs::metrics::service_metrics()
            .journal_append_ms
            .record(t0.elapsed().as_secs_f64() * 1e3);
        d.since_snapshot += 1;
        Ok(())
    }

    /// The sequence number the next journal append would get (None when
    /// durability is off) — lets the server tell whether a request was
    /// actually journaled without widening `handle_tagged`'s signature.
    fn journal_next_seq(&self) -> Option<u64> {
        self.durability.as_ref().map(|d| d.journal.next_seq())
    }

    /// Flush and fsync journal appends. The server calls this once per
    /// applied batch, before any of the batch's responses are released.
    pub fn sync_durability(&mut self) -> Result<()> {
        match self.durability.as_mut() {
            Some(d) => {
                let _sp = crate::obs::trace::span("service", "journal_fsync");
                let t0 = Instant::now();
                let res = d.journal.sync();
                let m = crate::obs::metrics::service_metrics();
                m.journal_fsync_ms
                    .record(t0.elapsed().as_secs_f64() * 1e3);
                m.journal_fsyncs_total.inc();
                res
            }
            None => Ok(()),
        }
    }

    /// Write a snapshot if `snapshot_every` journal records accumulated
    /// since the last one. Call only after a successful
    /// [`AgentCore::sync_durability`] — a snapshot must never cover
    /// records that are not yet on disk. Snapshot failures are warnings:
    /// the journal alone still recovers everything.
    pub fn maybe_snapshot(&mut self) {
        let (seq, dir) = match &self.durability {
            Some(d) if d.snapshot_every > 0 && d.since_snapshot >= d.snapshot_every => {
                (d.journal.next_seq() - 1, d.dir.clone())
            }
            _ => return,
        };
        let _sp = crate::obs::trace::span("service", "snapshot_write");
        let t0 = Instant::now();
        let doc = self.snapshot_json();
        match snapshot::write(&dir, seq, doc) {
            Ok(_path) => {
                let m = crate::obs::metrics::service_metrics();
                m.snapshot_write_ms
                    .record(t0.elapsed().as_secs_f64() * 1e3);
                m.snapshot_writes_total.inc();
                if let Some(d) = self.durability.as_mut() {
                    d.since_snapshot = 0;
                }
            }
            Err(e) => crate::log_warn!("snapshot write failed at seq {seq}: {e:#}"),
        }
    }

    /// Serialize the whole core — state, deferred arrivals, scheduled
    /// recoveries, and the dedup window — as one JSON document. Heaps
    /// are serialized sorted by `(time, id)`; `Pending`'s total order
    /// makes pop order a function of the multiset alone, so the restored
    /// heaps drain identically however they were built.
    pub fn snapshot_json(&self) -> Json {
        let heap_json = |h: &BinaryHeap<Pending>| -> Json {
            let mut entries: Vec<(f64, usize)> = h.iter().map(|p| (p.time, p.id)).collect();
            entries.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            Json::from(
                entries
                    .into_iter()
                    .map(|(t, i)| Json::from(vec![Json::from(t), Json::from(i)]))
                    .collect::<Vec<Json>>(),
            )
        };
        let dedup = Json::from(
            self.dedup
                .iter_in_order()
                .map(|(id, resp)| Json::from(vec![Json::from(id.as_str()), resp.to_json()]))
                .collect::<Vec<Json>>(),
        );
        Json::from_pairs(vec![
            ("state", self.state.snapshot_json()),
            ("pending", heap_json(&self.pending)),
            ("recoveries", heap_json(&self.recoveries)),
            ("dedup", dedup),
            ("n_deduped", Json::from(self.n_deduped)),
        ])
    }

    /// Rebuild this core from a [`AgentCore::snapshot_json`] document.
    /// The cluster shape must match the one the snapshot was taken
    /// against (checked bitwise by the state restore); the scheduler is
    /// kept as constructed — recovery determinism requires it to be a
    /// pure function of the state, which every in-tree scheduler is.
    pub fn restore_from(&mut self, doc: &Json) -> Result<()> {
        let state_doc = doc
            .get("state")
            .ok_or_else(|| anyhow!("snapshot missing state"))?;
        let state = SimState::from_snapshot_json(self.state.cluster.clone(), state_doc)
            .context("restoring simulation state")?;
        let parse_heap = |field: &str| -> Result<BinaryHeap<Pending>> {
            let arr = doc
                .get(field)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("snapshot missing {field}"))?;
            let mut heap = BinaryHeap::with_capacity(arr.len());
            for e in arr {
                let pair = e
                    .as_arr()
                    .filter(|p| p.len() == 2)
                    .ok_or_else(|| anyhow!("bad {field} entry (want [time, id])"))?;
                let time = pair[0]
                    .as_f64()
                    .filter(|t| t.is_finite())
                    .ok_or_else(|| anyhow!("bad {field} time"))?;
                let id = pair[1]
                    .as_usize()
                    .ok_or_else(|| anyhow!("bad {field} id"))?;
                heap.push(Pending { time, id });
            }
            Ok(heap)
        };
        let pending = parse_heap("pending")?;
        let recoveries = parse_heap("recoveries")?;
        // Cross-check the heap invariants against the restored state:
        // every unarrived job has exactly one pending entry, and every
        // scheduled recovery names a distinct, currently-down executor.
        let mut pending_ids: Vec<usize> = pending.iter().map(|p| p.id).collect();
        pending_ids.sort_unstable();
        let mut unarrived: Vec<usize> = (0..state.jobs.len()).filter(|&j| !state.arrived[j]).collect();
        unarrived.sort_unstable();
        if pending_ids != unarrived {
            bail!("pending heap does not match the state's unarrived jobs");
        }
        let mut rec_ids: Vec<usize> = recoveries.iter().map(|p| p.id).collect();
        rec_ids.sort_unstable();
        if rec_ids.windows(2).any(|w| w[0] == w[1]) {
            bail!("duplicate recovery entries");
        }
        for &e in &rec_ids {
            if e >= state.cluster.len() {
                bail!("recovery entry for executor {e} out of range");
            }
            if state.exec_available(e) {
                bail!("recovery scheduled for executor {e}, which is up");
            }
        }
        let mut dedup = DedupWindow::default();
        let dedup_arr = doc
            .get("dedup")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("snapshot missing dedup window"))?;
        for e in dedup_arr {
            let pair = e
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| anyhow!("bad dedup entry (want [id, response])"))?;
            let id = pair[0]
                .as_str()
                .ok_or_else(|| anyhow!("bad dedup id"))?;
            let resp = Response::from_json(&pair[1]).context("bad dedup response")?;
            dedup.insert(id.to_string(), resp);
        }
        let n_deduped = doc
            .get("n_deduped")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("snapshot missing n_deduped"))?;
        self.state = state;
        self.pending = pending;
        self.recoveries = recoveries;
        self.dedup = dedup;
        self.n_deduped = n_deduped;
        Ok(())
    }

    /// Apply one request to the live state (the engine shared by both
    /// the plain and the tagged entry points — no dedup, no journal).
    fn dispatch(&mut self, req: Request) -> Response {
        match req {
            Request::SubmitJob { .. } => match req.build_job(0) {
                Ok(job) => {
                    let arrival = job.arrival;
                    if !arrival.is_finite() {
                        return Response::Error("invalid job: non-finite arrival".to_string());
                    }
                    let id = self.state.add_job(job);
                    if arrival <= self.state.wall {
                        self.state.mark_arrived(id);
                    } else {
                        self.pending.push(Pending { time: arrival, id });
                    }
                    Response::Ok { job_id: Some(id) }
                }
                Err(e) => Response::Error(format!("invalid job: {e}")),
            },
            Request::TaskComplete { time, .. } => {
                // Heartbeat: completions advance the agent's wall clock
                // (placements already fix AFTs deterministically) and can
                // release deferred arrivals.
                self.advance_to(time);
                Response::Ok { job_id: None }
            }
            Request::Schedule { time } => {
                self.advance_to(time);
                let mut out = Vec::new();
                loop {
                    if self.state.executable().is_empty() {
                        break;
                    }
                    match self.scheduler.step(&self.state) {
                        // Assignments applied before a scheduler error are
                        // already committed to the state, so the master
                        // must learn them or its view diverges from ours:
                        // return the partial batch and let the next
                        // (empty) drain surface the error itself.
                        Err(e) => {
                            if out.is_empty() {
                                return Response::Error(format!("scheduler: {e}"));
                            }
                            crate::log_warn!(
                                "scheduler error after {} applied assignments: {e} \
                                 (returning the partial batch)",
                                out.len()
                            );
                            return Response::Assignments(out);
                        }
                        Ok(None) => break,
                        Ok(Some((task, alloc))) => {
                            let finish = self.state.apply(task, alloc);
                            let pl = self.state.placements[task.job][task.node]
                                .iter()
                                .rev()
                                .find(|p| !p.duplicate)
                                .copied()
                                .expect("primary placement exists");
                            out.push(assignment_from(task.job, task.node, alloc, pl.start, finish));
                        }
                    }
                }
                Response::Assignments(out)
            }
            Request::ReportFailure {
                exec,
                time,
                recovery,
            } => {
                if exec >= self.state.cluster.len() {
                    return Response::Error(format!("executor {exec} out of range"));
                }
                if !time.is_finite() {
                    return Response::Error("non-finite failure time".to_string());
                }
                if let Some(r) = recovery {
                    if !r.is_finite() || r < time {
                        return Response::Error(
                            "recovery must be finite and no earlier than the failure"
                                .to_string(),
                        );
                    }
                }
                // A stale report (time < wall) still takes effect now:
                // the wall never moves backwards, so the rollback runs
                // at the current clock.
                self.advance_to(time);
                let at = self.state.wall;
                let recovery = recovery.map(|r| r.max(at));
                // A duplicate report on an already-down executor is a
                // no-op and must not schedule a recovery (the original
                // report may have been permanent).
                let was_up = self.state.exec_available(exec);
                let out = self.state.apply_crash(exec, at, recovery);
                if was_up {
                    if let Some(r) = recovery {
                        self.recoveries.push(Pending { time: r, id: exec });
                    }
                } else if recovery.is_none() {
                    // Escalation: the master learned a transiently-down
                    // executor is actually gone for good — cancel its
                    // scheduled resurrection so no future request books
                    // work onto a dead machine. (A re-report with a new
                    // recovery time remains a no-op.)
                    let kept: Vec<Pending> = self
                        .recoveries
                        .drain()
                        .filter(|p| p.id != exec)
                        .collect();
                    self.recoveries = kept.into_iter().collect();
                }
                Response::Recovery {
                    cancelled: out.cancelled,
                    requeued: out.requeued,
                    survived: out.survived,
                }
            }
            Request::Status => self.status_snapshot().to_response(),
            Request::Shutdown => Response::Ok { job_id: None },
            Request::Metrics => metrics_response(),
        }
    }
}

/// Build a `metrics` response from the global telemetry registry. Pure
/// atomics — no core lock, so both engines answer it off the lock-free
/// path (the batched connection loop resolves it like `status`).
fn metrics_response() -> Response {
    let snap = crate::obs::metrics::snapshot_json();
    Response::Metrics {
        prometheus: crate::obs::metrics::prometheus_text(),
        series: snap
            .get("series")
            .cloned()
            .unwrap_or(Json::Arr(Vec::new())),
    }
}

/// Answer one HTTP scrape: consume the request head (any method, any
/// path — there is only one resource), then write a `200` with the
/// Prometheus text exposition and close. The head read is bounded by
/// the write deadline and a line cap so a misbehaving peer cannot pin
/// the listener.
fn serve_metrics_conn(stream: TcpStream) -> Result<()> {
    stream.set_nonblocking(false).context("blocking stream")?;
    stream
        .set_read_timeout(Some(WRITE_TIMEOUT))
        .context("read timeout")?;
    stream
        .set_write_timeout(Some(WRITE_TIMEOUT))
        .context("write timeout")?;
    let mut reader = BufReader::new(stream.try_clone()?);
    // Drain the request head: request line + headers up to a blank line.
    // HTTP/1.0 pollers (curl --http1.0, busybox wget) still send one.
    let mut line = String::new();
    let mut head_bytes = 0usize;
    loop {
        line.clear();
        let n = reader.read_line(&mut line).context("reading scrape head")?;
        head_bytes += n;
        if n == 0 || line == "\r\n" || line == "\n" {
            break;
        }
        if head_bytes > 64 << 10 {
            bail!("scrape request head exceeds 64 KiB");
        }
    }
    let body = crate::obs::metrics::prometheus_text();
    let mut writer = BufWriter::new(stream);
    write!(
        writer,
        "HTTP/1.1 200 OK\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\
         \r\n",
        body.len()
    )?;
    writer.write_all(body.as_bytes())?;
    writer.flush()?;
    Ok(())
}

/// The scheduling agent behind a TCP endpoint: a shared [`AgentCore`]
/// served by one thread per master connection, applied either serially
/// or through the batched core loop (see [`ServiceMode`]).
pub struct AgentServer {
    core: Mutex<AgentCore>,
    shutdown: AtomicBool,
    mode: ServiceMode,
    mailbox: Mailbox,
    status: StatusCell,
    /// Mailbox bound for the batched engine (0 = unbounded).
    max_queue: usize,
    admission: AdmissionPolicy,
    n_shed: AtomicU64,
    // Batch-formation counters (telemetry for the soak harness).
    n_batches: AtomicU64,
    n_batched_requests: AtomicU64,
    n_coalesced_heartbeats: AtomicU64,
}

impl AgentServer {
    /// A server in the default (batched) mode.
    pub fn new(cluster: Cluster, scheduler: Box<dyn Scheduler + Send>) -> AgentServer {
        AgentServer::with_mode(cluster, scheduler, ServiceMode::Batched)
    }

    pub fn with_mode(
        cluster: Cluster,
        scheduler: Box<dyn Scheduler + Send>,
        mode: ServiceMode,
    ) -> AgentServer {
        // A server is long-lived and network-bound: always collect
        // telemetry (the registry is pure atomics; recording never
        // changes scheduling behavior — integration_obs pins this).
        crate::obs::set_enabled(true);
        AgentServer {
            core: Mutex::new(AgentCore::new(cluster, scheduler)),
            shutdown: AtomicBool::new(false),
            mode,
            mailbox: Mailbox::new(),
            status: StatusCell::new(),
            max_queue: 0,
            admission: AdmissionPolicy::Shed,
            n_shed: AtomicU64::new(0),
            n_batches: AtomicU64::new(0),
            n_batched_requests: AtomicU64::new(0),
            n_coalesced_heartbeats: AtomicU64::new(0),
        }
    }

    /// Bound the mailbox at `max_queue` envelopes (0 = unbounded) with
    /// the given over-bound policy. Applies to the batched engine; the
    /// serial engine has no queue to bound.
    pub fn with_admission(mut self, max_queue: usize, admission: AdmissionPolicy) -> AgentServer {
        self.max_queue = max_queue;
        self.admission = admission;
        self
    }

    /// Attach a write-ahead journal (and periodic snapshots) to the
    /// core. With `restore` set, the core is rebuilt from the newest
    /// readable snapshot plus a deterministic replay of the journal
    /// suffix — bit-identical to a server that processed the same
    /// request stream without interruption. Without `restore`, the
    /// directory must be fresh: silently appending seq N+1 to a journal
    /// whose first N records were never applied would poison every
    /// future recovery.
    pub fn with_durability(mut self, d: Durability) -> Result<AgentServer> {
        let (journal, records) = Journal::open(&d.dir)?;
        let core = self
            .core
            .get_mut()
            .unwrap_or_else(|e| e.into_inner());
        if d.restore {
            let start_seq = match snapshot::load_latest(&d.dir)? {
                Some((seq, doc)) => {
                    core.restore_from(&doc)
                        .with_context(|| format!("restoring snapshot at seq {seq}"))?;
                    seq
                }
                None => 0,
            };
            if start_seq + 1 > journal.next_seq() {
                // Snapshots are written only after their records are
                // fsynced, so a journal shorter than the snapshot means
                // damage recovery cannot reason about.
                bail!(
                    "snapshot covers journal seq {start_seq} but the journal ends at \
                     {} — refusing to recover from inconsistent storage",
                    journal.next_seq() - 1
                );
            }
            let mut replayed = 0u64;
            for rec in &records {
                if rec.seq <= start_seq {
                    continue;
                }
                // Replay through the tagged path with durability still
                // unset: no re-journaling, but the dedup window and
                // deferred heaps rebuild exactly as the original
                // application built them. Responses are re-derived, not
                // delivered (their clients are long gone).
                let _ = core.handle_tagged(rec.id.as_deref(), rec.req.clone());
                replayed += 1;
            }
            crate::log_info!(
                "restored agent core: snapshot seq {start_seq}, {replayed} journal \
                 records replayed, {} pending jobs, wall {:.3}",
                core.pending.len(),
                core.state.wall
            );
        } else if !records.is_empty() || snapshot::load_latest(&d.dir)?.is_some() {
            bail!(
                "journal dir {} already holds a journal/snapshots; pass --restore to \
                 recover from it, or point --journal at a fresh directory",
                d.dir.display()
            );
        }
        core.durability = Some(DurabilityState {
            journal,
            dir: d.dir,
            snapshot_every: d.snapshot_every,
            since_snapshot: 0,
        });
        Ok(self)
    }

    pub fn mode(&self) -> ServiceMode {
        self.mode
    }

    /// Mutating requests refused with `Overloaded` so far.
    pub fn shed_count(&self) -> u64 {
        self.n_shed.load(Ordering::Relaxed)
    }

    /// `(batches, requests applied through batches, heartbeats coalesced
    /// away)` — requests/batches is the mean batch size the mailbox
    /// actually formed under load.
    pub fn batch_stats(&self) -> (u64, u64, u64) {
        (
            self.n_batches.load(Ordering::Relaxed),
            self.n_batched_requests.load(Ordering::Relaxed),
            self.n_coalesced_heartbeats.load(Ordering::Relaxed),
        )
    }

    /// Handle one request against the shared core (serialized at the
    /// lock). Exposed so embedders and tests can drive the agent without
    /// networking. Bypasses the mailbox — in batched mode, mutations
    /// made this way are reflected in `status` snapshots only after the
    /// next batch publishes.
    pub fn handle(&self, req: Request) -> Response {
        self.handle_tagged(None, req)
    }

    /// [`AgentServer::handle`] with an idempotency id — the serial
    /// engine's per-request path. Each request is its own durability
    /// batch: append, apply, fsync, maybe snapshot, then answer. A
    /// failed fsync degrades the acknowledgement to an error (the
    /// journal may not hold the record a crash-recovery would need),
    /// though the request *was* applied — a client retry gets the real
    /// response back from the dedup window.
    pub fn handle_tagged(&self, id: Option<&str>, req: Request) -> Response {
        let m = crate::obs::metrics::service_metrics();
        let ki = req.kind_index();
        m.requests_total[ki].inc();
        let t0 = Instant::now();
        let resp = match self.core.lock() {
            Ok(mut core) => {
                let before = core.journal_next_seq();
                let resp = core.handle_tagged(id, req);
                let journaled = core.journal_next_seq() != before;
                match core.sync_durability() {
                    Ok(()) => {
                        core.maybe_snapshot();
                        resp
                    }
                    Err(e) if journaled => {
                        crate::log_warn!("journal sync failed: {e:#}");
                        Response::Error(format!("journal sync failed: {e:#}"))
                    }
                    Err(e) => {
                        crate::log_warn!("journal sync failed: {e:#}");
                        resp
                    }
                }
            }
            // A panic mid-request may have left the state half-mutated:
            // refuse new decisions instead of scheduling against it, but
            // keep shutdown answerable so the server stays stoppable.
            Err(_poisoned) => {
                if matches!(req, Request::Shutdown) {
                    Response::Ok { job_id: None }
                } else {
                    Response::Error(
                        "agent core poisoned by a prior panic; refusing new requests \
                         (send shutdown)"
                            .to_string(),
                    )
                }
            }
        };
        m.request_latency_ms[ki]
            .record(t0.elapsed().as_secs_f64() * 1e3);
        resp
    }

    /// Run `f` with the core mutex held — the embedder's escape hatch
    /// for direct state inspection, and what the snapshot-isolation test
    /// uses to prove `status` never acquires this lock. Mutations made
    /// here do not refresh the status snapshot (prefer requests). A
    /// poisoned lock is recovered rather than propagated: inspection
    /// must keep working after a panic (that is when you need it most)
    /// — the request paths are the ones that refuse a poisoned core.
    pub fn with_core<R>(&self, f: impl FnOnce(&mut AgentCore) -> R) -> R {
        let mut core = self.core.lock().unwrap_or_else(|e| e.into_inner());
        f(&mut core)
    }

    fn publish_status(&self, core: &AgentCore) {
        let mut snap = core.status_snapshot();
        snap.queue = self.mailbox.lock().queue.len();
        snap.shed = self.n_shed.load(Ordering::Relaxed) as usize;
        self.status.publish(&snap);
    }

    /// Serve connections until a `shutdown` request arrives on any of
    /// them. Each accepted master gets its own thread; all of them share
    /// the core. Returns the bound address through `on_bound` (use port 0
    /// for ephemeral).
    pub fn serve(&self, addr: &str, on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        on_bound(listener.local_addr()?);
        // Non-blocking accepts so this loop can poll the shutdown flag
        // set by whichever connection thread receives the request.
        listener
            .set_nonblocking(true)
            .context("setting listener non-blocking")?;
        // Seed the snapshot so `status` is answerable before the first
        // batch (single-writer discipline: the core loop has not started
        // yet).
        if let Ok(core) = self.core.lock() {
            self.publish_status(&core);
        }
        let server = &*self;
        std::thread::scope(|s| {
            if server.mode == ServiceMode::Batched {
                s.spawn(move || server.core_loop());
            }
            let mut res: Result<()> = Ok(());
            while !server.shutdown.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        s.spawn(move || {
                            if let Err(e) = server.serve_conn(stream) {
                                crate::log_warn!("connection dropped: {e:#}");
                            }
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(ACCEPT_POLL);
                    }
                    // A peer aborting mid-handshake must not take down a
                    // long-lived multi-master server.
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::Interrupted
                                | std::io::ErrorKind::ConnectionAborted
                                | std::io::ErrorKind::ConnectionReset
                        ) =>
                    {
                        crate::log_warn!("transient accept error: {e}");
                    }
                    Err(e) => {
                        res = Err(anyhow::Error::from(e).context("accepting connection"));
                        break;
                    }
                }
            }
            // Wake every connection thread (they poll the same flag) and
            // the core loop (it sleeps on the mailbox condvar) before
            // the scope joins them.
            server.shutdown.store(true, Ordering::SeqCst);
            server.mailbox.cv.notify_all();
            res
        })
    }

    /// Serve the Prometheus text exposition over plain HTTP GET on
    /// `addr` until the agent shuts down (`lachesis serve
    /// --metrics-addr`). Every scrape reads the global atomic registry —
    /// no core lock, no mailbox — so a stalled scheduler never blocks
    /// monitoring. Scrape traffic is expected to be light (one poller);
    /// connections are handled one at a time, closed per response.
    pub fn serve_metrics_http(
        &self,
        addr: &str,
        on_bound: impl FnOnce(std::net::SocketAddr),
    ) -> Result<()> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding metrics {addr}"))?;
        on_bound(listener.local_addr()?);
        listener
            .set_nonblocking(true)
            .context("setting metrics listener non-blocking")?;
        while !self.shutdown.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    if let Err(e) = serve_metrics_conn(stream) {
                        crate::log_debug!("metrics scrape failed: {e:#}");
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::Interrupted
                            | std::io::ErrorKind::ConnectionAborted
                            | std::io::ErrorKind::ConnectionReset
                    ) =>
                {
                    crate::log_debug!("transient metrics accept error: {e}");
                }
                Err(e) => {
                    return Err(anyhow::Error::from(e).context("accepting metrics connection"))
                }
            }
        }
        Ok(())
    }

    /// The batched engine's only consumer of the core lock: sleep until
    /// the mailbox holds work, drain *everything* queued, apply it in
    /// FIFO order under one lock acquisition, refresh the status
    /// snapshot, then release the replies. Exits once shutdown is set
    /// and the mailbox has been drained dry.
    fn core_loop(&self) {
        // On any exit — including a panic inside a scheduler — close the
        // mailbox and answer every still-queued envelope with an explicit
        // error. Dropping them silently would also unblock the waiters
        // (disconnected channel), but the explicit reply distinguishes
        // "never applied, never journaled — safe to resubmit" from the
        // ambiguous disconnect a mid-apply crash produces.
        struct MailboxCloser<'a>(&'a AgentServer);
        impl Drop for MailboxCloser<'_> {
            fn drop(&mut self) {
                let drained: Vec<Envelope> = {
                    let mut q = self.0.mailbox.lock();
                    q.closed = true;
                    q.queue.drain(..).collect()
                };
                for env in drained {
                    let _ = env.resp_tx.send(Response::Error(
                        "server shutting down before the request was applied".to_string(),
                    ));
                }
            }
        }
        let _closer = MailboxCloser(self);
        while let Some(batch) = self.next_batch() {
            self.apply_batch(batch);
        }
    }

    /// Block until the mailbox is non-empty (drain it whole) or shutdown
    /// is set with nothing queued (return `None`).
    fn next_batch(&self) -> Option<Vec<Envelope>> {
        let mut q = self.mailbox.lock();
        loop {
            if !q.queue.is_empty() {
                self.n_batches.fetch_add(1, Ordering::Relaxed);
                self.n_batched_requests
                    .fetch_add(q.queue.len() as u64, Ordering::Relaxed);
                let batch = q.queue.drain(..).collect();
                drop(q);
                crate::obs::metrics::service_metrics()
                    .mailbox_depth
                    .set(0.0);
                // The drain freed the whole bound: wake producers the
                // `Block` admission policy parked on the shared condvar.
                self.mailbox.cv.notify_all();
                return Some(batch);
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            // The timeout is a backstop: shutdown is normally followed
            // by a notify, but a racing missed wakeup must not leave the
            // core loop (and the serve scope join) sleeping forever.
            q = self
                .mailbox
                .cv
                .wait_timeout(q, ACCEPT_POLL)
                .map(|(g, _t)| g)
                .unwrap_or_else(|e| e.into_inner().0);
        }
    }

    /// Apply one drained batch in FIFO order under a single core-lock
    /// acquisition. Consecutive `task_complete` heartbeats collapse into
    /// one `advance_to(max time)` — `advance_to` is monotone, so the run
    /// activates exactly the arrivals/recoveries the per-request
    /// advances would, and each heartbeat's response is the same plain
    /// `ok` either way. The snapshot refresh happens *before* replies
    /// are released, so a client that saw its mutation acknowledged
    /// reads a snapshot at least that fresh (read-your-writes).
    fn apply_batch(&self, batch: Vec<Envelope>) {
        let m = crate::obs::metrics::service_metrics();
        m.batch_size.record(batch.len() as f64);
        let _sp =
            crate::obs::trace::span_with("service", "apply_batch", "n", batch.len() as f64);
        // `(waiter, response, journaled-this-batch)` — the flag marks
        // which acknowledgements a failed batch fsync must degrade.
        let mut replies: Vec<(mpsc::Sender<Response>, Response, bool)> =
            Vec::with_capacity(batch.len());
        match self.core.lock() {
            Ok(mut core) => {
                let mut it = batch.into_iter().peekable();
                while let Some(env) = it.next() {
                    if matches!(env.req, Request::TaskComplete { .. }) {
                        // A run of consecutive heartbeats collapses into
                        // one `advance_to(max time)` — but each still
                        // goes through dedup and the journal (replay
                        // re-applies them one by one; `advance_to` is
                        // monotone, so the end state is identical).
                        let mut run = vec![env];
                        while matches!(
                            it.peek().map(|e| &e.req),
                            Some(Request::TaskComplete { .. })
                        ) {
                            run.push(it.next().expect("peeked entry exists"));
                        }
                        let n_run = run.len();
                        let mut max_t: Option<f64> = None;
                        for env in run {
                            let Envelope { id, req, resp_tx } = env;
                            m.requests_total[req.kind_index()].inc();
                            let Request::TaskComplete { time, .. } = req else {
                                unreachable!("run holds only heartbeats");
                            };
                            if let Some(cached) = core.dedup_cached(id.as_deref()) {
                                replies.push((resp_tx, cached, false));
                                continue;
                            }
                            if let Err(e) = core.journal_append(id.as_deref(), &req) {
                                crate::log_warn!("journal append failed: {e:#}");
                                replies.push((
                                    resp_tx,
                                    Response::Error(format!(
                                        "journal append failed; request not applied: {e:#}"
                                    )),
                                    false,
                                ));
                                continue;
                            }
                            // f64::max ignores NaN operands, exactly like
                            // the serial path's advance_wall no-op on a
                            // NaN heartbeat.
                            max_t = Some(max_t.map_or(time, |m: f64| m.max(time)));
                            let resp = Response::Ok { job_id: None };
                            core.dedup_store(id.as_deref(), &resp);
                            replies.push((resp_tx, resp, true));
                        }
                        if let Some(t) = max_t {
                            core.advance_to(t);
                        }
                        self.n_coalesced_heartbeats
                            .fetch_add(n_run as u64 - 1, Ordering::Relaxed);
                        m.heartbeats_coalesced_total.add(n_run as u64 - 1);
                    } else {
                        let Envelope { id, req, resp_tx } = env;
                        let ki = req.kind_index();
                        m.requests_total[ki].inc();
                        let t0 = Instant::now();
                        let before = core.journal_next_seq();
                        let resp = core.handle_tagged(id.as_deref(), req);
                        let journaled = core.journal_next_seq() != before;
                        m.request_latency_ms[ki]
                            .record(t0.elapsed().as_secs_f64() * 1e3);
                        replies.push((resp_tx, resp, journaled));
                    }
                }
                // Durability barrier: fsync the whole batch's appends
                // before any response escapes. On failure the journaled
                // acknowledgements become errors — the requests *were*
                // applied (a retry gets the real response from the dedup
                // window), but a crash-recovery might not see them, so
                // they must not be acknowledged as durable.
                match core.sync_durability() {
                    Ok(()) => core.maybe_snapshot(),
                    Err(e) => {
                        crate::log_warn!(
                            "journal sync failed: {e:#} (degrading this batch's acks)"
                        );
                        for (_tx, resp, journaled) in replies.iter_mut() {
                            if *journaled {
                                *resp =
                                    Response::Error(format!("journal sync failed: {e:#}"));
                            }
                        }
                    }
                }
                self.publish_status(&core);
            }
            Err(_poisoned) => {
                for env in batch {
                    replies.push((
                        env.resp_tx,
                        Response::Error(
                            "agent core poisoned by a prior panic; refusing new requests \
                             (send shutdown)"
                                .to_string(),
                        ),
                        false,
                    ));
                }
            }
        }
        for (tx, resp, _journaled) in replies {
            // A connection that died while waiting dropped its receiver;
            // nothing to do.
            let _ = tx.send(resp);
        }
    }

    /// Park a mutating request in the mailbox, subject to the admission
    /// bound. `Shed` refuses an over-bound request immediately with the
    /// observed depth; `Block` parks the connection thread until the
    /// core loop drains space (polling shutdown so it can never hang a
    /// stopping server).
    fn enqueue(&self, id: Option<String>, req: Request) -> Enqueued {
        let (tx, rx) = mpsc::channel();
        let mut q = self.mailbox.lock();
        loop {
            if q.closed {
                return Enqueued::Closed;
            }
            if self.max_queue == 0 || q.queue.len() < self.max_queue {
                q.queue.push_back(Envelope {
                    id,
                    req,
                    resp_tx: tx,
                });
                let depth = q.queue.len();
                drop(q);
                crate::obs::metrics::service_metrics()
                    .mailbox_depth
                    .set(depth as f64);
                // notify_all: the condvar is shared with producers
                // blocked on admission — a single wakeup could land on
                // one of them instead of the core loop.
                self.mailbox.cv.notify_all();
                return Enqueued::Queued(rx);
            }
            match self.admission {
                AdmissionPolicy::Shed => {
                    let depth = q.queue.len();
                    drop(q);
                    self.n_shed.fetch_add(1, Ordering::Relaxed);
                    crate::obs::metrics::service_metrics()
                        .requests_shed_total
                        .inc();
                    return Enqueued::Overloaded(depth);
                }
                AdmissionPolicy::Block => {
                    if self.shutdown.load(Ordering::SeqCst) {
                        return Enqueued::Closed;
                    }
                    // Timeout backstop mirrors `next_batch`: a missed
                    // wakeup must not park this producer forever.
                    q = self
                        .mailbox
                        .cv
                        .wait_timeout(q, ACCEPT_POLL)
                        .map(|(g, _t)| g)
                        .unwrap_or_else(|e| e.into_inner().0);
                }
            }
        }
    }

    /// Block until the core loop answers the envelope. A disconnected
    /// channel means the core loop dropped it (panic or shutdown race) —
    /// surfaced as an error response, never a hang.
    fn await_response(&self, rx: &mpsc::Receiver<Response>) -> Response {
        loop {
            match rx.recv_timeout(READ_POLL) {
                Ok(resp) => return resp,
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Response::Error(
                        "agent core unavailable (shutdown or panic before the request \
                         was applied)"
                            .to_string(),
                    )
                }
            }
        }
    }

    /// Serve one master connection until it closes, errors, or shutdown.
    fn serve_conn(&self, stream: TcpStream) -> Result<()> {
        // Accepted sockets can inherit the listener's non-blocking flag
        // on some platforms; we want blocking reads with a timeout so the
        // thread notices shutdown without busy-waiting.
        stream.set_nonblocking(false).context("blocking stream")?;
        stream
            .set_read_timeout(Some(READ_POLL))
            .context("read timeout")?;
        stream
            .set_write_timeout(Some(WRITE_TIMEOUT))
            .context("write timeout")?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        match self.mode {
            ServiceMode::Serial => self.serve_conn_serial(&mut reader, &mut writer),
            ServiceMode::Batched => self.serve_conn_batched(&mut reader, &mut writer),
        }
    }

    /// The single-lock engine: read a line, apply it under the core
    /// lock, answer, repeat.
    fn serve_conn_serial(
        &self,
        reader: &mut BufReader<TcpStream>,
        writer: &mut BufWriter<TcpStream>,
    ) -> Result<()> {
        // Accumulate raw bytes, not a String: a read timeout can land
        // mid-multibyte UTF-8 character, and `read_line` would drop the
        // already-consumed invalid-prefix bytes on the error path.
        let mut buf: Vec<u8> = Vec::new();
        loop {
            buf.clear();
            loop {
                if self.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                match read_capped_line(reader, &mut buf)? {
                    LineRead::Line => break,
                    LineRead::Timeout => continue, // poll the shutdown flag
                    LineRead::Eof => return Ok(()), // peer closed
                }
            }
            // Reject invalid UTF-8 outright: a lossy decode would accept
            // the request with U+FFFD-mangled strings (e.g. a job name
            // that no longer matches the master's).
            let resp = match std::str::from_utf8(&buf) {
                Err(_) => Response::Error("bad request: invalid utf-8".to_string()),
                Ok(line) => match Json::parse(line.trim())
                    .map_err(|e| anyhow!("{e}"))
                    .and_then(|v| Ok((request_id(&v)?, Request::from_json(&v)?)))
                {
                    Ok((id, req)) => {
                        let is_shutdown = matches!(req, Request::Shutdown);
                        let resp = self.handle_tagged(id.as_deref(), req);
                        writeln!(writer, "{}", resp.to_json().to_string())?;
                        writer.flush()?;
                        if is_shutdown {
                            self.shutdown.store(true, Ordering::SeqCst);
                            return Ok(());
                        }
                        continue;
                    }
                    Err(e) => Response::Error(format!("bad request: {e}")),
                },
            };
            writeln!(writer, "{}", resp.to_json().to_string())?;
            writer.flush()?;
        }
    }

    /// The batched engine's connection loop. One *burst* = the line the
    /// blocking read produced plus every complete line the client had
    /// already pipelined into our buffer. Every mutating request of the
    /// burst enters the mailbox before any response is awaited, so a
    /// pipelining client forms whole batches instead of lockstep round
    /// trips; responses are written back strictly in request order with
    /// one flush per burst.
    fn serve_conn_batched(
        &self,
        reader: &mut BufReader<TcpStream>,
        writer: &mut BufWriter<TcpStream>,
    ) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        loop {
            buf.clear();
            loop {
                if self.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                match read_capped_line(reader, &mut buf)? {
                    LineRead::Line => break,
                    LineRead::Timeout => continue,
                    LineRead::Eof => return Ok(()),
                }
            }
            let mut lines: Vec<Vec<u8>> = vec![std::mem::take(&mut buf)];
            while lines.len() < MAX_BURST {
                match take_buffered_line(reader) {
                    Some(line) => lines.push(line),
                    None => break,
                }
            }
            // Per-line dispatch plan. Parse failures answer immediately;
            // `status` resolves from the snapshot at write time (after
            // every earlier response of the burst arrived, so it already
            // reflects this connection's own earlier requests);
            // `shutdown` is handled by the connection itself.
            enum Slot {
                Ready(Response),
                Waiting(mpsc::Receiver<Response>),
                Snapshot,
                /// Telemetry scrape: resolved from the global atomic
                /// registry at write time — like `Snapshot`, it never
                /// touches the core lock or the mailbox.
                Metrics,
                Shutdown,
            }
            let mut plan: Vec<Slot> = Vec::with_capacity(lines.len());
            for line in &lines {
                let slot = match std::str::from_utf8(line) {
                    Err(_) => {
                        Slot::Ready(Response::Error("bad request: invalid utf-8".to_string()))
                    }
                    Ok(text) => match Json::parse(text.trim())
                        .map_err(|e| anyhow!("{e}"))
                        .and_then(|v| Ok((request_id(&v)?, Request::from_json(&v)?)))
                    {
                        Err(e) => Slot::Ready(Response::Error(format!("bad request: {e}"))),
                        Ok((_, Request::Status)) => Slot::Snapshot,
                        Ok((_, Request::Metrics)) => Slot::Metrics,
                        Ok((_, Request::Shutdown)) => Slot::Shutdown,
                        Ok((id, req)) => {
                            debug_assert!(req.is_mutating());
                            match self.enqueue(id, req) {
                                Enqueued::Queued(rx) => Slot::Waiting(rx),
                                Enqueued::Overloaded(queue) => {
                                    Slot::Ready(Response::Overloaded { queue })
                                }
                                Enqueued::Closed => Slot::Ready(Response::Error(
                                    "server shutting down".to_string(),
                                )),
                            }
                        }
                    },
                };
                plan.push(slot);
            }
            for slot in plan {
                let (resp, is_shutdown) = match slot {
                    Slot::Ready(r) => (r, false),
                    Slot::Waiting(rx) => (self.await_response(&rx), false),
                    Slot::Snapshot => {
                        let m = crate::obs::metrics::service_metrics();
                        let ki = Request::Status.kind_index();
                        m.requests_total[ki].inc();
                        let t0 = Instant::now();
                        let resp = self.status.read().to_response();
                        m.request_latency_ms[ki]
                            .record(t0.elapsed().as_secs_f64() * 1e3);
                        (resp, false)
                    }
                    Slot::Metrics => {
                        let m = crate::obs::metrics::service_metrics();
                        let ki = Request::Metrics.kind_index();
                        m.requests_total[ki].inc();
                        let t0 = Instant::now();
                        let resp = metrics_response();
                        m.request_latency_ms[ki]
                            .record(t0.elapsed().as_secs_f64() * 1e3);
                        (resp, false)
                    }
                    Slot::Shutdown => (Response::Ok { job_id: None }, true),
                };
                writeln!(writer, "{}", resp.to_json().to_string())?;
                if is_shutdown {
                    writer.flush()?;
                    self.shutdown.store(true, Ordering::SeqCst);
                    self.mailbox.cv.notify_all();
                    return Ok(());
                }
            }
            writer.flush()?;
        }
    }
}

/// Outcome of one capped line-read attempt.
enum LineRead {
    /// A complete line (or the final unterminated line at EOF) is in `buf`.
    Line,
    /// Read timeout with no complete line yet — poll shutdown and retry
    /// (the partial line stays buffered).
    Timeout,
    /// Peer closed with nothing buffered.
    Eof,
}

/// Append one `\n`-terminated request line to `buf`, enforcing
/// [`MAX_LINE_BYTES`] per buffered chunk. `read_until` would only return
/// at the delimiter, EOF, or error — a peer streaming a fast
/// newline-free payload could grow the buffer unboundedly inside a
/// single call, so the cap must be checked as each chunk lands.
fn read_capped_line(reader: &mut BufReader<TcpStream>, buf: &mut Vec<u8>) -> Result<LineRead> {
    loop {
        let (done, used) = {
            let chunk = match reader.fill_buf() {
                Ok(c) => c,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut
                        || e.kind() == std::io::ErrorKind::Interrupted =>
                {
                    return Ok(LineRead::Timeout)
                }
                Err(e) => return Err(anyhow::Error::from(e).context("reading request")),
            };
            if chunk.is_empty() {
                // EOF: a buffered partial line is the final message.
                return Ok(if buf.is_empty() {
                    LineRead::Eof
                } else {
                    LineRead::Line
                });
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    buf.extend_from_slice(&chunk[..=pos]);
                    (true, pos + 1)
                }
                None => {
                    buf.extend_from_slice(chunk);
                    (false, chunk.len())
                }
            }
        };
        reader.consume(used);
        if buf.len() > MAX_LINE_BYTES {
            bail!("request line exceeds {MAX_LINE_BYTES} bytes");
        }
        if done {
            return Ok(LineRead::Line);
        }
    }
}

/// Pop one complete line already sitting in the reader's internal buffer
/// without touching the socket — how a burst harvests the requests a
/// pipelining client sent ahead. `None` when the buffer holds no full
/// line; a buffered partial stays put for the next blocking read (which
/// also enforces the line cap — one buffered chunk is bounded by
/// `BufReader`'s capacity, far below it).
fn take_buffered_line(reader: &mut BufReader<TcpStream>) -> Option<Vec<u8>> {
    let (line, used) = {
        let buffered = reader.buffer();
        let pos = buffered.iter().position(|&b| b == b'\n')?;
        (buffered[..=pos].to_vec(), pos + 1)
    };
    reader.consume(used);
    Some(line)
}

/// Timeouts and retry policy for [`ServiceClient`]. The defaults are
/// deliberately generous: they exist to bound a *stalled* peer, not to
/// race a slow-but-live one.
#[derive(Debug, Clone)]
pub struct ClientConfig {
    pub connect_timeout: Duration,
    /// Per-response read deadline. A `schedule` over a large frontier
    /// can legitimately take a while — keep this well above the
    /// server's worst batch.
    pub read_timeout: Duration,
    pub write_timeout: Duration,
    /// Retries after the first attempt in [`ServiceClient::call_idempotent`].
    pub retries: u32,
    /// First retry backoff; doubles per attempt (capped at 2s) with up
    /// to +50% jitter so a reconnect stampede spreads out.
    pub backoff: Duration,
}

impl Default for ClientConfig {
    fn default() -> ClientConfig {
        ClientConfig {
            connect_timeout: Duration::from_secs(5),
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(5),
            retries: 5,
            backoff: Duration::from_millis(50),
        }
    }
}

/// One live connection: the reader/writer pair over a cloned stream.
struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Conn {
    fn open(addr: &str, cfg: &ClientConfig) -> Result<Conn> {
        use std::net::ToSocketAddrs;
        let addrs: Vec<std::net::SocketAddr> = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .collect();
        let mut last: Option<std::io::Error> = None;
        let mut stream: Option<TcpStream> = None;
        for a in addrs {
            match TcpStream::connect_timeout(&a, cfg.connect_timeout) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last = Some(e),
            }
        }
        let stream = match (stream, last) {
            (Some(s), _) => s,
            (None, Some(e)) => {
                return Err(anyhow::Error::from(e).context(format!("connecting {addr}")))
            }
            (None, None) => bail!("{addr} resolved to no addresses"),
        };
        stream
            .set_read_timeout(Some(cfg.read_timeout))
            .context("read timeout")?;
        stream
            .set_write_timeout(Some(cfg.write_timeout))
            .context("write timeout")?;
        Ok(Conn {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    /// One request/response round trip for an already-serialized line.
    fn call_line(&mut self, line: &str) -> Result<Response> {
        writeln!(self.writer, "{line}")?;
        self.writer.flush()?;
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf)?;
        if n == 0 {
            bail!("server closed the connection");
        }
        let v = Json::parse(buf.trim()).map_err(|e| anyhow!("bad response: {e}"))?;
        Response::from_json(&v)
    }
}

/// Blocking client for the agent protocol (what the resource manager —
/// or our examples/tests — runs). Every socket carries connect, read,
/// and write deadlines ([`ClientConfig`]), so a stalled or half-dead
/// server surfaces as an error instead of a hang; and
/// [`ServiceClient::call_idempotent`] layers exactly-once retries on
/// top: the request is tagged with a `request_id`, and on timeout or a
/// torn connection the client reconnects (exponential backoff, jittered)
/// and resends — the server's dedup window guarantees a request that
/// did land is applied once, never twice.
pub struct ServiceClient {
    addr: String,
    cfg: ClientConfig,
    /// `None` after an I/O error — the next call reconnects.
    conn: Option<Conn>,
    /// Backoff jitter only — never touches protocol decisions.
    rng: Rng,
    /// Counter behind [`ServiceClient::call_retrying`]'s auto ids.
    next_id: u64,
}

impl ServiceClient {
    pub fn connect(addr: &str) -> Result<ServiceClient> {
        ServiceClient::connect_with(addr, ClientConfig::default())
    }

    pub fn connect_with(addr: &str, cfg: ClientConfig) -> Result<ServiceClient> {
        let conn = Conn::open(addr, &cfg)?;
        // Jitter seed: distinct per process so a fleet of clients
        // restarting together doesn't retry in lockstep.
        let rng = Rng::new(0x5EED_C11E_47u64 ^ (std::process::id() as u64));
        Ok(ServiceClient {
            addr: addr.to_string(),
            cfg,
            conn: Some(conn),
            rng,
            next_id: 0,
        })
    }

    fn ensure_conn(&mut self) -> Result<&mut Conn> {
        if self.conn.is_none() {
            self.conn = Some(Conn::open(&self.addr, &self.cfg)?);
        }
        Ok(self.conn.as_mut().expect("just ensured"))
    }

    /// One-shot call, no request id, no retry: an I/O failure is the
    /// caller's problem (the connection is dropped and will be reopened
    /// by the next call).
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        let line = req.to_json().to_string();
        let conn = self.ensure_conn()?;
        let res = conn.call_line(&line);
        if res.is_err() {
            self.conn = None;
        }
        res
    }

    /// [`ServiceClient::call_idempotent`] with an auto-assigned id
    /// (`c<pid>-<n>`): unique across this process's clients for the
    /// lifetime of the server's dedup window.
    pub fn call_retrying(&mut self, req: &Request) -> Result<Response> {
        let id = format!("c{}-{}", std::process::id(), self.next_id);
        self.next_id += 1;
        self.call_idempotent(&id, req)
    }

    /// Send `req` tagged with `id`, retrying through timeouts, torn
    /// connections, and `Overloaded` shedding with exponential backoff
    /// and jittered reconnects. Safe for mutating requests precisely
    /// because of the tag: a resend of a request that did reach the
    /// server is answered from its dedup window, not re-applied.
    pub fn call_idempotent(&mut self, id: &str, req: &Request) -> Result<Response> {
        let line = super::protocol::with_request_id(req, id).to_string();
        let mut delay = self.cfg.backoff;
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 {
                let jitter = delay.mul_f64(self.rng.next_f64() * 0.5);
                std::thread::sleep(delay + jitter);
                delay = (delay * 2).min(Duration::from_secs(2));
            }
            if self.conn.is_none() {
                match Conn::open(&self.addr, &self.cfg) {
                    Ok(c) => self.conn = Some(c),
                    Err(e) => {
                        last_err = Some(e);
                        continue;
                    }
                }
            }
            let conn = self.conn.as_mut().expect("connection exists");
            match conn.call_line(&line) {
                Ok(Response::Overloaded { queue }) => {
                    last_err = Some(anyhow!("server overloaded (queue depth {queue})"));
                }
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    // The request may or may not have been applied —
                    // irrelevant: the id makes the resend exactly-once.
                    self.conn = None;
                    last_err = Some(e);
                }
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow!("no attempt recorded"))
            .context(format!(
                "request '{id}' failed after {} attempts",
                self.cfg.retries + 1
            )))
    }
}

impl Workload {
    /// An empty workload (service mode starts with no jobs).
    pub fn new_empty() -> Workload {
        Workload { jobs: Vec::new() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::FifoScheduler;

    #[test]
    fn handle_submit_schedule_status() {
        let cluster = Cluster::homogeneous(2, 2.0, 100.0);
        let mut agent = AgentCore::new(cluster, Box::new(FifoScheduler::new()));
        let resp = agent.handle(Request::SubmitJob {
            name: "j".into(),
            arrival: 0.0,
            computes: vec![2.0, 4.0],
            edges: vec![(0, 1, 10.0)],
        });
        match resp {
            Response::Ok { job_id: Some(0) } => {}
            other => panic!("unexpected {other:?}"),
        }
        let resp = agent.handle(Request::Schedule { time: 0.0 });
        match resp {
            Response::Assignments(asgs) => {
                assert_eq!(asgs.len(), 2);
                assert!(asgs[0].finish <= asgs[1].finish);
            }
            other => panic!("unexpected {other:?}"),
        }
        match agent.handle(Request::Status) {
            Response::Status { jobs, assigned, pending, .. } => {
                assert_eq!(jobs, 1);
                assert_eq!(assigned, 2);
                assert_eq!(pending, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        // The handle() path and the snapshot the batched server would
        // publish agree field for field.
        let snap = agent.status_snapshot();
        assert_eq!(
            snap.to_response().to_json().to_string(),
            agent.handle(Request::Status).to_json().to_string()
        );
    }

    /// Regression for the deferred-arrival bug: a future-dated submission
    /// must not be schedulable before the wall clock reaches its arrival,
    /// while an already-due job still schedules immediately.
    #[test]
    fn future_dated_job_defers_until_arrival() {
        let cluster = Cluster::homogeneous(2, 1.0, 100.0);
        let mut agent = AgentCore::new(cluster, Box::new(FifoScheduler::new()));
        agent.handle(Request::SubmitJob {
            name: "due".into(),
            arrival: 0.0,
            computes: vec![2.0],
            edges: vec![],
        });
        agent.handle(Request::SubmitJob {
            name: "future".into(),
            arrival: 50.0,
            computes: vec![3.0],
            edges: vec![],
        });
        assert_eq!(agent.pending_jobs(), 1);
        match agent.handle(Request::Schedule { time: 0.0 }) {
            Response::Assignments(asgs) => {
                assert_eq!(asgs.len(), 1, "only the due job schedules at t=0");
                assert_eq!(asgs[0].job, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        match agent.handle(Request::Status) {
            Response::Status { pending, executable, .. } => {
                assert_eq!(pending, 1);
                assert_eq!(executable, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        // A heartbeat short of the arrival releases nothing...
        agent.handle(Request::TaskComplete {
            job: 0,
            node: 0,
            time: 49.0,
        });
        assert_eq!(agent.pending_jobs(), 1);
        // ...and a schedule at the arrival time releases and places it,
        // never starting before the arrival.
        match agent.handle(Request::Schedule { time: 50.0 }) {
            Response::Assignments(asgs) => {
                assert_eq!(asgs.len(), 1);
                assert_eq!(asgs[0].job, 1);
                assert!(asgs[0].start >= 50.0 - 1e-9, "start={}", asgs[0].start);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(agent.pending_jobs(), 0);
    }

    /// Deferred jobs activate in arrival order even when submitted out of
    /// order, and ties break by job id (deterministic heap order).
    #[test]
    fn pending_heap_releases_in_arrival_order() {
        let cluster = Cluster::homogeneous(1, 1.0, 100.0);
        let mut agent = AgentCore::new(cluster, Box::new(FifoScheduler::new()));
        for (name, arrival) in [("c", 30.0), ("a", 10.0), ("b", 20.0)] {
            agent.handle(Request::SubmitJob {
                name: name.into(),
                arrival,
                computes: vec![1.0],
                edges: vec![],
            });
        }
        assert_eq!(agent.pending_jobs(), 3);
        agent.advance_to(20.0);
        assert_eq!(agent.pending_jobs(), 1);
        assert!(agent.state().arrived[1] && agent.state().arrived[2]);
        assert!(!agent.state().arrived[0]);
        agent.advance_to(30.0);
        assert_eq!(agent.pending_jobs(), 0);
        assert_eq!(agent.state().n_unarrived(), 0);
    }

    /// `report_failure` rolls back unfinished assignments, the next
    /// `schedule` re-places them off the dead executor, and a transient
    /// crash rejoins once the wall clock passes its recovery time.
    #[test]
    fn report_failure_requeues_and_recovers() {
        let cluster = Cluster::homogeneous(2, 1.0, 100.0);
        let mut agent = AgentCore::new(cluster, Box::new(FifoScheduler::new()));
        agent.handle(Request::SubmitJob {
            name: "j".into(),
            arrival: 0.0,
            computes: vec![4.0, 4.0],
            edges: vec![],
        });
        let (e0, e1) = match agent.handle(Request::Schedule { time: 0.0 }) {
            Response::Assignments(asgs) => {
                assert_eq!(asgs.len(), 2);
                (asgs[0].exec, asgs[1].exec)
            }
            other => panic!("unexpected {other:?}"),
        };
        assert_ne!(e0, e1, "independent equal tasks spread across executors");
        // Executor e0 dies at t=1 (in-flight task lost), back at t=10.
        match agent.handle(Request::ReportFailure {
            exec: e0,
            time: 1.0,
            recovery: Some(10.0),
        }) {
            Response::Recovery {
                cancelled,
                requeued,
                survived,
            } => {
                assert_eq!(cancelled, 1);
                assert_eq!(requeued, 1);
                assert_eq!(survived, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        match agent.handle(Request::Status) {
            Response::Status { assigned, down, executable, .. } => {
                assert_eq!(assigned, 1);
                assert_eq!(down, 1);
                assert_eq!(executable, 1);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Rescheduling places the lost task on the surviving executor.
        match agent.handle(Request::Schedule { time: 1.0 }) {
            Response::Assignments(asgs) => {
                assert_eq!(asgs.len(), 1);
                assert_eq!(asgs[0].exec, e1);
            }
            other => panic!("unexpected {other:?}"),
        }
        agent.state().validate().unwrap();
        // Past the recovery time the executor is back.
        agent.handle(Request::Schedule { time: 11.0 });
        match agent.handle(Request::Status) {
            Response::Status { down, .. } => assert_eq!(down, 0),
            other => panic!("unexpected {other:?}"),
        }
        // Bad reports are rejected.
        assert!(matches!(
            agent.handle(Request::ReportFailure {
                exec: 99,
                time: 0.0,
                recovery: None
            }),
            Response::Error(_)
        ));
        assert!(matches!(
            agent.handle(Request::ReportFailure {
                exec: 0,
                time: 5.0,
                recovery: Some(1.0)
            }),
            Response::Error(_)
        ));
    }

    /// Escalating a transient crash to permanent cancels the scheduled
    /// resurrection: the executor must stay down past the original
    /// recovery time.
    #[test]
    fn permanent_rereport_cancels_pending_recovery() {
        let cluster = Cluster::homogeneous(2, 1.0, 100.0);
        let mut agent = AgentCore::new(cluster, Box::new(FifoScheduler::new()));
        agent.handle(Request::ReportFailure {
            exec: 0,
            time: 1.0,
            recovery: Some(10.0),
        });
        agent.handle(Request::ReportFailure {
            exec: 0,
            time: 2.0,
            recovery: None,
        });
        agent.handle(Request::Schedule { time: 20.0 });
        match agent.handle(Request::Status) {
            Response::Status { down, .. } => {
                assert_eq!(down, 1, "escalated executor must not resurrect");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(!agent.state().exec_available(0));
    }

    #[test]
    fn handle_rejects_bad_job() {
        let cluster = Cluster::homogeneous(1, 1.0, 10.0);
        let mut agent = AgentCore::new(cluster, Box::new(FifoScheduler::new()));
        let resp = agent.handle(Request::SubmitJob {
            name: "cyclic".into(),
            arrival: 0.0,
            computes: vec![1.0, 1.0],
            edges: vec![(0, 1, 1.0), (1, 0, 1.0)],
        });
        assert!(matches!(resp, Response::Error(_)));
        let resp = agent.handle(Request::SubmitJob {
            name: "nan-arrival".into(),
            arrival: f64::NAN,
            computes: vec![1.0],
            edges: vec![],
        });
        assert!(matches!(resp, Response::Error(_)));
    }

    #[test]
    fn end_to_end_over_tcp() {
        let cluster = Cluster::homogeneous(2, 2.0, 100.0);
        let agent = AgentServer::new(cluster, Box::new(FifoScheduler::new()));
        assert_eq!(agent.mode(), ServiceMode::Batched);
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || {
            agent
                .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
                .unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
        let resp = client
            .call(&Request::SubmitJob {
                name: "q".into(),
                arrival: 0.0,
                computes: vec![1.0],
                edges: vec![],
            })
            .unwrap();
        assert!(matches!(resp, Response::Ok { job_id: Some(0) }));
        let resp = client.call(&Request::Schedule { time: 0.0 }).unwrap();
        match resp {
            Response::Assignments(a) => assert_eq!(a.len(), 1),
            other => panic!("unexpected {other:?}"),
        }
        client.call(&Request::Shutdown).unwrap();
        handle.join().unwrap();
    }

    /// The serial engine stays fully functional (it is the golden
    /// baseline the batched path is pinned against).
    #[test]
    fn serial_mode_end_to_end_over_tcp() {
        let cluster = Cluster::homogeneous(2, 2.0, 100.0);
        let agent = AgentServer::with_mode(
            cluster,
            Box::new(FifoScheduler::new()),
            ServiceMode::Serial,
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || {
            agent
                .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
                .unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
        client
            .call(&Request::SubmitJob {
                name: "q".into(),
                arrival: 0.0,
                computes: vec![1.0, 1.0],
                edges: vec![],
            })
            .unwrap();
        match client.call(&Request::Schedule { time: 0.0 }).unwrap() {
            Response::Assignments(a) => assert_eq!(a.len(), 2),
            other => panic!("unexpected {other:?}"),
        }
        match client.call(&Request::Status).unwrap() {
            Response::Status { jobs, assigned, .. } => {
                assert_eq!(jobs, 1);
                assert_eq!(assigned, 2);
            }
            other => panic!("unexpected {other:?}"),
        }
        client.call(&Request::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn service_mode_parses() {
        assert_eq!(ServiceMode::parse("serial").unwrap(), ServiceMode::Serial);
        assert_eq!(ServiceMode::parse("batched").unwrap(), ServiceMode::Batched);
        assert!(ServiceMode::parse("async").is_err());
        assert_eq!(ServiceMode::Batched.name(), "batched");
    }

    #[test]
    fn admission_policy_parses() {
        assert_eq!(
            AdmissionPolicy::parse("shed").unwrap(),
            AdmissionPolicy::Shed
        );
        assert_eq!(
            AdmissionPolicy::parse("block").unwrap(),
            AdmissionPolicy::Block
        );
        assert!(AdmissionPolicy::parse("drop").is_err());
        assert_eq!(AdmissionPolicy::Block.name(), "block");
    }

    /// A retried request id returns the cached response without
    /// re-applying; the window evicts oldest-first once full.
    #[test]
    fn dedup_window_is_exactly_once_and_bounded() {
        let cluster = Cluster::homogeneous(2, 2.0, 100.0);
        let mut agent = AgentCore::new(cluster, Box::new(FifoScheduler::new()));
        let submit = Request::SubmitJob {
            name: "j".into(),
            arrival: 0.0,
            computes: vec![1.0],
            edges: vec![],
        };
        let first = agent.handle_tagged(Some("m0-1"), submit.clone());
        assert!(matches!(first, Response::Ok { job_id: Some(0) }));
        // The retry must NOT create job 1.
        let retry = agent.handle_tagged(Some("m0-1"), submit.clone());
        assert_eq!(
            retry.to_json().to_string(),
            first.to_json().to_string(),
            "retry answered from the window, byte-identical"
        );
        assert_eq!(agent.state().jobs.len(), 1, "no double-submit");
        assert_eq!(agent.n_deduped, 1);
        // An untagged duplicate is a new request (that's the contract).
        agent.handle_tagged(None, submit);
        assert_eq!(agent.state().jobs.len(), 2);

        let mut w = DedupWindow::default();
        for i in 0..(DEDUP_WINDOW + 3) {
            w.insert(format!("id-{i}"), Response::Ok { job_id: Some(i) });
        }
        assert_eq!(w.len(), DEDUP_WINDOW);
        assert!(w.get("id-0").is_none(), "oldest evicted");
        assert!(w.get("id-2").is_none());
        assert!(w.get("id-3").is_some());
        let order: Vec<&String> = w.iter_in_order().map(|(id, _)| id).collect();
        assert_eq!(order[0], "id-3");
    }

    /// Over the bound, `Shed` answers `Overloaded` with the depth and
    /// bumps the shed counter; under the bound, requests queue.
    #[test]
    fn shed_admission_refuses_over_bound() {
        let cluster = Cluster::homogeneous(1, 1.0, 100.0);
        let server = AgentServer::new(cluster, Box::new(FifoScheduler::new()))
            .with_admission(2, AdmissionPolicy::Shed);
        // No core loop running: the queue only fills.
        let hb = |t: f64| Request::TaskComplete {
            job: 0,
            node: 0,
            time: t,
        };
        assert!(matches!(server.enqueue(None, hb(1.0)), Enqueued::Queued(_)));
        assert!(matches!(server.enqueue(None, hb(2.0)), Enqueued::Queued(_)));
        match server.enqueue(None, hb(3.0)) {
            Enqueued::Overloaded(depth) => assert_eq!(depth, 2),
            _ => panic!("third enqueue must shed"),
        }
        assert_eq!(server.shed_count(), 1);
    }

    /// Core snapshot/restore round trip: deferred arrivals, a scheduled
    /// recovery, and the dedup window all survive; the restored core
    /// makes the identical next decision.
    #[test]
    fn agent_core_snapshot_roundtrip() {
        let mk = || {
            let mut cluster = Cluster::homogeneous(2, 1.0, 100.0);
            cluster.executors[1].speed = 2.0;
            AgentCore::new(cluster, Box::new(FifoScheduler::new()))
        };
        let mut agent = mk();
        agent.handle_tagged(
            Some("m0-1"),
            Request::SubmitJob {
                name: "now".into(),
                arrival: 0.0,
                computes: vec![2.0, 3.0],
                edges: vec![(0, 1, 5.0)],
            },
        );
        agent.handle_tagged(
            Some("m0-2"),
            Request::SubmitJob {
                name: "later".into(),
                arrival: 40.0,
                computes: vec![1.0],
                edges: vec![],
            },
        );
        agent.handle(Request::Schedule { time: 1.0 });
        agent.handle(Request::ReportFailure {
            exec: 0,
            time: 2.0,
            recovery: Some(30.0),
        });
        let doc_text = agent.snapshot_json().to_string();

        let mut restored = mk();
        let doc = Json::parse(&doc_text).unwrap();
        restored.restore_from(&doc).unwrap();
        restored.state().validate().unwrap();
        assert_eq!(restored.pending_jobs(), 1);
        assert_eq!(restored.recoveries.len(), 1);
        assert_eq!(restored.dedup.len(), 2);
        assert_eq!(
            restored.status_snapshot(),
            agent.status_snapshot(),
            "restored status identical"
        );
        // The cached response survives the round trip byte-for-byte.
        let a = agent.handle_tagged(Some("m0-1"), Request::Schedule { time: 0.0 });
        let b = restored.handle_tagged(Some("m0-1"), Request::Schedule { time: 0.0 });
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        // And the next real decision is bit-identical on both.
        let a = agent.handle(Request::Schedule { time: 45.0 });
        let b = restored.handle(Request::Schedule { time: 45.0 });
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(agent.pending_jobs(), 0);
        assert_eq!(restored.recoveries.len(), 0, "recovery popped at t=30");
    }

    /// A mismatched snapshot is rejected with the heap cross-checks.
    #[test]
    fn restore_rejects_inconsistent_heaps() {
        let mk = || {
            AgentCore::new(
                Cluster::homogeneous(2, 1.0, 100.0),
                Box::new(FifoScheduler::new()),
            )
        };
        let mut agent = mk();
        agent.handle(Request::SubmitJob {
            name: "later".into(),
            arrival: 10.0,
            computes: vec![1.0],
            edges: vec![],
        });
        let mut doc = agent.snapshot_json();
        // Drop the pending entry: the state says job 0 is unarrived but
        // the heap no longer covers it.
        doc.set("pending", Json::from(Vec::<Json>::new()));
        let mut restored = mk();
        assert!(restored.restore_from(&doc).is_err());
        // A recovery entry for an executor that is up is also rejected.
        let mut doc = agent.snapshot_json();
        doc.set(
            "recoveries",
            Json::from(vec![Json::from(vec![Json::from(5.0), Json::from(0usize)])]),
        );
        let mut restored = mk();
        assert!(restored.restore_from(&doc).is_err());
    }

    /// Hammer the seqlock from concurrent readers while a writer
    /// publishes correlated snapshots: a reader must never observe a
    /// mix of two publishes (the invariants tie every field to `jobs`).
    #[test]
    fn status_cell_never_torn() {
        let cell = StatusCell::new();
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            let cell = &cell;
            let stop = &stop;
            s.spawn(move || {
                for k in 0..50_000usize {
                    cell.publish(&StatusSnapshot {
                        jobs: k,
                        assigned: 2 * k,
                        executors: 3 * k,
                        horizon: k as f64,
                        executable: k + 7,
                        pending: k % 13,
                        down: k % 5,
                        racks: k % 3 + 1,
                        queue: 4 * k,
                        shed: 5 * k,
                        deduped: 6 * k,
                    });
                }
                stop.store(true, Ordering::SeqCst);
            });
            for _ in 0..2 {
                s.spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        let snap = cell.read();
                        assert_eq!(snap.assigned, 2 * snap.jobs, "torn snapshot");
                        assert_eq!(snap.executors, 3 * snap.jobs, "torn snapshot");
                        assert_eq!(snap.horizon, snap.jobs as f64, "torn snapshot");
                        assert_eq!(snap.executable, snap.jobs + 7, "torn snapshot");
                        assert_eq!(snap.racks, snap.jobs % 3 + 1, "torn snapshot");
                        assert_eq!(snap.queue, 4 * snap.jobs, "torn snapshot");
                        assert_eq!(snap.shed, 5 * snap.jobs, "torn snapshot");
                        assert_eq!(snap.deduped, 6 * snap.jobs, "torn snapshot");
                    }
                });
            }
        });
        // The final publish is visible once the writer is done.
        assert_eq!(cell.read().jobs, 49_999);
    }
}
