//! The Lachesis agent as a network service, plus the resource-manager
//! client used by examples and tests. std::net + threads (the offline
//! registry has no tokio; the protocol is line-oriented and the master
//! node is a single long-lived peer, so blocking I/O is the right tool).

use super::protocol::{assignment_from, Request, Response};
use crate::cluster::Cluster;
use crate::sched::Scheduler;
use crate::sim::SimState;
use crate::util::json::Json;
use crate::workload::Workload;
use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};

/// The scheduling agent: live state + a scheduler behind a TCP endpoint.
pub struct AgentServer {
    state: SimState,
    scheduler: Box<dyn Scheduler + Send>,
}

impl AgentServer {
    pub fn new(cluster: Cluster, scheduler: Box<dyn Scheduler + Send>) -> AgentServer {
        AgentServer {
            state: SimState::new(cluster, Workload::new_empty()),
            scheduler,
        }
    }

    /// Handle one request against the live state.
    pub fn handle(&mut self, req: Request) -> Response {
        match req {
            Request::SubmitJob { .. } => match req.build_job(0) {
                Ok(job) => {
                    let id = self.state.add_job(job);
                    self.state.mark_arrived(id);
                    Response::Ok { job_id: Some(id) }
                }
                Err(e) => Response::Error(format!("invalid job: {e}")),
            },
            Request::TaskComplete { time, .. } => {
                // Heartbeat: completions advance the agent's wall clock
                // (placements already fix AFTs deterministically).
                if time > self.state.wall {
                    self.state.wall = time;
                }
                Response::Ok { job_id: None }
            }
            Request::Schedule { time } => {
                if time > self.state.wall {
                    self.state.wall = time;
                }
                let mut out = Vec::new();
                loop {
                    if self.state.executable().is_empty() {
                        break;
                    }
                    match self.scheduler.step(&self.state) {
                        Err(e) => return Response::Error(format!("scheduler: {e}")),
                        Ok(None) => break,
                        Ok(Some((task, alloc))) => {
                            let finish = self.state.apply(task, alloc);
                            let pl = self.state.placements[task.job][task.node]
                                .iter()
                                .rev()
                                .find(|p| !p.duplicate)
                                .copied()
                                .expect("primary placement exists");
                            out.push(assignment_from(task.job, task.node, alloc, pl.start, finish));
                        }
                    }
                }
                Response::Assignments(out)
            }
            Request::Status => Response::Status {
                jobs: self.state.jobs.len(),
                assigned: self.state.n_assigned,
                executors: self.state.cluster.len(),
                horizon: self.state.horizon,
                executable: self.state.executable().len(),
            },
            Request::Shutdown => Response::Ok { job_id: None },
        }
    }

    /// Serve connections until a `shutdown` request arrives. Returns the
    /// bound address through `on_bound` (use port 0 for ephemeral).
    pub fn serve(mut self, addr: &str, on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        on_bound(listener.local_addr()?);
        'outer: for stream in listener.incoming() {
            let stream = stream?;
            let mut reader = BufReader::new(stream.try_clone()?);
            let mut writer = BufWriter::new(stream);
            let mut line = String::new();
            loop {
                line.clear();
                let n = reader.read_line(&mut line)?;
                if n == 0 {
                    break; // peer closed; accept the next master
                }
                let resp = match Json::parse(line.trim())
                    .map_err(|e| anyhow!("{e}"))
                    .and_then(|v| Request::from_json(&v))
                {
                    Ok(req) => {
                        let shutdown = matches!(req, Request::Shutdown);
                        let resp = self.handle(req);
                        writeln!(writer, "{}", resp.to_json().to_string())?;
                        writer.flush()?;
                        if shutdown {
                            break 'outer;
                        }
                        continue;
                    }
                    Err(e) => Response::Error(format!("bad request: {e}")),
                };
                writeln!(writer, "{}", resp.to_json().to_string())?;
                writer.flush()?;
            }
        }
        Ok(())
    }
}

/// Blocking client for the agent protocol (what the resource manager — or
/// our examples/tests — runs).
pub struct ServiceClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl ServiceClient {
    pub fn connect(addr: &str) -> Result<ServiceClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        Ok(ServiceClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", req.to_json().to_string())?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let v = Json::parse(line.trim()).map_err(|e| anyhow!("bad response: {e}"))?;
        Response::from_json(&v)
    }
}

impl Workload {
    /// An empty workload (service mode starts with no jobs).
    pub fn new_empty() -> Workload {
        Workload { jobs: Vec::new() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::FifoScheduler;

    #[test]
    fn handle_submit_schedule_status() {
        let cluster = Cluster::homogeneous(2, 2.0, 100.0);
        let mut agent = AgentServer::new(cluster, Box::new(FifoScheduler::new()));
        let resp = agent.handle(Request::SubmitJob {
            name: "j".into(),
            arrival: 0.0,
            computes: vec![2.0, 4.0],
            edges: vec![(0, 1, 10.0)],
        });
        match resp {
            Response::Ok { job_id: Some(0) } => {}
            other => panic!("unexpected {other:?}"),
        }
        let resp = agent.handle(Request::Schedule { time: 0.0 });
        match resp {
            Response::Assignments(asgs) => {
                assert_eq!(asgs.len(), 2);
                assert!(asgs[0].finish <= asgs[1].finish);
            }
            other => panic!("unexpected {other:?}"),
        }
        match agent.handle(Request::Status) {
            Response::Status { jobs, assigned, .. } => {
                assert_eq!(jobs, 1);
                assert_eq!(assigned, 2);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn handle_rejects_bad_job() {
        let cluster = Cluster::homogeneous(1, 1.0, 10.0);
        let mut agent = AgentServer::new(cluster, Box::new(FifoScheduler::new()));
        let resp = agent.handle(Request::SubmitJob {
            name: "cyclic".into(),
            arrival: 0.0,
            computes: vec![1.0, 1.0],
            edges: vec![(0, 1, 1.0), (1, 0, 1.0)],
        });
        assert!(matches!(resp, Response::Error(_)));
    }

    #[test]
    fn end_to_end_over_tcp() {
        let cluster = Cluster::homogeneous(2, 2.0, 100.0);
        let agent = AgentServer::new(cluster, Box::new(FifoScheduler::new()));
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || {
            agent
                .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
                .unwrap();
        });
        let addr = rx.recv().unwrap();
        let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
        let resp = client
            .call(&Request::SubmitJob {
                name: "q".into(),
                arrival: 0.0,
                computes: vec![1.0],
                edges: vec![],
            })
            .unwrap();
        assert!(matches!(resp, Response::Ok { job_id: Some(0) }));
        let resp = client.call(&Request::Schedule { time: 0.0 }).unwrap();
        match resp {
            Response::Assignments(a) => assert_eq!(a.len(), 1),
            other => panic!("unexpected {other:?}"),
        }
        client.call(&Request::Shutdown).unwrap();
        handle.join().unwrap();
    }
}
