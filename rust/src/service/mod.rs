//! Plug-and-play scheduling service (paper §5.1, Fig 3).
//!
//! The data-processing platform's resource manager connects over TCP and
//! speaks a JSON-line protocol: it submits jobs, reports task completions
//! via heartbeats, and asks the Lachesis agent for the next assignments.
//! The agent holds the same [`SimState`] the simulator uses, so the
//! decision logic is byte-for-byte the scheduler zoo of [`crate::sched`].

pub mod protocol;
pub mod server;

pub use protocol::{Request, Response};
pub use server::{AgentServer, ServiceClient};
