//! Plug-and-play scheduling service (paper §5.1, Fig 3).
//!
//! Data-processing platform masters connect over TCP and speak a
//! JSON-line protocol: they submit jobs, report task completions via
//! heartbeats, and ask the Lachesis agent for the next assignments. The
//! agent holds the same [`SimState`] the simulator uses, so the decision
//! logic is byte-for-byte the scheduler zoo of [`crate::sched`].
//!
//! Many masters can be connected at once: [`AgentServer`] runs one
//! thread per connection over a shared [`AgentCore`]. In the default
//! batched [`ServiceMode`], mutating requests flow through a mailbox
//! drained by a dedicated core loop (one lock acquisition per batch,
//! consecutive heartbeats coalesced) and `status` is served from a
//! lock-free seqlock snapshot; the serial mode keeps the original
//! one-lock-per-request engine as the golden baseline. Both process
//! requests in a single total order, so decisions stay deterministic
//! and byte-identical across modes for the same request stream. Jobs
//! submitted with a future `arrival` are deferred in a min-heap and
//! activate only when the wall clock reaches them — matching the
//! simulator's event-driven arrival semantics.
//!
//! The service also survives its own crashes: with a `--journal`
//! directory every mutating request is appended to a write-ahead
//! [`journal`] before it is applied, periodic [`snapshot`]s checkpoint
//! the whole core, and `--restore` rebuilds the core bit-identically
//! from the latest snapshot plus the journal suffix. Clients tag
//! requests with a `request_id`; a bounded dedup window makes retries
//! exactly-once, and a bounded mailbox (`--max-queue`) sheds or blocks
//! new work under overload instead of growing without bound.
//!
//! [`SimState`]: crate::sim::SimState

pub mod journal;
pub mod protocol;
pub mod server;
pub mod snapshot;

pub use journal::{Journal, JournalRecord};
pub use protocol::{Assignment, Request, Response};
pub use server::{
    AdmissionPolicy, AgentCore, AgentServer, ClientConfig, Durability, ServiceClient,
    ServiceMode, StatusSnapshot,
};
