//! Plug-and-play scheduling service (paper §5.1, Fig 3).
//!
//! Data-processing platform masters connect over TCP and speak a
//! JSON-line protocol: they submit jobs, report task completions via
//! heartbeats, and ask the Lachesis agent for the next assignments. The
//! agent holds the same [`SimState`] the simulator uses, so the decision
//! logic is byte-for-byte the scheduler zoo of [`crate::sched`].
//!
//! Many masters can be connected at once: [`AgentServer`] runs one
//! thread per connection over a shared, mutex-guarded [`AgentCore`], so
//! requests are serialized and decisions stay deterministic. Jobs
//! submitted with a future `arrival` are deferred in a min-heap and
//! activate only when the wall clock reaches them — matching the
//! simulator's event-driven arrival semantics.
//!
//! [`SimState`]: crate::sim::SimState

pub mod protocol;
pub mod server;

pub use protocol::{Assignment, Request, Response};
pub use server::{AgentCore, AgentServer, ServiceClient};
