//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, HLO
//! *text* — see DESIGN.md for why not serialized protos) onto the CPU
//! PJRT client and executes them from the rust hot path. Python is never
//! involved after `make artifacts`.
//!
//! Everything touching the `xla` crate is gated behind the off-by-default
//! `pjrt` cargo feature so the crate builds and tests offline; without
//! the feature only [`ArtifactMeta`] (pure JSON parsing and the model
//! contract check) is available, and every policy consumer falls back to
//! the numerically identical pure-rust forward (`RustPolicy`).

#[cfg(feature = "pjrt")]
use crate::policy::encode::EncodedState;
use crate::policy::net;
#[cfg(feature = "pjrt")]
use crate::policy::PolicyEval;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

/// Parsed `artifacts/meta.json`, written by `python/compile/aot.py`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Flat parameter vector length (must equal `net::param_len()`).
    pub param_len: usize,
    pub f: usize,
    pub e: usize,
    pub k: usize,
    /// Policy-forward shape variants: (artifact stem, N, J).
    pub variants: Vec<(String, usize, usize)>,
    /// Train-step shapes: (artifact stem, batch B, N, J).
    pub train: Option<(String, usize, usize, usize)>,
}

impl ArtifactMeta {
    pub fn load(dir: &Path) -> Result<ArtifactMeta> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("meta.json: {e}"))?;
        let variants = v
            .req("variants")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("variants must be an array"))?
            .iter()
            .map(|x| {
                Ok((
                    x.req_str("name").map_err(|e| anyhow!("{e}"))?.to_string(),
                    x.req_usize("n").map_err(|e| anyhow!("{e}"))?,
                    x.req_usize("j").map_err(|e| anyhow!("{e}"))?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let train = match v.get("train") {
            Some(t) if !matches!(t, Json::Null) => Some((
                t.req_str("name").map_err(|e| anyhow!("{e}"))?.to_string(),
                t.req_usize("b").map_err(|e| anyhow!("{e}"))?,
                t.req_usize("n").map_err(|e| anyhow!("{e}"))?,
                t.req_usize("j").map_err(|e| anyhow!("{e}"))?,
            )),
            _ => None,
        };
        let meta = ArtifactMeta {
            param_len: v.req_usize("param_len").map_err(|e| anyhow!("{e}"))?,
            f: v.req_usize("f").map_err(|e| anyhow!("{e}"))?,
            e: v.req_usize("e").map_err(|e| anyhow!("{e}"))?,
            k: v.req_usize("k").map_err(|e| anyhow!("{e}"))?,
            variants,
            train,
        };
        meta.check_model_contract()?;
        Ok(meta)
    }

    /// The python model and the rust reference must agree on the layout.
    pub fn check_model_contract(&self) -> Result<()> {
        if self.param_len != net::param_len() {
            bail!(
                "model contract violation: python param_len {} != rust {} \
                 (python/compile/model.py and rust/src/policy/net.rs diverged)",
                self.param_len,
                net::param_len()
            );
        }
        if self.f != crate::policy::F || self.e != crate::policy::E || self.k != crate::policy::K {
            bail!(
                "model contract violation: (F,E,K) python ({},{},{}) != rust ({},{},{})",
                self.f,
                self.e,
                self.k,
                crate::policy::F,
                crate::policy::E,
                crate::policy::K
            );
        }
        Ok(())
    }
}

/// Compiled-executable cache over a PJRT CPU client.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub meta: ArtifactMeta,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

// SAFETY: `PjRtClient` wraps an `Rc` around the PJRT C-API client, which
// itself is thread-safe. The `Rc` only makes *sharing clones across
// threads* unsound; `Runtime` owns the client and every executable holding
// a clone of it, so moving the whole `Runtime` transfers the entire
// reference group to one thread at a time. `Runtime` is deliberately not
// `Sync`.
#[cfg(feature = "pjrt")]
unsafe impl Send for Runtime {}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open the artifact directory (default `artifacts/`), parse metadata
    /// and start a CPU PJRT client.
    pub fn new(dir: &str) -> Result<Runtime> {
        let dir = PathBuf::from(dir);
        let meta = ArtifactMeta::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            meta,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (once) the artifact with the given stem.
    pub fn load(&mut self, stem: &str) -> Result<()> {
        if self.cache.contains_key(stem) {
            return Ok(());
        }
        let path = self.dir.join(format!("{stem}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {stem}: {e:?}"))?;
        self.cache.insert(stem.to_string(), exe);
        Ok(())
    }

    /// Execute a cached artifact; returns the flattened tuple elements.
    pub fn execute(&mut self, stem: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.load(stem)?;
        let exe = self.cache.get(stem).unwrap();
        let out = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {stem}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {stem} result: {e:?}"))?;
        // Artifacts are lowered with return_tuple=True.
        out.to_tuple().map_err(|e| anyhow!("untupling {stem}: {e:?}"))
    }

    /// Helper: f32 tensor literal with the given dims.
    pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != data.len() {
            bail!("literal shape {:?} wants {} elements, got {}", dims, n, data.len());
        }
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    /// Helper: i32 tensor literal.
    pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != data.len() {
            bail!("literal shape {:?} wants {} elements, got {}", dims, n, data.len());
        }
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    pub fn read_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
    }
}

/// The PJRT-backed policy evaluator: the production inference path.
#[cfg(feature = "pjrt")]
pub struct PjrtPolicy {
    runtime: Runtime,
    pub params: Vec<f32>,
    /// Reused dense staging for the artifact's adj/jobmat inputs (the
    /// encoding itself is CSR; the AOT graph wants dense tensors).
    dense_adj: Vec<f32>,
    dense_jobmat: Vec<f32>,
}

#[cfg(feature = "pjrt")]
impl PjrtPolicy {
    /// Load from an artifact dir and a parameter file (defaults to the
    /// freshly initialized `params_init.bin`).
    pub fn new(artifact_dir: &str, params_path: Option<&str>) -> Result<PjrtPolicy> {
        let runtime = Runtime::new(artifact_dir)?;
        let default_params = format!("{artifact_dir}/params_init.bin");
        let path = params_path.unwrap_or(&default_params);
        let params = crate::policy::params::load_expected(path, runtime.meta.param_len)?;
        Ok(PjrtPolicy {
            runtime,
            params,
            dense_adj: Vec::new(),
            dense_jobmat: Vec::new(),
        })
    }

    pub fn with_params(artifact_dir: &str, params: Vec<f32>) -> Result<PjrtPolicy> {
        let runtime = Runtime::new(artifact_dir)?;
        if params.len() != runtime.meta.param_len {
            bail!("params length {} != {}", params.len(), runtime.meta.param_len);
        }
        Ok(PjrtPolicy {
            runtime,
            params,
            dense_adj: Vec::new(),
            dense_jobmat: Vec::new(),
        })
    }

    /// The variant artifact stem for an encoded state; errors if the AOT
    /// build lacks it.
    fn stem_for(&self, enc: &EncodedState) -> Result<String> {
        self.runtime
            .meta
            .variants
            .iter()
            .find(|(_, n, j)| *n == enc.variant.n && *j == enc.variant.j)
            .map(|(name, _, _)| name.clone())
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for variant N={} J={} — rebuild artifacts",
                    enc.variant.n,
                    enc.variant.j
                )
            })
    }
}

#[cfg(feature = "pjrt")]
impl PolicyEval for PjrtPolicy {
    fn logits_value_into(&mut self, enc: &EncodedState, logits: &mut Vec<f32>) -> Result<f32> {
        let stem = self.stem_for(enc)?;
        let n = enc.variant.n as i64;
        let j = enc.variant.j as i64;
        let f = crate::policy::F as i64;
        // The AOT artifact is compiled for dense inputs; materialize the
        // dense adjacency/jobmat from the CSR encoding into reused
        // staging buffers (no per-decision N²/J·N allocation).
        self.dense_adj.clear();
        self.dense_adj.resize(enc.variant.n * enc.variant.n, 0.0);
        enc.write_dense_adj(&mut self.dense_adj);
        self.dense_jobmat.clear();
        self.dense_jobmat.resize(enc.variant.j * enc.variant.n, 0.0);
        enc.write_dense_jobmat(&mut self.dense_jobmat);
        let inputs = [
            Runtime::lit_f32(&self.params, &[self.params.len() as i64])?,
            Runtime::lit_f32(&enc.x, &[n, f])?,
            Runtime::lit_f32(&self.dense_adj, &[n, n])?,
            Runtime::lit_f32(&self.dense_jobmat, &[j, n])?,
            Runtime::lit_f32(&enc.node_mask, &[n])?,
        ];
        let out = self.runtime.execute(&stem, &inputs)?;
        if out.len() != 2 {
            bail!("policy artifact returned {} outputs, expected 2", out.len());
        }
        // Copy into the caller's buffer so its capacity survives across
        // decisions (read_f32's own allocation is transient until the
        // runtime grows a read-into API).
        let l = Runtime::read_f32(&out[0])?;
        logits.clear();
        logits.extend_from_slice(&l);
        let value = Runtime::read_f32(&out[1])?;
        Ok(value.first().copied().unwrap_or(0.0))
    }

    fn backend_name(&self) -> &'static str {
        "pjrt"
    }
}
