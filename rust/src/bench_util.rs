//! Micro-benchmark harness (criterion is unavailable offline; this
//! reimplements its core loop: warmup, calibrated iteration counts,
//! percentile reporting). Bench binaries under `rust/benches/` use
//! `harness = false` and drive this directly.

use crate::util::stats::{mean, percentile, std_dev};
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters   mean {:>12}   p50 {:>12}   p95 {:>12}   ±{:.1}%",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            100.0 * self.std_ns / self.mean_ns.max(1e-9)
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// The harness: collects results for a final summary table.
#[derive(Default)]
pub struct Bench {
    pub results: Vec<BenchResult>,
    /// Target total measurement time per case, seconds.
    pub budget_secs: f64,
    /// Named scalar metrics recorded alongside the cases (makespans,
    /// throughputs, comparison ratios) — serialized into the JSON report.
    pub notes: Vec<(String, f64)>,
}

impl Bench {
    pub fn new() -> Bench {
        let budget_secs = std::env::var("BENCH_BUDGET_SECS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1.0);
        Bench {
            results: Vec::new(),
            budget_secs,
            notes: Vec::new(),
        }
    }

    /// Record a named scalar metric (printed and included in the JSON).
    pub fn note(&mut self, key: &str, value: f64) {
        println!("{key} = {value:.6}");
        self.notes.push((key.to_string(), value));
    }

    /// Run `f` repeatedly: warm up, calibrate an iteration count to fill
    /// the budget, measure per-iteration latency in batches.
    pub fn case<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let target_iters = ((self.budget_secs / once) as usize).clamp(5, 100_000);
        let batch = (target_iters / 20).max(1);
        let mut samples_ns: Vec<f64> = Vec::new();
        let mut done = 0;
        while done < target_iters {
            let n = batch.min(target_iters - done);
            let t = Instant::now();
            for _ in 0..n {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / n as f64);
            done += n;
        }
        let result = BenchResult {
            name: name.to_string(),
            iters: done,
            mean_ns: mean(&samples_ns),
            std_ns: std_dev(&samples_ns),
            p50_ns: percentile(&samples_ns, 50.0),
            p95_ns: percentile(&samples_ns, 95.0),
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Print a closing summary and optionally write CSV/JSON reports
    /// (`BENCH_CSV` / `BENCH_JSON` environment variables).
    pub fn finish(&self, label: &str) {
        println!("\n== {label}: {} cases ==", self.results.len());
        if let Ok(path) = std::env::var("BENCH_CSV") {
            let mut csv = String::from("name,iters,mean_ns,std_ns,p50_ns,p95_ns\n");
            for r in &self.results {
                csv.push_str(&format!(
                    "{},{},{:.1},{:.1},{:.1},{:.1}\n",
                    r.name, r.iters, r.mean_ns, r.std_ns, r.p50_ns, r.p95_ns
                ));
            }
            let _ = std::fs::write(path, csv);
        }
        if let Ok(path) = std::env::var("BENCH_JSON") {
            self.write_json(label, &path);
        }
    }

    /// Write the machine-readable report (cases + notes) as JSON, for
    /// cross-PR perf trajectories (e.g. `BENCH_sim.json`). Serialized
    /// through `util::json` so escaping and non-finite values are handled.
    pub fn write_json(&self, label: &str, path: &str) {
        use crate::util::json::Json;
        let cases: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::from_pairs(vec![
                    ("name", Json::from(r.name.clone())),
                    ("iters", Json::from(r.iters)),
                    ("mean_ns", Json::from(r.mean_ns)),
                    ("std_ns", Json::from(r.std_ns)),
                    ("p50_ns", Json::from(r.p50_ns)),
                    ("p95_ns", Json::from(r.p95_ns)),
                ])
            })
            .collect();
        let notes = Json::from_pairs(
            self.notes
                .iter()
                .map(|(k, v)| (k.as_str(), Json::from(*v)))
                .collect(),
        );
        let report = Json::from_pairs(vec![
            ("bench", Json::from(label)),
            ("cases", Json::Arr(cases)),
            ("notes", notes),
        ]);
        if std::fs::write(path, report.to_pretty()).is_ok() {
            println!("bench report written to {path}");
        } else {
            eprintln!("failed to write bench report to {path}");
        }
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench {
            budget_secs: 0.02,
            ..Default::default()
        };
        let mut acc = 0u64;
        let r = b
            .case("noop-ish", || {
                acc = black_box(acc.wrapping_add(1));
            })
            .clone();
        assert!(r.iters >= 5);
        assert!(r.mean_ns >= 0.0);
        b.finish("test");
    }

    #[test]
    fn json_report_includes_cases_and_notes() {
        let mut b = Bench {
            budget_secs: 0.01,
            ..Default::default()
        };
        b.case("c1", || {});
        b.note("makespan_ratio", 1.5);
        let path = std::env::temp_dir().join("lachesis_bench_util_test.json");
        let path = path.to_str().unwrap().to_string();
        b.write_json("t", &path);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"c1\""));
        assert!(text.contains("makespan_ratio"));
        assert!(text.contains("\"bench\": \"t\""));
        let _ = std::fs::remove_file(&path);
    }
}
