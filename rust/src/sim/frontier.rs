//! Incremental executable-set tracker.
//!
//! The executable set `A_t` (paper notation: arrived ∧ unassigned ∧ every
//! parent assigned) used to be maintained by re-checking all parents of
//! every affected child on each assignment. `Frontier` instead keeps a
//! per-task counter of *unassigned distinct parents*: an assignment
//! decrements its children's counters in O(out-degree) and a task enters
//! the frontier exactly when its counter hits zero. Membership updates on
//! the sorted item list are a binary search plus a memmove.

use crate::dag::{Job, NodeId, TaskRef};

/// The executable frontier plus the dependency counters that drive it.
#[derive(Debug, Clone, Default)]
pub struct Frontier {
    /// Executable tasks, kept sorted for deterministic iteration.
    items: Vec<TaskRef>,
    /// `pending[job][node]` — number of distinct unassigned parents.
    pending: Vec<Vec<usize>>,
}

impl Frontier {
    pub fn new() -> Frontier {
        Frontier::default()
    }

    /// Number of jobs registered.
    pub fn n_jobs(&self) -> usize {
        self.pending.len()
    }

    /// Register a job's dependency counters (distinct parents per node).
    /// Must be called once per job, in job-id order.
    pub fn add_job(&mut self, job: &Job) {
        let counts = (0..job.n_tasks())
            .map(|n| {
                let mut parents: Vec<NodeId> =
                    job.parents[n].iter().map(|e| e.other).collect();
                parents.sort_unstable();
                parents.dedup();
                parents.len()
            })
            .collect();
        self.pending.push(counts);
    }

    /// A job arrived: its dependency-free tasks enter the frontier. (At
    /// arrival no task of the job can be assigned yet, so "counter zero"
    /// is exactly "all parents assigned".)
    pub fn activate_job(&mut self, job: usize) {
        for node in 0..self.pending[job].len() {
            if self.pending[job][node] == 0 {
                self.insert(TaskRef::new(job, node));
            }
        }
    }

    /// A task was assigned: remove it and admit every child whose last
    /// unassigned parent this was. The caller guarantees `t` was
    /// executable, which implies its job has arrived.
    pub fn assign(&mut self, dag: &Job, t: TaskRef) {
        self.remove(t);
        // Parallel edges to the same child must decrement only once.
        let mut seen: Vec<NodeId> = Vec::new();
        for e in &dag.children[t.node] {
            if seen.contains(&e.other) {
                continue;
            }
            seen.push(e.other);
            let c = &mut self.pending[t.job][e.other];
            debug_assert!(*c > 0, "child ({}, {}) underflow", t.job, e.other);
            *c -= 1;
            if *c == 0 {
                self.insert(TaskRef::new(t.job, e.other));
            }
        }
    }

    /// A task's assignment was rolled back (fault recovery): the inverse
    /// of [`Frontier::assign`]. Children's unassigned-parent counters
    /// re-increment (any child sitting in the frontier leaves it), and
    /// `t` itself re-enters the frontier if its own counter is zero —
    /// the caller guarantees `t`'s job has arrived and `t` is marked
    /// unassigned again. Safe under any cascade order: a task whose
    /// parent is rolled back first simply never re-enters (counter > 0),
    /// and one rolled back before its parent is removed again when the
    /// parent's rollback increments its counter.
    pub fn unassign(&mut self, dag: &Job, t: TaskRef) {
        let mut seen: Vec<NodeId> = Vec::new();
        for e in &dag.children[t.node] {
            if seen.contains(&e.other) {
                continue;
            }
            seen.push(e.other);
            let c = &mut self.pending[t.job][e.other];
            if *c == 0 {
                // The child was executable (or assigned — then this
                // remove is a no-op); it loses executability now.
                self.remove(TaskRef::new(t.job, e.other));
            }
            *c += 1;
        }
        if self.pending[t.job][t.node] == 0 {
            self.insert(t);
        }
    }

    /// Rebuild the whole frontier from scratch against flag vectors
    /// (snapshot restore). Replaying `assign` per already-assigned task
    /// would be order-sensitive — a parent assigned *after* its child
    /// in `(job, node)` order would re-admit the assigned child — so
    /// the counters and the item list are computed directly: the same
    /// scan `SimState::validate` pins the incremental state against.
    pub fn rebuild(jobs: &[Job], arrived: &[bool], assigned: &[Vec<bool>]) -> Frontier {
        let mut f = Frontier::new();
        for (j, job) in jobs.iter().enumerate() {
            let counts: Vec<usize> = (0..job.n_tasks())
                .map(|n| {
                    let mut parents: Vec<NodeId> =
                        job.parents[n].iter().map(|e| e.other).collect();
                    parents.sort_unstable();
                    parents.dedup();
                    parents.iter().filter(|&&p| !assigned[j][p]).count()
                })
                .collect();
            for (n, &c) in counts.iter().enumerate() {
                if c == 0 && arrived[j] && !assigned[j][n] {
                    // Job-major, node-minor push order is already the
                    // sorted TaskRef order.
                    f.items.push(TaskRef::new(j, n));
                }
            }
            f.pending.push(counts);
        }
        f
    }

    /// The executable set, sorted.
    pub fn items(&self) -> &[TaskRef] {
        &self.items
    }

    pub fn contains(&self, t: TaskRef) -> bool {
        self.items.binary_search(&t).is_ok()
    }

    /// Remaining unassigned distinct parents of a task.
    pub fn unassigned_parents(&self, t: TaskRef) -> usize {
        self.pending[t.job][t.node]
    }

    fn insert(&mut self, t: TaskRef) {
        if let Err(i) = self.items.binary_search(&t) {
            self.items.insert(i, t);
        }
    }

    fn remove(&mut self, t: TaskRef) {
        if let Ok(i) = self.items.binary_search(&t) {
            self.items.remove(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Job;

    fn diamond() -> Job {
        // 0 -> {1, 2} -> 3
        Job::new(
            0,
            "diamond",
            0.0,
            vec![1.0, 2.0, 3.0, 4.0],
            &[(0, 1, 1.0), (0, 2, 1.0), (1, 3, 1.0), (2, 3, 1.0)],
        )
    }

    #[test]
    fn activation_admits_entries_only() {
        let job = diamond();
        let mut f = Frontier::new();
        f.add_job(&job);
        assert!(f.items().is_empty());
        f.activate_job(0);
        assert_eq!(f.items(), &[TaskRef::new(0, 0)]);
        assert_eq!(f.unassigned_parents(TaskRef::new(0, 3)), 2);
    }

    #[test]
    fn assignment_cascades_through_counters() {
        let job = diamond();
        let mut f = Frontier::new();
        f.add_job(&job);
        f.activate_job(0);
        f.assign(&job, TaskRef::new(0, 0));
        assert_eq!(f.items(), &[TaskRef::new(0, 1), TaskRef::new(0, 2)]);
        f.assign(&job, TaskRef::new(0, 1));
        assert_eq!(f.items(), &[TaskRef::new(0, 2)]);
        assert_eq!(f.unassigned_parents(TaskRef::new(0, 3)), 1);
        f.assign(&job, TaskRef::new(0, 2));
        assert_eq!(f.items(), &[TaskRef::new(0, 3)]);
        f.assign(&job, TaskRef::new(0, 3));
        assert!(f.items().is_empty());
    }

    #[test]
    fn unassign_reverses_assign() {
        // A leaf rollback (node 1 lost its copies, its children were
        // never assigned) and the forward re-run afterwards. The repair
        // cascade guarantees no task stays assigned under an unassigned
        // parent, so rollbacks always arrive leaf-first per chain.
        let job = diamond();
        let mut f = Frontier::new();
        f.add_job(&job);
        f.activate_job(0);
        f.assign(&job, TaskRef::new(0, 0));
        f.assign(&job, TaskRef::new(0, 1));
        assert_eq!(f.items(), &[TaskRef::new(0, 2)]);
        f.unassign(&job, TaskRef::new(0, 1));
        assert_eq!(f.items(), &[TaskRef::new(0, 1), TaskRef::new(0, 2)]);
        assert_eq!(f.unassigned_parents(TaskRef::new(0, 3)), 2);
        // Re-assigning walks the same admission path as the fresh run.
        f.assign(&job, TaskRef::new(0, 1));
        f.assign(&job, TaskRef::new(0, 2));
        assert_eq!(f.items(), &[TaskRef::new(0, 3)]);
    }

    #[test]
    fn unassign_cascade_is_order_insensitive() {
        // A chain rollback (0, 1, 3 roll back). The cascade may settle
        // tasks parent-first or child-first; both orders must land on
        // the same frontier.
        let job = diamond();
        let run = |order: &[usize]| {
            let mut f = Frontier::new();
            f.add_job(&job);
            f.activate_job(0);
            for n in [0usize, 1, 2, 3] {
                f.assign(&job, TaskRef::new(0, n));
            }
            assert!(f.items().is_empty());
            for &n in order {
                f.unassign(&job, TaskRef::new(0, n));
            }
            (f.items().to_vec(), f.unassigned_parents(TaskRef::new(0, 3)))
        };
        let parent_first = run(&[0, 1, 3]);
        let child_first = run(&[3, 1, 0]);
        assert_eq!(parent_first, child_first);
        // Only node 0 is executable (node 1 waits on it; node 3 waits on
        // node 1; node 2 is still assigned).
        assert_eq!(parent_first.0, vec![TaskRef::new(0, 0)]);
        assert_eq!(parent_first.1, 1);
    }

    #[test]
    fn rebuild_matches_incremental_state() {
        // Node 1 is the *parent* of node 0 (a legal DAG — indices need
        // not be topological). A rebuild that replayed `assign` in
        // (job, node) order would re-admit node 0 when replaying its
        // parent's assignment; the scan must not.
        let back = Job::new(1, "back", 0.0, vec![1.0, 1.0, 2.0], &[(1, 0, 1.0), (0, 2, 1.0)]);
        let j0 = diamond();
        let jobs = vec![j0.clone(), back.clone()];
        let mut live = Frontier::new();
        live.add_job(&j0);
        live.add_job(&back);
        live.activate_job(0);
        live.activate_job(1);
        live.assign(&j0, TaskRef::new(0, 0));
        live.assign(&back, TaskRef::new(1, 1));
        live.assign(&back, TaskRef::new(1, 0));
        let arrived = vec![true, true];
        let assigned = vec![
            vec![true, false, false, false],
            vec![true, true, false],
        ];
        let rebuilt = Frontier::rebuild(&jobs, &arrived, &assigned);
        assert_eq!(rebuilt.items(), live.items());
        for (j, job) in jobs.iter().enumerate() {
            for n in 0..job.n_tasks() {
                let t = TaskRef::new(j, n);
                assert_eq!(
                    rebuilt.unassigned_parents(t),
                    live.unassigned_parents(t),
                    "counter mismatch at ({j}, {n})"
                );
            }
        }
        // Unarrived jobs contribute counters but no items.
        let cold = Frontier::rebuild(&jobs, &[true, false], &assigned);
        assert_eq!(cold.items(), &[TaskRef::new(0, 1), TaskRef::new(0, 2)]);
    }

    #[test]
    fn parallel_edges_count_once() {
        // Two edges 0 -> 1: node 1 has one distinct parent.
        let job = Job::new(0, "multi", 0.0, vec![1.0, 1.0], &[(0, 1, 1.0), (0, 1, 2.0)]);
        let mut f = Frontier::new();
        f.add_job(&job);
        f.activate_job(0);
        assert_eq!(f.unassigned_parents(TaskRef::new(0, 1)), 1);
        f.assign(&job, TaskRef::new(0, 0));
        assert!(f.contains(TaskRef::new(0, 1)));
    }

    #[test]
    fn multiple_jobs_are_independent() {
        let j0 = diamond();
        let j1 = Job::new(1, "solo", 0.0, vec![1.0], &[]);
        let mut f = Frontier::new();
        f.add_job(&j0);
        f.add_job(&j1);
        f.activate_job(1);
        assert_eq!(f.items(), &[TaskRef::new(1, 0)]);
        f.activate_job(0);
        assert_eq!(f.items(), &[TaskRef::new(0, 0), TaskRef::new(1, 0)]);
    }
}
