//! Incremental executable-set tracker.
//!
//! The executable set `A_t` (paper notation: arrived ∧ unassigned ∧ every
//! parent assigned) used to be maintained by re-checking all parents of
//! every affected child on each assignment. `Frontier` instead keeps a
//! per-task counter of *unassigned distinct parents*: an assignment
//! decrements its children's counters in O(out-degree) and a task enters
//! the frontier exactly when its counter hits zero. Membership updates on
//! the sorted item list are a binary search plus a memmove.

use crate::dag::{Job, NodeId, TaskRef};

/// The executable frontier plus the dependency counters that drive it.
#[derive(Debug, Clone, Default)]
pub struct Frontier {
    /// Executable tasks, kept sorted for deterministic iteration.
    items: Vec<TaskRef>,
    /// `pending[job][node]` — number of distinct unassigned parents.
    pending: Vec<Vec<usize>>,
}

impl Frontier {
    pub fn new() -> Frontier {
        Frontier::default()
    }

    /// Number of jobs registered.
    pub fn n_jobs(&self) -> usize {
        self.pending.len()
    }

    /// Register a job's dependency counters (distinct parents per node).
    /// Must be called once per job, in job-id order.
    pub fn add_job(&mut self, job: &Job) {
        let counts = (0..job.n_tasks())
            .map(|n| {
                let mut parents: Vec<NodeId> =
                    job.parents[n].iter().map(|e| e.other).collect();
                parents.sort_unstable();
                parents.dedup();
                parents.len()
            })
            .collect();
        self.pending.push(counts);
    }

    /// A job arrived: its dependency-free tasks enter the frontier. (At
    /// arrival no task of the job can be assigned yet, so "counter zero"
    /// is exactly "all parents assigned".)
    pub fn activate_job(&mut self, job: usize) {
        for node in 0..self.pending[job].len() {
            if self.pending[job][node] == 0 {
                self.insert(TaskRef::new(job, node));
            }
        }
    }

    /// A task was assigned: remove it and admit every child whose last
    /// unassigned parent this was. The caller guarantees `t` was
    /// executable, which implies its job has arrived.
    pub fn assign(&mut self, dag: &Job, t: TaskRef) {
        self.remove(t);
        // Parallel edges to the same child must decrement only once.
        let mut seen: Vec<NodeId> = Vec::new();
        for e in &dag.children[t.node] {
            if seen.contains(&e.other) {
                continue;
            }
            seen.push(e.other);
            let c = &mut self.pending[t.job][e.other];
            debug_assert!(*c > 0, "child ({}, {}) underflow", t.job, e.other);
            *c -= 1;
            if *c == 0 {
                self.insert(TaskRef::new(t.job, e.other));
            }
        }
    }

    /// The executable set, sorted.
    pub fn items(&self) -> &[TaskRef] {
        &self.items
    }

    pub fn contains(&self, t: TaskRef) -> bool {
        self.items.binary_search(&t).is_ok()
    }

    /// Remaining unassigned distinct parents of a task.
    pub fn unassigned_parents(&self, t: TaskRef) -> usize {
        self.pending[t.job][t.node]
    }

    fn insert(&mut self, t: TaskRef) {
        if let Err(i) = self.items.binary_search(&t) {
            self.items.insert(i, t);
        }
    }

    fn remove(&mut self, t: TaskRef) {
        if let Ok(i) = self.items.binary_search(&t) {
            self.items.remove(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Job;

    fn diamond() -> Job {
        // 0 -> {1, 2} -> 3
        Job::new(
            0,
            "diamond",
            0.0,
            vec![1.0, 2.0, 3.0, 4.0],
            &[(0, 1, 1.0), (0, 2, 1.0), (1, 3, 1.0), (2, 3, 1.0)],
        )
    }

    #[test]
    fn activation_admits_entries_only() {
        let job = diamond();
        let mut f = Frontier::new();
        f.add_job(&job);
        assert!(f.items().is_empty());
        f.activate_job(0);
        assert_eq!(f.items(), &[TaskRef::new(0, 0)]);
        assert_eq!(f.unassigned_parents(TaskRef::new(0, 3)), 2);
    }

    #[test]
    fn assignment_cascades_through_counters() {
        let job = diamond();
        let mut f = Frontier::new();
        f.add_job(&job);
        f.activate_job(0);
        f.assign(&job, TaskRef::new(0, 0));
        assert_eq!(f.items(), &[TaskRef::new(0, 1), TaskRef::new(0, 2)]);
        f.assign(&job, TaskRef::new(0, 1));
        assert_eq!(f.items(), &[TaskRef::new(0, 2)]);
        assert_eq!(f.unassigned_parents(TaskRef::new(0, 3)), 1);
        f.assign(&job, TaskRef::new(0, 2));
        assert_eq!(f.items(), &[TaskRef::new(0, 3)]);
        f.assign(&job, TaskRef::new(0, 3));
        assert!(f.items().is_empty());
    }

    #[test]
    fn parallel_edges_count_once() {
        // Two edges 0 -> 1: node 1 has one distinct parent.
        let job = Job::new(0, "multi", 0.0, vec![1.0, 1.0], &[(0, 1, 1.0), (0, 1, 2.0)]);
        let mut f = Frontier::new();
        f.add_job(&job);
        f.activate_job(0);
        assert_eq!(f.unassigned_parents(TaskRef::new(0, 1)), 1);
        f.assign(&job, TaskRef::new(0, 0));
        assert!(f.contains(TaskRef::new(0, 1)));
    }

    #[test]
    fn multiple_jobs_are_independent() {
        let j0 = diamond();
        let j1 = Job::new(1, "solo", 0.0, vec![1.0], &[]);
        let mut f = Frontier::new();
        f.add_job(&j0);
        f.add_job(&j1);
        f.activate_job(1);
        assert_eq!(f.items(), &[TaskRef::new(1, 0)]);
        f.activate_job(0);
        assert_eq!(f.items(), &[TaskRef::new(0, 0), TaskRef::new(1, 0)]);
    }
}
