//! Event-driven simulator for the heterogeneous data-processing platform
//! (paper Appendix D, Algorithm 3), layered as three subsystems:
//!
//! * [`timeline`] — per-executor busy-interval timelines with O(1) append
//!   booking and O(log n) gap search. Append mode reproduces the paper's
//!   single-`exec_ready`-scalar semantics exactly; gap-aware mode
//!   backfills tasks into idle windows (insertion-based HEFT style),
//!   toggled via `ClusterConfig::sched_mode`.
//! * [`frontier`] — the incremental executable-set tracker: per-task
//!   unassigned-parent counters instead of re-scanning all parents.
//! * [`state`] — the composed [`state::SimState`]: placements (including
//!   duplicated copies), cached ranks, and O(1) incremental caches for
//!   `min_aft`, per-job remaining work/tasks, and cluster averages.
//!
//! The [`engine`] replays scheduling events (job arrivals, task
//! completions) in time order and invokes the scheduler at each event
//! until no executable unassigned task remains, recording per-decision
//! wall-clock latency — the paper's decision-time metric (Figs 5d/6d/7b).

pub mod engine;
pub mod frontier;
pub mod state;
pub mod timeline;

pub use crate::config::SchedMode;
pub use engine::{EventKind, Simulator};
pub use frontier::Frontier;
pub use state::{Allocation, EncEvent, Placement, SimState, ENC_LOG_COMPACT_THRESHOLD};
pub use timeline::Timeline;
