//! Event-driven simulator for the heterogeneous data-processing platform
//! (paper Appendix D, Algorithm 3).
//!
//! The simulator owns the shared scheduling state ([`state::SimState`]):
//! executor timelines, task placements (including duplicated copies), the
//! executable frontier and cached rank features. The engine replays
//! scheduling events (job arrivals, task completions) in time order and
//! invokes the scheduler at each event until no executable unassigned task
//! remains, recording per-decision wall-clock latency — the paper's
//! decision-time metric (Figs 5d/6d/7b).

pub mod engine;
pub mod state;

pub use engine::Simulator;
pub use state::{Allocation, Placement, SimState};
