//! Per-executor busy-interval timeline.
//!
//! The pre-refactor simulator modeled each executor as a single
//! `exec_ready` scalar — append-only scheduling with no memory of idle
//! windows. `Timeline` keeps the full sorted list of booked busy
//! intervals instead, so the allocator can either reproduce the append
//! semantics exactly ([`SchedMode::Append`], the paper-faithful default)
//! or backfill a task into the earliest idle gap that fits
//! ([`SchedMode::GapAware`], the insertion-based HEFT variant). Gap
//! search binary-searches for the first constraining interval and then
//! walks forward; appends book in O(1).

use crate::config::SchedMode;

/// Float slack for interval comparisons, matching the tolerance
/// `SimState::validate` accepts for adjacent bookings.
pub const EPS: f64 = 1e-9;

/// Sorted, non-overlapping busy intervals `(start, finish)` of one
/// executor. Non-overlap means sorting by start also sorts by finish, so
/// the append tail is just the last interval's finish.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    busy: Vec<(f64, f64)>,
}

impl Timeline {
    pub fn new() -> Timeline {
        Timeline { busy: Vec::new() }
    }

    /// The append-mode ready time: when the executor goes idle forever.
    /// Equals the old `exec_ready` scalar.
    pub fn tail(&self) -> f64 {
        self.busy.last().map_or(0.0, |&(_, f)| f)
    }

    pub fn len(&self) -> usize {
        self.busy.len()
    }

    pub fn is_empty(&self) -> bool {
        self.busy.is_empty()
    }

    /// The booked intervals, sorted by start.
    pub fn intervals(&self) -> &[(f64, f64)] {
        &self.busy
    }

    /// Rebuild a timeline from intervals previously observed via
    /// [`Timeline::intervals`] (snapshot restore). Trusting the stored
    /// list verbatim — rather than re-booking entries from the
    /// execution log — keeps tie orderings bit-identical to the
    /// process that wrote the snapshot. The caller guarantees the list
    /// is sorted and non-overlapping; debug builds re-check.
    pub fn from_intervals(busy: Vec<(f64, f64)>) -> Timeline {
        debug_assert!(busy.windows(2).all(|w| {
            w[0].0 <= w[1].0 && w[0].1 <= w[1].0 + EPS
        }));
        debug_assert!(busy
            .iter()
            .all(|&(s, f)| s.is_finite() && f.is_finite() && f >= s - EPS));
        Timeline { busy }
    }

    /// Total booked time (the utilization numerator).
    pub fn busy_time(&self) -> f64 {
        self.busy.iter().map(|&(s, f)| f - s).sum()
    }

    /// Earliest start ≥ `ready` of a `dur`-long slot under `mode`.
    ///
    /// In append mode this is `max(ready, tail())` — identical to the
    /// pre-refactor `max(EST, exec_ready)`. In gap-aware mode it is never
    /// later than the append answer (the fall-through of the gap walk is
    /// bounded by `max(ready, tail())`).
    pub fn earliest_start(&self, ready: f64, dur: f64, mode: SchedMode) -> f64 {
        match mode {
            SchedMode::Append => ready.max(self.tail()),
            SchedMode::GapAware => self.earliest_gap(ready, dur),
        }
    }

    /// Earliest `t ≥ ready` such that `[t, t + dur]` overlaps no booked
    /// interval. Binary search skips every interval finishing before
    /// `ready`; the walk then visits only intervals that actually
    /// constrain the slot.
    pub fn earliest_gap(&self, ready: f64, dur: f64) -> f64 {
        let first = self.busy.partition_point(|&(_, f)| f <= ready + EPS);
        let mut t = ready;
        for &(s, f) in &self.busy[first..] {
            if t + dur <= s + EPS {
                return t;
            }
            if f > t {
                t = f;
            }
        }
        t
    }

    /// Book `[start, finish]`. The caller must have planned the slot with
    /// [`Timeline::earliest_start`] (or otherwise guaranteed no overlap);
    /// booking keeps the interval list sorted — O(1) for tail appends,
    /// O(n) memmove for gap insertions.
    pub fn book(&mut self, start: f64, finish: f64) {
        debug_assert!(start.is_finite() && finish.is_finite());
        debug_assert!(finish >= start - EPS, "negative-length booking");
        if self.busy.last().map_or(true, |&(s, _)| s <= start) {
            debug_assert!(
                self.tail() <= start + EPS,
                "booking [{start:.4}, {finish:.4}] overlaps tail {:.4}",
                self.tail()
            );
            self.busy.push((start, finish));
            return;
        }
        let idx = self.busy.partition_point(|&(s, _)| s <= start);
        debug_assert!(idx == 0 || self.busy[idx - 1].1 <= start + EPS);
        debug_assert!(finish <= self.busy[idx].0 + EPS);
        self.busy.insert(idx, (start, finish));
    }

    /// Remove the booked interval matching `(start, finish)` (fault
    /// cancellation / re-timing). Returns `false` if no such interval is
    /// booked. Located by binary search on the sorted starts; the
    /// endpoints must match to within [`EPS`] — callers pass back the
    /// exact values they booked.
    pub fn unbook(&mut self, start: f64, finish: f64) -> bool {
        let idx = self.busy.partition_point(|&(s, _)| s < start - EPS);
        if idx < self.busy.len()
            && (self.busy[idx].0 - start).abs() <= EPS
            && (self.busy[idx].1 - finish).abs() <= EPS
        {
            self.busy.remove(idx);
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn booked(intervals: &[(f64, f64)]) -> Timeline {
        let mut tl = Timeline::new();
        for &(s, f) in intervals {
            tl.book(s, f);
        }
        tl
    }

    #[test]
    fn empty_timeline_starts_at_ready() {
        let tl = Timeline::new();
        assert_eq!(tl.tail(), 0.0);
        assert_eq!(tl.earliest_start(3.0, 1.0, SchedMode::Append), 3.0);
        assert_eq!(tl.earliest_start(3.0, 1.0, SchedMode::GapAware), 3.0);
        assert!(tl.is_empty());
    }

    #[test]
    fn append_mode_matches_tail_scalar() {
        let tl = booked(&[(0.0, 2.0), (5.0, 7.0)]);
        assert_eq!(tl.tail(), 7.0);
        // Append ignores the [2, 5] gap entirely.
        assert_eq!(tl.earliest_start(1.0, 1.0, SchedMode::Append), 7.0);
        assert_eq!(tl.earliest_start(9.0, 1.0, SchedMode::Append), 9.0);
    }

    #[test]
    fn gap_search_fits_earliest_hole() {
        let tl = booked(&[(0.0, 2.0), (5.0, 7.0), (10.0, 12.0)]);
        // Fits in [2, 5].
        assert_eq!(tl.earliest_gap(0.0, 3.0), 2.0);
        assert_eq!(tl.earliest_gap(3.0, 2.0), 3.0);
        // Too long for [2, 5], fits in [7, 10].
        assert_eq!(tl.earliest_gap(0.0, 3.5), 7.0);
        // Too long for every hole: falls through to the tail.
        assert_eq!(tl.earliest_gap(0.0, 4.0), 12.0);
        // Ready inside a busy interval pushes to its finish.
        assert_eq!(tl.earliest_gap(6.0, 1.0), 7.0);
    }

    #[test]
    fn gap_never_later_than_append() {
        let tl = booked(&[(1.0, 4.0), (6.0, 9.0), (9.5, 20.0)]);
        for ready in [0.0, 0.5, 2.0, 4.0, 5.9, 8.0, 21.0] {
            for dur in [0.1, 0.5, 2.0, 5.0] {
                let gap = tl.earliest_start(ready, dur, SchedMode::GapAware);
                let app = tl.earliest_start(ready, dur, SchedMode::Append);
                assert!(gap <= app + EPS, "ready={ready} dur={dur}: {gap} > {app}");
                assert!(gap >= ready);
            }
        }
    }

    #[test]
    fn booking_into_gap_keeps_order() {
        let mut tl = booked(&[(0.0, 2.0), (8.0, 10.0)]);
        let t = tl.earliest_gap(0.0, 3.0);
        assert_eq!(t, 2.0);
        tl.book(t, t + 3.0);
        assert_eq!(tl.intervals(), &[(0.0, 2.0), (2.0, 5.0), (8.0, 10.0)]);
        assert_eq!(tl.tail(), 10.0);
        assert!((tl.busy_time() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn unbook_removes_exact_interval_only() {
        let mut tl = booked(&[(0.0, 2.0), (5.0, 7.0), (10.0, 12.0)]);
        assert!(!tl.unbook(5.0, 6.0), "finish mismatch");
        assert!(!tl.unbook(4.0, 7.0), "start mismatch");
        assert!(tl.unbook(5.0, 7.0));
        assert_eq!(tl.intervals(), &[(0.0, 2.0), (10.0, 12.0)]);
        // The freed window is bookable again.
        assert_eq!(tl.earliest_gap(0.0, 5.0), 2.0);
        assert!(tl.unbook(10.0, 12.0));
        assert_eq!(tl.tail(), 2.0);
        assert!(!tl.unbook(10.0, 12.0), "double unbook");
    }

    #[test]
    fn booked_slot_no_longer_available() {
        let mut tl = booked(&[(0.0, 1.0), (4.0, 5.0)]);
        let t = tl.earliest_gap(0.0, 2.0);
        tl.book(t, t + 2.0);
        // The [1, 4] hole now only has one unit left.
        assert_eq!(tl.earliest_gap(0.0, 2.0), 5.0);
        assert_eq!(tl.earliest_gap(0.0, 1.0), 3.0);
    }
}
