//! The event loop (paper Appendix D, Algorithm 3): pop scheduling events in
//! time order, update state, invoke the scheduler until it has no more
//! legal decision, repeat until every task is assigned.

use super::state::SimState;
use crate::cluster::Cluster;
use crate::dag::TaskRef;
use crate::fault::{FaultKind, FaultPlan};
use crate::metrics::ScheduleReport;
use crate::obs::trace;
use crate::sched::Scheduler;
use crate::util::stats::Recorder;
use crate::workload::Workload;
use anyhow::{bail, Result};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

/// A scheduling event (Algorithm 3's event set `E`, extended with the
/// fault subsystem's disruptions).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// A job arrives at the system.
    Arrival(usize),
    /// A task copy completes on its executor.
    Completion(TaskRef),
    /// Executor `k` crashes, losing its unfinished bookings; it recovers
    /// at the given absolute time (`None` = permanent).
    ExecutorDown(usize, Option<f64>),
    /// Executor `k` recovers from a transient crash.
    ExecutorUp(usize),
    /// Executor `k` straggles: in-flight work stretches by the factor,
    /// queued bookings return to the scheduler.
    Straggle(usize, f64),
}

#[derive(Debug, Clone, Copy)]
struct Ev {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    // Reverse ordering: BinaryHeap is a max-heap, we want earliest first.
    // `total_cmp` keeps the order total even for pathological times;
    // `push_event` additionally rejects non-finite times outright, since a
    // NaN completion time would otherwise corrupt the heap invariant.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}

/// The simulator: state + event queue + decision-latency recorder.
pub struct Simulator {
    pub state: SimState,
    events: BinaryHeap<Ev>,
    seq: u64,
    /// Wall-clock latency of each scheduling decision, in milliseconds.
    pub decision_ms: Recorder,
}

impl Simulator {
    pub fn new(cluster: Cluster, workload: Workload) -> Simulator {
        let state = SimState::new(cluster, workload);
        let mut sim = Simulator {
            state,
            events: BinaryHeap::new(),
            seq: 0,
            decision_ms: Recorder::new(),
        };
        // Seed arrivals through `push_event` so seq numbers stay unique
        // even when events are added later (hand-rolled job-id seqs would
        // collide with service-mode arrivals pushed mid-run).
        let arrivals: Vec<(f64, usize)> =
            sim.state.jobs.iter().map(|j| (j.arrival, j.id)).collect();
        for (time, id) in arrivals {
            sim.push_event(time, EventKind::Arrival(id));
        }
        sim
    }

    /// Build a simulator with a pre-generated fault schedule attached.
    pub fn with_faults(cluster: Cluster, workload: Workload, plan: &FaultPlan) -> Simulator {
        let mut sim = Simulator::new(cluster, workload);
        sim.inject_faults(plan);
        sim
    }

    /// Queue every event of a fault plan. An empty plan queues nothing,
    /// so the run is bit-identical to one with no plan at all. Transient
    /// crashes queue their recovery immediately (same event heap, later
    /// time), so the scheduler is re-invoked the moment capacity returns.
    pub fn inject_faults(&mut self, plan: &FaultPlan) {
        for ev in &plan.events {
            match ev.kind {
                FaultKind::Crash { recovery } => {
                    self.push_event(ev.time, EventKind::ExecutorDown(ev.exec, recovery));
                    if let Some(up) = recovery {
                        self.push_event(up, EventKind::ExecutorUp(ev.exec));
                    }
                }
                FaultKind::Straggle { factor } => {
                    self.push_event(ev.time, EventKind::Straggle(ev.exec, factor));
                }
            }
        }
    }

    fn push_event(&mut self, time: f64, kind: EventKind) {
        assert!(
            time.is_finite(),
            "non-finite event time {time} for {kind:?}"
        );
        self.seq += 1;
        self.events.push(Ev {
            time,
            seq: self.seq,
            kind,
        });
    }

    /// Run the full simulation under `scheduler`. Returns the schedule
    /// report (makespan, speedup, SLR, decision-time distribution).
    ///
    /// Errors if the scheduler fails, emits an illegal decision, or leaves
    /// tasks unassigned after all events drain.
    pub fn run(&mut self, scheduler: &mut dyn Scheduler) -> Result<ScheduleReport> {
        scheduler.reset();
        // Telemetry handles are resolved once per run; when telemetry is
        // off the per-decision cost is a relaxed load + branch (gated in
        // CI by bench_sim's obs_disabled_overhead_ratio).
        let obs = if crate::obs::enabled() {
            Some(crate::obs::metrics::sim_metrics())
        } else {
            None
        };
        while let Some(ev) = self.events.pop() {
            // Advance wall time monotonically (events can tie).
            self.state.advance_wall(ev.time);
            match ev.kind {
                EventKind::Arrival(job) => self.state.mark_arrived(job),
                EventKind::Completion(_) => {}
                EventKind::ExecutorDown(k, recovery) => {
                    // Recovery pass: cancel, cascade, promote duplicates,
                    // requeue — then fall through to the scheduling loop
                    // so lost tasks are replaced at this very event.
                    self.state.apply_crash(k, ev.time, recovery);
                }
                EventKind::ExecutorUp(k) => self.state.mark_executor_up(k),
                EventKind::Straggle(k, factor) => {
                    for (task, finish) in self.state.apply_straggle(k, ev.time, factor) {
                        // The stretched copy finishes later than its
                        // original completion event; re-announce it so
                        // the wall clock visits the new finish too (the
                        // stale event only advances the wall early,
                        // which is harmless).
                        self.push_event(finish, EventKind::Completion(task));
                    }
                }
            }
            // Scheduling loop: one decision per iteration until the
            // scheduler passes (Algorithm 3 line 9).
            loop {
                if self.state.executable().is_empty() {
                    break;
                }
                let t0 = Instant::now();
                let decision = {
                    let _sp = trace::span("sim", "decision");
                    scheduler.step(&self.state)?
                };
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                self.decision_ms.push(ms);
                if let Some(m) = &obs {
                    m.decisions_total.inc();
                    m.decision_ms.record(ms);
                }
                match decision {
                    None => break,
                    Some((task, alloc)) => {
                        // Clock reads only when telemetry wants them —
                        // the disabled path must not pay for timing.
                        let t1 = obs.is_some().then(Instant::now);
                        let finish = {
                            let _sp = trace::span("sim", "apply");
                            self.state.apply(task, alloc)
                        };
                        if let (Some(m), Some(t1)) = (&obs, t1) {
                            m.apply_ms.record(t1.elapsed().as_secs_f64() * 1e3);
                        }
                        self.push_event(finish, EventKind::Completion(task));
                    }
                }
            }
        }
        if !self.state.all_assigned() {
            // Name the stranded jobs — a bare count is useless when
            // debugging multi-job continuous workloads.
            let mut stranded: Vec<String> = Vec::new();
            let mut more = 0usize;
            for (ji, job) in self.state.jobs.iter().enumerate() {
                let left = self.state.job_left_tasks(ji);
                if left == 0 {
                    continue;
                }
                if stranded.len() < 8 {
                    stranded.push(format!("job {ji} '{}': {left}", job.name));
                } else {
                    more += 1;
                }
            }
            let mut detail = stranded.join(", ");
            if more > 0 {
                detail.push_str(&format!(", … {more} more jobs"));
            }
            bail!(
                "scheduler '{}' left {} tasks unassigned ({detail})",
                scheduler.name(),
                self.state.n_tasks_total() - self.state.n_assigned
            );
        }
        Ok(ScheduleReport::from_state(
            &self.state,
            &scheduler.name(),
            self.decision_ms.clone(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::WorkloadConfig;
    use crate::sched::FifoScheduler;
    use crate::workload::WorkloadGenerator;

    #[test]
    fn runs_batch_workload_to_completion() {
        let cluster = Cluster::homogeneous(4, 2.5, 100.0);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), 1).generate();
        let n = w.n_tasks();
        let mut sim = Simulator::new(cluster, w);
        let report = sim.run(&mut FifoScheduler::new()).unwrap();
        assert_eq!(sim.state.n_assigned, n);
        assert!(report.makespan > 0.0);
        assert!(report.speedup > 0.0);
        sim.state.validate().unwrap();
    }

    #[test]
    fn continuous_jobs_wait_for_arrival() {
        let cluster = Cluster::homogeneous(4, 2.5, 100.0);
        let w = WorkloadGenerator::new(WorkloadConfig::continuous(5), 2).generate();
        let last_arrival = w.jobs.last().unwrap().arrival;
        let mut sim = Simulator::new(cluster, w);
        let report = sim.run(&mut FifoScheduler::new()).unwrap();
        // Makespan must cover the last arrival — its tasks run after it.
        assert!(report.makespan >= last_arrival);
        sim.state.validate().unwrap();
    }

    #[test]
    fn arrival_seeding_has_unique_seqs() {
        let cluster = Cluster::homogeneous(2, 2.0, 100.0);
        // Two jobs with identical arrival times must still pop in job-id
        // order (seq tie-break), and later pushes must not collide.
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(3), 7).generate();
        let mut sim = Simulator::new(cluster, w);
        assert_eq!(sim.seq, sim.state.jobs.len() as u64);
        sim.push_event(1.0, EventKind::Completion(crate::dag::TaskRef::new(0, 0)));
        let seqs: Vec<u64> = sim.events.iter().map(|e| e.seq).collect();
        let distinct: std::collections::BTreeSet<u64> = seqs.iter().copied().collect();
        assert_eq!(seqs.len(), distinct.len(), "duplicate event seqs");
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn push_event_rejects_nan_time() {
        let cluster = Cluster::homogeneous(1, 1.0, 10.0);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(1), 1).generate();
        let mut sim = Simulator::new(cluster, w);
        sim.push_event(f64::NAN, EventKind::Arrival(0));
    }

    #[test]
    fn ev_order_total_even_with_nan() {
        // Defense in depth: even if a NaN slipped past the push assert,
        // total_cmp keeps Ord consistent (no panic, deterministic order).
        let a = Ev {
            time: f64::NAN,
            seq: 1,
            kind: EventKind::Arrival(0),
        };
        let b = Ev {
            time: 1.0,
            seq: 2,
            kind: EventKind::Arrival(1),
        };
        assert_ne!(a.cmp(&b), std::cmp::Ordering::Equal);
        assert_eq!(a.cmp(&b), b.cmp(&a).reverse());
    }

    #[test]
    fn event_order_is_time_then_seq() {
        let a = Ev {
            time: 2.0,
            seq: 1,
            kind: EventKind::Arrival(0),
        };
        let b = Ev {
            time: 1.0,
            seq: 2,
            kind: EventKind::Arrival(1),
        };
        let mut heap = BinaryHeap::new();
        heap.push(a);
        heap.push(b);
        assert_eq!(heap.pop().unwrap().time, 1.0);
        assert_eq!(heap.pop().unwrap().time, 2.0);
    }
}
