//! Shared scheduling state: placements (with task duplication), the
//! paper's timing equations' common building blocks (actual finish times,
//! data-ready times), and the composition of the two incremental
//! subsystems — per-executor [`Timeline`]s and the executable
//! [`Frontier`] — plus O(1) caches for the quantities schedulers and the
//! policy featurizer probe on every decision (`min_aft`, per-job
//! `left_tasks`/`left_work`, cluster-average transfer terms).

use super::frontier::Frontier;
use super::timeline::{Timeline, EPS};
use crate::cluster::Cluster;
use crate::config::SchedMode;
use crate::dag::{ranks, Job, NodeId, TaskRef};
use crate::fault::{FaultStats, RecoveryOutcome};
use crate::util::json::Json;
use crate::workload::Workload;

/// One scheduled copy of a task on an executor (a member of `R_{n_i}`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Placement {
    pub exec: usize,
    /// Actual start time (AST).
    pub start: f64,
    /// Actual finish time (AFT, Eq 1).
    pub finish: f64,
    /// True if this copy was created by DEFT's parent duplication.
    pub duplicate: bool,
}

impl Placement {
    /// Booking identity: same executor, bit-exact same slot, same role.
    /// Fault rollback uses this to locate the exact copy being
    /// cancelled, re-timed, or promoted across the placement list and
    /// the executor schedule log.
    pub fn same_booking(&self, other: &Placement) -> bool {
        self.exec == other.exec
            && self.start.to_bits() == other.start.to_bits()
            && self.finish.to_bits() == other.finish.to_bits()
            && self.duplicate == other.duplicate
    }
}

/// A scheduler's allocation decision for one selected task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Allocation {
    /// Run the task on `exec` (EFT mode).
    Direct { exec: usize },
    /// First duplicate parent `parent` onto `exec`, then run the task there
    /// (CPEFT mode, Eq 9–10).
    Duplicate { exec: usize, parent: NodeId },
}

impl Allocation {
    pub fn exec(&self) -> usize {
        match *self {
            Allocation::Direct { exec } => exec,
            Allocation::Duplicate { exec, .. } => exec,
        }
    }
}

/// One encoder-visible state mutation, appended to the state's event
/// log ([`SimState::enc_events_since`]) in order. These are the
/// dirty-tracking hooks incremental consumers
/// (e.g. [`crate::policy::EncoderCache`]) replay instead of re-deriving
/// the whole encoding: an assignment removes exactly one slot and moves
/// one job's counters, a booking schedules a future parent-finished flip,
/// an arrival adds a job's tasks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EncEvent {
    /// `task`'s primary copy was scheduled: it leaves the encoding, its
    /// children's `executable` feature may flip, and its job's
    /// `left_tasks`/`left_work` counters moved.
    Assigned { task: TaskRef },
    /// A copy of `task` (primary or DEFT duplicate) was booked finishing
    /// at `finish`: children's finished-parent fraction flips once the
    /// wall clock passes `finish`.
    Booked { task: TaskRef, finish: f64 },
    /// A job arrived: its unassigned tasks enter the encoding.
    Arrived { job: usize },
    /// A fault-recovery pass rolled state back (cancelled copies,
    /// re-timed finishes, re-enqueued tasks) — mutations with no
    /// incremental patch form. Consumers must rebuild from live state
    /// and re-derive any future-finish bookkeeping.
    Invalidated,
}

/// Everything a scheduler may observe, plus assignment bookkeeping.
#[derive(Debug, Clone)]
pub struct SimState {
    pub cluster: Cluster,
    pub jobs: Vec<Job>,
    /// Whether each job has arrived (continuous mode).
    pub arrived: Vec<bool>,
    /// Whether each task has been assigned (its primary copy scheduled).
    pub assigned: Vec<Vec<bool>>,
    /// All scheduled copies per task: `placements[job][node]` = `R_{n_i}`.
    pub placements: Vec<Vec<Vec<Placement>>>,
    /// Full per-executor schedule log for validation and reporting.
    pub exec_log: Vec<Vec<(TaskRef, Placement)>>,
    /// Current simulation wall time.
    pub wall: f64,
    /// max AFT over all scheduled copies — the running makespan horizon.
    pub horizon: f64,
    /// Cached rank_up per job (Eq 6, with cluster averages).
    pub rank_up: Vec<Vec<f64>>,
    /// Cached rank_down per job (Eq 7).
    pub rank_down: Vec<Vec<f64>>,
    /// Count of assigned tasks (primary copies).
    pub n_assigned: usize,
    /// Count of duplicated copies created.
    pub n_duplicates: usize,
    /// Executor-time booking mode, threaded from the cluster config.
    pub sched_mode: SchedMode,
    /// Per-executor busy-interval timelines (replace the old append-only
    /// `exec_ready` scalars).
    timelines: Vec<Timeline>,
    /// Incremental executable-set tracker.
    frontier: Frontier,
    /// `min_aft_cache[job][node]` — earliest finish over scheduled copies
    /// (∞ while unscheduled), min-updated on every booking.
    min_aft_cache: Vec<Vec<f64>>,
    /// Remaining unassigned task count per job.
    left_tasks: Vec<usize>,
    /// Remaining unassigned work per job, GHz·s.
    left_work: Vec<f64>,
    /// Memoized cluster averages (the cluster is immutable after
    /// construction; `Cluster::v_avg` is an O(M) scan).
    v_avg: f64,
    c_avg: f64,
    /// Fault blackout intervals per executor: outage windows booked into
    /// the timeline (so no task can ever be placed inside one) but not
    /// task work — validation and utilization account for them
    /// separately.
    blackouts: Vec<Vec<(f64, f64)>>,
    /// When each executor went down (`None` = up). Permanent crashes
    /// stay `Some` forever; transient ones clear on recovery.
    down_since: Vec<Option<f64>>,
    /// `reexec[job][node]` — the task lost every copy to a fault at some
    /// point and had to be rescheduled (gantt marks these).
    reexec: Vec<Vec<bool>>,
    /// Running fault-activity counters (crashes, cancellations,
    /// requeues, duplication saves).
    pub faults: FaultStats,
    /// Log of encoder-visible mutations (see [`EncEvent`]). Consumers
    /// keep an *absolute* cursor; the buffer auto-compacts beyond
    /// [`ENC_LOG_COMPACT_THRESHOLD`] so a months-long service state stays
    /// bounded — a consumer whose cursor predates the compacted range
    /// gets `None` from [`SimState::enc_events_since`] and rebuilds.
    enc_log: Vec<EncEvent>,
    /// Absolute position of `enc_log[0]` (grows on compaction).
    enc_log_start: u64,
}

/// Keep at most this many encoder events buffered; beyond it the oldest
/// half is dropped. Large enough that a per-decision consumer (cursor at
/// the tail) never rebuilds because of compaction, small enough to bound
/// long-running service states.
pub const ENC_LOG_COMPACT_THRESHOLD: usize = 4096;

impl SimState {
    pub fn new(cluster: Cluster, workload: Workload) -> SimState {
        let v_avg = cluster.v_avg();
        let c_avg = cluster.c_avg();
        let jobs = workload.jobs;
        let rank_up: Vec<Vec<f64>> = jobs.iter().map(|j| ranks::rank_up(j, v_avg, c_avg)).collect();
        let rank_down: Vec<Vec<f64>> = jobs
            .iter()
            .map(|j| ranks::rank_down(j, v_avg, c_avg))
            .collect();
        let n_exec = cluster.len();
        let mut frontier = Frontier::new();
        for job in &jobs {
            frontier.add_job(job);
        }
        SimState {
            arrived: vec![false; jobs.len()],
            assigned: jobs.iter().map(|j| vec![false; j.n_tasks()]).collect(),
            placements: jobs.iter().map(|j| vec![Vec::new(); j.n_tasks()]).collect(),
            exec_log: vec![Vec::new(); n_exec],
            wall: 0.0,
            horizon: 0.0,
            rank_up,
            rank_down,
            n_assigned: 0,
            n_duplicates: 0,
            sched_mode: cluster.sched_mode,
            timelines: vec![Timeline::new(); n_exec],
            frontier,
            min_aft_cache: jobs
                .iter()
                .map(|j| vec![f64::INFINITY; j.n_tasks()])
                .collect(),
            left_tasks: jobs.iter().map(|j| j.n_tasks()).collect(),
            left_work: jobs.iter().map(|j| j.total_work()).collect(),
            v_avg,
            c_avg,
            blackouts: vec![Vec::new(); n_exec],
            down_since: vec![None; n_exec],
            reexec: jobs.iter().map(|j| vec![false; j.n_tasks()]).collect(),
            faults: FaultStats::default(),
            enc_log: Vec::new(),
            enc_log_start: 0,
            cluster,
            jobs,
        }
    }

    /// Absolute end position of the encoder-event log (the cursor a
    /// fully caught-up consumer holds).
    pub fn enc_log_end(&self) -> u64 {
        self.enc_log_start + self.enc_log.len() as u64
    }

    /// The encoder-visible mutations at absolute positions
    /// `[cursor, enc_log_end())` — the dirty-tracking hook driving
    /// [`crate::policy::EncoderCache`]. Returns `None` when `cursor`
    /// predates the compacted range (or belongs to a different state):
    /// the consumer must rebuild from the live state instead of
    /// replaying.
    pub fn enc_events_since(&self, cursor: u64) -> Option<&[EncEvent]> {
        if cursor < self.enc_log_start {
            return None;
        }
        let rel = (cursor - self.enc_log_start) as usize;
        if rel > self.enc_log.len() {
            return None;
        }
        Some(&self.enc_log[rel..])
    }

    /// Drop the oldest half of the encoder-event buffer. Called
    /// automatically past [`ENC_LOG_COMPACT_THRESHOLD`]; exposed for
    /// long-running services that want tighter bounds.
    pub fn compact_enc_log(&mut self) {
        let drop = self.enc_log.len() / 2;
        self.enc_log.drain(..drop);
        self.enc_log_start += drop as u64;
    }

    fn push_enc_event(&mut self, ev: EncEvent) {
        if self.enc_log.len() >= ENC_LOG_COMPACT_THRESHOLD {
            self.compact_enc_log();
        }
        self.enc_log.push(ev);
    }

    pub fn n_tasks_total(&self) -> usize {
        self.jobs.iter().map(|j| j.n_tasks()).sum()
    }

    pub fn task_compute(&self, t: TaskRef) -> f64 {
        self.jobs[t.job].tasks[t.node].compute
    }

    /// Memoized mean executor speed `v̄` — the *construction-time* mean,
    /// deliberately frozen so `rank_up`/`rank_down` caches, selector
    /// scores and policy features stay mutually consistent across fault
    /// outages (and so the zero-fault path is bit-identical). The
    /// availability-aware live mean is [`Cluster::v_avg`].
    pub fn v_avg(&self) -> f64 {
        self.v_avg
    }

    /// Memoized average inter-executor transmission speed `c̄`.
    pub fn c_avg(&self) -> f64 {
        self.c_avg
    }

    /// Append-mode ready time of an executor (the old `exec_ready`
    /// scalar): when its timeline goes idle forever.
    pub fn exec_ready(&self, exec: usize) -> f64 {
        self.timelines[exec].tail()
    }

    /// The executor's full busy-interval timeline.
    pub fn timeline(&self, exec: usize) -> &Timeline {
        &self.timelines[exec]
    }

    /// Dynamically add a job (plug-and-play service mode, where jobs are
    /// submitted over the wire instead of known up front). Returns its id.
    pub fn add_job(&mut self, mut job: Job) -> usize {
        let id = self.jobs.len();
        job.id = id;
        self.rank_up.push(ranks::rank_up(&job, self.v_avg, self.c_avg));
        self.rank_down
            .push(ranks::rank_down(&job, self.v_avg, self.c_avg));
        self.arrived.push(false);
        self.assigned.push(vec![false; job.n_tasks()]);
        self.placements.push(vec![Vec::new(); job.n_tasks()]);
        self.min_aft_cache.push(vec![f64::INFINITY; job.n_tasks()]);
        self.left_tasks.push(job.n_tasks());
        self.left_work.push(job.total_work());
        self.reexec.push(vec![false; job.n_tasks()]);
        self.frontier.add_job(&job);
        self.jobs.push(job);
        id
    }

    /// Monotonically advance the wall clock: time never moves backwards,
    /// even if a caller (service heartbeat, schedule poll, out-of-order
    /// event) reports a stale timestamp.
    pub fn advance_wall(&mut self, time: f64) {
        if time > self.wall {
            self.wall = time;
        }
    }

    /// Number of jobs added but not yet arrived — in service mode, the
    /// future-dated submissions still waiting for the wall clock to
    /// reach their arrival time.
    pub fn n_unarrived(&self) -> usize {
        self.arrived.iter().filter(|&&a| !a).count()
    }

    /// Mark a job as arrived and add its newly executable tasks to the
    /// frontier. Called by the engine on arrival events.
    pub fn mark_arrived(&mut self, job: usize) {
        if self.arrived[job] {
            return;
        }
        self.arrived[job] = true;
        self.frontier.activate_job(job);
        self.push_enc_event(EncEvent::Arrived { job });
    }

    /// The executable set `A_t` (paper notation): arrived, unassigned,
    /// every parent assigned. Sorted, deterministic, maintained
    /// incrementally by the [`Frontier`].
    pub fn executable(&self) -> &[TaskRef] {
        self.frontier.items()
    }

    pub fn is_executable(&self, t: TaskRef) -> bool {
        self.frontier.contains(t)
    }

    /// Recompute the executable set from scratch (the pre-refactor
    /// definition). Used by `validate` and the property tests to pin the
    /// incremental frontier to its scan-based meaning.
    pub fn executable_scan(&self) -> Vec<TaskRef> {
        let mut out = Vec::new();
        for (ji, job) in self.jobs.iter().enumerate() {
            if !self.arrived[ji] {
                continue;
            }
            for node in 0..job.n_tasks() {
                if !self.assigned[ji][node]
                    && job.parents[node].iter().all(|e| self.assigned[ji][e.other])
                {
                    out.push(TaskRef::new(ji, node));
                }
            }
        }
        out
    }

    /// Earliest finish time among a task's scheduled copies
    /// (`min_{r_k ∈ R_{n_p}} AFT(n_p, r_k)`; ∞ if unassigned). O(1) from
    /// the incremental cache.
    pub fn min_aft(&self, t: TaskRef) -> f64 {
        self.min_aft_cache[t.job][t.node]
    }

    /// Scan-based `min_aft` definition (for validation).
    pub fn min_aft_scan(&self, t: TaskRef) -> f64 {
        self.placements[t.job][t.node]
            .iter()
            .map(|p| p.finish)
            .fold(f64::INFINITY, f64::min)
    }

    /// Has the task's earliest copy finished by the current wall time?
    pub fn is_finished(&self, t: TaskRef) -> bool {
        self.min_aft(t) <= self.wall
    }

    /// Earliest time parent `p`'s output data can be available on executor
    /// `exec` (Eq 9's AFTC): min over parent copies of copy AFT + transfer.
    pub fn parent_data_at(&self, child: TaskRef, parent: NodeId, exec: usize) -> f64 {
        let p = TaskRef::new(child.job, parent);
        let edge = self.jobs[child.job].edge_data(parent, child.node);
        self.placements[p.job][p.node]
            .iter()
            .map(|pl| pl.finish + self.cluster.transfer_time(edge, pl.exec, exec))
            .fold(f64::INFINITY, f64::min)
    }

    /// Locality summary of a task's placed parents: `(dominant_rack,
    /// local_mb, total_mb)` where `total_mb` sums the edge data of every
    /// parent with at least one placed copy, `dominant_rack` is the rack
    /// holding the most parent bytes (lowest rack id on ties, 0 when no
    /// parent is placed), and `local_mb` is the bytes available in that
    /// rack. A parent counts toward a rack if *any* of its copies
    /// (primary or duplicate) lives there — the scheduler could source
    /// the transfer rack-locally. Drives the policy's locality features;
    /// under `flat` everything is rack 0 and `local_mb == total_mb`.
    pub fn parent_locality(&self, t: TaskRef) -> (usize, f64, f64) {
        let n_racks = self.cluster.n_racks();
        let mut per_rack = vec![0.0f64; n_racks];
        let mut total = 0.0f64;
        for e in &self.jobs[t.job].parents[t.node] {
            let copies = &self.placements[t.job][e.other];
            if copies.is_empty() {
                continue;
            }
            total += e.data;
            let mut seen = vec![false; n_racks];
            for pl in copies {
                let r = self.cluster.rack_of(pl.exec);
                if !seen[r] {
                    seen[r] = true;
                    per_rack[r] += e.data;
                }
            }
        }
        let mut dominant = 0usize;
        for r in 1..n_racks {
            if per_rack[r] > per_rack[dominant] {
                dominant = r;
            }
        }
        (dominant, per_rack[dominant], total)
    }

    /// Earliest time *all* of a task's input data is available on `exec`
    /// (the inner max of Eq 2). Job arrival bounds entry tasks.
    pub fn data_ready(&self, t: TaskRef, exec: usize) -> f64 {
        let job = &self.jobs[t.job];
        let mut ready = job.arrival;
        for e in &job.parents[t.node] {
            let avail = self.parent_data_at(t, e.other, exec);
            if avail > ready {
                ready = avail;
            }
        }
        ready
    }

    /// Lower bound on a task's start on `exec` independent of executor
    /// availability: data readiness, the wall clock, and the job arrival
    /// (the online constraints of Eq 2).
    pub fn ready_time(&self, t: TaskRef, exec: usize) -> f64 {
        self.data_ready(t, exec)
            .max(self.wall)
            .max(self.jobs[t.job].arrival)
    }

    /// Plan the primary copy of `task` on `exec` without committing:
    /// `(start, finish)` under the state's booking mode. `apply` uses the
    /// same plan, so an allocator's predicted finish always matches the
    /// committed one.
    pub fn plan_direct(&self, task: TaskRef, exec: usize) -> (f64, f64) {
        let ready = self.ready_time(task, exec);
        let dur = self.task_compute(task) / self.cluster.speed(exec);
        let start = self.timelines[exec].earliest_start(ready, dur, self.sched_mode);
        (start, start + dur)
    }

    /// Plan duplicating `parent` onto `exec` and then running `task` there
    /// (Eq 9–10): returns `((dup_start, dup_finish), (start, finish))`.
    ///
    /// The duplicate waits for its own inputs and an executor slot; the
    /// task then starts no earlier than the duplicate's finish (the copy
    /// holds the executor and makes the parent's output local) and the
    /// other parents' data arrivals. Because the task's ready time is ≥
    /// the duplicate's finish, planning both against the pre-booking
    /// timeline cannot produce overlapping slots, in either booking mode.
    pub fn plan_duplicate(
        &self,
        task: TaskRef,
        parent: NodeId,
        exec: usize,
    ) -> ((f64, f64), (f64, f64)) {
        let p = TaskRef::new(task.job, parent);
        let (dup_start, dup_finish) = self.plan_direct(p, exec);
        let mut ready = dup_finish;
        for e in &self.jobs[task.job].parents[task.node] {
            if e.other == parent {
                continue;
            }
            let avail = self.parent_data_at(task, e.other, exec);
            if avail > ready {
                ready = avail;
            }
        }
        let dur = self.task_compute(task) / self.cluster.speed(exec);
        let start = self.timelines[exec].earliest_start(ready, dur, self.sched_mode);
        ((dup_start, dup_finish), (start, start + dur))
    }

    /// Remaining (unassigned) task count of a job. O(1) from the counter.
    pub fn job_left_tasks(&self, job: usize) -> usize {
        self.left_tasks[job]
    }

    /// Remaining (unassigned) work of a job, in GHz·s. O(1) from the
    /// counter (clamped against float drift from repeated subtraction).
    pub fn job_left_work(&self, job: usize) -> f64 {
        self.left_work[job].max(0.0)
    }

    /// Scan-based `job_left_tasks` definition (for validation).
    pub fn job_left_tasks_scan(&self, job: usize) -> usize {
        self.assigned[job].iter().filter(|&&a| !a).count()
    }

    /// Scan-based `job_left_work` definition (for validation).
    pub fn job_left_work_scan(&self, job: usize) -> f64 {
        self.assigned[job]
            .iter()
            .enumerate()
            .filter(|(_, &a)| !a)
            .map(|(n, _)| self.jobs[job].tasks[n].compute)
            .sum()
    }

    pub fn all_assigned(&self) -> bool {
        self.n_assigned == self.n_tasks_total()
    }

    /// Commit one booked copy: placement list, timeline, log, and the
    /// min-AFT / horizon caches.
    fn book(&mut self, t: TaskRef, exec: usize, start: f64, finish: f64, duplicate: bool) {
        let pl = Placement {
            exec,
            start,
            finish,
            duplicate,
        };
        self.placements[t.job][t.node].push(pl);
        self.timelines[exec].book(start, finish);
        self.exec_log[exec].push((t, pl));
        if finish < self.min_aft_cache[t.job][t.node] {
            self.min_aft_cache[t.job][t.node] = finish;
        }
        if finish > self.horizon {
            self.horizon = finish;
        }
        if duplicate {
            self.n_duplicates += 1;
        }
        self.push_enc_event(EncEvent::Booked { task: t, finish });
    }

    /// Apply an allocation decision for `task`. Returns the task's finish
    /// time (its completion event time). Panics if `task` is not
    /// executable or `alloc` is invalid — schedulers must only emit legal
    /// decisions; the engine relies on this invariant.
    pub fn apply(&mut self, task: TaskRef, alloc: Allocation) -> f64 {
        assert!(
            self.is_executable(task),
            "scheduler selected non-executable task {task:?}"
        );
        let exec = alloc.exec();
        assert!(exec < self.cluster.len(), "executor {exec} out of range");
        assert!(
            self.cluster.available(exec),
            "scheduler booked task {task:?} onto down executor {exec}"
        );

        let finish = match alloc {
            Allocation::Duplicate { parent, .. } => {
                assert!(
                    self.jobs[task.job].parents[task.node]
                        .iter()
                        .any(|e| e.other == parent),
                    "duplicate of non-parent node {parent}"
                );
                let (dup, primary) = self.plan_duplicate(task, parent, exec);
                let p = TaskRef::new(task.job, parent);
                self.book(p, exec, dup.0, dup.1, true);
                self.book(task, exec, primary.0, primary.1, false);
                primary.1
            }
            Allocation::Direct { .. } => {
                let (start, finish) = self.plan_direct(task, exec);
                self.book(task, exec, start, finish, false);
                finish
            }
        };

        // Assignment bookkeeping: flags, per-job counters, frontier.
        self.assigned[task.job][task.node] = true;
        self.n_assigned += 1;
        self.left_tasks[task.job] -= 1;
        self.left_work[task.job] -= self.task_compute(task);
        self.frontier.assign(&self.jobs[task.job], task);
        self.push_enc_event(EncEvent::Assigned { task });
        finish
    }

    // ------------------------------------------------------------------
    // Fault recovery (see rust/src/fault/): crashes, stragglers, and the
    // rollback cascade that keeps every incremental cache coherent.
    // ------------------------------------------------------------------

    /// Whether executor `k` is currently up.
    pub fn exec_available(&self, k: usize) -> bool {
        self.cluster.available(k)
    }

    /// Is at least one executor up? Schedulers pass (wait for a recovery
    /// event) when this is false.
    pub fn any_executor_available(&self) -> bool {
        self.cluster.any_available()
    }

    /// Fault blackout (outage) windows booked on executor `k`.
    pub fn blackouts(&self, k: usize) -> &[(f64, f64)] {
        &self.blackouts[k]
    }

    /// Total outage time booked on executor `k` (subtracted from the
    /// timeline's busy time when computing utilization).
    pub fn blackout_time(&self, k: usize) -> f64 {
        self.blackouts[k].iter().map(|&(s, f)| f - s).sum()
    }

    /// When executor `k` went down; `None` while it is up.
    pub fn down_since(&self, k: usize) -> Option<f64> {
        self.down_since[k]
    }

    /// Did this task ever lose all copies to a fault and return to the
    /// frontier? (Counts never-started queued copies too — this is
    /// "re-placed", not necessarily "work re-done".)
    pub fn was_requeued(&self, t: TaskRef) -> bool {
        self.reexec[t.job][t.node]
    }

    /// Executor `k` recovered from a transient crash.
    pub fn mark_executor_up(&mut self, k: usize) {
        self.cluster.set_available(k, true);
        self.down_since[k] = None;
    }

    /// Executor `exec` crashes at `time`: every unfinished copy on it is
    /// lost (finished copies persist their outputs off-executor), the
    /// loss cascades to dependents booked against those copies, tasks
    /// with a surviving duplicate copy are promoted in place
    /// (duplication-as-fault-tolerance), and truly lost tasks return to
    /// the executable frontier. For transient crashes (`recovery =
    /// Some(t_up)`) the outage is booked into the timeline as a blackout
    /// so no later booking can land inside it; the executor is marked
    /// unavailable until [`SimState::mark_executor_up`].
    pub fn apply_crash(
        &mut self,
        exec: usize,
        time: f64,
        recovery: Option<f64>,
    ) -> RecoveryOutcome {
        assert!(exec < self.cluster.len(), "executor {exec} out of range");
        assert!(time.is_finite(), "non-finite crash time");
        if let Some(up) = recovery {
            assert!(up.is_finite() && up >= time, "recovery predates the crash");
        }
        if !self.cluster.available(exec) {
            // Already down (duplicate report): nothing to recover.
            return RecoveryOutcome::default();
        }
        let before = self.faults;
        self.faults.n_crashes += 1;
        let lost: Vec<(TaskRef, Placement)> = self.exec_log[exec]
            .iter()
            .filter(|(_, pl)| pl.finish > time + EPS)
            .copied()
            .collect();
        for &(t, pl) in &lost {
            self.cancel_copy(t, pl);
        }
        self.cluster.set_available(exec, false);
        self.down_since[exec] = Some(time);
        if let Some(up) = recovery {
            // After cancellation every kept booking finishes by `time`,
            // but an earlier, still-open blackout can extend past it
            // (crash during a manually-cut-short outage): clamp so
            // blackouts never overlap.
            let from = time.max(self.timelines[exec].tail());
            if up > from {
                self.timelines[exec].book(from, up);
                self.blackouts[exec].push((from, up));
            }
        }
        // Availability and blackouts are not part of the encoding: only a
        // pass that actually cancelled copies invalidates incremental
        // consumers (an idle-executor crash stays encoder-invisible and
        // costs the EncoderCache nothing).
        if !lost.is_empty() {
            let mut seeds: Vec<TaskRef> = lost.iter().map(|&(t, _)| t).collect();
            seeds.sort_unstable();
            seeds.dedup();
            self.repair_cascade(seeds);
            self.recompute_horizon();
            self.push_enc_event(EncEvent::Invalidated);
        }
        RecoveryOutcome {
            cancelled: self.faults.n_cancelled - before.n_cancelled,
            requeued: self.faults.n_requeued - before.n_requeued,
            survived: self.faults.n_dup_survived - before.n_dup_survived,
        }
    }

    /// Executor `exec` straggles at `time`: its in-flight copy (at most
    /// one — intervals never overlap) keeps running with the remaining
    /// duration stretched by `factor`, and queued-but-unstarted bookings
    /// on it are cancelled back to the frontier so the scheduler can
    /// reconsider them (possibly duplicating around the slow node).
    /// Returns the re-timed `(task, new_finish)` completions so the
    /// engine can re-schedule their completion events.
    pub fn apply_straggle(&mut self, exec: usize, time: f64, factor: f64) -> Vec<(TaskRef, f64)> {
        assert!(exec < self.cluster.len(), "executor {exec} out of range");
        assert!(time.is_finite(), "non-finite straggle time");
        assert!(factor >= 1.0 && factor.is_finite(), "slowdown must be >= 1");
        if !self.cluster.available(exec) {
            return Vec::new(); // nothing runs on a down executor
        }
        self.faults.n_straggles += 1;
        let queued: Vec<(TaskRef, Placement)> = self.exec_log[exec]
            .iter()
            .filter(|(_, pl)| pl.start > time + EPS)
            .copied()
            .collect();
        for &(t, pl) in &queued {
            self.cancel_copy(t, pl);
        }
        let inflight: Vec<(TaskRef, Placement)> = self.exec_log[exec]
            .iter()
            .filter(|(_, pl)| pl.start <= time + EPS && pl.finish > time + EPS)
            .copied()
            .collect();
        let mut retimed: Vec<(TaskRef, f64)> = Vec::new();
        for &(t, pl) in &inflight {
            let new_finish = time + (pl.finish - time) * factor;
            assert!(new_finish.is_finite());
            for c in self.placements[t.job][t.node].iter_mut() {
                if c.same_booking(&pl) {
                    c.finish = new_finish;
                    break;
                }
            }
            for (lt, lp) in self.exec_log[exec].iter_mut() {
                if *lt == t && lp.same_booking(&pl) {
                    lp.finish = new_finish;
                    break;
                }
            }
            assert!(
                self.timelines[exec].unbook(pl.start, pl.finish),
                "stretched copy missing from timeline"
            );
            self.timelines[exec].book(pl.start, new_finish);
            self.min_aft_cache[t.job][t.node] = self.min_aft_scan(t);
            retimed.push((t, new_finish));
        }
        // As in `apply_crash`: an empty pass (idle executor) is
        // encoder-invisible and triggers no rebuild.
        if !queued.is_empty() || !retimed.is_empty() {
            let mut seeds: Vec<TaskRef> = queued
                .iter()
                .map(|&(t, _)| t)
                .chain(retimed.iter().map(|&(t, _)| t))
                .collect();
            seeds.sort_unstable();
            seeds.dedup();
            self.repair_cascade(seeds);
            self.recompute_horizon();
            self.push_enc_event(EncEvent::Invalidated);
        }
        retimed
    }

    /// Remove one booked copy of `t` from the placement list, the
    /// executor timeline, and the schedule log (exact endpoint match —
    /// callers pass back the values they booked).
    fn cancel_copy(&mut self, t: TaskRef, pl: Placement) {
        let copies = &mut self.placements[t.job][t.node];
        let idx = copies
            .iter()
            .position(|c| c.same_booking(&pl))
            .expect("cancelled copy present in placements");
        copies.remove(idx);
        assert!(
            self.timelines[pl.exec].unbook(pl.start, pl.finish),
            "cancelled copy missing from timeline"
        );
        let log = &mut self.exec_log[pl.exec];
        let li = log
            .iter()
            .position(|(lt, lp)| *lt == t && lp.same_booking(&pl))
            .expect("cancelled copy present in exec log");
        log.remove(li);
        if pl.duplicate {
            self.n_duplicates -= 1;
        }
        self.faults.n_cancelled += 1;
    }

    /// Settle a task whose copy set shrank: refresh its `min_aft`,
    /// promote the earliest surviving copy to primary if the primary was
    /// lost, or — when nothing survives — roll the assignment back and
    /// return the task to the executable frontier.
    fn settle_task(&mut self, t: TaskRef) {
        self.min_aft_cache[t.job][t.node] = self.min_aft_scan(t);
        if self.placements[t.job][t.node].is_empty() {
            if self.assigned[t.job][t.node] {
                self.assigned[t.job][t.node] = false;
                self.n_assigned -= 1;
                self.left_tasks[t.job] += 1;
                self.left_work[t.job] += self.task_compute(t);
                self.frontier.unassign(&self.jobs[t.job], t);
                self.reexec[t.job][t.node] = true;
                self.faults.n_requeued += 1;
            }
            return;
        }
        if self.assigned[t.job][t.node]
            && !self.placements[t.job][t.node].iter().any(|c| !c.duplicate)
        {
            // Primary lost but a duplicate survives: the earliest copy
            // becomes the new authoritative finish — no rescheduling.
            let best = self.placements[t.job][t.node]
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    a.finish.total_cmp(&b.finish).then(a.exec.cmp(&b.exec))
                })
                .map(|(i, _)| i)
                .expect("non-empty copy list");
            // `pl` is copied before the flag flips, so `same_booking`
            // still matches the log entry's duplicate=true role.
            let pl = self.placements[t.job][t.node][best];
            self.placements[t.job][t.node][best].duplicate = false;
            let log = &mut self.exec_log[pl.exec];
            let li = log
                .iter()
                .position(|(lt, lp)| *lt == t && lp.same_booking(&pl))
                .expect("promoted copy present in exec log");
            log[li].1.duplicate = false;
            self.n_duplicates -= 1;
            self.faults.n_dup_survived += 1;
        }
    }

    /// Propagate cancellations downstream: any copy whose start is no
    /// longer supported by its parents' (shrunken or re-timed) copy sets
    /// is cancelled too, and tasks that lose every copy roll back to the
    /// frontier. `seeds` are the tasks whose copy sets the caller already
    /// changed. Terminates because every round strictly removes copies.
    fn repair_cascade(&mut self, seeds: Vec<TaskRef>) {
        use std::collections::VecDeque;
        let mut queue: VecDeque<TaskRef> = VecDeque::new();
        for &t in &seeds {
            self.settle_task(t);
        }
        for &t in &seeds {
            for e in &self.jobs[t.job].children[t.node] {
                queue.push_back(TaskRef::new(t.job, e.other));
            }
        }
        while let Some(c) = queue.pop_front() {
            let mut drop: Vec<Placement> = Vec::new();
            for pl in &self.placements[c.job][c.node] {
                for e in &self.jobs[c.job].parents[c.node] {
                    let avail = self.parent_data_at(c, e.other, pl.exec);
                    // Same tolerance as `validate`'s data-readiness check.
                    if pl.start + 1e-6 < avail {
                        drop.push(*pl);
                        break;
                    }
                }
            }
            if drop.is_empty() {
                continue;
            }
            for pl in drop {
                self.cancel_copy(c, pl);
            }
            self.settle_task(c);
            for e in &self.jobs[c.job].children[c.node] {
                queue.push_back(TaskRef::new(c.job, e.other));
            }
        }
    }

    /// Re-derive the horizon after cancellations (it can shrink — the
    /// incremental max no longer upper-bounds the live bookings).
    fn recompute_horizon(&mut self) {
        let mut h = 0.0f64;
        for log in &self.exec_log {
            for (_, pl) in log {
                if pl.finish > h {
                    h = pl.finish;
                }
            }
        }
        self.horizon = h;
    }

    /// Completion time of a job: max AFT over primary copies (∞ until all
    /// assigned).
    pub fn job_completion(&self, job: usize) -> f64 {
        let mut t = 0.0f64;
        for node in 0..self.jobs[job].n_tasks() {
            if !self.assigned[job][node] {
                return f64::INFINITY;
            }
            // Primary (non-duplicate) copy finish.
            let f = self.placements[job][node]
                .iter()
                .filter(|p| !p.duplicate)
                .map(|p| p.finish)
                .fold(f64::NEG_INFINITY, f64::max);
            if f > t {
                t = f;
            }
        }
        t
    }

    /// Validate the composed state: no overlapping intervals on any
    /// executor, every start ≥ job arrival, every child starts after the
    /// copy of each parent it could have read from, the executor
    /// timelines agree with the schedule log, and every incremental cache
    /// (frontier, `min_aft`, per-job counters) equals its scan-based
    /// definition. Used by tests and the `--validate` flag.
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::bail;
        for (e, log) in self.exec_log.iter().enumerate() {
            let mut sorted = log.clone();
            sorted.sort_by(|a, b| a.1.start.total_cmp(&b.1.start));
            for w in sorted.windows(2) {
                if w[1].1.start < w[0].1.finish - 1e-9 {
                    bail!(
                        "executor {e}: overlap {:?}@{:.3}-{:.3} vs {:?}@{:.3}",
                        w[0].0,
                        w[0].1.start,
                        w[0].1.finish,
                        w[1].0,
                        w[1].1.start
                    );
                }
            }
            // The timeline must be exactly the sorted log intervals plus
            // the fault blackout windows — and no booking may overlap a
            // blackout (the executor was down then).
            let mut entries: Vec<(f64, f64, bool)> = sorted
                .iter()
                .map(|(_, pl)| (pl.start, pl.finish, false))
                .collect();
            entries.extend(self.blackouts[e].iter().map(|&(s, f)| (s, f, true)));
            entries.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in entries.windows(2) {
                if (w[0].2 || w[1].2) && w[1].0 < w[0].1 - 1e-9 {
                    bail!(
                        "executor {e}: booking overlaps blackout ({:.3}-{:.3} vs {:.3}-{:.3})",
                        w[0].0,
                        w[0].1,
                        w[1].0,
                        w[1].1
                    );
                }
            }
            let tl = self.timelines[e].intervals();
            if tl.len() != entries.len() {
                bail!(
                    "executor {e}: timeline has {} intervals, log + blackouts have {}",
                    tl.len(),
                    entries.len()
                );
            }
            for (iv, en) in tl.iter().zip(&entries) {
                if (iv.0 - en.0).abs() > 1e-9 || (iv.1 - en.1).abs() > 1e-9 {
                    bail!(
                        "executor {e}: timeline interval {:.4}-{:.4} != {} {:.4}-{:.4}",
                        iv.0,
                        iv.1,
                        if en.2 { "blackout" } else { "log" },
                        en.0,
                        en.1
                    );
                }
            }
            // A down executor hosts no unfinished work.
            if let Some(t_down) = self.down_since[e] {
                for (t, pl) in log {
                    if pl.finish > t_down + 1e-9 {
                        bail!(
                            "executor {e} down since {t_down:.3} but hosts {t:?} \
                             finishing {:.3}",
                            pl.finish
                        );
                    }
                }
            }
        }
        for (ji, job) in self.jobs.iter().enumerate() {
            for node in 0..job.n_tasks() {
                for pl in &self.placements[ji][node] {
                    if pl.start + 1e-9 < job.arrival {
                        bail!("task ({ji},{node}) starts before its job arrives");
                    }
                    // Data-readiness: the copy must not start before every
                    // parent's data could be at pl.exec.
                    for edge in &job.parents[node] {
                        let avail =
                            self.parent_data_at(TaskRef::new(ji, node), edge.other, pl.exec);
                        if pl.start + 1e-6 < avail {
                            bail!(
                                "task ({ji},{node}) on exec {} starts {:.4} before parent {} data at {:.4}",
                                pl.exec,
                                pl.start,
                                edge.other,
                                avail
                            );
                        }
                    }
                }
                let t = TaskRef::new(ji, node);
                let cached = self.min_aft(t);
                let scanned = self.min_aft_scan(t);
                if cached != scanned && !(cached.is_infinite() && scanned.is_infinite()) {
                    bail!("task ({ji},{node}): min_aft cache {cached} != scan {scanned}");
                }
                // Assignment ↔ copy-set consistency (fault rollbacks must
                // never leave a half-cancelled task behind).
                if self.assigned[ji][node] {
                    if !self.placements[ji][node].iter().any(|p| !p.duplicate) {
                        bail!("task ({ji},{node}) assigned but has no primary copy");
                    }
                } else if !self.placements[ji][node].is_empty() {
                    bail!("task ({ji},{node}) unassigned but retains booked copies");
                }
            }
            if self.job_left_tasks(ji) != self.job_left_tasks_scan(ji) {
                bail!(
                    "job {ji}: left_tasks counter {} != scan {}",
                    self.job_left_tasks(ji),
                    self.job_left_tasks_scan(ji)
                );
            }
            let (lw, lws) = (self.job_left_work(ji), self.job_left_work_scan(ji));
            if (lw - lws).abs() > 1e-6 * (1.0 + lws.abs()) {
                bail!("job {ji}: left_work counter {lw} != scan {lws}");
            }
        }
        if self.frontier.items() != self.executable_scan().as_slice() {
            bail!(
                "frontier {:?} != scan {:?}",
                self.frontier.items(),
                self.executable_scan()
            );
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Snapshot serialization (service crash recovery). The contract is
    // bitwise: a state restored from `snapshot_json` must plan, apply,
    // and report exactly what the live state would have — so every
    // float travels as a JSON number (the writer prints f64 exactly;
    // see `util::json::write_num`), order-bearing lists (placements,
    // exec logs, adjacency, timelines) are stored verbatim, and
    // scan-recomputable caches (`min_aft`, `left_tasks`, frontier,
    // ranks) are re-derived — `validate()` pins each of those to its
    // scan, so recomputation is exact. `left_work` is the one cache
    // that drifts from its scan (incremental subtraction, 1e-6
    // tolerance in `validate`): it is serialized, not recomputed.
    // ------------------------------------------------------------------

    /// Serialize everything needed to rebuild this state bit-identically
    /// (given the same cluster). The encoder-event log is deliberately
    /// excluded: a fresh consumer rebuilds from live state and PR 2's
    /// cache tests pin that rebuild to be decision-identical.
    pub fn snapshot_json(&self) -> Json {
        let edges = |es: &[crate::dag::Edge]| -> Json {
            Json::Arr(
                es.iter()
                    .map(|e| Json::Arr(vec![Json::from(e.other), Json::from(e.data)]))
                    .collect(),
            )
        };
        let jobs: Vec<Json> = self
            .jobs
            .iter()
            .map(|j| {
                Json::from_pairs(vec![
                    ("name", Json::from(j.name.clone())),
                    ("arrival", Json::from(j.arrival)),
                    (
                        "computes",
                        Json::from(j.tasks.iter().map(|t| t.compute).collect::<Vec<f64>>()),
                    ),
                    (
                        "children",
                        Json::Arr(j.children.iter().map(|es| edges(es)).collect()),
                    ),
                    (
                        "parents",
                        Json::Arr(j.parents.iter().map(|es| edges(es)).collect()),
                    ),
                ])
            })
            .collect();
        let placement_json = |pl: &Placement| -> Json {
            Json::Arr(vec![
                Json::from(pl.exec),
                Json::from(pl.start),
                Json::from(pl.finish),
                Json::from(pl.duplicate),
            ])
        };
        let placements: Vec<Json> = self
            .placements
            .iter()
            .map(|job| {
                Json::Arr(
                    job.iter()
                        .map(|copies| Json::Arr(copies.iter().map(placement_json).collect()))
                        .collect(),
                )
            })
            .collect();
        let exec_log: Vec<Json> = self
            .exec_log
            .iter()
            .map(|log| {
                Json::Arr(
                    log.iter()
                        .map(|(t, pl)| {
                            Json::Arr(vec![
                                Json::from(t.job),
                                Json::from(t.node),
                                placement_json(pl),
                            ])
                        })
                        .collect(),
                )
            })
            .collect();
        let intervals = |iv: &[(f64, f64)]| -> Json {
            Json::Arr(
                iv.iter()
                    .map(|&(s, f)| Json::Arr(vec![Json::from(s), Json::from(f)]))
                    .collect(),
            )
        };
        Json::from_pairs(vec![
            ("version", Json::from(1usize)),
            ("sched_mode", Json::from(self.sched_mode.as_str())),
            (
                "speeds",
                Json::from(
                    self.cluster
                        .executors
                        .iter()
                        .map(|e| e.speed)
                        .collect::<Vec<f64>>(),
                ),
            ),
            ("comm_mbps", Json::from(self.cluster.comm_mbps)),
            ("net", Json::from(self.cluster.net.config().snapshot_key())),
            ("wall", Json::from(self.wall)),
            ("horizon", Json::from(self.horizon)),
            ("n_assigned", Json::from(self.n_assigned)),
            ("n_duplicates", Json::from(self.n_duplicates)),
            ("v_avg", Json::from(self.v_avg)),
            ("c_avg", Json::from(self.c_avg)),
            ("jobs", Json::Arr(jobs)),
            (
                "arrived",
                Json::from(self.arrived.iter().map(|&a| Json::from(a)).collect::<Vec<_>>()),
            ),
            (
                "assigned",
                Json::Arr(
                    self.assigned
                        .iter()
                        .map(|j| Json::from(j.iter().map(|&a| Json::from(a)).collect::<Vec<_>>()))
                        .collect(),
                ),
            ),
            (
                "reexec",
                Json::Arr(
                    self.reexec
                        .iter()
                        .map(|j| Json::from(j.iter().map(|&a| Json::from(a)).collect::<Vec<_>>()))
                        .collect(),
                ),
            ),
            ("left_work", Json::from(self.left_work.clone())),
            ("placements", Json::Arr(placements)),
            ("exec_log", Json::Arr(exec_log)),
            (
                "timelines",
                Json::Arr(
                    self.timelines
                        .iter()
                        .map(|tl| intervals(tl.intervals()))
                        .collect(),
                ),
            ),
            (
                "blackouts",
                Json::Arr(self.blackouts.iter().map(|b| intervals(b)).collect()),
            ),
            (
                "down_since",
                Json::Arr(
                    self.down_since
                        .iter()
                        .map(|d| d.map_or(Json::Null, |t: f64| Json::from(t)))
                        .collect(),
                ),
            ),
            (
                "faults",
                Json::from_pairs(vec![
                    ("crashes", Json::from(self.faults.n_crashes)),
                    ("straggles", Json::from(self.faults.n_straggles)),
                    ("cancelled", Json::from(self.faults.n_cancelled)),
                    ("requeued", Json::from(self.faults.n_requeued)),
                    ("dup_survived", Json::from(self.faults.n_dup_survived)),
                ]),
            ),
        ])
    }

    /// Rebuild a state from [`SimState::snapshot_json`] output against a
    /// freshly-constructed cluster (same config flags and seed as the
    /// process that wrote the snapshot — speeds, comm, and booking mode
    /// are cross-checked so an operator restarting with different flags
    /// gets an error instead of silent divergence). Executor
    /// availability is restored from the snapshot's `down_since`.
    pub fn from_snapshot_json(mut cluster: Cluster, v: &Json) -> anyhow::Result<SimState> {
        use anyhow::{anyhow, bail};
        let version = v.req_usize("version").map_err(|e| anyhow!("{e}"))?;
        if version != 1 {
            bail!("unsupported state snapshot version {version}");
        }
        let mode = v.req_str("sched_mode").map_err(|e| anyhow!("{e}"))?;
        if mode != cluster.sched_mode.as_str() {
            bail!(
                "snapshot booked executor time in '{mode}' mode but the cluster \
                 is '{}' — restart with the flags the snapshot was taken under",
                cluster.sched_mode.as_str()
            );
        }
        let speeds = parse_f64s(v.req("speeds").map_err(|e| anyhow!("{e}"))?, "speeds")?;
        if speeds.len() != cluster.len()
            || speeds
                .iter()
                .zip(&cluster.executors)
                .any(|(s, e)| s.to_bits() != e.speed.to_bits())
        {
            bail!(
                "snapshot cluster ({} executors) does not match the configured one \
                 ({}) — restart with the same --executors/--seed flags",
                speeds.len(),
                cluster.len()
            );
        }
        let comm = v.req_f64("comm_mbps").map_err(|e| anyhow!("{e}"))?;
        if comm.to_bits() != cluster.comm_mbps.to_bits() {
            bail!("snapshot comm speed {comm} != configured {}", cluster.comm_mbps);
        }
        // Pre-topology snapshots carry no net key; they were taken under
        // the scalar model, which is exactly the flat topology.
        let snap_net = v
            .get("net")
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| crate::net::NetConfig::flat().snapshot_key());
        if snap_net != cluster.net.config().snapshot_key() {
            bail!(
                "snapshot network topology '{snap_net}' != configured '{}' — \
                 restart with the --net flag the snapshot was taken under",
                cluster.net.config().snapshot_key()
            );
        }
        let n_exec = cluster.len();
        let v_avg = v.req_f64("v_avg").map_err(|e| anyhow!("{e}"))?;
        let c_avg = v.req_f64("c_avg").map_err(|e| anyhow!("{e}"))?;

        let jobs_json = v
            .req("jobs")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("jobs must be an array"))?;
        let mut jobs: Vec<Job> = Vec::with_capacity(jobs_json.len());
        for (id, jj) in jobs_json.iter().enumerate() {
            let computes =
                parse_f64s(jj.req("computes").map_err(|e| anyhow!("{e}"))?, "computes")?;
            let adj = |key: &str| -> anyhow::Result<Vec<Vec<crate::dag::Edge>>> {
                jj.req(key)
                    .map_err(|e| anyhow!("{e}"))?
                    .as_arr()
                    .ok_or_else(|| anyhow!("{key} must be an array"))?
                    .iter()
                    .map(|es| {
                        es.as_arr()
                            .ok_or_else(|| anyhow!("{key} entry must be an array"))?
                            .iter()
                            .map(|e| {
                                let other = e.at(0).and_then(Json::as_usize);
                                let data = e.at(1).and_then(Json::as_f64);
                                match (other, data) {
                                    (Some(other), Some(data)) => {
                                        Ok(crate::dag::Edge { other, data })
                                    }
                                    _ => Err(anyhow!("bad {key} edge")),
                                }
                            })
                            .collect()
                    })
                    .collect()
            };
            jobs.push(Job::from_adjacency(
                id,
                jj.req_str("name").map_err(|e| anyhow!("{e}"))?.to_string(),
                jj.req_f64("arrival").map_err(|e| anyhow!("{e}"))?,
                computes,
                adj("children")?,
                adj("parents")?,
            )?);
        }

        let arrived = parse_bools(v.req("arrived").map_err(|e| anyhow!("{e}"))?, "arrived")?;
        let assigned = parse_bool_rows(v.req("assigned").map_err(|e| anyhow!("{e}"))?, "assigned")?;
        let reexec = parse_bool_rows(v.req("reexec").map_err(|e| anyhow!("{e}"))?, "reexec")?;
        let left_work =
            parse_f64s(v.req("left_work").map_err(|e| anyhow!("{e}"))?, "left_work")?;
        if arrived.len() != jobs.len()
            || assigned.len() != jobs.len()
            || reexec.len() != jobs.len()
            || left_work.len() != jobs.len()
        {
            bail!("per-job snapshot arrays disagree with the job count");
        }
        for (j, job) in jobs.iter().enumerate() {
            if assigned[j].len() != job.n_tasks() || reexec[j].len() != job.n_tasks() {
                bail!("per-task snapshot arrays disagree with job {j}'s task count");
            }
        }

        let parse_placement = |e: &Json| -> anyhow::Result<Placement> {
            let exec = e.at(0).and_then(Json::as_usize);
            let start = e.at(1).and_then(Json::as_f64);
            let finish = e.at(2).and_then(Json::as_f64);
            let duplicate = e.at(3).and_then(Json::as_bool);
            match (exec, start, finish, duplicate) {
                (Some(exec), Some(start), Some(finish), Some(duplicate)) if exec < n_exec => {
                    Ok(Placement {
                        exec,
                        start,
                        finish,
                        duplicate,
                    })
                }
                _ => Err(anyhow!("bad placement entry")),
            }
        };
        let placements_json = v
            .req("placements")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("placements must be an array"))?;
        if placements_json.len() != jobs.len() {
            bail!("placements disagree with the job count");
        }
        let mut placements: Vec<Vec<Vec<Placement>>> = Vec::with_capacity(jobs.len());
        for (j, pj) in placements_json.iter().enumerate() {
            let rows = pj
                .as_arr()
                .ok_or_else(|| anyhow!("placements[{j}] must be an array"))?;
            if rows.len() != jobs[j].n_tasks() {
                bail!("placements[{j}] disagrees with the task count");
            }
            let mut job_rows = Vec::with_capacity(rows.len());
            for copies in rows {
                job_rows.push(
                    copies
                        .as_arr()
                        .ok_or_else(|| anyhow!("placement copies must be an array"))?
                        .iter()
                        .map(&parse_placement)
                        .collect::<anyhow::Result<Vec<_>>>()?,
                );
            }
            placements.push(job_rows);
        }
        let exec_log_json = v
            .req("exec_log")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("exec_log must be an array"))?;
        if exec_log_json.len() != n_exec {
            bail!("exec_log disagrees with the executor count");
        }
        let mut exec_log: Vec<Vec<(TaskRef, Placement)>> = Vec::with_capacity(n_exec);
        for (k, lj) in exec_log_json.iter().enumerate() {
            let mut log = Vec::new();
            for entry in lj
                .as_arr()
                .ok_or_else(|| anyhow!("exec_log[{k}] must be an array"))?
            {
                let job = entry.at(0).and_then(Json::as_usize);
                let node = entry.at(1).and_then(Json::as_usize);
                let pl = entry
                    .at(2)
                    .ok_or_else(|| anyhow!("bad exec_log entry"))
                    .and_then(|p| parse_placement(p))?;
                match (job, node) {
                    (Some(job), Some(node))
                        if job < jobs.len() && node < jobs[job].n_tasks() && pl.exec == k =>
                    {
                        log.push((TaskRef::new(job, node), pl));
                    }
                    _ => bail!("bad exec_log entry on executor {k}"),
                }
            }
            exec_log.push(log);
        }
        let timelines = parse_interval_rows(
            v.req("timelines").map_err(|e| anyhow!("{e}"))?,
            "timelines",
            n_exec,
        )?
        .into_iter()
        .map(Timeline::from_intervals)
        .collect();
        let blackouts = parse_interval_rows(
            v.req("blackouts").map_err(|e| anyhow!("{e}"))?,
            "blackouts",
            n_exec,
        )?;
        let down_json = v
            .req("down_since")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("down_since must be an array"))?;
        if down_json.len() != n_exec {
            bail!("down_since disagrees with the executor count");
        }
        let mut down_since: Vec<Option<f64>> = Vec::with_capacity(n_exec);
        for (k, d) in down_json.iter().enumerate() {
            let d = match d {
                Json::Null => None,
                other => Some(
                    other
                        .as_f64()
                        .ok_or_else(|| anyhow!("down_since[{k}] must be a number or null"))?,
                ),
            };
            cluster.set_available(k, d.is_none());
            down_since.push(d);
        }
        let fj = v.req("faults").map_err(|e| anyhow!("{e}"))?;
        let faults = FaultStats {
            n_crashes: fj.req_usize("crashes").map_err(|e| anyhow!("{e}"))?,
            n_straggles: fj.req_usize("straggles").map_err(|e| anyhow!("{e}"))?,
            n_cancelled: fj.req_usize("cancelled").map_err(|e| anyhow!("{e}"))?,
            n_requeued: fj.req_usize("requeued").map_err(|e| anyhow!("{e}"))?,
            n_dup_survived: fj.req_usize("dup_survived").map_err(|e| anyhow!("{e}"))?,
        };

        // Recomputed caches: each is pinned to its scan by `validate`,
        // so re-deriving them here is bit-exact.
        let rank_up = jobs.iter().map(|j| ranks::rank_up(j, v_avg, c_avg)).collect();
        let rank_down = jobs
            .iter()
            .map(|j| ranks::rank_down(j, v_avg, c_avg))
            .collect();
        let min_aft_cache = placements
            .iter()
            .map(|job| {
                job.iter()
                    .map(|copies| {
                        copies
                            .iter()
                            .map(|p| p.finish)
                            .fold(f64::INFINITY, f64::min)
                    })
                    .collect()
            })
            .collect();
        let left_tasks = assigned
            .iter()
            .map(|j| j.iter().filter(|&&a| !a).count())
            .collect();
        let frontier = Frontier::rebuild(&jobs, &arrived, &assigned);

        let state = SimState {
            arrived,
            assigned,
            placements,
            exec_log,
            wall: v.req_f64("wall").map_err(|e| anyhow!("{e}"))?,
            horizon: v.req_f64("horizon").map_err(|e| anyhow!("{e}"))?,
            rank_up,
            rank_down,
            n_assigned: v.req_usize("n_assigned").map_err(|e| anyhow!("{e}"))?,
            n_duplicates: v.req_usize("n_duplicates").map_err(|e| anyhow!("{e}"))?,
            sched_mode: cluster.sched_mode,
            timelines,
            frontier,
            min_aft_cache,
            left_tasks,
            left_work,
            v_avg,
            c_avg,
            blackouts,
            down_since,
            reexec,
            faults,
            enc_log: Vec::new(),
            enc_log_start: 0,
            cluster,
            jobs,
        };
        state
            .validate()
            .map_err(|e| anyhow!("restored state failed validation: {e}"))?;
        Ok(state)
    }
}

fn parse_f64s(v: &Json, what: &str) -> anyhow::Result<Vec<f64>> {
    use anyhow::anyhow;
    v.as_arr()
        .ok_or_else(|| anyhow!("{what} must be an array"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .ok_or_else(|| anyhow!("{what} entries must be numbers"))
        })
        .collect()
}

fn parse_bools(v: &Json, what: &str) -> anyhow::Result<Vec<bool>> {
    use anyhow::anyhow;
    v.as_arr()
        .ok_or_else(|| anyhow!("{what} must be an array"))?
        .iter()
        .map(|x| {
            x.as_bool()
                .ok_or_else(|| anyhow!("{what} entries must be booleans"))
        })
        .collect()
}

fn parse_bool_rows(v: &Json, what: &str) -> anyhow::Result<Vec<Vec<bool>>> {
    use anyhow::anyhow;
    v.as_arr()
        .ok_or_else(|| anyhow!("{what} must be an array"))?
        .iter()
        .map(|row| parse_bools(row, what))
        .collect()
}

fn parse_interval_rows(v: &Json, what: &str, n: usize) -> anyhow::Result<Vec<Vec<(f64, f64)>>> {
    use anyhow::{anyhow, bail};
    let rows = v
        .as_arr()
        .ok_or_else(|| anyhow!("{what} must be an array"))?;
    if rows.len() != n {
        bail!("{what} disagrees with the executor count");
    }
    rows.iter()
        .map(|row| {
            row.as_arr()
                .ok_or_else(|| anyhow!("{what} rows must be arrays"))?
                .iter()
                .map(|iv| {
                    let s = iv.at(0).and_then(Json::as_f64);
                    let f = iv.at(1).and_then(Json::as_f64);
                    match (s, f) {
                        (Some(s), Some(f)) => Ok((s, f)),
                        _ => Err(anyhow!("bad {what} interval")),
                    }
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::dag::Job;
    use crate::workload::Workload;

    fn two_exec_state() -> SimState {
        // speeds 1.0 and 2.0, comm 10 MB/s
        let mut cluster = Cluster::homogeneous(2, 1.0, 10.0);
        cluster.executors[1].speed = 2.0;
        // chain 0 -> 1 with 20 MB edge; w = [4, 6]
        let job = Job::new(0, "chain", 0.0, vec![4.0, 6.0], &[(0, 1, 20.0)]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st
    }

    #[test]
    fn frontier_starts_with_entries() {
        let st = two_exec_state();
        assert_eq!(st.executable(), &[TaskRef::new(0, 0)]);
        assert!(!st.is_executable(TaskRef::new(0, 1)));
    }

    #[test]
    fn apply_direct_chain_accounts_comm() {
        let mut st = two_exec_state();
        let t0 = TaskRef::new(0, 0);
        let f0 = st.apply(t0, Allocation::Direct { exec: 0 });
        assert!((f0 - 4.0).abs() < 1e-12); // 4 / 1.0
        assert!(st.is_executable(TaskRef::new(0, 1)));
        // child on other executor: data ready at 4 + 20/10 = 6; run 6/2 = 3.
        let f1 = st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 1 });
        assert!((f1 - 9.0).abs() < 1e-12);
        assert!((st.horizon - 9.0).abs() < 1e-12);
        st.validate().unwrap();
    }

    #[test]
    fn apply_same_executor_no_comm() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 1 });
        // f0 = 4/2 = 2; child same exec: no comm, start at max(2, ready=2)
        let f1 = st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 1 });
        assert!((f1 - (2.0 + 3.0)).abs() < 1e-12);
        st.validate().unwrap();
    }

    #[test]
    fn apply_duplicate_parent() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 }); // AFT 4 on e0
        // Duplicate parent 0 onto e1, then run child there:
        // dup start 0, dup finish 4/2 = 2; child start max(2, data local) = 2,
        // finish 2 + 3 = 5. Better than the 9.0 of the cross-exec path.
        let f1 = st.apply(
            TaskRef::new(0, 1),
            Allocation::Duplicate { exec: 1, parent: 0 },
        );
        assert!((f1 - 5.0).abs() < 1e-12, "f1={f1}");
        assert_eq!(st.n_duplicates, 1);
        assert_eq!(st.placements[0][0].len(), 2);
        st.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "non-executable")]
    fn apply_rejects_non_executable() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 0 });
    }

    #[test]
    fn wall_time_lower_bounds_start() {
        let mut st = two_exec_state();
        st.wall = 100.0;
        let f = st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 1 });
        assert!((f - 102.0).abs() < 1e-12);
    }

    #[test]
    fn job_completion_ignores_duplicates() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        st.apply(
            TaskRef::new(0, 1),
            Allocation::Duplicate { exec: 1, parent: 0 },
        );
        // Completion = child primary finish (5.0), not the dup copy's.
        assert!((st.job_completion(0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn advance_wall_is_monotone() {
        let mut st = two_exec_state();
        st.advance_wall(5.0);
        assert_eq!(st.wall, 5.0);
        st.advance_wall(3.0); // stale timestamp: ignored
        assert_eq!(st.wall, 5.0);
        st.advance_wall(5.0);
        assert_eq!(st.wall, 5.0);
    }

    #[test]
    fn n_unarrived_counts_deferred_jobs() {
        let cluster = Cluster::homogeneous(1, 1.0, 10.0);
        let early = Job::new(0, "early", 0.0, vec![1.0], &[]);
        let late = Job::new(1, "late", 50.0, vec![1.0], &[]);
        let mut st = SimState::new(cluster, Workload::new(vec![early, late]));
        assert_eq!(st.n_unarrived(), 2);
        st.mark_arrived(0);
        assert_eq!(st.n_unarrived(), 1);
        st.mark_arrived(1);
        assert_eq!(st.n_unarrived(), 0);
    }

    #[test]
    fn unarrived_jobs_not_executable() {
        let cluster = Cluster::homogeneous(1, 1.0, 10.0);
        let job = Job::new(0, "late", 50.0, vec![1.0], &[]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        assert!(st.executable().is_empty());
        st.mark_arrived(0);
        assert_eq!(st.executable().len(), 1);
        // Even though wall=0, start must respect arrival.
        let f = st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        assert!((f - 51.0).abs() < 1e-12);
    }

    #[test]
    fn enc_log_compacts_and_reports_absolute_positions() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        let end = st.enc_log_end();
        assert!(end >= 3); // arrival + booked + assigned
        assert_eq!(st.enc_events_since(end).unwrap().len(), 0);
        assert!(st.enc_events_since(end + 1).is_none(), "future cursor");
        st.compact_enc_log();
        assert!(st.enc_events_since(0).is_none(), "compacted range gone");
        assert_eq!(st.enc_log_end(), end, "absolute positions stable");
        assert!(st.enc_events_since(end).unwrap().is_empty());
    }

    #[test]
    fn incremental_caches_track_assignments() {
        let mut st = two_exec_state();
        assert_eq!(st.job_left_tasks(0), 2);
        assert!((st.job_left_work(0) - 10.0).abs() < 1e-12);
        assert!(st.min_aft(TaskRef::new(0, 0)).is_infinite());
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        assert_eq!(st.job_left_tasks(0), 1);
        assert!((st.job_left_work(0) - 6.0).abs() < 1e-12);
        assert_eq!(st.min_aft(TaskRef::new(0, 0)), 4.0);
        // A duplicate does not change the left counters but can lower the
        // parent's min AFT.
        st.apply(
            TaskRef::new(0, 1),
            Allocation::Duplicate { exec: 1, parent: 0 },
        );
        assert_eq!(st.job_left_tasks(0), 0);
        assert!(st.job_left_work(0).abs() < 1e-9);
        assert_eq!(st.min_aft(TaskRef::new(0, 0)), 2.0); // dup copy 0..2
        st.validate().unwrap();
    }

    /// Gap-aware booking backfills an idle window that append mode cannot
    /// use: a late-arriving job books far in the future, then an
    /// earlier-ready task slots into the hole before it. Note that
    /// `Workload::new` orders jobs by arrival and renumbers ids, so the
    /// early job is job 0 and the late job is job 1.
    #[test]
    fn gap_aware_backfills_idle_window() {
        let cluster =
            Cluster::homogeneous(1, 1.0, 10.0).with_sched_mode(SchedMode::GapAware);
        let early = Job::new(0, "early", 0.0, vec![3.0], &[]);
        let late = Job::new(1, "late", 10.0, vec![2.0], &[]);
        let mut st = SimState::new(cluster, Workload::new(vec![early, late]));
        st.mark_arrived(0);
        st.mark_arrived(1);
        // The late job is arrival-bound: books 10..12, leaving [0, 10] idle.
        let f_late = st.apply(TaskRef::new(1, 0), Allocation::Direct { exec: 0 });
        assert!((f_late - 12.0).abs() < 1e-12, "f_late={f_late}");
        // Gap mode backfills the hole: 0..3 instead of append's 12..15.
        let f_early = st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        assert!((f_early - 3.0).abs() < 1e-12, "f_early={f_early}");
        assert!((st.horizon - 12.0).abs() < 1e-12);
        st.validate().unwrap();

        // The identical decisions under append mode queue behind the tail.
        let cluster = Cluster::homogeneous(1, 1.0, 10.0);
        let early = Job::new(0, "early", 0.0, vec![3.0], &[]);
        let late = Job::new(1, "late", 10.0, vec![2.0], &[]);
        let mut st = SimState::new(cluster, Workload::new(vec![early, late]));
        st.mark_arrived(0);
        st.mark_arrived(1);
        st.apply(TaskRef::new(1, 0), Allocation::Direct { exec: 0 });
        let f_early = st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        assert!((f_early - 15.0).abs() < 1e-12, "f_early={f_early}");
        st.validate().unwrap();
    }

    // ---- fault recovery ------------------------------------------------

    /// A crash cancels the in-flight copy, rolls every cache back, and
    /// returns the task to the frontier; a transient blackout keeps the
    /// outage window unbookable after recovery.
    #[test]
    fn crash_requeues_lost_task_and_books_blackout() {
        let mut st = two_exec_state();
        let t0 = TaskRef::new(0, 0);
        st.apply(t0, Allocation::Direct { exec: 0 }); // [0, 4] on e0
        let out = st.apply_crash(0, 1.0, Some(10.0));
        assert_eq!((out.cancelled, out.requeued, out.survived), (1, 1, 0));
        assert!(!st.exec_available(0));
        assert_eq!(st.down_since(0), Some(1.0));
        assert_eq!(st.blackouts(0), &[(1.0, 10.0)]);
        assert!((st.blackout_time(0) - 9.0).abs() < 1e-12);
        assert_eq!(st.n_assigned, 0);
        assert_eq!(st.job_left_tasks(0), 2);
        assert!((st.job_left_work(0) - 10.0).abs() < 1e-12);
        assert!(st.min_aft(t0).is_infinite());
        assert!(st.is_executable(t0));
        assert!(st.was_requeued(t0));
        st.validate().unwrap();
        // Recovery reopens the executor, but the blackout window stays
        // booked: the next append lands after it.
        st.mark_executor_up(0);
        assert!(st.exec_available(0));
        st.advance_wall(1.0);
        let f = st.apply(t0, Allocation::Direct { exec: 0 });
        assert!((f - 14.0).abs() < 1e-12, "10 + 4/1.0, got {f}");
        st.validate().unwrap();
    }

    /// Duplication as fault tolerance: the primary dies but a duplicate
    /// copy survives elsewhere — the task is promoted in place, nothing
    /// is rescheduled, and dependents booked against the surviving copy
    /// are untouched.
    #[test]
    fn crash_promotes_surviving_duplicate() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 }); // [0,4] e0
        st.apply(
            TaskRef::new(0, 1),
            Allocation::Duplicate { exec: 1, parent: 0 },
        ); // dup of 0 on e1 [0,2], child [2,5]
        assert_eq!(st.n_duplicates, 1);
        let out = st.apply_crash(0, 1.0, None);
        assert_eq!((out.cancelled, out.requeued, out.survived), (1, 0, 1));
        assert_eq!(st.faults.n_dup_survived, 1);
        // Both tasks remain assigned; the surviving copy is now primary.
        assert!(st.all_assigned());
        assert_eq!(st.placements[0][0].len(), 1);
        assert!(!st.placements[0][0][0].duplicate);
        assert_eq!(st.n_duplicates, 0);
        assert_eq!(st.min_aft(TaskRef::new(0, 0)), 2.0);
        assert!((st.job_completion(0) - 5.0).abs() < 1e-12);
        // Permanent crash: no blackout interval, down forever.
        assert!(st.blackouts(0).is_empty());
        assert_eq!(st.down_since(0), Some(1.0));
        st.validate().unwrap();
    }

    /// Losing a parent's only copy cascades: the child's booking (placed
    /// against the lost copy's data) is invalid and rolls back too.
    #[test]
    fn crash_cascades_to_dependent_bookings() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 }); // [0,4] e0
        st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 1 }); // data 6, [6,9] e1
        let out = st.apply_crash(0, 1.0, Some(20.0));
        assert_eq!((out.cancelled, out.requeued, out.survived), (2, 2, 0));
        assert_eq!(st.n_assigned, 0);
        assert!(st.placements[0][1].is_empty());
        assert_eq!(st.exec_ready(1), 0.0, "e1 freed by the cascade");
        assert_eq!(st.executable(), &[TaskRef::new(0, 0)]);
        st.validate().unwrap();
        // Rescheduling on the survivor completes the job.
        st.advance_wall(1.0);
        let f0 = st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 1 });
        assert!((f0 - 3.0).abs() < 1e-12); // 1 + 4/2
        let f1 = st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 1 });
        assert!((f1 - 6.0).abs() < 1e-12); // local data, 3 + 6/2
        assert!(st.all_assigned());
        st.validate().unwrap();
    }

    /// A straggle stretches the in-flight copy's remaining time and
    /// returns queued (unstarted) bookings to the frontier.
    #[test]
    fn straggle_stretches_inflight_and_requeues_queued() {
        let cluster = Cluster::homogeneous(1, 1.0, 10.0);
        let job = Job::new(0, "par", 0.0, vec![4.0, 4.0], &[]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 }); // [0,4]
        st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 0 }); // [4,8]
        st.advance_wall(2.0);
        let retimed = st.apply_straggle(0, 2.0, 2.0);
        // In-flight [0,4] at t=2: remaining 2 s doubles → finish 6.
        assert_eq!(retimed, vec![(TaskRef::new(0, 0), 6.0)]);
        assert_eq!(st.min_aft(TaskRef::new(0, 0)), 6.0);
        assert_eq!(st.faults.n_straggles, 1);
        // The queued task rolled back...
        assert_eq!(st.faults.n_requeued, 1);
        assert!(st.is_executable(TaskRef::new(0, 1)));
        assert_eq!(st.exec_ready(0), 6.0);
        st.validate().unwrap();
        // ...and re-books behind the stretched copy.
        let f = st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 0 });
        assert!((f - 10.0).abs() < 1e-12);
        st.validate().unwrap();
    }

    /// Faults on an already-down executor are no-ops, and booking onto a
    /// down executor is a hard programming error.
    #[test]
    fn faults_on_down_executor_are_noops() {
        let mut st = two_exec_state();
        st.apply_crash(0, 1.0, Some(5.0));
        assert_eq!(st.faults.n_crashes, 1);
        let out = st.apply_crash(0, 2.0, None);
        assert_eq!(out, crate::fault::RecoveryOutcome::default());
        assert_eq!(st.faults.n_crashes, 1, "duplicate crash ignored");
        assert!(st.apply_straggle(0, 3.0, 2.0).is_empty());
        assert_eq!(st.faults.n_straggles, 0);
        assert_eq!(st.down_since(0), Some(1.0), "original outage preserved");
    }

    #[test]
    #[should_panic(expected = "down executor")]
    fn apply_rejects_down_executor() {
        let mut st = two_exec_state();
        st.apply_crash(0, 0.5, None);
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
    }

    /// The enc-event log announces fault rollbacks so incremental
    /// consumers rebuild instead of patching stale state.
    #[test]
    fn recovery_pass_logs_invalidation() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        let cursor = st.enc_log_end();
        st.apply_crash(0, 1.0, Some(3.0));
        let evs = st.enc_events_since(cursor).unwrap();
        assert!(
            evs.iter().any(|e| matches!(e, EncEvent::Invalidated)),
            "{evs:?}"
        );
    }

    // ---- snapshot restore ---------------------------------------------

    /// Assert two states agree bitwise on everything a scheduler can
    /// observe (and on the bookkeeping the service reports).
    fn assert_states_bitwise_equal(a: &SimState, b: &SimState) {
        assert_eq!(a.wall.to_bits(), b.wall.to_bits());
        assert_eq!(a.horizon.to_bits(), b.horizon.to_bits());
        assert_eq!(a.n_assigned, b.n_assigned);
        assert_eq!(a.n_duplicates, b.n_duplicates);
        assert_eq!(a.faults, b.faults);
        assert_eq!(a.arrived, b.arrived);
        assert_eq!(a.assigned, b.assigned);
        assert_eq!(a.reexec, b.reexec);
        assert_eq!(a.executable(), b.executable());
        assert_eq!(a.jobs.len(), b.jobs.len());
        for j in 0..a.jobs.len() {
            assert_eq!(a.jobs[j].topo(), b.jobs[j].topo());
            assert_eq!(
                a.job_left_work(j).to_bits(),
                b.job_left_work(j).to_bits(),
                "left_work[{j}]"
            );
            assert_eq!(a.job_left_tasks(j), b.job_left_tasks(j));
            for n in 0..a.jobs[j].n_tasks() {
                let t = TaskRef::new(j, n);
                assert_eq!(a.min_aft(t).to_bits(), b.min_aft(t).to_bits());
                assert_eq!(a.rank_up[j][n].to_bits(), b.rank_up[j][n].to_bits());
                assert_eq!(a.rank_down[j][n].to_bits(), b.rank_down[j][n].to_bits());
                let (pa, pb) = (&a.placements[j][n], &b.placements[j][n]);
                assert_eq!(pa.len(), pb.len());
                for (x, y) in pa.iter().zip(pb) {
                    assert!(x.same_booking(y), "placement mismatch at ({j},{n})");
                }
            }
        }
        for k in 0..a.cluster.len() {
            assert_eq!(a.exec_available(k), b.exec_available(k));
            assert_eq!(a.down_since(k), b.down_since(k));
            assert_eq!(a.blackouts(k), b.blackouts(k));
            let (ta, tb) = (a.timeline(k).intervals(), b.timeline(k).intervals());
            assert_eq!(ta.len(), tb.len());
            for (x, y) in ta.iter().zip(tb) {
                assert_eq!(x.0.to_bits(), y.0.to_bits());
                assert_eq!(x.1.to_bits(), y.1.to_bits());
            }
            assert_eq!(a.exec_log[k].len(), b.exec_log[k].len());
            for ((t1, p1), (t2, p2)) in a.exec_log[k].iter().zip(&b.exec_log[k]) {
                assert_eq!(t1, t2);
                assert!(p1.same_booking(p2));
            }
        }
    }

    /// Snapshot → JSON text → restore is bitwise lossless, including
    /// after duplicates, crashes (with blackouts and a down executor),
    /// and straggles — and the restored state plans identically.
    #[test]
    fn snapshot_roundtrips_bitwise_through_text() {
        let mut cluster = Cluster::homogeneous(3, 1.0, 10.0);
        cluster.executors[1].speed = 2.0;
        cluster.executors[2].speed = 0.7;
        let j0 = Job::new(0, "chain", 0.0, vec![4.0, 6.0, 3.0], &[(0, 1, 20.0), (1, 2, 5.0)]);
        let j1 = Job::new(1, "late", 6.5, vec![2.0, 2.0], &[(0, 1, 1.0)]);
        let mut st = SimState::new(cluster, Workload::new(vec![j0, j1]));
        st.mark_arrived(0);
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        st.apply(
            TaskRef::new(0, 1),
            Allocation::Duplicate { exec: 1, parent: 0 },
        );
        st.advance_wall(3.0);
        st.apply_crash(0, 3.0, Some(9.0));
        st.apply_straggle(1, 3.0, 1.5);
        st.advance_wall(7.0);
        st.mark_arrived(1);
        st.apply(TaskRef::new(1, 0), Allocation::Direct { exec: 2 });
        st.validate().unwrap();

        let text = st.snapshot_json().to_string();
        let back = Json::parse(&text).unwrap();
        let cluster2 = {
            let mut c = Cluster::homogeneous(3, 1.0, 10.0);
            c.executors[1].speed = 2.0;
            c.executors[2].speed = 0.7;
            c
        };
        let restored = SimState::from_snapshot_json(cluster2, &back).unwrap();
        assert_states_bitwise_equal(&st, &restored);

        // Planning and applying from both states stays bit-identical.
        for t in st.executable().to_vec() {
            for k in 0..st.cluster.len() {
                if !st.exec_available(k) {
                    continue;
                }
                let (s1, f1) = st.plan_direct(t, k);
                let (s2, f2) = restored.plan_direct(t, k);
                assert_eq!(s1.to_bits(), s2.to_bits());
                assert_eq!(f1.to_bits(), f2.to_bits());
            }
        }
        let mut live = st.clone();
        let mut rest = restored;
        let t = live.executable()[0];
        let f1 = live.apply(t, Allocation::Direct { exec: 1 });
        let f2 = rest.apply(t, Allocation::Direct { exec: 1 });
        assert_eq!(f1.to_bits(), f2.to_bits());
        assert_states_bitwise_equal(&live, &rest);
    }

    /// Restoring against a cluster built from different flags fails
    /// loudly instead of silently diverging.
    #[test]
    fn snapshot_restore_rejects_mismatched_cluster() {
        let st = two_exec_state();
        let snap = st.snapshot_json();
        let wrong_count = Cluster::homogeneous(3, 1.0, 10.0);
        assert!(SimState::from_snapshot_json(wrong_count, &snap).is_err());
        let wrong_speed = Cluster::homogeneous(2, 1.0, 10.0);
        assert!(
            SimState::from_snapshot_json(wrong_speed, &snap).is_err(),
            "executor 1's speed differs"
        );
        let wrong_mode = {
            let mut c = Cluster::homogeneous(2, 1.0, 10.0);
            c.executors[1].speed = 2.0;
            c.with_sched_mode(SchedMode::GapAware)
        };
        assert!(SimState::from_snapshot_json(wrong_mode, &snap).is_err());
        let right = {
            let mut c = Cluster::homogeneous(2, 1.0, 10.0);
            c.executors[1].speed = 2.0;
            c
        };
        assert!(SimState::from_snapshot_json(right, &snap).is_ok());
    }

    #[test]
    fn gap_aware_duplicate_plans_match_apply() {
        let mut cluster = Cluster::homogeneous(2, 1.0, 10.0);
        cluster.executors[1].speed = 2.0;
        let cluster = cluster.with_sched_mode(SchedMode::GapAware);
        let job = Job::new(0, "chain", 0.0, vec![4.0, 6.0], &[(0, 1, 20.0)]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        let t1 = TaskRef::new(0, 1);
        let (_, (_, predicted)) = st.plan_duplicate(t1, 0, 1);
        let actual = st.apply(t1, Allocation::Duplicate { exec: 1, parent: 0 });
        assert!((predicted - actual).abs() < 1e-12);
        st.validate().unwrap();
    }
}
