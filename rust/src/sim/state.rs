//! Shared scheduling state: placements (with task duplication), executor
//! timelines, the executable frontier, and the paper's timing equations'
//! common building blocks (actual finish times, data-ready times).

use crate::cluster::Cluster;
use crate::dag::{ranks, Job, NodeId, TaskRef};
use crate::workload::Workload;

/// One scheduled copy of a task on an executor (a member of `R_{n_i}`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Placement {
    pub exec: usize,
    /// Actual start time (AST).
    pub start: f64,
    /// Actual finish time (AFT, Eq 1).
    pub finish: f64,
    /// True if this copy was created by DEFT's parent duplication.
    pub duplicate: bool,
}

/// A scheduler's allocation decision for one selected task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Allocation {
    /// Run the task on `exec` (EFT mode).
    Direct { exec: usize },
    /// First duplicate parent `parent` onto `exec`, then run the task there
    /// (CPEFT mode, Eq 9–10).
    Duplicate { exec: usize, parent: NodeId },
}

impl Allocation {
    pub fn exec(&self) -> usize {
        match *self {
            Allocation::Direct { exec } => exec,
            Allocation::Duplicate { exec, .. } => exec,
        }
    }
}

/// Everything a scheduler may observe, plus assignment bookkeeping.
#[derive(Debug, Clone)]
pub struct SimState {
    pub cluster: Cluster,
    pub jobs: Vec<Job>,
    /// Whether each job has arrived (continuous mode).
    pub arrived: Vec<bool>,
    /// Whether each task has been assigned (its primary copy scheduled).
    pub assigned: Vec<Vec<bool>>,
    /// All scheduled copies per task: `placements[job][node]` = `R_{n_i}`.
    pub placements: Vec<Vec<Vec<Placement>>>,
    /// Time each executor's timeline becomes free (append scheduling).
    pub exec_ready: Vec<f64>,
    /// Full per-executor schedule log for validation and reporting.
    pub exec_log: Vec<Vec<(TaskRef, Placement)>>,
    /// Current simulation wall time.
    pub wall: f64,
    /// max AFT over all scheduled copies — the running makespan horizon.
    pub horizon: f64,
    /// Cached rank_up per job (Eq 6, with cluster averages).
    pub rank_up: Vec<Vec<f64>>,
    /// Cached rank_down per job (Eq 7).
    pub rank_down: Vec<Vec<f64>>,
    /// Count of assigned tasks (primary copies).
    pub n_assigned: usize,
    /// Count of duplicated copies created.
    pub n_duplicates: usize,
    /// Incremental executable frontier (arrived ∧ unassigned ∧ parents all
    /// assigned), kept sorted for deterministic iteration.
    frontier: Vec<TaskRef>,
}

impl SimState {
    pub fn new(cluster: Cluster, workload: Workload) -> SimState {
        let v_avg = cluster.v_avg();
        let c_avg = cluster.c_avg();
        let jobs = workload.jobs;
        let rank_up: Vec<Vec<f64>> = jobs.iter().map(|j| ranks::rank_up(j, v_avg, c_avg)).collect();
        let rank_down: Vec<Vec<f64>> = jobs
            .iter()
            .map(|j| ranks::rank_down(j, v_avg, c_avg))
            .collect();
        let n_exec = cluster.len();
        SimState {
            arrived: vec![false; jobs.len()],
            assigned: jobs.iter().map(|j| vec![false; j.n_tasks()]).collect(),
            placements: jobs.iter().map(|j| vec![Vec::new(); j.n_tasks()]).collect(),
            exec_ready: vec![0.0; n_exec],
            exec_log: vec![Vec::new(); n_exec],
            wall: 0.0,
            horizon: 0.0,
            rank_up,
            rank_down,
            n_assigned: 0,
            n_duplicates: 0,
            frontier: Vec::new(),
            cluster,
            jobs,
        }
    }

    pub fn n_tasks_total(&self) -> usize {
        self.jobs.iter().map(|j| j.n_tasks()).sum()
    }

    pub fn task_compute(&self, t: TaskRef) -> f64 {
        self.jobs[t.job].tasks[t.node].compute
    }

    /// Dynamically add a job (plug-and-play service mode, where jobs are
    /// submitted over the wire instead of known up front). Returns its id.
    pub fn add_job(&mut self, mut job: Job) -> usize {
        let id = self.jobs.len();
        job.id = id;
        let v_avg = self.cluster.v_avg();
        let c_avg = self.cluster.c_avg();
        self.rank_up.push(ranks::rank_up(&job, v_avg, c_avg));
        self.rank_down.push(ranks::rank_down(&job, v_avg, c_avg));
        self.arrived.push(false);
        self.assigned.push(vec![false; job.n_tasks()]);
        self.placements.push(vec![Vec::new(); job.n_tasks()]);
        self.jobs.push(job);
        id
    }

    /// Mark a job as arrived and add its newly executable tasks to the
    /// frontier. Called by the engine on arrival events.
    pub fn mark_arrived(&mut self, job: usize) {
        if self.arrived[job] {
            return;
        }
        self.arrived[job] = true;
        for node in 0..self.jobs[job].n_tasks() {
            let t = TaskRef::new(job, node);
            if self.compute_executable(t) {
                self.frontier.push(t);
            }
        }
        self.frontier.sort_unstable();
    }

    /// Slow-path executability check (used to maintain the frontier).
    fn compute_executable(&self, t: TaskRef) -> bool {
        self.arrived[t.job]
            && !self.assigned[t.job][t.node]
            && self.jobs[t.job].parents[t.node]
                .iter()
                .all(|e| self.assigned[t.job][e.other])
    }

    /// The executable set `A_t` (paper notation): arrived, unassigned,
    /// every parent assigned. Sorted, deterministic.
    pub fn executable(&self) -> &[TaskRef] {
        &self.frontier
    }

    pub fn is_executable(&self, t: TaskRef) -> bool {
        self.frontier.binary_search(&t).is_ok()
    }

    /// Earliest finish time among a task's scheduled copies
    /// (`min_{r_k ∈ R_{n_p}} AFT(n_p, r_k)`; ∞ if unassigned).
    pub fn min_aft(&self, t: TaskRef) -> f64 {
        self.placements[t.job][t.node]
            .iter()
            .map(|p| p.finish)
            .fold(f64::INFINITY, f64::min)
    }

    /// Has the task's earliest copy finished by the current wall time?
    pub fn is_finished(&self, t: TaskRef) -> bool {
        self.min_aft(t) <= self.wall
    }

    /// Earliest time parent `p`'s output data can be available on executor
    /// `exec` (Eq 9's AFTC): min over parent copies of copy AFT + transfer.
    pub fn parent_data_at(&self, child: TaskRef, parent: NodeId, exec: usize) -> f64 {
        let p = TaskRef::new(child.job, parent);
        let edge = self.jobs[child.job].edge_data(parent, child.node);
        self.placements[p.job][p.node]
            .iter()
            .map(|pl| pl.finish + self.cluster.transfer_time(edge, pl.exec, exec))
            .fold(f64::INFINITY, f64::min)
    }

    /// Earliest time *all* of a task's input data is available on `exec`
    /// (the inner max of Eq 2). Job arrival bounds entry tasks.
    pub fn data_ready(&self, t: TaskRef, exec: usize) -> f64 {
        let job = &self.jobs[t.job];
        let mut ready = job.arrival;
        for e in &job.parents[t.node] {
            let avail = self.parent_data_at(t, e.other, exec);
            if avail > ready {
                ready = avail;
            }
        }
        ready
    }

    /// Remaining (unassigned) task count of a job.
    pub fn job_left_tasks(&self, job: usize) -> usize {
        self.assigned[job].iter().filter(|&&a| !a).count()
    }

    /// Remaining (unassigned) work of a job, in GHz·s.
    pub fn job_left_work(&self, job: usize) -> f64 {
        self.assigned[job]
            .iter()
            .enumerate()
            .filter(|(_, &a)| !a)
            .map(|(n, _)| self.jobs[job].tasks[n].compute)
            .sum()
    }

    pub fn all_assigned(&self) -> bool {
        self.n_assigned == self.n_tasks_total()
    }

    /// Apply an allocation decision for `task`. Returns the task's finish
    /// time (its completion event time). Panics if `task` is not
    /// executable or `alloc` is invalid — schedulers must only emit legal
    /// decisions; the engine relies on this invariant.
    pub fn apply(&mut self, task: TaskRef, alloc: Allocation) -> f64 {
        assert!(
            self.is_executable(task),
            "scheduler selected non-executable task {task:?}"
        );
        let exec = alloc.exec();
        assert!(exec < self.cluster.len(), "executor {exec} out of range");
        let arrival = self.jobs[task.job].arrival;

        if let Allocation::Duplicate { parent, .. } = alloc {
            assert!(
                self.jobs[task.job].parents[task.node]
                    .iter()
                    .any(|e| e.other == parent),
                "duplicate of non-parent node {parent}"
            );
            // Re-execute the parent on `exec`: it needs its own inputs
            // there, plus the executor slot.
            let p = TaskRef::new(task.job, parent);
            let p_data = self.data_ready(p, exec);
            let start = p_data
                .max(self.exec_ready[exec])
                .max(self.wall)
                .max(arrival);
            let finish = start + self.task_compute(p) / self.cluster.speed(exec);
            let pl = Placement {
                exec,
                start,
                finish,
                duplicate: true,
            };
            self.placements[p.job][p.node].push(pl);
            self.exec_ready[exec] = finish;
            self.exec_log[exec].push((p, pl));
            self.n_duplicates += 1;
            if finish > self.horizon {
                self.horizon = finish;
            }
        }

        // Primary copy of the selected task.
        let data = self.data_ready(task, exec);
        let start = data
            .max(self.exec_ready[exec])
            .max(self.wall)
            .max(arrival);
        let finish = start + self.task_compute(task) / self.cluster.speed(exec);
        let pl = Placement {
            exec,
            start,
            finish,
            duplicate: false,
        };
        self.placements[task.job][task.node].push(pl);
        self.exec_ready[exec] = finish;
        self.exec_log[exec].push((task, pl));
        self.assigned[task.job][task.node] = true;
        self.n_assigned += 1;
        if finish > self.horizon {
            self.horizon = finish;
        }

        // Frontier maintenance: remove `task`, add children that became
        // executable.
        if let Ok(idx) = self.frontier.binary_search(&task) {
            self.frontier.remove(idx);
        }
        let child_ids: Vec<NodeId> = self.jobs[task.job].children[task.node]
            .iter()
            .map(|e| e.other)
            .collect();
        for c in child_ids {
            let cref = TaskRef::new(task.job, c);
            if self.compute_executable(cref) {
                if let Err(idx) = self.frontier.binary_search(&cref) {
                    self.frontier.insert(idx, cref);
                }
            }
        }
        finish
    }

    /// Completion time of a job: max AFT over primary copies (∞ until all
    /// assigned).
    pub fn job_completion(&self, job: usize) -> f64 {
        let mut t = 0.0f64;
        for node in 0..self.jobs[job].n_tasks() {
            if !self.assigned[job][node] {
                return f64::INFINITY;
            }
            // Primary (non-duplicate) copy finish.
            let f = self.placements[job][node]
                .iter()
                .filter(|p| !p.duplicate)
                .map(|p| p.finish)
                .fold(f64::NEG_INFINITY, f64::max);
            if f > t {
                t = f;
            }
        }
        t
    }

    /// Validate executor timelines: no overlapping intervals on any
    /// executor, every start ≥ job arrival, every child starts after the
    /// copy of each parent it could have read from. Used by tests and the
    /// `--validate` flag.
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::bail;
        for (e, log) in self.exec_log.iter().enumerate() {
            let mut sorted = log.clone();
            sorted.sort_by(|a, b| a.1.start.partial_cmp(&b.1.start).unwrap());
            for w in sorted.windows(2) {
                if w[1].1.start < w[0].1.finish - 1e-9 {
                    bail!(
                        "executor {e}: overlap {:?}@{:.3}-{:.3} vs {:?}@{:.3}",
                        w[0].0,
                        w[0].1.start,
                        w[0].1.finish,
                        w[1].0,
                        w[1].1.start
                    );
                }
            }
        }
        for (ji, job) in self.jobs.iter().enumerate() {
            for node in 0..job.n_tasks() {
                for pl in &self.placements[ji][node] {
                    if pl.start + 1e-9 < job.arrival {
                        bail!("task ({ji},{node}) starts before its job arrives");
                    }
                    // Data-readiness: the copy must not start before every
                    // parent's data could be at pl.exec.
                    for edge in &job.parents[node] {
                        let avail =
                            self.parent_data_at(TaskRef::new(ji, node), edge.other, pl.exec);
                        if pl.start + 1e-6 < avail {
                            bail!(
                                "task ({ji},{node}) on exec {} starts {:.4} before parent {} data at {:.4}",
                                pl.exec,
                                pl.start,
                                edge.other,
                                avail
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::dag::Job;
    use crate::workload::Workload;

    fn two_exec_state() -> SimState {
        // speeds 1.0 and 2.0, comm 10 MB/s
        let mut cluster = Cluster::homogeneous(2, 1.0, 10.0);
        cluster.executors[1].speed = 2.0;
        // chain 0 -> 1 with 20 MB edge; w = [4, 6]
        let job = Job::new(0, "chain", 0.0, vec![4.0, 6.0], &[(0, 1, 20.0)]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st
    }

    #[test]
    fn frontier_starts_with_entries() {
        let st = two_exec_state();
        assert_eq!(st.executable(), &[TaskRef::new(0, 0)]);
        assert!(!st.is_executable(TaskRef::new(0, 1)));
    }

    #[test]
    fn apply_direct_chain_accounts_comm() {
        let mut st = two_exec_state();
        let t0 = TaskRef::new(0, 0);
        let f0 = st.apply(t0, Allocation::Direct { exec: 0 });
        assert!((f0 - 4.0).abs() < 1e-12); // 4 / 1.0
        assert!(st.is_executable(TaskRef::new(0, 1)));
        // child on other executor: data ready at 4 + 20/10 = 6; run 6/2 = 3.
        let f1 = st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 1 });
        assert!((f1 - 9.0).abs() < 1e-12);
        assert!((st.horizon - 9.0).abs() < 1e-12);
        st.validate().unwrap();
    }

    #[test]
    fn apply_same_executor_no_comm() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 1 });
        // f0 = 4/2 = 2; child same exec: no comm, start at max(2, ready=2)
        let f1 = st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 1 });
        assert!((f1 - (2.0 + 3.0)).abs() < 1e-12);
        st.validate().unwrap();
    }

    #[test]
    fn apply_duplicate_parent() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 }); // AFT 4 on e0
        // Duplicate parent 0 onto e1, then run child there:
        // dup start 0, dup finish 4/2 = 2; child start max(2, data local) = 2,
        // finish 2 + 3 = 5. Better than the 9.0 of the cross-exec path.
        let f1 = st.apply(
            TaskRef::new(0, 1),
            Allocation::Duplicate { exec: 1, parent: 0 },
        );
        assert!((f1 - 5.0).abs() < 1e-12, "f1={f1}");
        assert_eq!(st.n_duplicates, 1);
        assert_eq!(st.placements[0][0].len(), 2);
        st.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "non-executable")]
    fn apply_rejects_non_executable() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 0 });
    }

    #[test]
    fn wall_time_lower_bounds_start() {
        let mut st = two_exec_state();
        st.wall = 100.0;
        let f = st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 1 });
        assert!((f - 102.0).abs() < 1e-12);
    }

    #[test]
    fn job_completion_ignores_duplicates() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        st.apply(
            TaskRef::new(0, 1),
            Allocation::Duplicate { exec: 1, parent: 0 },
        );
        // Completion = child primary finish (5.0), not the dup copy's.
        assert!((st.job_completion(0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn unarrived_jobs_not_executable() {
        let cluster = Cluster::homogeneous(1, 1.0, 10.0);
        let job = Job::new(0, "late", 50.0, vec![1.0], &[]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        assert!(st.executable().is_empty());
        st.mark_arrived(0);
        assert_eq!(st.executable().len(), 1);
        // Even though wall=0, start must respect arrival.
        let f = st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        assert!((f - 51.0).abs() < 1e-12);
    }
}
