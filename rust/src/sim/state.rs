//! Shared scheduling state: placements (with task duplication), the
//! paper's timing equations' common building blocks (actual finish times,
//! data-ready times), and the composition of the two incremental
//! subsystems — per-executor [`Timeline`]s and the executable
//! [`Frontier`] — plus O(1) caches for the quantities schedulers and the
//! policy featurizer probe on every decision (`min_aft`, per-job
//! `left_tasks`/`left_work`, cluster-average transfer terms).

use super::frontier::Frontier;
use super::timeline::Timeline;
use crate::cluster::Cluster;
use crate::config::SchedMode;
use crate::dag::{ranks, Job, NodeId, TaskRef};
use crate::workload::Workload;

/// One scheduled copy of a task on an executor (a member of `R_{n_i}`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Placement {
    pub exec: usize,
    /// Actual start time (AST).
    pub start: f64,
    /// Actual finish time (AFT, Eq 1).
    pub finish: f64,
    /// True if this copy was created by DEFT's parent duplication.
    pub duplicate: bool,
}

/// A scheduler's allocation decision for one selected task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Allocation {
    /// Run the task on `exec` (EFT mode).
    Direct { exec: usize },
    /// First duplicate parent `parent` onto `exec`, then run the task there
    /// (CPEFT mode, Eq 9–10).
    Duplicate { exec: usize, parent: NodeId },
}

impl Allocation {
    pub fn exec(&self) -> usize {
        match *self {
            Allocation::Direct { exec } => exec,
            Allocation::Duplicate { exec, .. } => exec,
        }
    }
}

/// One encoder-visible state mutation, appended to the state's event
/// log ([`SimState::enc_events_since`]) in order. These are the
/// dirty-tracking hooks incremental consumers
/// (e.g. [`crate::policy::EncoderCache`]) replay instead of re-deriving
/// the whole encoding: an assignment removes exactly one slot and moves
/// one job's counters, a booking schedules a future parent-finished flip,
/// an arrival adds a job's tasks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EncEvent {
    /// `task`'s primary copy was scheduled: it leaves the encoding, its
    /// children's `executable` feature may flip, and its job's
    /// `left_tasks`/`left_work` counters moved.
    Assigned { task: TaskRef },
    /// A copy of `task` (primary or DEFT duplicate) was booked finishing
    /// at `finish`: children's finished-parent fraction flips once the
    /// wall clock passes `finish`.
    Booked { task: TaskRef, finish: f64 },
    /// A job arrived: its unassigned tasks enter the encoding.
    Arrived { job: usize },
}

/// Everything a scheduler may observe, plus assignment bookkeeping.
#[derive(Debug, Clone)]
pub struct SimState {
    pub cluster: Cluster,
    pub jobs: Vec<Job>,
    /// Whether each job has arrived (continuous mode).
    pub arrived: Vec<bool>,
    /// Whether each task has been assigned (its primary copy scheduled).
    pub assigned: Vec<Vec<bool>>,
    /// All scheduled copies per task: `placements[job][node]` = `R_{n_i}`.
    pub placements: Vec<Vec<Vec<Placement>>>,
    /// Full per-executor schedule log for validation and reporting.
    pub exec_log: Vec<Vec<(TaskRef, Placement)>>,
    /// Current simulation wall time.
    pub wall: f64,
    /// max AFT over all scheduled copies — the running makespan horizon.
    pub horizon: f64,
    /// Cached rank_up per job (Eq 6, with cluster averages).
    pub rank_up: Vec<Vec<f64>>,
    /// Cached rank_down per job (Eq 7).
    pub rank_down: Vec<Vec<f64>>,
    /// Count of assigned tasks (primary copies).
    pub n_assigned: usize,
    /// Count of duplicated copies created.
    pub n_duplicates: usize,
    /// Executor-time booking mode, threaded from the cluster config.
    pub sched_mode: SchedMode,
    /// Per-executor busy-interval timelines (replace the old append-only
    /// `exec_ready` scalars).
    timelines: Vec<Timeline>,
    /// Incremental executable-set tracker.
    frontier: Frontier,
    /// `min_aft_cache[job][node]` — earliest finish over scheduled copies
    /// (∞ while unscheduled), min-updated on every booking.
    min_aft_cache: Vec<Vec<f64>>,
    /// Remaining unassigned task count per job.
    left_tasks: Vec<usize>,
    /// Remaining unassigned work per job, GHz·s.
    left_work: Vec<f64>,
    /// Memoized cluster averages (the cluster is immutable after
    /// construction; `Cluster::v_avg` is an O(M) scan).
    v_avg: f64,
    c_avg: f64,
    /// Log of encoder-visible mutations (see [`EncEvent`]). Consumers
    /// keep an *absolute* cursor; the buffer auto-compacts beyond
    /// [`ENC_LOG_COMPACT_THRESHOLD`] so a months-long service state stays
    /// bounded — a consumer whose cursor predates the compacted range
    /// gets `None` from [`SimState::enc_events_since`] and rebuilds.
    enc_log: Vec<EncEvent>,
    /// Absolute position of `enc_log[0]` (grows on compaction).
    enc_log_start: u64,
}

/// Keep at most this many encoder events buffered; beyond it the oldest
/// half is dropped. Large enough that a per-decision consumer (cursor at
/// the tail) never rebuilds because of compaction, small enough to bound
/// long-running service states.
pub const ENC_LOG_COMPACT_THRESHOLD: usize = 4096;

impl SimState {
    pub fn new(cluster: Cluster, workload: Workload) -> SimState {
        let v_avg = cluster.v_avg();
        let c_avg = cluster.c_avg();
        let jobs = workload.jobs;
        let rank_up: Vec<Vec<f64>> = jobs.iter().map(|j| ranks::rank_up(j, v_avg, c_avg)).collect();
        let rank_down: Vec<Vec<f64>> = jobs
            .iter()
            .map(|j| ranks::rank_down(j, v_avg, c_avg))
            .collect();
        let n_exec = cluster.len();
        let mut frontier = Frontier::new();
        for job in &jobs {
            frontier.add_job(job);
        }
        SimState {
            arrived: vec![false; jobs.len()],
            assigned: jobs.iter().map(|j| vec![false; j.n_tasks()]).collect(),
            placements: jobs.iter().map(|j| vec![Vec::new(); j.n_tasks()]).collect(),
            exec_log: vec![Vec::new(); n_exec],
            wall: 0.0,
            horizon: 0.0,
            rank_up,
            rank_down,
            n_assigned: 0,
            n_duplicates: 0,
            sched_mode: cluster.sched_mode,
            timelines: vec![Timeline::new(); n_exec],
            frontier,
            min_aft_cache: jobs
                .iter()
                .map(|j| vec![f64::INFINITY; j.n_tasks()])
                .collect(),
            left_tasks: jobs.iter().map(|j| j.n_tasks()).collect(),
            left_work: jobs.iter().map(|j| j.total_work()).collect(),
            v_avg,
            c_avg,
            enc_log: Vec::new(),
            enc_log_start: 0,
            cluster,
            jobs,
        }
    }

    /// Absolute end position of the encoder-event log (the cursor a
    /// fully caught-up consumer holds).
    pub fn enc_log_end(&self) -> u64 {
        self.enc_log_start + self.enc_log.len() as u64
    }

    /// The encoder-visible mutations at absolute positions
    /// `[cursor, enc_log_end())` — the dirty-tracking hook driving
    /// [`crate::policy::EncoderCache`]. Returns `None` when `cursor`
    /// predates the compacted range (or belongs to a different state):
    /// the consumer must rebuild from the live state instead of
    /// replaying.
    pub fn enc_events_since(&self, cursor: u64) -> Option<&[EncEvent]> {
        if cursor < self.enc_log_start {
            return None;
        }
        let rel = (cursor - self.enc_log_start) as usize;
        if rel > self.enc_log.len() {
            return None;
        }
        Some(&self.enc_log[rel..])
    }

    /// Drop the oldest half of the encoder-event buffer. Called
    /// automatically past [`ENC_LOG_COMPACT_THRESHOLD`]; exposed for
    /// long-running services that want tighter bounds.
    pub fn compact_enc_log(&mut self) {
        let drop = self.enc_log.len() / 2;
        self.enc_log.drain(..drop);
        self.enc_log_start += drop as u64;
    }

    fn push_enc_event(&mut self, ev: EncEvent) {
        if self.enc_log.len() >= ENC_LOG_COMPACT_THRESHOLD {
            self.compact_enc_log();
        }
        self.enc_log.push(ev);
    }

    pub fn n_tasks_total(&self) -> usize {
        self.jobs.iter().map(|j| j.n_tasks()).sum()
    }

    pub fn task_compute(&self, t: TaskRef) -> f64 {
        self.jobs[t.job].tasks[t.node].compute
    }

    /// Memoized mean executor speed `v̄`.
    pub fn v_avg(&self) -> f64 {
        self.v_avg
    }

    /// Memoized average inter-executor transmission speed `c̄`.
    pub fn c_avg(&self) -> f64 {
        self.c_avg
    }

    /// Append-mode ready time of an executor (the old `exec_ready`
    /// scalar): when its timeline goes idle forever.
    pub fn exec_ready(&self, exec: usize) -> f64 {
        self.timelines[exec].tail()
    }

    /// The executor's full busy-interval timeline.
    pub fn timeline(&self, exec: usize) -> &Timeline {
        &self.timelines[exec]
    }

    /// Dynamically add a job (plug-and-play service mode, where jobs are
    /// submitted over the wire instead of known up front). Returns its id.
    pub fn add_job(&mut self, mut job: Job) -> usize {
        let id = self.jobs.len();
        job.id = id;
        self.rank_up.push(ranks::rank_up(&job, self.v_avg, self.c_avg));
        self.rank_down
            .push(ranks::rank_down(&job, self.v_avg, self.c_avg));
        self.arrived.push(false);
        self.assigned.push(vec![false; job.n_tasks()]);
        self.placements.push(vec![Vec::new(); job.n_tasks()]);
        self.min_aft_cache.push(vec![f64::INFINITY; job.n_tasks()]);
        self.left_tasks.push(job.n_tasks());
        self.left_work.push(job.total_work());
        self.frontier.add_job(&job);
        self.jobs.push(job);
        id
    }

    /// Monotonically advance the wall clock: time never moves backwards,
    /// even if a caller (service heartbeat, schedule poll, out-of-order
    /// event) reports a stale timestamp.
    pub fn advance_wall(&mut self, time: f64) {
        if time > self.wall {
            self.wall = time;
        }
    }

    /// Number of jobs added but not yet arrived — in service mode, the
    /// future-dated submissions still waiting for the wall clock to
    /// reach their arrival time.
    pub fn n_unarrived(&self) -> usize {
        self.arrived.iter().filter(|&&a| !a).count()
    }

    /// Mark a job as arrived and add its newly executable tasks to the
    /// frontier. Called by the engine on arrival events.
    pub fn mark_arrived(&mut self, job: usize) {
        if self.arrived[job] {
            return;
        }
        self.arrived[job] = true;
        self.frontier.activate_job(job);
        self.push_enc_event(EncEvent::Arrived { job });
    }

    /// The executable set `A_t` (paper notation): arrived, unassigned,
    /// every parent assigned. Sorted, deterministic, maintained
    /// incrementally by the [`Frontier`].
    pub fn executable(&self) -> &[TaskRef] {
        self.frontier.items()
    }

    pub fn is_executable(&self, t: TaskRef) -> bool {
        self.frontier.contains(t)
    }

    /// Recompute the executable set from scratch (the pre-refactor
    /// definition). Used by `validate` and the property tests to pin the
    /// incremental frontier to its scan-based meaning.
    pub fn executable_scan(&self) -> Vec<TaskRef> {
        let mut out = Vec::new();
        for (ji, job) in self.jobs.iter().enumerate() {
            if !self.arrived[ji] {
                continue;
            }
            for node in 0..job.n_tasks() {
                if !self.assigned[ji][node]
                    && job.parents[node].iter().all(|e| self.assigned[ji][e.other])
                {
                    out.push(TaskRef::new(ji, node));
                }
            }
        }
        out
    }

    /// Earliest finish time among a task's scheduled copies
    /// (`min_{r_k ∈ R_{n_p}} AFT(n_p, r_k)`; ∞ if unassigned). O(1) from
    /// the incremental cache.
    pub fn min_aft(&self, t: TaskRef) -> f64 {
        self.min_aft_cache[t.job][t.node]
    }

    /// Scan-based `min_aft` definition (for validation).
    pub fn min_aft_scan(&self, t: TaskRef) -> f64 {
        self.placements[t.job][t.node]
            .iter()
            .map(|p| p.finish)
            .fold(f64::INFINITY, f64::min)
    }

    /// Has the task's earliest copy finished by the current wall time?
    pub fn is_finished(&self, t: TaskRef) -> bool {
        self.min_aft(t) <= self.wall
    }

    /// Earliest time parent `p`'s output data can be available on executor
    /// `exec` (Eq 9's AFTC): min over parent copies of copy AFT + transfer.
    pub fn parent_data_at(&self, child: TaskRef, parent: NodeId, exec: usize) -> f64 {
        let p = TaskRef::new(child.job, parent);
        let edge = self.jobs[child.job].edge_data(parent, child.node);
        self.placements[p.job][p.node]
            .iter()
            .map(|pl| pl.finish + self.cluster.transfer_time(edge, pl.exec, exec))
            .fold(f64::INFINITY, f64::min)
    }

    /// Earliest time *all* of a task's input data is available on `exec`
    /// (the inner max of Eq 2). Job arrival bounds entry tasks.
    pub fn data_ready(&self, t: TaskRef, exec: usize) -> f64 {
        let job = &self.jobs[t.job];
        let mut ready = job.arrival;
        for e in &job.parents[t.node] {
            let avail = self.parent_data_at(t, e.other, exec);
            if avail > ready {
                ready = avail;
            }
        }
        ready
    }

    /// Lower bound on a task's start on `exec` independent of executor
    /// availability: data readiness, the wall clock, and the job arrival
    /// (the online constraints of Eq 2).
    pub fn ready_time(&self, t: TaskRef, exec: usize) -> f64 {
        self.data_ready(t, exec)
            .max(self.wall)
            .max(self.jobs[t.job].arrival)
    }

    /// Plan the primary copy of `task` on `exec` without committing:
    /// `(start, finish)` under the state's booking mode. `apply` uses the
    /// same plan, so an allocator's predicted finish always matches the
    /// committed one.
    pub fn plan_direct(&self, task: TaskRef, exec: usize) -> (f64, f64) {
        let ready = self.ready_time(task, exec);
        let dur = self.task_compute(task) / self.cluster.speed(exec);
        let start = self.timelines[exec].earliest_start(ready, dur, self.sched_mode);
        (start, start + dur)
    }

    /// Plan duplicating `parent` onto `exec` and then running `task` there
    /// (Eq 9–10): returns `((dup_start, dup_finish), (start, finish))`.
    ///
    /// The duplicate waits for its own inputs and an executor slot; the
    /// task then starts no earlier than the duplicate's finish (the copy
    /// holds the executor and makes the parent's output local) and the
    /// other parents' data arrivals. Because the task's ready time is ≥
    /// the duplicate's finish, planning both against the pre-booking
    /// timeline cannot produce overlapping slots, in either booking mode.
    pub fn plan_duplicate(
        &self,
        task: TaskRef,
        parent: NodeId,
        exec: usize,
    ) -> ((f64, f64), (f64, f64)) {
        let p = TaskRef::new(task.job, parent);
        let (dup_start, dup_finish) = self.plan_direct(p, exec);
        let mut ready = dup_finish;
        for e in &self.jobs[task.job].parents[task.node] {
            if e.other == parent {
                continue;
            }
            let avail = self.parent_data_at(task, e.other, exec);
            if avail > ready {
                ready = avail;
            }
        }
        let dur = self.task_compute(task) / self.cluster.speed(exec);
        let start = self.timelines[exec].earliest_start(ready, dur, self.sched_mode);
        ((dup_start, dup_finish), (start, start + dur))
    }

    /// Remaining (unassigned) task count of a job. O(1) from the counter.
    pub fn job_left_tasks(&self, job: usize) -> usize {
        self.left_tasks[job]
    }

    /// Remaining (unassigned) work of a job, in GHz·s. O(1) from the
    /// counter (clamped against float drift from repeated subtraction).
    pub fn job_left_work(&self, job: usize) -> f64 {
        self.left_work[job].max(0.0)
    }

    /// Scan-based `job_left_tasks` definition (for validation).
    pub fn job_left_tasks_scan(&self, job: usize) -> usize {
        self.assigned[job].iter().filter(|&&a| !a).count()
    }

    /// Scan-based `job_left_work` definition (for validation).
    pub fn job_left_work_scan(&self, job: usize) -> f64 {
        self.assigned[job]
            .iter()
            .enumerate()
            .filter(|(_, &a)| !a)
            .map(|(n, _)| self.jobs[job].tasks[n].compute)
            .sum()
    }

    pub fn all_assigned(&self) -> bool {
        self.n_assigned == self.n_tasks_total()
    }

    /// Commit one booked copy: placement list, timeline, log, and the
    /// min-AFT / horizon caches.
    fn book(&mut self, t: TaskRef, exec: usize, start: f64, finish: f64, duplicate: bool) {
        let pl = Placement {
            exec,
            start,
            finish,
            duplicate,
        };
        self.placements[t.job][t.node].push(pl);
        self.timelines[exec].book(start, finish);
        self.exec_log[exec].push((t, pl));
        if finish < self.min_aft_cache[t.job][t.node] {
            self.min_aft_cache[t.job][t.node] = finish;
        }
        if finish > self.horizon {
            self.horizon = finish;
        }
        if duplicate {
            self.n_duplicates += 1;
        }
        self.push_enc_event(EncEvent::Booked { task: t, finish });
    }

    /// Apply an allocation decision for `task`. Returns the task's finish
    /// time (its completion event time). Panics if `task` is not
    /// executable or `alloc` is invalid — schedulers must only emit legal
    /// decisions; the engine relies on this invariant.
    pub fn apply(&mut self, task: TaskRef, alloc: Allocation) -> f64 {
        assert!(
            self.is_executable(task),
            "scheduler selected non-executable task {task:?}"
        );
        let exec = alloc.exec();
        assert!(exec < self.cluster.len(), "executor {exec} out of range");

        let finish = match alloc {
            Allocation::Duplicate { parent, .. } => {
                assert!(
                    self.jobs[task.job].parents[task.node]
                        .iter()
                        .any(|e| e.other == parent),
                    "duplicate of non-parent node {parent}"
                );
                let (dup, primary) = self.plan_duplicate(task, parent, exec);
                let p = TaskRef::new(task.job, parent);
                self.book(p, exec, dup.0, dup.1, true);
                self.book(task, exec, primary.0, primary.1, false);
                primary.1
            }
            Allocation::Direct { .. } => {
                let (start, finish) = self.plan_direct(task, exec);
                self.book(task, exec, start, finish, false);
                finish
            }
        };

        // Assignment bookkeeping: flags, per-job counters, frontier.
        self.assigned[task.job][task.node] = true;
        self.n_assigned += 1;
        self.left_tasks[task.job] -= 1;
        self.left_work[task.job] -= self.task_compute(task);
        self.frontier.assign(&self.jobs[task.job], task);
        self.push_enc_event(EncEvent::Assigned { task });
        finish
    }

    /// Completion time of a job: max AFT over primary copies (∞ until all
    /// assigned).
    pub fn job_completion(&self, job: usize) -> f64 {
        let mut t = 0.0f64;
        for node in 0..self.jobs[job].n_tasks() {
            if !self.assigned[job][node] {
                return f64::INFINITY;
            }
            // Primary (non-duplicate) copy finish.
            let f = self.placements[job][node]
                .iter()
                .filter(|p| !p.duplicate)
                .map(|p| p.finish)
                .fold(f64::NEG_INFINITY, f64::max);
            if f > t {
                t = f;
            }
        }
        t
    }

    /// Validate the composed state: no overlapping intervals on any
    /// executor, every start ≥ job arrival, every child starts after the
    /// copy of each parent it could have read from, the executor
    /// timelines agree with the schedule log, and every incremental cache
    /// (frontier, `min_aft`, per-job counters) equals its scan-based
    /// definition. Used by tests and the `--validate` flag.
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::bail;
        for (e, log) in self.exec_log.iter().enumerate() {
            let mut sorted = log.clone();
            sorted.sort_by(|a, b| a.1.start.total_cmp(&b.1.start));
            for w in sorted.windows(2) {
                if w[1].1.start < w[0].1.finish - 1e-9 {
                    bail!(
                        "executor {e}: overlap {:?}@{:.3}-{:.3} vs {:?}@{:.3}",
                        w[0].0,
                        w[0].1.start,
                        w[0].1.finish,
                        w[1].0,
                        w[1].1.start
                    );
                }
            }
            // The timeline must be exactly the sorted log intervals.
            let tl = self.timelines[e].intervals();
            if tl.len() != sorted.len() {
                bail!(
                    "executor {e}: timeline has {} intervals, log has {}",
                    tl.len(),
                    sorted.len()
                );
            }
            for (iv, (_, pl)) in tl.iter().zip(&sorted) {
                if (iv.0 - pl.start).abs() > 1e-9 || (iv.1 - pl.finish).abs() > 1e-9 {
                    bail!(
                        "executor {e}: timeline interval {:.4}-{:.4} != log {:.4}-{:.4}",
                        iv.0,
                        iv.1,
                        pl.start,
                        pl.finish
                    );
                }
            }
        }
        for (ji, job) in self.jobs.iter().enumerate() {
            for node in 0..job.n_tasks() {
                for pl in &self.placements[ji][node] {
                    if pl.start + 1e-9 < job.arrival {
                        bail!("task ({ji},{node}) starts before its job arrives");
                    }
                    // Data-readiness: the copy must not start before every
                    // parent's data could be at pl.exec.
                    for edge in &job.parents[node] {
                        let avail =
                            self.parent_data_at(TaskRef::new(ji, node), edge.other, pl.exec);
                        if pl.start + 1e-6 < avail {
                            bail!(
                                "task ({ji},{node}) on exec {} starts {:.4} before parent {} data at {:.4}",
                                pl.exec,
                                pl.start,
                                edge.other,
                                avail
                            );
                        }
                    }
                }
                let t = TaskRef::new(ji, node);
                let cached = self.min_aft(t);
                let scanned = self.min_aft_scan(t);
                if cached != scanned && !(cached.is_infinite() && scanned.is_infinite()) {
                    bail!("task ({ji},{node}): min_aft cache {cached} != scan {scanned}");
                }
            }
            if self.job_left_tasks(ji) != self.job_left_tasks_scan(ji) {
                bail!(
                    "job {ji}: left_tasks counter {} != scan {}",
                    self.job_left_tasks(ji),
                    self.job_left_tasks_scan(ji)
                );
            }
            let (lw, lws) = (self.job_left_work(ji), self.job_left_work_scan(ji));
            if (lw - lws).abs() > 1e-6 * (1.0 + lws.abs()) {
                bail!("job {ji}: left_work counter {lw} != scan {lws}");
            }
        }
        if self.frontier.items() != self.executable_scan().as_slice() {
            bail!(
                "frontier {:?} != scan {:?}",
                self.frontier.items(),
                self.executable_scan()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::dag::Job;
    use crate::workload::Workload;

    fn two_exec_state() -> SimState {
        // speeds 1.0 and 2.0, comm 10 MB/s
        let mut cluster = Cluster::homogeneous(2, 1.0, 10.0);
        cluster.executors[1].speed = 2.0;
        // chain 0 -> 1 with 20 MB edge; w = [4, 6]
        let job = Job::new(0, "chain", 0.0, vec![4.0, 6.0], &[(0, 1, 20.0)]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st
    }

    #[test]
    fn frontier_starts_with_entries() {
        let st = two_exec_state();
        assert_eq!(st.executable(), &[TaskRef::new(0, 0)]);
        assert!(!st.is_executable(TaskRef::new(0, 1)));
    }

    #[test]
    fn apply_direct_chain_accounts_comm() {
        let mut st = two_exec_state();
        let t0 = TaskRef::new(0, 0);
        let f0 = st.apply(t0, Allocation::Direct { exec: 0 });
        assert!((f0 - 4.0).abs() < 1e-12); // 4 / 1.0
        assert!(st.is_executable(TaskRef::new(0, 1)));
        // child on other executor: data ready at 4 + 20/10 = 6; run 6/2 = 3.
        let f1 = st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 1 });
        assert!((f1 - 9.0).abs() < 1e-12);
        assert!((st.horizon - 9.0).abs() < 1e-12);
        st.validate().unwrap();
    }

    #[test]
    fn apply_same_executor_no_comm() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 1 });
        // f0 = 4/2 = 2; child same exec: no comm, start at max(2, ready=2)
        let f1 = st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 1 });
        assert!((f1 - (2.0 + 3.0)).abs() < 1e-12);
        st.validate().unwrap();
    }

    #[test]
    fn apply_duplicate_parent() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 }); // AFT 4 on e0
        // Duplicate parent 0 onto e1, then run child there:
        // dup start 0, dup finish 4/2 = 2; child start max(2, data local) = 2,
        // finish 2 + 3 = 5. Better than the 9.0 of the cross-exec path.
        let f1 = st.apply(
            TaskRef::new(0, 1),
            Allocation::Duplicate { exec: 1, parent: 0 },
        );
        assert!((f1 - 5.0).abs() < 1e-12, "f1={f1}");
        assert_eq!(st.n_duplicates, 1);
        assert_eq!(st.placements[0][0].len(), 2);
        st.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "non-executable")]
    fn apply_rejects_non_executable() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 0 });
    }

    #[test]
    fn wall_time_lower_bounds_start() {
        let mut st = two_exec_state();
        st.wall = 100.0;
        let f = st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 1 });
        assert!((f - 102.0).abs() < 1e-12);
    }

    #[test]
    fn job_completion_ignores_duplicates() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        st.apply(
            TaskRef::new(0, 1),
            Allocation::Duplicate { exec: 1, parent: 0 },
        );
        // Completion = child primary finish (5.0), not the dup copy's.
        assert!((st.job_completion(0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn advance_wall_is_monotone() {
        let mut st = two_exec_state();
        st.advance_wall(5.0);
        assert_eq!(st.wall, 5.0);
        st.advance_wall(3.0); // stale timestamp: ignored
        assert_eq!(st.wall, 5.0);
        st.advance_wall(5.0);
        assert_eq!(st.wall, 5.0);
    }

    #[test]
    fn n_unarrived_counts_deferred_jobs() {
        let cluster = Cluster::homogeneous(1, 1.0, 10.0);
        let early = Job::new(0, "early", 0.0, vec![1.0], &[]);
        let late = Job::new(1, "late", 50.0, vec![1.0], &[]);
        let mut st = SimState::new(cluster, Workload::new(vec![early, late]));
        assert_eq!(st.n_unarrived(), 2);
        st.mark_arrived(0);
        assert_eq!(st.n_unarrived(), 1);
        st.mark_arrived(1);
        assert_eq!(st.n_unarrived(), 0);
    }

    #[test]
    fn unarrived_jobs_not_executable() {
        let cluster = Cluster::homogeneous(1, 1.0, 10.0);
        let job = Job::new(0, "late", 50.0, vec![1.0], &[]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        assert!(st.executable().is_empty());
        st.mark_arrived(0);
        assert_eq!(st.executable().len(), 1);
        // Even though wall=0, start must respect arrival.
        let f = st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        assert!((f - 51.0).abs() < 1e-12);
    }

    #[test]
    fn enc_log_compacts_and_reports_absolute_positions() {
        let mut st = two_exec_state();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        let end = st.enc_log_end();
        assert!(end >= 3); // arrival + booked + assigned
        assert_eq!(st.enc_events_since(end).unwrap().len(), 0);
        assert!(st.enc_events_since(end + 1).is_none(), "future cursor");
        st.compact_enc_log();
        assert!(st.enc_events_since(0).is_none(), "compacted range gone");
        assert_eq!(st.enc_log_end(), end, "absolute positions stable");
        assert!(st.enc_events_since(end).unwrap().is_empty());
    }

    #[test]
    fn incremental_caches_track_assignments() {
        let mut st = two_exec_state();
        assert_eq!(st.job_left_tasks(0), 2);
        assert!((st.job_left_work(0) - 10.0).abs() < 1e-12);
        assert!(st.min_aft(TaskRef::new(0, 0)).is_infinite());
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        assert_eq!(st.job_left_tasks(0), 1);
        assert!((st.job_left_work(0) - 6.0).abs() < 1e-12);
        assert_eq!(st.min_aft(TaskRef::new(0, 0)), 4.0);
        // A duplicate does not change the left counters but can lower the
        // parent's min AFT.
        st.apply(
            TaskRef::new(0, 1),
            Allocation::Duplicate { exec: 1, parent: 0 },
        );
        assert_eq!(st.job_left_tasks(0), 0);
        assert!(st.job_left_work(0).abs() < 1e-9);
        assert_eq!(st.min_aft(TaskRef::new(0, 0)), 2.0); // dup copy 0..2
        st.validate().unwrap();
    }

    /// Gap-aware booking backfills an idle window that append mode cannot
    /// use: a late-arriving job books far in the future, then an
    /// earlier-ready task slots into the hole before it. Note that
    /// `Workload::new` orders jobs by arrival and renumbers ids, so the
    /// early job is job 0 and the late job is job 1.
    #[test]
    fn gap_aware_backfills_idle_window() {
        let cluster =
            Cluster::homogeneous(1, 1.0, 10.0).with_sched_mode(SchedMode::GapAware);
        let early = Job::new(0, "early", 0.0, vec![3.0], &[]);
        let late = Job::new(1, "late", 10.0, vec![2.0], &[]);
        let mut st = SimState::new(cluster, Workload::new(vec![early, late]));
        st.mark_arrived(0);
        st.mark_arrived(1);
        // The late job is arrival-bound: books 10..12, leaving [0, 10] idle.
        let f_late = st.apply(TaskRef::new(1, 0), Allocation::Direct { exec: 0 });
        assert!((f_late - 12.0).abs() < 1e-12, "f_late={f_late}");
        // Gap mode backfills the hole: 0..3 instead of append's 12..15.
        let f_early = st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        assert!((f_early - 3.0).abs() < 1e-12, "f_early={f_early}");
        assert!((st.horizon - 12.0).abs() < 1e-12);
        st.validate().unwrap();

        // The identical decisions under append mode queue behind the tail.
        let cluster = Cluster::homogeneous(1, 1.0, 10.0);
        let early = Job::new(0, "early", 0.0, vec![3.0], &[]);
        let late = Job::new(1, "late", 10.0, vec![2.0], &[]);
        let mut st = SimState::new(cluster, Workload::new(vec![early, late]));
        st.mark_arrived(0);
        st.mark_arrived(1);
        st.apply(TaskRef::new(1, 0), Allocation::Direct { exec: 0 });
        let f_early = st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        assert!((f_early - 15.0).abs() < 1e-12, "f_early={f_early}");
        st.validate().unwrap();
    }

    #[test]
    fn gap_aware_duplicate_plans_match_apply() {
        let mut cluster = Cluster::homogeneous(2, 1.0, 10.0);
        cluster.executors[1].speed = 2.0;
        let cluster = cluster.with_sched_mode(SchedMode::GapAware);
        let job = Job::new(0, "chain", 0.0, vec![4.0, 6.0], &[(0, 1, 20.0)]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        let t1 = TaskRef::new(0, 1);
        let (_, (_, predicted)) = st.plan_duplicate(t1, 0, 1);
        let actual = st.apply(t1, Allocation::Duplicate { exec: 1, parent: 0 });
        assert!((predicted - actual).abs() < 1e-12);
        st.validate().unwrap();
    }
}
