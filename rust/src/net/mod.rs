//! Network topology model: per-pair effective bandwidth/latency.
//!
//! The paper collapses the cluster network to one scalar `c` (uniform
//! transfer speed between any two executors). Real clusters move task
//! outputs — first-class [`DataItem`]s — over shared links whose
//! effective bandwidth depends on *where* the endpoints sit: two hosts
//! under the same top-of-rack switch talk faster than hosts separated
//! by an oversubscribed uplink. This module models that as a
//! [`NetworkModel`]: a topology ([`NetTopology`]) plus knobs
//! ([`NetConfig`]) compiled into flat `n×n` bandwidth/latency matrices
//! so the hot path (`transfer_time` inside every EFT/duplication
//! evaluation) is one multiply-add after an index lookup.
//!
//! Three topologies:
//!
//! * **`flat`** — today's semantics, bit-identical: every distinct pair
//!   moves data at `comm_mbps`, zero latency. No matrices are even
//!   allocated; the lookup short-circuits to the scalar formula, so the
//!   pre-refactor golden schedules are preserved bitwise.
//! * **`tree:RxW`** — `R` racks of `W` hosts under one core switch.
//!   Intra-rack pairs get `comm_mbps × rack_mult`; cross-rack pairs
//!   share an oversubscribed uplink and get `comm_mbps / oversub`.
//! * **`fat-tree:K`** — a k-ary fat-tree (Al-Fares et al.): `k/2` hosts
//!   per edge switch ("rack"), `k/2` edge switches per pod, `k` pods,
//!   capacity `k³/4` hosts. Full bisection bandwidth: cross-rack pairs
//!   keep `comm_mbps`, only the hop count (latency) grows with distance
//!   (same edge 2, same pod 4, cross-pod 6 hops).
//!
//! Invariants (pinned by proptests in `tests/proptest_invariants.rs`):
//! the matrices are symmetric, self-transfer is free (infinite
//! bandwidth, zero latency), and rack-local bandwidth is never below
//! cross-rack bandwidth.

use anyhow::{bail, Result};

/// The shape of the cluster network.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NetTopology {
    /// Uniform scalar model (the paper's assumption, the default).
    #[default]
    Flat,
    /// `racks` racks of `width` hosts under a single core switch.
    Tree { racks: usize, width: usize },
    /// k-ary fat-tree: `k/2` hosts per edge switch, `k` pods.
    FatTree { k: usize },
}

/// Topology plus link knobs. `(NetConfig, comm_mbps, n)` fully
/// determines a [`NetworkModel`], so network-aware runs are exactly as
/// reproducible as flat ones.
#[derive(Debug, Clone, PartialEq)]
pub struct NetConfig {
    pub topology: NetTopology,
    /// Intra-rack bandwidth multiplier (rack-local pairs move data at
    /// `comm_mbps × rack_mult`). Must be ≥ 1.
    pub rack_mult: f64,
    /// Tree-uplink oversubscription: cross-rack pairs in `tree` move at
    /// `comm_mbps / oversub`. Must be ≥ 1. Ignored by `flat`/`fat-tree`.
    pub oversub: f64,
    /// Per-switch-hop latency in seconds, added once per transfer.
    pub hop_latency: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            topology: NetTopology::Flat,
            rack_mult: 4.0,
            oversub: 2.0,
            hop_latency: 5e-4,
        }
    }
}

impl NetConfig {
    /// The uniform scalar model (today's semantics).
    pub fn flat() -> NetConfig {
        NetConfig::default()
    }

    pub fn tree(racks: usize, width: usize) -> NetConfig {
        NetConfig {
            topology: NetTopology::Tree { racks, width },
            ..NetConfig::default()
        }
    }

    pub fn fat_tree(k: usize) -> NetConfig {
        NetConfig {
            topology: NetTopology::FatTree { k },
            ..NetConfig::default()
        }
    }

    /// Parse the CLI/JSON syntax: `flat`, `tree:RxW`, or `fat-tree:K`.
    pub fn parse(s: &str) -> Result<NetConfig> {
        let s = s.trim();
        if s.is_empty() || s == "flat" {
            return Ok(NetConfig::flat());
        }
        if let Some(spec) = s.strip_prefix("tree:") {
            let (r, w) = spec
                .split_once('x')
                .ok_or_else(|| anyhow::anyhow!("tree topology must be tree:RxW, got '{s}'"))?;
            let racks: usize = r.parse()?;
            let width: usize = w.parse()?;
            return Ok(NetConfig::tree(racks, width));
        }
        if let Some(spec) = s.strip_prefix("fat-tree:").or_else(|| s.strip_prefix("fattree:")) {
            let k: usize = spec.parse()?;
            return Ok(NetConfig::fat_tree(k));
        }
        bail!("unknown network topology '{s}' (flat | tree:RxW | fat-tree:K)")
    }

    /// Canonical topology string (inverse of [`NetConfig::parse`]).
    pub fn topology_str(&self) -> String {
        match self.topology {
            NetTopology::Flat => "flat".to_string(),
            NetTopology::Tree { racks, width } => format!("tree:{racks}x{width}"),
            NetTopology::FatTree { k } => format!("fat-tree:{k}"),
        }
    }

    /// Exact identity string for snapshot cross-checks: topology plus
    /// the bit patterns of every knob that changes transfer times.
    pub fn snapshot_key(&self) -> String {
        format!(
            "{}|{:016x}|{:016x}|{:016x}",
            self.topology_str(),
            self.rack_mult.to_bits(),
            self.oversub.to_bits(),
            self.hop_latency.to_bits()
        )
    }

    pub fn is_flat(&self) -> bool {
        self.topology == NetTopology::Flat
    }

    /// Maximum number of hosts the topology can place (`usize::MAX` for
    /// flat — it has no structure to run out of).
    pub fn capacity(&self) -> usize {
        match self.topology {
            NetTopology::Flat => usize::MAX,
            NetTopology::Tree { racks, width } => racks.saturating_mul(width),
            NetTopology::FatTree { k } => (k * k * k) / 4,
        }
    }

    pub fn validate(&self, n_executors: usize) -> Result<()> {
        if !self.rack_mult.is_finite() || self.rack_mult < 1.0 {
            bail!("rack_mult must be a finite factor >= 1");
        }
        if !self.oversub.is_finite() || self.oversub < 1.0 {
            bail!("oversub must be a finite factor >= 1");
        }
        if !self.hop_latency.is_finite() || self.hop_latency < 0.0 {
            bail!("hop_latency must be finite and non-negative");
        }
        match self.topology {
            NetTopology::Flat => {}
            NetTopology::Tree { racks, width } => {
                if racks == 0 || width == 0 {
                    bail!("tree topology needs racks > 0 and width > 0");
                }
            }
            NetTopology::FatTree { k } => {
                if k < 2 || k % 2 != 0 {
                    bail!("fat-tree k must be an even integer >= 2");
                }
            }
        }
        if n_executors > self.capacity() {
            bail!(
                "topology {} holds at most {} hosts, cluster has {}",
                self.topology_str(),
                self.capacity(),
                n_executors
            );
        }
        Ok(())
    }
}

/// A task output: `size_mb` megabytes that must reach the child's
/// executor before it can start (Eq 2's `e_pi`). Today every DAG edge
/// is one data item; the type exists so transfers are priced through
/// one door ([`DataItem::transfer_time`]) instead of raw scalars.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DataItem {
    pub size_mb: f64,
}

impl DataItem {
    pub fn new(size_mb: f64) -> DataItem {
        DataItem { size_mb }
    }

    /// Time to move this item between two executors over `net`.
    #[inline]
    pub fn transfer_time(&self, net: &NetworkModel, from: usize, to: usize) -> f64 {
        net.transfer_time(self.size_mb, from, to)
    }
}

/// Compiled per-pair lookup tables for one cluster. Rebuilt whenever
/// the executor count or the [`NetConfig`] changes (see
/// `Cluster::with_net`); between rebuilds every lookup is O(1).
#[derive(Debug, Clone)]
pub struct NetworkModel {
    cfg: NetConfig,
    n: usize,
    comm_mbps: f64,
    /// Rack id per executor (all zero for flat).
    rack: Vec<u32>,
    n_racks: usize,
    /// Effective bandwidth per ordered pair, MB/s (`n×n`, row-major).
    /// Empty for flat: the lookup short-circuits to `comm_mbps`, so the
    /// flat model costs no memory and stays bit-identical to the
    /// pre-topology scalar code.
    bw: Vec<f64>,
    /// Latency per ordered pair, seconds (`n×n`; empty for flat).
    lat: Vec<f64>,
    /// Mean off-diagonal bandwidth (the `c̄` the rank features see).
    c_avg: f64,
}

impl NetworkModel {
    /// Compile `cfg` for an `n`-executor cluster with base speed
    /// `comm_mbps`.
    pub fn build(cfg: &NetConfig, comm_mbps: f64, n: usize) -> NetworkModel {
        cfg.validate(n).expect("invalid network config");
        assert!(comm_mbps > 0.0 && comm_mbps.is_finite());
        assert!(n > 0);
        if cfg.is_flat() {
            return NetworkModel {
                cfg: cfg.clone(),
                n,
                comm_mbps,
                rack: vec![0; n],
                n_racks: 1,
                bw: Vec::new(),
                lat: Vec::new(),
                c_avg: comm_mbps,
            };
        }
        // Host → rack (and, for fat-tree, rack → pod) assignment.
        let rack: Vec<u32> = match cfg.topology {
            NetTopology::Flat => unreachable!(),
            NetTopology::Tree { width, .. } => (0..n).map(|i| (i / width) as u32).collect(),
            NetTopology::FatTree { k } => (0..n).map(|i| (i / (k / 2)) as u32).collect(),
        };
        let n_racks = rack.iter().map(|&r| r as usize + 1).max().unwrap_or(1);
        let mut bw = vec![0.0f64; n * n];
        let mut lat = vec![0.0f64; n * n];
        let mut sum = 0.0f64;
        let mut pairs = 0usize;
        for i in 0..n {
            for j in 0..n {
                let idx = i * n + j;
                if i == j {
                    bw[idx] = f64::INFINITY;
                    lat[idx] = 0.0;
                    continue;
                }
                let (b, hops) = match cfg.topology {
                    NetTopology::Flat => unreachable!(),
                    NetTopology::Tree { .. } => {
                        if rack[i] == rack[j] {
                            (comm_mbps * cfg.rack_mult, 2usize)
                        } else {
                            (comm_mbps / cfg.oversub, 4usize)
                        }
                    }
                    NetTopology::FatTree { k } => {
                        let racks_per_pod = k / 2;
                        let (pi, pj) = (
                            rack[i] as usize / racks_per_pod,
                            rack[j] as usize / racks_per_pod,
                        );
                        if rack[i] == rack[j] {
                            (comm_mbps * cfg.rack_mult, 2usize)
                        } else if pi == pj {
                            (comm_mbps, 4usize)
                        } else {
                            // Full bisection bandwidth: the fat-tree's
                            // whole point is that cross-pod pairs keep
                            // line rate; only the path length grows.
                            (comm_mbps, 6usize)
                        }
                    }
                };
                bw[idx] = b;
                lat[idx] = hops as f64 * cfg.hop_latency;
                sum += b;
                pairs += 1;
            }
        }
        let c_avg = if pairs > 0 { sum / pairs as f64 } else { comm_mbps };
        NetworkModel {
            cfg: cfg.clone(),
            n,
            comm_mbps,
            rack,
            n_racks,
            bw,
            lat,
            c_avg,
        }
    }

    pub fn config(&self) -> &NetConfig {
        &self.cfg
    }

    pub fn is_flat(&self) -> bool {
        self.bw.is_empty()
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Effective bandwidth between two executors, MB/s (infinite within
    /// one executor).
    #[inline]
    pub fn bandwidth(&self, from: usize, to: usize) -> f64 {
        if from == to {
            f64::INFINITY
        } else if self.is_flat() {
            self.comm_mbps
        } else {
            self.bw[from * self.n + to]
        }
    }

    /// Path latency between two executors, seconds (zero within one).
    #[inline]
    pub fn latency(&self, from: usize, to: usize) -> f64 {
        if from == to || self.is_flat() {
            0.0
        } else {
            self.lat[from * self.n + to]
        }
    }

    /// Transfer time of `data` MB from `from` to `to`. The flat branch
    /// computes exactly the pre-topology scalar formula (`data /
    /// comm_mbps`, no latency term, no matrix read) so flat schedules
    /// stay bit-identical to the golden references.
    #[inline]
    pub fn transfer_time(&self, data: f64, from: usize, to: usize) -> f64 {
        if from == to || data == 0.0 {
            0.0
        } else if self.is_flat() {
            data / self.comm_mbps
        } else {
            let idx = from * self.n + to;
            self.lat[idx] + data / self.bw[idx]
        }
    }

    /// Mean off-diagonal bandwidth `c̄` (rank features, TDCA replan).
    /// Exactly `comm_mbps` for flat.
    #[inline]
    pub fn c_avg(&self) -> f64 {
        self.c_avg
    }

    /// Rack id of executor `k` (0 for every executor under flat).
    #[inline]
    pub fn rack_of(&self, k: usize) -> usize {
        self.rack[k] as usize
    }

    /// Number of racks the placed executors span (1 for flat).
    pub fn n_racks(&self) -> usize {
        self.n_racks
    }

    #[inline]
    pub fn same_rack(&self, a: usize, b: usize) -> bool {
        self.rack[a] == self.rack[b]
    }

    /// Executors in rack `r` (used by the rack-failure fault mode).
    pub fn rack_members(&self, r: usize) -> Vec<usize> {
        (0..self.n).filter(|&k| self.rack[k] as usize == r).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["flat", "tree:4x8", "fat-tree:4"] {
            let cfg = NetConfig::parse(s).unwrap();
            assert_eq!(cfg.topology_str(), s);
        }
        assert_eq!(
            NetConfig::parse("fattree:6").unwrap().topology,
            NetTopology::FatTree { k: 6 }
        );
        assert!(NetConfig::parse("ring:4").is_err());
        assert!(NetConfig::parse("tree:4").is_err());
    }

    #[test]
    fn validation() {
        assert!(NetConfig::tree(2, 4).validate(8).is_ok());
        assert!(NetConfig::tree(2, 4).validate(9).is_err(), "over capacity");
        assert!(NetConfig::fat_tree(3).validate(1).is_err(), "odd k");
        assert!(NetConfig::fat_tree(4).validate(16).is_ok());
        assert!(NetConfig::fat_tree(4).validate(17).is_err());
        let mut bad = NetConfig::tree(2, 2);
        bad.rack_mult = 0.5;
        assert!(bad.validate(4).is_err());
    }

    #[test]
    fn flat_matches_scalar_formula_bitwise() {
        let net = NetworkModel::build(&NetConfig::flat(), 100.0, 8);
        assert!(net.is_flat());
        for data in [0.0, 1.0, 512.37, 1e5] {
            for (i, j) in [(0usize, 1usize), (3, 7), (5, 5)] {
                let expect = if i == j || data == 0.0 { 0.0 } else { data / 100.0 };
                assert_eq!(net.transfer_time(data, i, j).to_bits(), expect.to_bits());
            }
        }
        assert_eq!(net.c_avg().to_bits(), 100.0f64.to_bits());
        assert_eq!(net.n_racks(), 1);
        assert_eq!(net.rack_of(7), 0);
    }

    #[test]
    fn tree_locality_gradient() {
        let cfg = NetConfig::tree(2, 4);
        let net = NetworkModel::build(&cfg, 100.0, 8);
        assert_eq!(net.n_racks(), 2);
        assert_eq!(net.rack_of(3), 0);
        assert_eq!(net.rack_of(4), 1);
        // Intra-rack faster, cross-rack slower than base.
        assert_eq!(net.bandwidth(0, 1), 400.0);
        assert_eq!(net.bandwidth(0, 4), 50.0);
        assert!(net.latency(0, 1) < net.latency(0, 4));
        // Transfer times order accordingly.
        let local = net.transfer_time(100.0, 0, 1);
        let remote = net.transfer_time(100.0, 0, 4);
        assert!(local < remote);
        assert_eq!(net.transfer_time(100.0, 2, 2), 0.0);
        // c̄ sits strictly between the extremes.
        assert!(net.c_avg() > 50.0 && net.c_avg() < 400.0);
    }

    #[test]
    fn fat_tree_hop_structure() {
        let cfg = NetConfig::fat_tree(4); // 2 hosts/edge, 2 edges/pod, 16 cap
        let net = NetworkModel::build(&cfg, 100.0, 12);
        // Hosts 0,1 share an edge switch; 2,3 are the same pod's other
        // edge; 4.. are the next pod.
        assert!(net.same_rack(0, 1));
        assert!(!net.same_rack(0, 2));
        assert_eq!(net.bandwidth(0, 1), 400.0);
        assert_eq!(net.bandwidth(0, 2), 100.0);
        assert_eq!(net.bandwidth(0, 4), 100.0, "full bisection");
        assert!(net.latency(0, 1) < net.latency(0, 2));
        assert!(net.latency(0, 2) < net.latency(0, 4));
    }

    #[test]
    fn matrices_symmetric() {
        for cfg in [NetConfig::tree(3, 3), NetConfig::fat_tree(4)] {
            let net = NetworkModel::build(&cfg, 80.0, 9);
            for i in 0..9 {
                for j in 0..9 {
                    assert_eq!(net.bandwidth(i, j).to_bits(), net.bandwidth(j, i).to_bits());
                    assert_eq!(net.latency(i, j).to_bits(), net.latency(j, i).to_bits());
                }
            }
        }
    }

    #[test]
    fn data_item_prices_through_net() {
        let net = NetworkModel::build(&NetConfig::tree(2, 2), 100.0, 4);
        let item = DataItem::new(200.0);
        assert_eq!(
            item.transfer_time(&net, 0, 3).to_bits(),
            net.transfer_time(200.0, 0, 3).to_bits()
        );
        assert_eq!(item.transfer_time(&net, 1, 1), 0.0);
    }

    #[test]
    fn rack_members_partition() {
        let net = NetworkModel::build(&NetConfig::tree(3, 2), 100.0, 5);
        assert_eq!(net.rack_members(0), vec![0, 1]);
        assert_eq!(net.rack_members(1), vec![2, 3]);
        assert_eq!(net.rack_members(2), vec![4]);
    }

    #[test]
    fn snapshot_key_distinguishes_knobs() {
        let a = NetConfig::tree(2, 4);
        let mut b = NetConfig::tree(2, 4);
        assert_eq!(a.snapshot_key(), b.snapshot_key());
        b.oversub = 3.0;
        assert_ne!(a.snapshot_key(), b.snapshot_key());
        assert_ne!(a.snapshot_key(), NetConfig::flat().snapshot_key());
    }
}
