//! ASCII line charts for terminal-friendly experiment reports (the Fig 4
//! learning curve and the decision-time CDFs render through this).

/// Render one or more named series as an ASCII chart. Each series is a
/// list of (x, y) points; NaN y-values are skipped (sparse series like
/// the every-5-episodes eval makespan).
pub fn line_chart(
    title: &str,
    series: &[(&str, Vec<(f64, f64)>)],
    width: usize,
    height: usize,
) -> String {
    let width = width.clamp(16, 200);
    let height = height.clamp(4, 60);
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|(_, s)| s.iter().copied())
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if pts.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![b' '; width]; height];
    const MARKS: &[u8] = b"*o+x#%@&";
    for (si, (_, s)) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in s {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = mark;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{y1:>10.1}")
        } else if r == height - 1 {
            format!("{y0:>10.1}")
        } else {
            " ".repeat(10)
        };
        out.push_str(&format!(
            "{label} |{}|\n",
            String::from_utf8_lossy(row)
        ));
    }
    out.push_str(&format!(
        "{:>10}  {x0:<10.1}{}{x1:>10.1}\n",
        "",
        " ".repeat(width.saturating_sub(20))
    ));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {name}", MARKS[i % MARKS.len()] as char))
        .collect();
    out.push_str(&format!("{:>12}{}\n", "", legend.join("   ")));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_single_series() {
        let s: Vec<(f64, f64)> = (0..50).map(|i| (i as f64, (i as f64).sqrt())).collect();
        let chart = line_chart("sqrt", &[("y", s)], 60, 12);
        assert!(chart.contains("sqrt"));
        assert!(chart.contains('*'));
        assert!(chart.lines().count() >= 12);
    }

    #[test]
    fn skips_nan_points() {
        let s = vec![(0.0, 1.0), (1.0, f64::NAN), (2.0, 3.0)];
        let chart = line_chart("nan", &[("y", s)], 40, 8);
        assert!(chart.contains('*'));
    }

    #[test]
    fn multiple_series_use_distinct_marks() {
        let a: Vec<(f64, f64)> = (0..20).map(|i| (i as f64, i as f64)).collect();
        let b: Vec<(f64, f64)> = (0..20).map(|i| (i as f64, 20.0 - i as f64)).collect();
        let chart = line_chart("xy", &[("up", a), ("down", b)], 50, 10);
        assert!(chart.contains('*') && chart.contains('o'));
        assert!(chart.contains("* up") && chart.contains("o down"));
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        assert!(line_chart("e", &[("y", vec![])], 40, 8).contains("no data"));
        let flat = vec![(0.0, 5.0), (1.0, 5.0)];
        let chart = line_chart("flat", &[("y", flat)], 40, 8);
        assert!(chart.contains('*'));
    }
}
