//! ASCII Gantt-chart rendering of a completed schedule — invaluable when
//! debugging duplication decisions, executor contention, and fault
//! recovery (`lachesis schedule --gantt`).

use crate::sim::SimState;

/// Render the executor timelines as an ASCII Gantt chart. `width` is the
/// number of character columns for the time axis. Tasks are labeled
//  `j<job>.<node>`; duplicated copies get a trailing `'`, requeued
//  tasks (re-placed after losing all copies to a fault) a trailing `!`. Fault blackout
//  windows render as `x` bands; a permanently-dead executor shows `x`
//  from its crash to the horizon.
pub fn render(state: &SimState, width: usize) -> String {
    let width = width.clamp(20, 400);
    let horizon = state.horizon.max(1e-9);
    let any_faults = state.faults.n_crashes > 0 || state.faults.n_straggles > 0;
    let mut out = String::new();
    out.push_str(&format!(
        "schedule horizon {:.2}s — {} executors, {} tasks, {} duplicates, {} booking\n",
        state.horizon,
        state.cluster.len(),
        state.n_assigned,
        state.n_duplicates,
        state.sched_mode.as_str(),
    ));
    if any_faults {
        out.push_str(&format!(
            "faults: {} crashes, {} straggles — {} copies cancelled, {} tasks \
             requeued, {} saved by duplicates\n",
            state.faults.n_crashes,
            state.faults.n_straggles,
            state.faults.n_cancelled,
            state.faults.n_requeued,
            state.faults.n_dup_survived,
        ));
    }
    // Rack placement only appears under a non-flat topology, so flat
    // charts render byte-identically to the pre-topology output.
    let n_racks = state.cluster.n_racks();
    if n_racks > 1 {
        out.push_str(&format!(
            "topology: {} — {} racks\n",
            state.cluster.net.config().topology_str(),
            n_racks
        ));
    }
    let col = |t: f64| ((t / horizon) * width as f64).floor() as usize;
    for (e, log) in state.exec_log.iter().enumerate() {
        let mut row = vec![b' '; width];
        let mut labels: Vec<(usize, String)> = Vec::new();
        // Blackout bands first, so task glyphs (which never overlap a
        // blackout) stay visible on top of adjacent cells.
        let paint = |s: f64, f: f64, row: &mut Vec<u8>| {
            let c0 = col(s);
            let c1 = (((f / horizon) * width as f64).ceil() as usize).min(width);
            for c in c0..c1.max(c0 + 1).min(width) {
                row[c] = b'x';
            }
        };
        for &(s, f) in state.blackouts(e) {
            paint(s, f, &mut row);
        }
        if let Some(t_down) = state.down_since(e) {
            // Still down: permanent crash (or unrecovered transient) —
            // shade through the horizon.
            paint(t_down, state.horizon.max(t_down), &mut row);
        }
        let mut sorted = log.clone();
        sorted.sort_by(|a, b| a.1.start.total_cmp(&b.1.start));
        for (task, pl) in &sorted {
            let c0 = col(pl.start);
            let c1 = (((pl.finish / horizon) * width as f64).ceil() as usize).min(width);
            for c in c0..c1.max(c0 + 1).min(width) {
                row[c] = if pl.duplicate { b'+' } else { b'#' };
            }
            let tag = format!(
                "j{}.{}{}{}",
                task.job,
                task.node,
                if pl.duplicate { "'" } else { "" },
                if state.was_requeued(*task) { "!" } else { "" }
            );
            labels.push((c0, tag));
        }
        let speed = state.cluster.speed(e);
        // Per-executor busy share of the horizon, from the timeline
        // (outage windows are not work).
        let busy_pct =
            100.0 * (state.timeline(e).busy_time() - state.blackout_time(e)) / horizon;
        let rack_tag = if n_racks > 1 {
            format!("r{:<2} ", state.cluster.rack_of(e))
        } else {
            String::new()
        };
        out.push_str(&format!(
            "e{e:<3} {rack_tag}{speed:.1}GHz {busy_pct:>3.0}% |{}|",
            String::from_utf8(row).unwrap()
        ));
        // Append up to 4 labels to keep lines readable.
        if !labels.is_empty() {
            let shown: Vec<String> = labels.iter().take(4).map(|(_, t)| t.clone()).collect();
            out.push_str(&format!(
                "  {}{}",
                shown.join(" "),
                if labels.len() > 4 { " …" } else { "" }
            ));
        }
        out.push('\n');
    }
    // Time axis.
    out.push_str(&format!(
        "{:>10} 0{}{:.1}s\n",
        "",
        " ".repeat(width.saturating_sub(6)),
        state.horizon
    ));
    out.push_str("   ('#' primary copy, '+' duplicated copy)\n");
    if any_faults {
        out.push_str("   ('x' executor outage, '!' task requeued by a fault)\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::dag::TaskRef;
    use crate::sim::{Allocation, SimState};
    use crate::workload::Workload;

    fn simple_state() -> SimState {
        let mut cluster = Cluster::homogeneous(2, 1.0, 10.0);
        cluster.executors[1].speed = 2.0;
        let job = crate::dag::Job::new(0, "chain", 0.0, vec![4.0, 6.0], &[(0, 1, 20.0)]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        st.apply(
            TaskRef::new(0, 1),
            Allocation::Duplicate { exec: 1, parent: 0 },
        );
        st
    }

    #[test]
    fn renders_all_executors_and_markers() {
        let st = simple_state();
        let g = render(&st, 60);
        assert!(g.contains("e0"));
        assert!(g.contains("e1"));
        assert!(g.contains('#'), "primary copies rendered");
        assert!(g.contains('+'), "duplicate copies rendered");
        assert!(g.contains("j0.0"));
        assert!(g.contains("j0.0'"), "duplicate label marked");
        assert!(g.contains("1 duplicates"));
    }

    #[test]
    fn width_is_clamped() {
        let st = simple_state();
        let narrow = render(&st, 1);
        let wide = render(&st, 100_000);
        for line in narrow.lines().chain(wide.lines()) {
            assert!(line.len() < 500);
        }
    }

    #[test]
    fn blackouts_and_reexecutions_are_marked() {
        let cluster = Cluster::homogeneous(2, 1.0, 10.0);
        let job = crate::dag::Job::new(0, "par", 0.0, vec![4.0, 4.0], &[]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 1 });
        // Executor 0 dies mid-flight; its task re-executes on executor 1.
        st.apply_crash(0, 1.0, Some(6.0));
        st.wall = 1.0;
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 1 });
        st.validate().unwrap();
        let g = render(&st, 60);
        assert!(g.contains('x'), "blackout band rendered: {g}");
        assert!(g.contains("j0.0!"), "requeued task marked: {g}");
        assert!(g.contains("1 crashes"), "fault summary line: {g}");
        assert!(g.contains("outage"), "fault legend: {g}");
    }

    #[test]
    fn rack_tags_only_under_topologies() {
        let flat = render(&simple_state(), 60);
        assert!(!flat.contains("topology:"), "flat chart stays unchanged");
        assert!(!flat.contains(" r0 "), "flat rows carry no rack tag");

        let cluster = Cluster::homogeneous(4, 1.0, 10.0)
            .with_net(&crate::net::NetConfig::tree(2, 2));
        let job = crate::dag::Job::new(0, "par", 0.0, vec![4.0, 4.0], &[]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 3 });
        let g = render(&st, 60);
        assert!(g.contains("topology: tree:2x2 — 2 racks"), "header: {g}");
        assert!(g.contains("e0   r0 "), "rack tag on rack-0 row: {g}");
        assert!(g.contains("e3   r1 "), "rack tag on rack-1 row: {g}");
    }

    #[test]
    fn empty_schedule_renders() {
        let cluster = Cluster::homogeneous(1, 1.0, 10.0);
        let job = crate::dag::Job::new(0, "j", 10.0, vec![1.0], &[]);
        let st = SimState::new(cluster, Workload::new(vec![job]));
        let g = render(&st, 40);
        assert!(g.contains("0 tasks"));
    }
}
