//! Evaluation metrics (paper §5.2): makespan, speedup (Eq 13), schedule
//! length ratio (Eq 14), decision-time distribution, plus reporting
//! helpers that print the markdown/CSV tables the experiment harness
//! emits for each figure.

pub mod chart;
pub mod gantt;

use crate::dag::graph::critical_path_min;
use crate::sim::SimState;
use crate::util::stats::{mean, Recorder};

/// Metrics of one completed schedule.
#[derive(Debug, Clone)]
pub struct ScheduleReport {
    pub algo: String,
    pub n_jobs: usize,
    pub n_tasks: usize,
    /// Completion time of the whole workload (max primary-copy AFT).
    pub makespan: f64,
    /// Eq 13: sequential time on the fastest executor / makespan.
    pub speedup: f64,
    /// Eq 14 averaged over jobs: (completion − arrival) / critical-path
    /// lower bound.
    pub avg_slr: f64,
    /// Mean job completion time (completion − arrival).
    pub avg_jct: f64,
    /// Number of duplicated task copies DEFT created.
    pub n_duplicates: usize,
    /// Busy time / (executors × makespan). Fault blackout windows are
    /// not busy time.
    pub utilization: f64,
    /// Per-decision scheduler latency in milliseconds.
    pub decision_ms: Recorder,
    /// Fault activity during the run (all zero on a reliable cluster).
    pub faults: crate::fault::FaultStats,
}

impl ScheduleReport {
    pub fn from_state(state: &SimState, algo: &str, decision_ms: Recorder) -> ScheduleReport {
        let v_max = state.cluster.v_max();
        let total_work: f64 = state.jobs.iter().map(|j| j.total_work()).sum();
        let mut makespan = 0.0f64;
        let mut slrs = Vec::with_capacity(state.jobs.len());
        let mut jcts = Vec::with_capacity(state.jobs.len());
        for (ji, job) in state.jobs.iter().enumerate() {
            let completion = state.job_completion(ji);
            if completion > makespan {
                makespan = completion;
            }
            let (_, cp) = critical_path_min(job, v_max);
            let jct = completion - job.arrival;
            jcts.push(jct);
            slrs.push(jct / cp.max(1e-12));
        }
        // Busy time straight off the executor timelines (identical to
        // summing the schedule log — `validate` pins them together),
        // minus fault blackout windows, which occupy the timeline but do
        // no work. Subtracting zero keeps fault-free runs bit-identical.
        let busy: f64 = (0..state.cluster.len())
            .map(|e| state.timeline(e).busy_time() - state.blackout_time(e))
            .sum();
        let utilization = if makespan > 0.0 {
            busy / (state.cluster.len() as f64 * makespan)
        } else {
            0.0
        };
        ScheduleReport {
            algo: algo.to_string(),
            n_jobs: state.jobs.len(),
            n_tasks: state.n_tasks_total(),
            makespan,
            speedup: (total_work / v_max) / makespan.max(1e-12),
            avg_slr: mean(&slrs),
            avg_jct: mean(&jcts),
            n_duplicates: state.n_duplicates,
            utilization,
            decision_ms,
            faults: state.faults,
        }
    }
}

/// Aggregation of reports across seeds for one (algorithm, x) point of a
/// figure sweep.
#[derive(Debug, Clone)]
pub struct PointSummary {
    pub algo: String,
    /// x-axis value (number of jobs for Figs 5–7).
    pub x: usize,
    pub makespan: f64,
    pub speedup: f64,
    pub slr: f64,
    pub jct: f64,
    pub decision_p98_ms: f64,
    pub n_seeds: usize,
}

/// Collects reports over a sweep and renders the paper-style series.
#[derive(Debug, Clone, Default)]
pub struct SuiteReport {
    reports: Vec<(usize, ScheduleReport)>,
}

impl SuiteReport {
    pub fn new() -> SuiteReport {
        SuiteReport::default()
    }

    pub fn push(&mut self, x: usize, report: ScheduleReport) {
        self.reports.push((x, report));
    }

    pub fn merge(&mut self, other: SuiteReport) {
        self.reports.extend(other.reports);
    }

    pub fn is_empty(&self) -> bool {
        self.reports.is_empty()
    }

    /// Distinct algorithm names in insertion order.
    pub fn algos(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for (_, r) in &self.reports {
            if !out.contains(&r.algo) {
                out.push(r.algo.clone());
            }
        }
        out
    }

    /// Distinct x values sorted ascending.
    pub fn xs(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self.reports.iter().map(|(x, _)| *x).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Mean metrics for one (algo, x) cell across seeds.
    pub fn summarize(&self, algo: &str, x: usize) -> Option<PointSummary> {
        let cell: Vec<&ScheduleReport> = self
            .reports
            .iter()
            .filter(|(rx, r)| *rx == x && r.algo == algo)
            .map(|(_, r)| r)
            .collect();
        if cell.is_empty() {
            return None;
        }
        let mut dec = Recorder::new();
        for r in &cell {
            dec.extend_from(&r.decision_ms);
        }
        Some(PointSummary {
            algo: algo.to_string(),
            x,
            makespan: mean(&cell.iter().map(|r| r.makespan).collect::<Vec<_>>()),
            speedup: mean(&cell.iter().map(|r| r.speedup).collect::<Vec<_>>()),
            slr: mean(&cell.iter().map(|r| r.avg_slr).collect::<Vec<_>>()),
            jct: mean(&cell.iter().map(|r| r.avg_jct).collect::<Vec<_>>()),
            decision_p98_ms: dec.percentile(98.0),
            n_seeds: cell.len(),
        })
    }

    /// Merge every decision-time sample of one algorithm (for CDF panels).
    pub fn decision_recorder(&self, algo: &str) -> Recorder {
        let mut rec = Recorder::new();
        for (_, r) in &self.reports {
            if r.algo == algo {
                rec.extend_from(&r.decision_ms);
            }
        }
        rec
    }

    /// Render one metric as a markdown table: rows = x, columns = algos.
    /// `metric` ∈ {"makespan", "speedup", "slr", "p98"}.
    pub fn table(&self, metric: &str, title: &str) -> String {
        let algos = self.algos();
        let xs = self.xs();
        let mut out = String::new();
        out.push_str(&format!("### {title}\n\n"));
        out.push_str("| jobs |");
        for a in &algos {
            out.push_str(&format!(" {a} |"));
        }
        out.push('\n');
        out.push_str("|---|");
        for _ in &algos {
            out.push_str("---|");
        }
        out.push('\n');
        for &x in &xs {
            out.push_str(&format!("| {x} |"));
            for a in &algos {
                match self.summarize(a, x) {
                    Some(s) => {
                        let v = match metric {
                            "makespan" => s.makespan,
                            "speedup" => s.speedup,
                            "slr" => s.slr,
                            "jct" => s.jct,
                            "p98" => s.decision_p98_ms,
                            other => panic!("unknown metric '{other}'"),
                        };
                        out.push_str(&format!(" {v:.3} |"));
                    }
                    None => out.push_str(" - |"),
                }
            }
            out.push('\n');
        }
        out.push('\n');
        out
    }

    /// CSV dump of all cells (one row per algo × x), for plotting.
    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("algo,jobs,n_seeds,makespan,speedup,slr,decision_p98_ms\n");
        for a in self.algos() {
            for x in self.xs() {
                if let Some(s) = self.summarize(&a, x) {
                    out.push_str(&format!(
                        "{},{},{},{:.6},{:.6},{:.6},{:.6}\n",
                        s.algo, s.x, s.n_seeds, s.makespan, s.speedup, s.slr, s.decision_p98_ms
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::WorkloadConfig;
    use crate::sched::{FifoScheduler, Scheduler};
    use crate::sim::Simulator;
    use crate::workload::WorkloadGenerator;

    fn quick_report(seed: u64) -> ScheduleReport {
        let cluster = Cluster::homogeneous(4, 2.5, 100.0);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(3), seed).generate();
        let mut sim = Simulator::new(cluster, w);
        let mut s = FifoScheduler::new();
        let _ = s.name();
        sim.run(&mut s).unwrap()
    }

    #[test]
    fn report_metrics_sane() {
        let r = quick_report(5);
        assert!(r.makespan > 0.0);
        assert!(r.speedup > 0.0);
        // SLR is lower-bounded by 1 for every job.
        assert!(r.avg_slr >= 1.0 - 1e-9, "slr={}", r.avg_slr);
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        assert!(!r.decision_ms.is_empty());
    }

    #[test]
    fn suite_aggregates_and_renders() {
        let mut suite = SuiteReport::new();
        for seed in 0..3 {
            suite.push(3, quick_report(seed));
        }
        let s = suite.summarize("FIFO-DEFT", 3).unwrap();
        assert_eq!(s.n_seeds, 3);
        assert!(s.makespan > 0.0);
        let table = suite.table("makespan", "test");
        assert!(table.contains("FIFO-DEFT"));
        assert!(table.contains("| 3 |"));
        let csv = suite.to_csv();
        assert!(csv.lines().count() >= 2);
    }
}
