//! DAG job model: tasks (`w_i`), data edges (`e_ij`), jobs, and the graph
//! algorithms the schedulers need (topological order, critical path,
//! `rank_up`/`rank_down`).

pub mod graph;
pub mod ranks;

pub use graph::{critical_path_min, topo_order};
pub use ranks::{rank_down, rank_up};

/// Node index within a job.
pub type NodeId = usize;
/// Job index within a workload.
pub type JobId = usize;
/// Global task identity: (job, node).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskRef {
    pub job: JobId,
    pub node: NodeId,
}

impl TaskRef {
    pub fn new(job: JobId, node: NodeId) -> Self {
        TaskRef { job, node }
    }
}

/// Legacy alias used by some call sites.
pub type TaskId = TaskRef;

/// A single task: the minimum scheduling unit.
#[derive(Debug, Clone)]
pub struct Task {
    /// Computation size `w_i` in GHz·seconds: execution time on executor
    /// `r_k` is `w_i / v_k` (paper Eq 1).
    pub compute: f64,
}

/// A directed data edge within a job's DAG.
#[derive(Debug, Clone, Copy)]
pub struct Edge {
    /// The other endpoint (child for `children[]`, parent for `parents[]`).
    pub other: NodeId,
    /// Data size `e_ij` in MB transferred along the edge.
    pub data: f64,
}

/// A job: a DAG of tasks with an arrival time (continuous mode) and a
/// human-readable name (`tpch-q05-50g`).
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub name: String,
    /// Time the job arrives at the system (0 in batch mode).
    pub arrival: f64,
    pub tasks: Vec<Task>,
    /// `children[i]` — outgoing edges of node `i`.
    pub children: Vec<Vec<Edge>>,
    /// `parents[i]` — incoming edges of node `i` (edge.other = parent id).
    pub parents: Vec<Vec<Edge>>,
    /// Cached topological order (parents before children).
    topo: Vec<NodeId>,
}

impl Job {
    /// Build a job from an edge list. Panics on cyclic or out-of-range
    /// input — job construction is programmer/generator controlled; use
    /// [`Job::try_new`] for untrusted traces.
    pub fn new(
        id: JobId,
        name: impl Into<String>,
        arrival: f64,
        computes: Vec<f64>,
        edges: &[(NodeId, NodeId, f64)],
    ) -> Job {
        Job::try_new(id, name, arrival, computes, edges).expect("invalid job DAG")
    }

    /// Fallible construction with full validation (acyclicity, ranges,
    /// positive sizes).
    pub fn try_new(
        id: JobId,
        name: impl Into<String>,
        arrival: f64,
        computes: Vec<f64>,
        edges: &[(NodeId, NodeId, f64)],
    ) -> anyhow::Result<Job> {
        use anyhow::bail;
        let n = computes.len();
        if n == 0 {
            bail!("job must have at least one task");
        }
        if computes.iter().any(|&w| !(w > 0.0)) {
            bail!("task compute sizes must be positive");
        }
        let mut children: Vec<Vec<Edge>> = vec![Vec::new(); n];
        let mut parents: Vec<Vec<Edge>> = vec![Vec::new(); n];
        for &(u, v, data) in edges {
            if u >= n || v >= n {
                bail!("edge ({u},{v}) out of range for {n} tasks");
            }
            if u == v {
                bail!("self-loop at node {u}");
            }
            if data < 0.0 {
                bail!("negative edge data size");
            }
            children[u].push(Edge { other: v, data });
            parents[v].push(Edge { other: u, data });
        }
        let tasks = computes.into_iter().map(|compute| Task { compute }).collect();
        let mut job = Job {
            id,
            name: name.into(),
            arrival,
            tasks,
            children,
            parents,
            topo: Vec::new(),
        };
        match graph::try_topo_order(&job) {
            Some(order) => job.topo = order,
            None => bail!("job '{}' contains a cycle", job.name),
        }
        Ok(job)
    }

    /// Rebuild a job from serialized adjacency lists (snapshot restore).
    ///
    /// [`Job::try_new`] takes a flat edge list, but a `Job` does not
    /// retain the original interleaving of that list across source
    /// nodes — only the per-node orders of `children[u]` and
    /// `parents[v]`, which tie-breaking consumers (e.g. DEFT's
    /// duplicate-parent scan) iterate in. Restoring through a
    /// reconstructed edge list could therefore reorder `parents` and
    /// change decisions; restoring the adjacency verbatim cannot. The
    /// two lists are cross-checked against each other and the usual
    /// structural validation (ranges, positivity, acyclicity) reruns.
    pub fn from_adjacency(
        id: JobId,
        name: impl Into<String>,
        arrival: f64,
        computes: Vec<f64>,
        children: Vec<Vec<Edge>>,
        parents: Vec<Vec<Edge>>,
    ) -> anyhow::Result<Job> {
        use anyhow::bail;
        let n = computes.len();
        if n == 0 {
            bail!("job must have at least one task");
        }
        if computes.iter().any(|&w| !(w > 0.0)) {
            bail!("task compute sizes must be positive");
        }
        if children.len() != n || parents.len() != n {
            bail!("adjacency lists must have one entry per task");
        }
        // The child and parent views must describe the same edge
        // multiset: collect each as (parent, child, data-bits) and
        // compare order-insensitively.
        let mut from_children: Vec<(NodeId, NodeId, u64)> = Vec::new();
        for (u, es) in children.iter().enumerate() {
            for e in es {
                if e.other >= n || e.other == u {
                    bail!("edge ({u},{}) invalid for {n} tasks", e.other);
                }
                if !(e.data >= 0.0) {
                    bail!("negative edge data size");
                }
                from_children.push((u, e.other, e.data.to_bits()));
            }
        }
        let mut from_parents: Vec<(NodeId, NodeId, u64)> = Vec::new();
        for (v, es) in parents.iter().enumerate() {
            for e in es {
                if e.other >= n || e.other == v {
                    bail!("edge ({},{v}) invalid for {n} tasks", e.other);
                }
                from_parents.push((e.other, v, e.data.to_bits()));
            }
        }
        from_children.sort_unstable();
        from_parents.sort_unstable();
        if from_children != from_parents {
            bail!("children and parents adjacency disagree");
        }
        let tasks = computes.into_iter().map(|compute| Task { compute }).collect();
        let mut job = Job {
            id,
            name: name.into(),
            arrival,
            tasks,
            children,
            parents,
            topo: Vec::new(),
        };
        match graph::try_topo_order(&job) {
            Some(order) => job.topo = order,
            None => bail!("job '{}' contains a cycle", job.name),
        }
        Ok(job)
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    pub fn n_edges(&self) -> usize {
        self.children.iter().map(|c| c.len()).sum()
    }

    /// Total computation size of the job (sum of `w_i`).
    pub fn total_work(&self) -> f64 {
        self.tasks.iter().map(|t| t.compute).sum()
    }

    /// Total data volume on edges.
    pub fn total_data(&self) -> f64 {
        self.children
            .iter()
            .flat_map(|es| es.iter().map(|e| e.data))
            .sum()
    }

    /// Cached topological order (parents precede children).
    pub fn topo(&self) -> &[NodeId] {
        &self.topo
    }

    /// Entry nodes (no parents).
    pub fn entries(&self) -> Vec<NodeId> {
        (0..self.n_tasks())
            .filter(|&i| self.parents[i].is_empty())
            .collect()
    }

    /// Exit nodes (no children).
    pub fn exits(&self) -> Vec<NodeId> {
        (0..self.n_tasks())
            .filter(|&i| self.children[i].is_empty())
            .collect()
    }

    /// Data size on edge `u -> v`, or 0 if absent.
    pub fn edge_data(&self, u: NodeId, v: NodeId) -> f64 {
        self.children[u]
            .iter()
            .find(|e| e.other == v)
            .map(|e| e.data)
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn diamond() -> Job {
        // 0 -> {1, 2} -> 3
        Job::new(
            0,
            "diamond",
            0.0,
            vec![1.0, 2.0, 3.0, 4.0],
            &[(0, 1, 10.0), (0, 2, 20.0), (1, 3, 30.0), (2, 3, 40.0)],
        )
    }

    #[test]
    fn builds_adjacency() {
        let j = diamond();
        assert_eq!(j.n_tasks(), 4);
        assert_eq!(j.n_edges(), 4);
        assert_eq!(j.entries(), vec![0]);
        assert_eq!(j.exits(), vec![3]);
        assert_eq!(j.children[0].len(), 2);
        assert_eq!(j.parents[3].len(), 2);
        assert_eq!(j.edge_data(0, 2), 20.0);
        assert_eq!(j.edge_data(2, 0), 0.0);
        assert_eq!(j.total_work(), 10.0);
        assert_eq!(j.total_data(), 100.0);
    }

    #[test]
    fn rejects_cycle() {
        let r = Job::try_new(
            0,
            "cycle",
            0.0,
            vec![1.0, 1.0, 1.0],
            &[(0, 1, 1.0), (1, 2, 1.0), (2, 0, 1.0)],
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Job::try_new(0, "e", 0.0, vec![], &[]).is_err());
        assert!(Job::try_new(0, "w", 0.0, vec![0.0], &[]).is_err());
        assert!(Job::try_new(0, "r", 0.0, vec![1.0], &[(0, 1, 1.0)]).is_err());
        assert!(Job::try_new(0, "s", 0.0, vec![1.0, 1.0], &[(0, 0, 1.0)]).is_err());
        assert!(Job::try_new(0, "d", 0.0, vec![1.0, 1.0], &[(0, 1, -1.0)]).is_err());
    }

    #[test]
    fn from_adjacency_reproduces_job() {
        let j = diamond();
        let j2 = Job::from_adjacency(
            j.id,
            j.name.clone(),
            j.arrival,
            j.tasks.iter().map(|t| t.compute).collect(),
            j.children.clone(),
            j.parents.clone(),
        )
        .unwrap();
        assert_eq!(j2.topo(), j.topo());
        for n in 0..j.n_tasks() {
            assert_eq!(j2.children[n].len(), j.children[n].len());
            for (a, b) in j2.parents[n].iter().zip(&j.parents[n]) {
                assert_eq!(a.other, b.other);
                assert_eq!(a.data.to_bits(), b.data.to_bits());
            }
        }
        // Non-u-major parent orders survive verbatim (an edge-list
        // round-trip would have reordered them).
        let j3 = Job::new(0, "rev", 0.0, vec![1.0, 1.0, 1.0], &[(1, 2, 5.0), (0, 2, 3.0)]);
        assert_eq!(j3.parents[2][0].other, 1);
        let j4 = Job::from_adjacency(
            0,
            "rev",
            0.0,
            vec![1.0, 1.0, 1.0],
            j3.children.clone(),
            j3.parents.clone(),
        )
        .unwrap();
        assert_eq!(j4.parents[2][0].other, 1);
        assert_eq!(j4.parents[2][1].other, 0);
    }

    #[test]
    fn from_adjacency_rejects_mismatched_views() {
        let j = diamond();
        let mut bad_parents = j.parents.clone();
        bad_parents[3][0].data += 1.0;
        assert!(Job::from_adjacency(
            0,
            "bad",
            0.0,
            j.tasks.iter().map(|t| t.compute).collect(),
            j.children.clone(),
            bad_parents,
        )
        .is_err());
        // A cycle hidden in consistent adjacency is still rejected.
        let mk = |o, d| Edge { other: o, data: d };
        assert!(Job::from_adjacency(
            0,
            "cyc",
            0.0,
            vec![1.0, 1.0],
            vec![vec![mk(1, 1.0)], vec![mk(0, 1.0)]],
            vec![vec![mk(1, 1.0)], vec![mk(0, 1.0)]],
        )
        .is_err());
    }

    #[test]
    fn topo_respects_dependencies() {
        let j = diamond();
        let pos: Vec<usize> = {
            let mut p = vec![0; 4];
            for (idx, &n) in j.topo().iter().enumerate() {
                p[n] = idx;
            }
            p
        };
        assert!(pos[0] < pos[1] && pos[0] < pos[2]);
        assert!(pos[1] < pos[3] && pos[2] < pos[3]);
    }
}
