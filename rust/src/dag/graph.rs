//! Graph algorithms over job DAGs: topological order, cycle detection,
//! critical path (the SLR lower bound, Eq 14), reachability.

use super::{Job, NodeId};

/// Kahn's algorithm. Returns `None` if the graph has a cycle.
pub fn try_topo_order(job: &Job) -> Option<Vec<NodeId>> {
    let n = job.n_tasks();
    let mut indeg: Vec<usize> = (0..n).map(|i| job.parents[i].len()).collect();
    let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    let mut head = 0;
    while head < queue.len() {
        let u = queue[head];
        head += 1;
        order.push(u);
        for e in &job.children[u] {
            indeg[e.other] -= 1;
            if indeg[e.other] == 0 {
                queue.push(e.other);
            }
        }
    }
    if order.len() == n {
        Some(order)
    } else {
        None
    }
}

/// Topological order; panics on cycles (jobs are validated at build time).
pub fn topo_order(job: &Job) -> Vec<NodeId> {
    try_topo_order(job).expect("cyclic job DAG")
}

/// The minimum-computation critical path of a job (paper Eq 14): the path
/// from an entry to an exit node that maximizes the sum of per-node
/// *minimum* execution times (`w_i / v_max`). Returns `(path, length_secs)`.
///
/// The denominator of SLR is the length of this path — a lower bound on any
/// schedule's makespan, since those tasks must run sequentially even on the
/// fastest executor with free communication.
pub fn critical_path_min(job: &Job, v_max: f64) -> (Vec<NodeId>, f64) {
    assert!(v_max > 0.0);
    let n = job.n_tasks();
    // dist[i] = best path length ending at i (inclusive of i).
    let mut dist = vec![0.0f64; n];
    let mut pred: Vec<Option<NodeId>> = vec![None; n];
    for &u in job.topo() {
        let w = job.tasks[u].compute / v_max;
        let mut best = 0.0;
        let mut best_p = None;
        for e in &job.parents[u] {
            if dist[e.other] > best {
                best = dist[e.other];
                best_p = Some(e.other);
            }
        }
        dist[u] = best + w;
        pred[u] = best_p;
    }
    let end = (0..n)
        .max_by(|&a, &b| dist[a].partial_cmp(&dist[b]).unwrap())
        .expect("non-empty job");
    let mut path = vec![end];
    let mut cur = end;
    while let Some(p) = pred[cur] {
        path.push(p);
        cur = p;
    }
    path.reverse();
    (path, dist[end])
}

/// Set of nodes reachable from `start` (descendants, exclusive).
pub fn descendants(job: &Job, start: NodeId) -> Vec<NodeId> {
    let n = job.n_tasks();
    let mut seen = vec![false; n];
    let mut stack = vec![start];
    let mut out = Vec::new();
    while let Some(u) = stack.pop() {
        for e in &job.children[u] {
            if !seen[e.other] {
                seen[e.other] = true;
                out.push(e.other);
                stack.push(e.other);
            }
        }
    }
    out.sort_unstable();
    out
}

/// Set of ancestors of `start` (exclusive).
pub fn ancestors(job: &Job, start: NodeId) -> Vec<NodeId> {
    let n = job.n_tasks();
    let mut seen = vec![false; n];
    let mut stack = vec![start];
    let mut out = Vec::new();
    while let Some(u) = stack.pop() {
        for e in &job.parents[u] {
            if !seen[e.other] {
                seen[e.other] = true;
                out.push(e.other);
                stack.push(e.other);
            }
        }
    }
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Job;

    fn chain() -> Job {
        Job::new(
            0,
            "chain",
            0.0,
            vec![2.0, 4.0, 6.0],
            &[(0, 1, 1.0), (1, 2, 1.0)],
        )
    }

    fn diamond() -> Job {
        Job::new(
            0,
            "diamond",
            0.0,
            vec![1.0, 2.0, 3.0, 4.0],
            &[(0, 1, 10.0), (0, 2, 20.0), (1, 3, 30.0), (2, 3, 40.0)],
        )
    }

    #[test]
    fn critical_path_of_chain_is_whole_chain() {
        let j = chain();
        let (path, len) = critical_path_min(&j, 2.0);
        assert_eq!(path, vec![0, 1, 2]);
        assert!((len - (2.0 + 4.0 + 6.0) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn critical_path_of_diamond_takes_heavier_branch() {
        let j = diamond();
        let (path, len) = critical_path_min(&j, 1.0);
        assert_eq!(path, vec![0, 2, 3]); // 1+3+4 > 1+2+4
        assert!((len - 8.0).abs() < 1e-12);
    }

    #[test]
    fn single_node_path() {
        let j = Job::new(0, "one", 0.0, vec![5.0], &[]);
        let (path, len) = critical_path_min(&j, 2.5);
        assert_eq!(path, vec![0]);
        assert!((len - 2.0).abs() < 1e-12);
    }

    #[test]
    fn reachability() {
        let j = diamond();
        assert_eq!(descendants(&j, 0), vec![1, 2, 3]);
        assert_eq!(descendants(&j, 3), Vec::<usize>::new());
        assert_eq!(ancestors(&j, 3), vec![0, 1, 2]);
        assert_eq!(ancestors(&j, 0), Vec::<usize>::new());
    }
}
