//! Upward/downward ranks (paper Eq 6–7, following HEFT).
//!
//! `rank_up(n_i)` — average execution time of `n_i` plus the maximum over
//! children of (average communication time + child's rank_up): the longest
//! remaining path to an exit node. HEFT's task priority; also a node
//! feature for MGNet.
//!
//! `rank_down(n_i)` — the longest path from an entry node down to (but not
//! including) `n_i`, using average execution and communication times.

use super::Job;

/// `rank_up` for every node of a job. `v_avg` is the average executor
/// speed, `c_avg` the average transmission speed (paper Eq 6 uses mean
/// costs so the rank is executor-independent).
pub fn rank_up(job: &Job, v_avg: f64, c_avg: f64) -> Vec<f64> {
    assert!(v_avg > 0.0 && c_avg > 0.0);
    let n = job.n_tasks();
    let mut rank = vec![0.0f64; n];
    // Reverse topological order: children before parents.
    for &u in job.topo().iter().rev() {
        let mut best = 0.0f64;
        for e in &job.children[u] {
            let cand = e.data / c_avg + rank[e.other];
            if cand > best {
                best = cand;
            }
        }
        rank[u] = job.tasks[u].compute / v_avg + best;
    }
    rank
}

/// `rank_down` for every node (Eq 7): 0 for entry nodes; otherwise the
/// maximum over parents of (parent's rank_down + parent's average execution
/// time + edge communication time).
pub fn rank_down(job: &Job, v_avg: f64, c_avg: f64) -> Vec<f64> {
    assert!(v_avg > 0.0 && c_avg > 0.0);
    let n = job.n_tasks();
    let mut rank = vec![0.0f64; n];
    for &u in job.topo() {
        let mut best = 0.0f64;
        for e in &job.parents[u] {
            let p = e.other;
            let cand = rank[p] + job.tasks[p].compute / v_avg + e.data / c_avg;
            if cand > best {
                best = cand;
            }
        }
        rank[u] = best;
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Job;

    fn diamond() -> Job {
        // 0 -> {1, 2} -> 3, w = [1,2,3,4], e = 0->1:10, 0->2:20, 1->3:30, 2->3:40
        Job::new(
            0,
            "diamond",
            0.0,
            vec![1.0, 2.0, 3.0, 4.0],
            &[(0, 1, 10.0), (0, 2, 20.0), (1, 3, 30.0), (2, 3, 40.0)],
        )
    }

    #[test]
    fn rank_up_hand_computed() {
        let j = diamond();
        let r = rank_up(&j, 1.0, 10.0);
        // exit: rank[3] = 4
        assert!((r[3] - 4.0).abs() < 1e-12);
        // rank[1] = 2 + (30/10 + 4) = 9 ; rank[2] = 3 + (40/10 + 4) = 11
        assert!((r[1] - 9.0).abs() < 1e-12);
        assert!((r[2] - 11.0).abs() < 1e-12);
        // rank[0] = 1 + max(10/10 + 9, 20/10 + 11) = 1 + 13 = 14
        assert!((r[0] - 14.0).abs() < 1e-12);
    }

    #[test]
    fn rank_down_hand_computed() {
        let j = diamond();
        let r = rank_down(&j, 1.0, 10.0);
        assert!((r[0] - 0.0).abs() < 1e-12);
        // rank_down[1] = 0 + 1 + 1 = 2 ; rank_down[2] = 0 + 1 + 2 = 3
        assert!((r[1] - 2.0).abs() < 1e-12);
        assert!((r[2] - 3.0).abs() < 1e-12);
        // rank_down[3] = max(2 + 2 + 3, 3 + 3 + 4) = 10
        assert!((r[3] - 10.0).abs() < 1e-12);
    }

    #[test]
    fn rank_up_decreases_along_edges() {
        let j = diamond();
        let r = rank_up(&j, 2.3, 55.0);
        for u in 0..j.n_tasks() {
            for e in &j.children[u] {
                assert!(
                    r[u] > r[e.other],
                    "rank_up must strictly decrease along edges"
                );
            }
        }
    }

    #[test]
    fn entry_rank_up_bounds_critical_path() {
        // rank_up at the entry with c -> inf equals the computation-only
        // critical path length.
        let j = diamond();
        let r = rank_up(&j, 1.0, 1e18);
        let (_, cp) = crate::dag::graph::critical_path_min(&j, 1.0);
        assert!((r[0] - cp).abs() < 1e-6);
    }
}
