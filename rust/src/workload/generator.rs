//! Random workload sampling per the paper's experiment settings:
//! query shape uniform over the 22 TPC-H plans (or a configured subset),
//! scale factor uniform over {2, 5, 10, 50, 80, 100} GB, and arrival
//! times either all-zero (batch) or a Poisson process with mean
//! inter-arrival 45 s (continuous).

use super::tpch;
use super::Workload;
use crate::config::{Arrival, WorkloadConfig};
use crate::util::rng::{Rng, STREAM_WORKLOAD};

/// Deterministic workload generator: (config, seed) → workload.
#[derive(Debug, Clone)]
pub struct WorkloadGenerator {
    cfg: WorkloadConfig,
    seed: u64,
}

impl WorkloadGenerator {
    pub fn new(cfg: WorkloadConfig, seed: u64) -> WorkloadGenerator {
        cfg.validate().expect("invalid workload config");
        WorkloadGenerator { cfg, seed }
    }

    pub fn config(&self) -> &WorkloadConfig {
        &self.cfg
    }

    /// Generate the workload. Same (config, seed) → identical jobs.
    pub fn generate(&self) -> Workload {
        let mut rng = Rng::stream(self.seed, STREAM_WORKLOAD);
        let shapes: Vec<tpch::Shape> = if self.cfg.query_ids.is_empty() {
            tpch::all_shapes()
        } else {
            self.cfg.query_ids.iter().map(|&q| tpch::shape(q)).collect()
        };
        let mut jobs = Vec::with_capacity(self.cfg.n_jobs);
        let mut t = 0.0f64;
        for id in 0..self.cfg.n_jobs {
            let shape = rng.choice(&shapes);
            let size = *rng.choice(&self.cfg.sizes_gb);
            let arrival = match self.cfg.arrival {
                Arrival::Batch => 0.0,
                Arrival::Poisson { mean_interval } => {
                    // First job arrives at t = 0 (paper §5.3.3); the rest
                    // follow the Poisson process.
                    if id == 0 {
                        0.0
                    } else {
                        t += rng.exponential(mean_interval);
                        t
                    }
                }
            };
            jobs.push(shape.instantiate(id, size, arrival));
        }
        Workload::new(jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    #[test]
    fn batch_workload_all_at_zero() {
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(12), 1).generate();
        assert_eq!(w.n_jobs(), 12);
        assert!(w.is_batch());
        assert!(w.n_tasks() > 12);
    }

    #[test]
    fn continuous_arrivals_increase() {
        let w = WorkloadGenerator::new(WorkloadConfig::continuous(20), 2).generate();
        assert_eq!(w.jobs[0].arrival, 0.0);
        for pair in w.jobs.windows(2) {
            assert!(pair[1].arrival >= pair[0].arrival);
        }
        assert!(w.jobs.last().unwrap().arrival > 0.0);
    }

    #[test]
    fn continuous_mean_interval_roughly_45s() {
        let mut cfg = WorkloadConfig::continuous(400);
        cfg.sizes_gb = vec![2.0];
        let w = WorkloadGenerator::new(cfg, 3).generate();
        let last = w.jobs.last().unwrap().arrival;
        let mean = last / 399.0;
        assert!((mean - 45.0).abs() < 6.0, "mean interval {mean}");
    }

    #[test]
    fn deterministic_by_seed() {
        let g = WorkloadGenerator::new(WorkloadConfig::small_batch(8), 99);
        let a = g.generate();
        let b = g.generate();
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.n_tasks(), y.n_tasks());
        }
        let c = WorkloadGenerator::new(WorkloadConfig::small_batch(8), 100).generate();
        let same_names = a
            .jobs
            .iter()
            .zip(&c.jobs)
            .filter(|(x, y)| x.name == y.name)
            .count();
        assert!(same_names < a.n_jobs(), "different seeds should differ");
    }

    #[test]
    fn respects_query_subset() {
        let mut cfg = WorkloadConfig::small_batch(10);
        cfg.query_ids = vec![1, 6];
        let w = WorkloadGenerator::new(cfg, 5).generate();
        for j in &w.jobs {
            assert!(
                j.name.contains("q01") || j.name.contains("q06"),
                "unexpected {}",
                j.name
            );
        }
    }

    #[test]
    fn sizes_come_from_config() {
        let mut cfg = WorkloadConfig::small_batch(30);
        cfg.sizes_gb = vec![5.0];
        let w = WorkloadGenerator::new(cfg, 6).generate();
        for j in &w.jobs {
            assert!(j.name.ends_with("-5g"), "{}", j.name);
        }
    }
}
