//! TPC-H derived job shapes (paper §5.2).
//!
//! The paper extracts "task dependencies and workload size" from TPC-H
//! queries executed on a real data-processing platform and replays them in
//! a simulator. We reproduce that structural distribution: each of the 22
//! TPC-H queries is hand-encoded as the operator DAG a Spark-SQL-style
//! planner produces (scans → join tree → aggregation → sort/limit, plus
//! scalar/semi-join subplans), with per-node computation and per-edge data
//! volumes proportional to the scale factor (2/5/10/50/80/100 GB).
//!
//! Table size fractions follow the TPC-H schema (lineitem dominates):
//! L≈0.75, O≈0.16, PS≈0.11, P≈0.03, C≈0.02, S≈0.002, N/R tiny.
//!
//! Units: a node's `work_factor` is GHz·seconds per GB of scale factor; an
//! edge's `data_factor` is MB per GB of scale factor. A q5 job at 50 GB on
//! a 3 GHz executor therefore takes `50 * Σwork / 3` seconds of pure
//! compute.

use crate::dag::Job;

/// TPC-H table size fractions of the total database volume.
pub const L: f64 = 0.75; // lineitem
pub const O: f64 = 0.16; // orders
pub const PS: f64 = 0.11; // partsupp
pub const P: f64 = 0.03; // part
pub const C: f64 = 0.02; // customer
pub const S: f64 = 0.002; // supplier
pub const N: f64 = 0.0002; // nation
pub const R: f64 = 0.0001; // region

/// Number of distinct query shapes (TPC-H Q1–Q22).
pub const N_QUERIES: usize = 22;

/// Cost model knobs (GHz·s per GB scanned / joined / aggregated).
const SCAN_CPG: f64 = 6.0; // full-table scan cost per GB of table
const JOIN_CPG: f64 = 9.0; // join cost per GB of combined input
const AGG_CPG: f64 = 3.0; // aggregation cost per GB of input
const SORT_CPG: f64 = 4.0; // sort cost per GB of input
/// Fraction of scanned bytes surviving the scan's filter, in MB/GB.
const SCAN_OUT: f64 = 300.0;
/// Shuffle compression/projection factor applied at joins.
const JOIN_OUT: f64 = 0.55;
/// Aggregations collapse data hard.
const AGG_OUT: f64 = 0.05;

/// A query shape: computation factors per node and data factors per edge,
/// all scaled by the job's size in GB at instantiation.
#[derive(Debug, Clone)]
pub struct Shape {
    pub qid: usize,
    pub labels: Vec<&'static str>,
    pub work_factors: Vec<f64>,
    /// (src, dst, data_factor MB/GB)
    pub edges: Vec<(usize, usize, f64)>,
}

/// Incremental DAG builder used by the per-query constructors below.
struct B {
    labels: Vec<&'static str>,
    work: Vec<f64>,
    edges: Vec<(usize, usize, f64)>,
    /// Data volume factor flowing out of each node (MB/GB), tracked so
    /// joins/aggs can size their cost and output from their inputs.
    out: Vec<f64>,
}

impl B {
    fn new() -> B {
        B {
            labels: Vec::new(),
            work: Vec::new(),
            edges: Vec::new(),
            out: Vec::new(),
        }
    }

    /// Table scan node; returns its id.
    fn scan(&mut self, label: &'static str, frac: f64) -> usize {
        self.labels.push(label);
        self.work.push((SCAN_CPG * frac).max(0.01));
        self.out.push((SCAN_OUT * frac).max(0.5));
        self.work.len() - 1
    }

    /// Generic operator combining `inputs`; `cost_per_gb` applies to the
    /// summed input volume and `out_ratio` shrinks/grows it.
    fn op(
        &mut self,
        label: &'static str,
        inputs: &[usize],
        cost_cpg: f64,
        out_ratio: f64,
    ) -> usize {
        let in_mb: f64 = inputs.iter().map(|&i| self.out[i]).sum();
        let in_gb = in_mb / 1000.0;
        self.labels.push(label);
        self.work.push((cost_cpg * in_gb).max(0.02));
        self.out.push((in_mb * out_ratio).max(0.25));
        let id = self.work.len() - 1;
        for &i in inputs {
            self.edges.push((i, id, self.out[i]));
        }
        id
    }

    fn join(&mut self, a: usize, b: usize) -> usize {
        self.op("join", &[a, b], JOIN_CPG, JOIN_OUT)
    }

    fn agg(&mut self, input: usize) -> usize {
        self.op("agg", &[input], AGG_CPG, AGG_OUT)
    }

    fn sort(&mut self, input: usize) -> usize {
        self.op("sort", &[input], SORT_CPG, 1.0)
    }

    fn finish(self, qid: usize) -> Shape {
        Shape {
            qid,
            labels: self.labels,
            work_factors: self.work,
            edges: self.edges,
        }
    }
}

/// Build the shape of TPC-H query `qid` (1-based, 1..=22).
pub fn shape(qid: usize) -> Shape {
    assert!((1..=N_QUERIES).contains(&qid), "qid must be 1..=22");
    let mut b = B::new();
    match qid {
        // Q1: pricing summary — scan lineitem, aggregate, order.
        1 => {
            let l = b.scan("scan:L", L);
            let a = b.agg(l);
            b.sort(a);
        }
        // Q2: minimum-cost supplier — 5-way join + correlated min subquery.
        2 => {
            let p = b.scan("scan:P", P);
            let ps = b.scan("scan:PS", PS);
            let s = b.scan("scan:S", S);
            let n = b.scan("scan:N", N);
            let r = b.scan("scan:R", R);
            let j1 = b.join(p, ps);
            let j2 = b.join(j1, s);
            let j3 = b.join(j2, n);
            let j4 = b.join(j3, r);
            // scalar subquery: min supplycost over PS⋈S⋈N⋈R
            let ps2 = b.scan("scan:PS'", PS);
            let s2 = b.scan("scan:S'", S);
            let sj = b.join(ps2, s2);
            let sub = b.agg(sj);
            let f = b.op("filter-min", &[j4, sub], JOIN_CPG, 0.2);
            b.sort(f);
        }
        // Q3: shipping priority — C⋈O⋈L, aggregate, top-k.
        3 => {
            let c = b.scan("scan:C", C);
            let o = b.scan("scan:O", O);
            let l = b.scan("scan:L", L);
            let j1 = b.join(c, o);
            let j2 = b.join(j1, l);
            let a = b.agg(j2);
            b.sort(a);
        }
        // Q4: order priority checking — O semi-join L(exists).
        4 => {
            let o = b.scan("scan:O", O);
            let l = b.scan("scan:L", L);
            let semi = b.op("semijoin", &[o, l], JOIN_CPG, 0.25);
            let a = b.agg(semi);
            b.sort(a);
        }
        // Q5: local supplier volume — 6-way join.
        5 => {
            let c = b.scan("scan:C", C);
            let o = b.scan("scan:O", O);
            let l = b.scan("scan:L", L);
            let s = b.scan("scan:S", S);
            let n = b.scan("scan:N", N);
            let r = b.scan("scan:R", R);
            let j1 = b.join(c, o);
            let j2 = b.join(j1, l);
            let j3 = b.join(j2, s);
            let j4 = b.join(n, r);
            let j5 = b.join(j3, j4);
            let a = b.agg(j5);
            b.sort(a);
        }
        // Q6: forecasting revenue — single scan + aggregate.
        6 => {
            let l = b.scan("scan:L", L);
            b.agg(l);
        }
        // Q7: volume shipping — S⋈L⋈O⋈C with two nation dims.
        7 => {
            let s = b.scan("scan:S", S);
            let l = b.scan("scan:L", L);
            let o = b.scan("scan:O", O);
            let c = b.scan("scan:C", C);
            let n1 = b.scan("scan:N1", N);
            let n2 = b.scan("scan:N2", N);
            let j1 = b.join(s, l);
            let j2 = b.join(j1, o);
            let j3 = b.join(j2, c);
            let j4 = b.join(j3, n1);
            let j5 = b.join(j4, n2);
            let a = b.agg(j5);
            b.sort(a);
        }
        // Q8: national market share — widest join tree (8 tables).
        8 => {
            let p = b.scan("scan:P", P);
            let l = b.scan("scan:L", L);
            let s = b.scan("scan:S", S);
            let o = b.scan("scan:O", O);
            let c = b.scan("scan:C", C);
            let n1 = b.scan("scan:N1", N);
            let n2 = b.scan("scan:N2", N);
            let r = b.scan("scan:R", R);
            let j1 = b.join(p, l);
            let j2 = b.join(j1, s);
            let j3 = b.join(j2, o);
            let cn = b.join(c, n1);
            let j4 = b.join(cn, r);
            let j5 = b.join(j3, j4);
            let j6 = b.join(j5, n2);
            let a = b.agg(j6);
            b.sort(a);
        }
        // Q9: product type profit — P⋈L⋈S⋈PS⋈O⋈N.
        9 => {
            let p = b.scan("scan:P", P);
            let l = b.scan("scan:L", L);
            let s = b.scan("scan:S", S);
            let ps = b.scan("scan:PS", PS);
            let o = b.scan("scan:O", O);
            let n = b.scan("scan:N", N);
            let j1 = b.join(p, l);
            let j2 = b.join(j1, ps);
            let j3 = b.join(j2, s);
            let j4 = b.join(j3, o);
            let j5 = b.join(j4, n);
            let a = b.agg(j5);
            b.sort(a);
        }
        // Q10: returned items — C⋈O⋈L⋈N, top 20.
        10 => {
            let c = b.scan("scan:C", C);
            let o = b.scan("scan:O", O);
            let l = b.scan("scan:L", L);
            let n = b.scan("scan:N", N);
            let j1 = b.join(c, o);
            let j2 = b.join(j1, l);
            let j3 = b.join(j2, n);
            let a = b.agg(j3);
            b.sort(a);
        }
        // Q11: important stock — PS⋈S⋈N twice (group + global threshold).
        11 => {
            let ps = b.scan("scan:PS", PS);
            let s = b.scan("scan:S", S);
            let n = b.scan("scan:N", N);
            let j1 = b.join(ps, s);
            let j2 = b.join(j1, n);
            let a1 = b.agg(j2);
            let ps2 = b.scan("scan:PS'", PS);
            let s2 = b.scan("scan:S'", S);
            let j3 = b.join(ps2, s2);
            let a2 = b.agg(j3);
            let f = b.op("filter-having", &[a1, a2], AGG_CPG, 0.5);
            b.sort(f);
        }
        // Q12: shipping modes — O⋈L.
        12 => {
            let o = b.scan("scan:O", O);
            let l = b.scan("scan:L", L);
            let j = b.join(o, l);
            let a = b.agg(j);
            b.sort(a);
        }
        // Q13: customer distribution — C left-outer-join O, double agg.
        13 => {
            let c = b.scan("scan:C", C);
            let o = b.scan("scan:O", O);
            let j = b.op("outerjoin", &[c, o], JOIN_CPG, 0.8);
            let a1 = b.agg(j);
            let a2 = b.agg(a1);
            b.sort(a2);
        }
        // Q14: promotion effect — L⋈P.
        14 => {
            let l = b.scan("scan:L", L);
            let p = b.scan("scan:P", P);
            let j = b.join(l, p);
            b.agg(j);
        }
        // Q15: top supplier — revenue view (L agg), max subquery, join S.
        15 => {
            let l = b.scan("scan:L", L);
            let rev = b.agg(l);
            let mx = b.agg(rev);
            let s = b.scan("scan:S", S);
            let j1 = b.op("filter-max", &[rev, mx], AGG_CPG, 0.5);
            let j2 = b.join(j1, s);
            b.sort(j2);
        }
        // Q16: parts/supplier relationship — PS⋈P anti-join S subquery.
        16 => {
            let ps = b.scan("scan:PS", PS);
            let p = b.scan("scan:P", P);
            let s = b.scan("scan:S", S);
            let j1 = b.join(ps, p);
            let anti = b.op("antijoin", &[j1, s], JOIN_CPG, 0.6);
            let a = b.agg(anti);
            b.sort(a);
        }
        // Q17: small-quantity-order revenue — L⋈P with per-part avg subplan.
        17 => {
            let l = b.scan("scan:L", L);
            let p = b.scan("scan:P", P);
            let j1 = b.join(l, p);
            let l2 = b.scan("scan:L'", L);
            let avg = b.agg(l2);
            let f = b.op("filter-avg", &[j1, avg], JOIN_CPG, 0.1);
            b.agg(f);
        }
        // Q18: large volume customer — group L subquery ⋈ O ⋈ C ⋈ L.
        18 => {
            let l1 = b.scan("scan:L1", L);
            let big = b.agg(l1);
            let o = b.scan("scan:O", O);
            let c = b.scan("scan:C", C);
            let l2 = b.scan("scan:L2", L);
            let j1 = b.op("semijoin", &[o, big], JOIN_CPG, 0.3);
            let j2 = b.join(j1, c);
            let j3 = b.join(j2, l2);
            let a = b.agg(j3);
            b.sort(a);
        }
        // Q19: discounted revenue — L⋈P, three OR predicate branches.
        19 => {
            let l = b.scan("scan:L", L);
            let p = b.scan("scan:P", P);
            let f1 = b.op("filter-b1", &[l], AGG_CPG, 0.2);
            let f2 = b.op("filter-b2", &[l], AGG_CPG, 0.2);
            let f3 = b.op("filter-b3", &[l], AGG_CPG, 0.2);
            let u = b.op("union", &[f1, f2, f3], AGG_CPG, 1.0);
            let j = b.join(u, p);
            b.agg(j);
        }
        // Q20: potential part promotion — nested subqueries feeding S⋈N.
        20 => {
            let p = b.scan("scan:P", P);
            let ps = b.scan("scan:PS", PS);
            let l = b.scan("scan:L", L);
            let s = b.scan("scan:S", S);
            let n = b.scan("scan:N", N);
            let sub1 = b.op("semijoin", &[ps, p], JOIN_CPG, 0.4);
            let agg_l = b.agg(l);
            let sub2 = b.op("filter-qty", &[sub1, agg_l], JOIN_CPG, 0.3);
            let j1 = b.op("semijoin", &[s, sub2], JOIN_CPG, 0.4);
            let j2 = b.join(j1, n);
            b.sort(j2);
        }
        // Q21: suppliers who kept orders waiting — L three ways.
        21 => {
            let s = b.scan("scan:S", S);
            let l1 = b.scan("scan:L1", L);
            let o = b.scan("scan:O", O);
            let n = b.scan("scan:N", N);
            let l2 = b.scan("scan:L2", L);
            let l3 = b.scan("scan:L3", L);
            let j1 = b.join(s, l1);
            let j2 = b.join(j1, o);
            let j3 = b.join(j2, n);
            let semi = b.op("semijoin", &[j3, l2], JOIN_CPG, 0.5);
            let anti = b.op("antijoin", &[semi, l3], JOIN_CPG, 0.5);
            let a = b.agg(anti);
            b.sort(a);
        }
        // Q22: global sales opportunity — C with avg subquery, anti-join O.
        22 => {
            let c = b.scan("scan:C", C);
            let c2 = b.scan("scan:C'", C);
            let o = b.scan("scan:O", O);
            let avg = b.agg(c2);
            let f = b.op("filter-avg", &[c, avg], AGG_CPG, 0.4);
            let anti = b.op("antijoin", &[f, o], JOIN_CPG, 0.6);
            let a = b.agg(anti);
            b.sort(a);
        }
        _ => unreachable!(),
    }
    b.finish(qid)
}

impl Shape {
    /// Instantiate this shape as a concrete [`Job`] at `size_gb` scale.
    pub fn instantiate(&self, job_id: usize, size_gb: f64, arrival: f64) -> Job {
        assert!(size_gb > 0.0);
        let name = format!("tpch-q{:02}-{}g", self.qid, size_gb);
        let computes: Vec<f64> = self.work_factors.iter().map(|w| w * size_gb).collect();
        let edges: Vec<(usize, usize, f64)> = self
            .edges
            .iter()
            .map(|&(u, v, d)| (u, v, d * size_gb))
            .collect();
        Job::new(job_id, name, arrival, computes, &edges)
    }

    pub fn n_nodes(&self) -> usize {
        self.work_factors.len()
    }
}

/// All 22 shapes (cached construction is cheap; call freely).
pub fn all_shapes() -> Vec<Shape> {
    (1..=N_QUERIES).map(shape).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_22_shapes_build_valid_dags() {
        for s in all_shapes() {
            let job = s.instantiate(0, 10.0, 0.0);
            assert!(job.n_tasks() >= 2, "q{} too small", s.qid);
            assert!(job.n_tasks() <= 26, "q{} too big: {}", s.qid, job.n_tasks());
            // Exactly one exit node (the final sort/agg).
            assert_eq!(job.exits().len(), 1, "q{} should have one sink", s.qid);
            // Entries are scans.
            for e in job.entries() {
                assert!(
                    s.labels[e].starts_with("scan"),
                    "q{} entry {} is {}",
                    s.qid,
                    e,
                    s.labels[e]
                );
            }
        }
    }

    #[test]
    fn shapes_are_distinct() {
        let shapes = all_shapes();
        let mut sigs: Vec<(usize, usize)> = shapes
            .iter()
            .map(|s| (s.n_nodes(), s.edges.len()))
            .collect();
        sigs.sort_unstable();
        sigs.dedup();
        // Not all 22 need unique (nodes, edges) signatures, but most should.
        assert!(sigs.len() >= 15, "only {} distinct signatures", sigs.len());
    }

    #[test]
    fn work_scales_linearly_with_size() {
        let s = shape(5);
        let j2 = s.instantiate(0, 2.0, 0.0);
        let j100 = s.instantiate(0, 100.0, 0.0);
        let ratio = j100.total_work() / j2.total_work();
        assert!((ratio - 50.0).abs() < 1e-9);
        let dratio = j100.total_data() / j2.total_data();
        assert!((dratio - 50.0).abs() < 1e-9);
    }

    #[test]
    fn lineitem_queries_dominate_cost() {
        // Q1 scans lineitem; Q6 too. Their scan node must dominate Q11's
        // (partsupp-based) at equal scale.
        let q1 = shape(1).instantiate(0, 10.0, 0.0);
        let q11 = shape(11).instantiate(0, 10.0, 0.0);
        let q1_max = q1.tasks.iter().map(|t| t.compute).fold(0.0, f64::max);
        let q11_max = q11.tasks.iter().map(|t| t.compute).fold(0.0, f64::max);
        assert!(q1_max > q11_max);
    }

    #[test]
    fn q8_is_the_widest_join_tree() {
        let q8 = shape(8);
        let scans = q8
            .labels
            .iter()
            .filter(|l| l.starts_with("scan"))
            .count();
        assert_eq!(scans, 8);
    }

    #[test]
    fn shape_panics_on_bad_qid() {
        assert!(std::panic::catch_unwind(|| shape(0)).is_err());
        assert!(std::panic::catch_unwind(|| shape(23)).is_err());
    }
}
