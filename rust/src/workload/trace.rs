//! Workload trace serialization: save generated workloads to JSON and load
//! them back bit-identically, so experiment runs can be archived and
//! replayed (`lachesis workload --out trace.json` / `--trace trace.json`).

use super::Workload;
use crate::dag::Job;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

/// Serialize a workload to a JSON value.
pub fn to_json(w: &Workload) -> Json {
    let jobs: Vec<Json> = w
        .jobs
        .iter()
        .map(|j| {
            let computes: Vec<f64> = j.tasks.iter().map(|t| t.compute).collect();
            let edges: Vec<Json> = (0..j.n_tasks())
                .flat_map(|u| {
                    j.children[u].iter().map(move |e| {
                        Json::Arr(vec![
                            Json::from(u),
                            Json::from(e.other),
                            Json::from(e.data),
                        ])
                    })
                })
                .collect();
            Json::from_pairs(vec![
                ("name", Json::from(j.name.clone())),
                ("arrival", Json::from(j.arrival)),
                ("computes", Json::from(computes)),
                ("edges", Json::Arr(edges)),
            ])
        })
        .collect();
    Json::from_pairs(vec![
        ("format", Json::from("lachesis-trace-v1")),
        ("jobs", Json::Arr(jobs)),
    ])
}

/// Deserialize a workload from a JSON value, revalidating every DAG.
pub fn from_json(v: &Json) -> Result<Workload> {
    let fmt = v.req_str("format").map_err(|e| anyhow!("{e}"))?;
    if fmt != "lachesis-trace-v1" {
        anyhow::bail!("unsupported trace format '{fmt}'");
    }
    let jobs_json = v
        .req("jobs")
        .map_err(|e| anyhow!("{e}"))?
        .as_arr()
        .ok_or_else(|| anyhow!("'jobs' must be an array"))?;
    let mut jobs = Vec::with_capacity(jobs_json.len());
    for (id, jj) in jobs_json.iter().enumerate() {
        let name = jj.req_str("name").map_err(|e| anyhow!("{e}"))?.to_string();
        let arrival = jj.req_f64("arrival").map_err(|e| anyhow!("{e}"))?;
        let computes = jj
            .req("computes")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("'computes' must be an array"))?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow!("bad compute")))
            .collect::<Result<Vec<f64>>>()?;
        let edges = jj
            .req("edges")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("'edges' must be an array"))?
            .iter()
            .map(|e| {
                let u = e.at(0).and_then(Json::as_usize);
                let v = e.at(1).and_then(Json::as_usize);
                let d = e.at(2).and_then(Json::as_f64);
                match (u, v, d) {
                    (Some(u), Some(v), Some(d)) => Ok((u, v, d)),
                    _ => Err(anyhow!("bad edge triple")),
                }
            })
            .collect::<Result<Vec<_>>>()?;
        let job = Job::try_new(id, name, arrival, computes, &edges)
            .with_context(|| format!("trace job {id}"))?;
        jobs.push(job);
    }
    Ok(Workload::new(jobs))
}

/// Save a workload trace to a file (pretty JSON).
pub fn save(w: &Workload, path: &str) -> Result<()> {
    std::fs::write(path, to_json(w).to_pretty()).with_context(|| format!("writing {path}"))
}

/// Load a workload trace from a file.
pub fn load(path: &str) -> Result<Workload> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let v = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    from_json(&v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::workload::WorkloadGenerator;

    #[test]
    fn roundtrip_preserves_everything() {
        let w = WorkloadGenerator::new(WorkloadConfig::continuous(6), 11).generate();
        let j = to_json(&w);
        let w2 = from_json(&j).unwrap();
        assert_eq!(w.n_jobs(), w2.n_jobs());
        assert_eq!(w.n_tasks(), w2.n_tasks());
        assert_eq!(w.n_edges(), w2.n_edges());
        for (a, b) in w.jobs.iter().zip(&w2.jobs) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.arrival, b.arrival);
            for (ta, tb) in a.tasks.iter().zip(&b.tasks) {
                assert_eq!(ta.compute, tb.compute);
            }
            for u in 0..a.n_tasks() {
                assert_eq!(a.children[u].len(), b.children[u].len());
                for (ea, eb) in a.children[u].iter().zip(&b.children[u]) {
                    assert_eq!(ea.other, eb.other);
                    assert_eq!(ea.data, eb.data);
                }
            }
        }
    }

    #[test]
    fn rejects_wrong_format() {
        let v = Json::parse(r#"{"format": "other", "jobs": []}"#).unwrap();
        assert!(from_json(&v).is_err());
    }

    #[test]
    fn rejects_cyclic_trace() {
        let text = r#"{"format":"lachesis-trace-v1","jobs":[{"name":"x","arrival":0,
            "computes":[1,1],"edges":[[0,1,1],[1,0,1]]}]}"#;
        let v = Json::parse(text).unwrap();
        assert!(from_json(&v).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(3), 4).generate();
        let path = "/tmp/lachesis_trace_test.json";
        save(&w, path).unwrap();
        let w2 = load(path).unwrap();
        assert_eq!(w.n_tasks(), w2.n_tasks());
        std::fs::remove_file(path).ok();
    }
}
