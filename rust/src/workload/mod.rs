//! Workload generation: TPC-H shaped jobs under batch or Poisson
//! (continuous-mode) arrival processes, plus JSON trace save/load so
//! experiments can be replayed bit-identically.

pub mod generator;
pub mod tpch;
pub mod trace;

pub use generator::WorkloadGenerator;

use crate::dag::Job;

/// A concrete set of jobs to schedule. Jobs are ordered by arrival time.
#[derive(Debug, Clone)]
pub struct Workload {
    pub jobs: Vec<Job>,
}

impl Workload {
    pub fn new(mut jobs: Vec<Job>) -> Workload {
        jobs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        for (i, j) in jobs.iter_mut().enumerate() {
            j.id = i;
        }
        Workload { jobs }
    }

    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    pub fn n_tasks(&self) -> usize {
        self.jobs.iter().map(|j| j.n_tasks()).sum()
    }

    pub fn n_edges(&self) -> usize {
        self.jobs.iter().map(|j| j.n_edges()).sum()
    }

    /// Total computation volume (GHz·s) across all jobs — the numerator of
    /// the paper's speedup metric divides this by the fastest speed.
    pub fn total_work(&self) -> f64 {
        self.jobs.iter().map(|j| j.total_work()).sum()
    }

    /// True if every job arrives at t=0 (batch mode).
    pub fn is_batch(&self) -> bool {
        self.jobs.iter().all(|j| j.arrival == 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Job;

    #[test]
    fn workload_sorts_and_reindexes_by_arrival() {
        let j1 = Job::new(0, "late", 10.0, vec![1.0], &[]);
        let j2 = Job::new(1, "early", 0.0, vec![1.0], &[]);
        let w = Workload::new(vec![j1, j2]);
        assert_eq!(w.jobs[0].name, "early");
        assert_eq!(w.jobs[0].id, 0);
        assert_eq!(w.jobs[1].name, "late");
        assert_eq!(w.jobs[1].id, 1);
        assert!(!w.is_batch());
    }
}
