//! Deterministic fault injection: executor crashes, recoveries and
//! stragglers, pre-generated from a `(FaultConfig, seed, n_executors)`
//! triple so every fault run is exactly as reproducible as a fault-free
//! one.
//!
//! The subsystem splits in two:
//!
//! * **Planning (this module)** — [`FaultPlan::generate`] draws, per
//!   executor, a Poisson process of incidents over `[0, horizon]`. Each
//!   incident is either a *straggle* (in-flight work on the executor
//!   stretches by the config's slowdown factor, queued-but-unstarted
//!   bookings are returned to the scheduler) or a *crash* (every
//!   unfinished booking on the executor is lost; transient crashes
//!   recover after an exponential outage, permanent ones never do). Each
//!   executor draws from its own forked sub-stream of the master fault
//!   stream, so plans are stable under changes to other executors' draws.
//! * **Recovery (sim/state.rs)** — `SimState::apply_crash` /
//!   `apply_straggle` cancel the affected bookings, roll back every
//!   incremental cache, promote surviving duplicate copies to primary
//!   (duplication-as-fault-tolerance: a task with a live copy elsewhere
//!   needs no rescheduling), and re-enqueue truly lost tasks onto the
//!   executable frontier for the scheduler to place again.
//!
//! Completed copies survive a crash: the model assumes task outputs are
//! persisted off-executor once a copy finishes (the usual shuffle-to-
//! distributed-store assumption), so only unfinished work is lost.

use crate::config::FaultConfig;
use crate::net::NetworkModel;
use crate::util::rng::{Rng, STREAM_FAULT};

/// Salt separating the per-rack incident streams from the per-executor
/// ones (which fork `0..n_exec` off the master fault stream). Pure
/// `stream_n` members, so adding rack draws never perturbs the
/// per-executor plan — `rack_rate = 0` stays bit-identical.
const STREAM_RACK_SALT: u64 = 0x5AC4_FA11_D0C4_BEEF;

/// What happens to an executor at a fault event's time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The executor goes down, losing all unfinished bookings. `recovery`
    /// is the absolute time it comes back up; `None` means permanent.
    Crash { recovery: Option<f64> },
    /// In-flight work on the executor stretches: its remaining duration
    /// is multiplied by `factor`; queued bookings return to the frontier.
    Straggle { factor: f64 },
}

/// One pre-generated fault event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub exec: usize,
    pub time: f64,
    pub kind: FaultKind,
}

/// A deterministic, time-sorted fault schedule for one simulation run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan — attaching it to a simulator is bit-identical to
    /// attaching nothing.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Pre-generate the fault schedule for `n_exec` executors. Same
    /// `(cfg, n_exec, seed)` → identical plan, regardless of what else
    /// the simulation does. If the draw would leave *every* executor
    /// permanently dead, the latest permanent crash is demoted to a
    /// transient one (outage = `mttr`), so a workload always retains at
    /// least one executor to finish on.
    pub fn generate(cfg: &FaultConfig, n_exec: usize, seed: u64) -> FaultPlan {
        cfg.validate().expect("invalid fault config");
        if cfg.is_none() || n_exec == 0 {
            return FaultPlan::none();
        }
        let mean_gap = 1.0 / cfg.crash_rate;
        let mut root = Rng::stream(seed, STREAM_FAULT);
        let mut events: Vec<FaultEvent> = Vec::new();
        let mut permanent: Vec<usize> = Vec::new(); // indices into `events`
        for exec in 0..n_exec {
            let mut rng = root.fork(exec as u64);
            let mut t = rng.exponential(mean_gap);
            while t < cfg.horizon {
                if rng.chance(cfg.straggler_prob) {
                    events.push(FaultEvent {
                        exec,
                        time: t,
                        kind: FaultKind::Straggle {
                            factor: cfg.slowdown,
                        },
                    });
                    t += rng.exponential(mean_gap);
                } else if rng.chance(cfg.p_permanent) {
                    permanent.push(events.len());
                    events.push(FaultEvent {
                        exec,
                        time: t,
                        kind: FaultKind::Crash { recovery: None },
                    });
                    break; // nothing further can happen to a dead executor
                } else {
                    // Transient outage; the next incident can only occur
                    // after the executor is back up.
                    let up = t + rng.exponential(cfg.mttr).max(1e-3);
                    events.push(FaultEvent {
                        exec,
                        time: t,
                        kind: FaultKind::Crash { recovery: Some(up) },
                    });
                    t = up + rng.exponential(mean_gap);
                }
            }
        }
        // Keep the cluster schedulable: demote the latest permanent crash
        // when every executor drew one.
        if permanent.len() == n_exec && n_exec > 0 {
            let &last = permanent
                .iter()
                .max_by(|&&a, &&b| {
                    events[a]
                        .time
                        .total_cmp(&events[b].time)
                        .then(events[a].exec.cmp(&events[b].exec))
                })
                .expect("non-empty permanent list");
            let t = events[last].time;
            events[last].kind = FaultKind::Crash {
                recovery: Some(t + cfg.mttr),
            };
        }
        // Time order with a deterministic executor tie-break — the order
        // the simulator will inject them in.
        events.sort_by(|a, b| a.time.total_cmp(&b.time).then(a.exec.cmp(&b.exec)));
        FaultPlan { events }
    }

    /// [`FaultPlan::generate`] plus the topology-correlated rack-failure
    /// mode: each rack additionally draws a Poisson process of
    /// whole-rack incidents (ToR switch / PDU loss) at
    /// `cfg.rack_rate`; one incident downs *every* executor in the rack
    /// at the same time and recovers them at the same time. Rack
    /// incidents are always transient (a permanent whole-rack loss
    /// would leave single-rack topologies unschedulable). With
    /// `rack_rate = 0` the result is bit-identical to
    /// [`FaultPlan::generate`], so flat runs and pre-topology configs
    /// are unaffected.
    pub fn generate_with_topology(
        cfg: &FaultConfig,
        net: &NetworkModel,
        seed: u64,
    ) -> FaultPlan {
        let mut plan = FaultPlan::generate(cfg, net.len(), seed);
        if cfg.rack_rate <= 0.0 {
            return plan;
        }
        cfg.validate().expect("invalid fault config");
        let mean_gap = 1.0 / cfg.rack_rate;
        for rack in 0..net.n_racks() {
            let mut rng = Rng::stream_n(seed, STREAM_FAULT ^ STREAM_RACK_SALT, rack as u64);
            let mut t = rng.exponential(mean_gap);
            while t < cfg.horizon {
                let up = t + rng.exponential(cfg.mttr).max(1e-3);
                for exec in net.rack_members(rack) {
                    plan.events.push(FaultEvent {
                        exec,
                        time: t,
                        kind: FaultKind::Crash { recovery: Some(up) },
                    });
                }
                t = up + rng.exponential(mean_gap);
            }
        }
        // A rack event can overlap an executor's own outage; the
        // recovery pass treats the duplicate down as a no-op and the
        // earliest queued recovery wins — deterministic either way.
        plan.events
            .sort_by(|a, b| a.time.total_cmp(&b.time).then(a.exec.cmp(&b.exec)));
        plan
    }

    /// Crash count in the plan (transient + permanent).
    pub fn n_crashes(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::Crash { .. }))
            .count()
    }

    /// Straggle count in the plan.
    pub fn n_straggles(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::Straggle { .. }))
            .count()
    }
}

/// Running totals of fault activity inside one `SimState`, for reports
/// and the robustness sweep.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultStats {
    /// Crash events processed (transient + permanent).
    pub n_crashes: usize,
    /// Straggle events processed.
    pub n_straggles: usize,
    /// Booked copies cancelled (directly lost + cascade-invalidated).
    pub n_cancelled: usize,
    /// Tasks that lost every copy and were re-enqueued for rescheduling.
    pub n_requeued: usize,
    /// Tasks whose primary copy was lost but a surviving duplicate copy
    /// was promoted to primary — recovered without rescheduling.
    pub n_dup_survived: usize,
}

/// Outcome of one recovery pass (one crash or straggle), echoed to
/// service masters answering a `report_failure` request.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RecoveryOutcome {
    /// Copies cancelled by this pass.
    pub cancelled: usize,
    /// Tasks returned to the executable frontier.
    pub requeued: usize,
    /// Tasks saved by promoting a surviving duplicate copy.
    pub survived: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_plan_is_empty() {
        let plan = FaultPlan::generate(&FaultConfig::none(), 8, 42);
        assert!(plan.is_empty());
        assert_eq!(plan, FaultPlan::none());
    }

    #[test]
    fn generation_is_deterministic_and_sorted() {
        let cfg = FaultConfig::with_rate(5e-3);
        let a = FaultPlan::generate(&cfg, 6, 7);
        let b = FaultPlan::generate(&cfg, 6, 7);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "5e-3 over 10k s must draw incidents");
        for w in a.events.windows(2) {
            assert!(w[0].time <= w[1].time, "plan must be time-sorted");
        }
        let c = FaultPlan::generate(&cfg, 6, 8);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn transient_recovery_follows_the_crash() {
        let mut cfg = FaultConfig::with_rate(1e-2);
        cfg.p_permanent = 0.0;
        cfg.straggler_prob = 0.0;
        let plan = FaultPlan::generate(&cfg, 4, 3);
        assert!(plan.n_crashes() > 0);
        for e in &plan.events {
            match e.kind {
                FaultKind::Crash { recovery } => {
                    let up = recovery.expect("p_permanent = 0 → transient");
                    assert!(up > e.time);
                }
                FaultKind::Straggle { .. } => panic!("straggler_prob = 0"),
            }
        }
    }

    #[test]
    fn per_executor_incidents_never_overlap_outages() {
        let cfg = FaultConfig::with_rate(1e-2);
        let plan = FaultPlan::generate(&cfg, 5, 11);
        for exec in 0..5 {
            let mut up_until = 0.0f64;
            let mut dead = false;
            for e in plan.events.iter().filter(|e| e.exec == exec) {
                assert!(!dead, "events after a permanent crash on {exec}");
                assert!(
                    e.time >= up_until,
                    "incident at {} inside outage ending {up_until}",
                    e.time
                );
                if let FaultKind::Crash { recovery } = e.kind {
                    match recovery {
                        Some(up) => up_until = up,
                        None => dead = true,
                    }
                }
            }
        }
    }

    #[test]
    fn never_all_permanently_dead() {
        // Force permanent crashes: with p_permanent = 1 every executor's
        // first crash would be final; the demotion rule must keep one
        // executor recoverable.
        let mut cfg = FaultConfig::with_rate(1e-2);
        cfg.p_permanent = 1.0;
        cfg.straggler_prob = 0.0;
        for seed in 0..10u64 {
            let plan = FaultPlan::generate(&cfg, 4, seed);
            let perm = plan
                .events
                .iter()
                .filter(|e| matches!(e.kind, FaultKind::Crash { recovery: None }))
                .count();
            assert!(perm < 4, "seed {seed}: all executors permanently dead");
        }
    }

    #[test]
    fn rack_mode_off_is_bit_identical() {
        use crate::net::{NetConfig, NetworkModel};
        let cfg = FaultConfig::with_rate(5e-3);
        let net = NetworkModel::build(&NetConfig::tree(2, 3), 100.0, 6);
        let plain = FaultPlan::generate(&cfg, 6, 7);
        let topo = FaultPlan::generate_with_topology(&cfg, &net, 7);
        assert_eq!(plain, topo, "rack_rate = 0 must not perturb the plan");
    }

    #[test]
    fn rack_incidents_down_every_member_together() {
        use crate::net::{NetConfig, NetworkModel};
        let mut cfg = FaultConfig::none();
        cfg.rack_rate = 2e-3;
        let net = NetworkModel::build(&NetConfig::tree(3, 4), 100.0, 12);
        let plan = FaultPlan::generate_with_topology(&cfg, &net, 9);
        assert!(!plan.is_empty(), "2e-3 over 10k s must draw incidents");
        // Group events by (time, recovery): each group must be exactly
        // one rack's full membership, transient, with a shared window.
        let mut by_time: std::collections::BTreeMap<u64, Vec<&FaultEvent>> =
            std::collections::BTreeMap::new();
        for e in &plan.events {
            by_time.entry(e.time.to_bits()).or_default().push(e);
        }
        for (_, group) in by_time {
            let rack = net.rack_of(group[0].exec);
            let members = net.rack_members(rack);
            let execs: Vec<usize> = group.iter().map(|e| e.exec).collect();
            assert_eq!(execs, members, "incident must cover the whole rack");
            let recs: std::collections::BTreeSet<u64> = group
                .iter()
                .map(|e| match e.kind {
                    FaultKind::Crash { recovery } => {
                        recovery.expect("rack incidents are transient").to_bits()
                    }
                    _ => panic!("rack incidents are crashes"),
                })
                .collect();
            assert_eq!(recs.len(), 1, "shared recovery time per incident");
        }
        // Determinism.
        let again = FaultPlan::generate_with_topology(&cfg, &net, 9);
        assert_eq!(plan, again);
    }

    #[test]
    fn straggles_carry_the_config_factor() {
        let mut cfg = FaultConfig::with_rate(1e-2);
        cfg.straggler_prob = 1.0;
        cfg.slowdown = 2.5;
        let plan = FaultPlan::generate(&cfg, 3, 5);
        assert!(plan.n_straggles() > 0);
        assert_eq!(plan.n_crashes(), 0);
        for e in &plan.events {
            assert_eq!(e.kind, FaultKind::Straggle { factor: 2.5 });
        }
    }
}
