//! Earliest start / finish time math (paper Definitions 1–2, Eq 1–3) and
//! the non-duplicating EFT allocator used by HEFT.

use super::Allocator;
use crate::dag::TaskRef;
use crate::sim::{Allocation, SimState};

/// Earliest start time of `task` on `exec` (Eq 2), additionally bounded by
/// the current wall clock and the job's arrival (online constraints).
/// Does *not* include the executor-availability bound — that's applied by
/// the timeline probe in [`eft`] (append tail or earliest feasible gap,
/// per the state's booking mode).
pub fn est(state: &SimState, task: TaskRef, exec: usize) -> f64 {
    state.ready_time(task, exec)
}

/// Earliest finish time of `task` on `exec` (Eq 3): the executor timeline
/// is probed through [`SimState::plan_direct`], so the same math drives
/// the prediction here and the booking in `apply` — in append mode this
/// is `max(EST, tail) + w/v` exactly as the paper writes it, in gap-aware
/// mode the earliest idle window that fits.
pub fn eft(state: &SimState, task: TaskRef, exec: usize) -> f64 {
    state.plan_direct(task, exec).1
}

/// The *available* executor minimizing EFT, with the winning finish
/// time. Down executors (fault outages) are never candidates; with every
/// executor down this returns `(0, ∞)` — callers guard on
/// [`SimState::any_executor_available`] before booking.
pub fn best_eft(state: &SimState, task: TaskRef) -> (usize, f64) {
    let mut best_exec = 0;
    let mut best = f64::INFINITY;
    for e in 0..state.cluster.len() {
        if !state.exec_available(e) {
            continue;
        }
        let f = eft(state, task, e);
        if f < best {
            best = f;
            best_exec = e;
        }
    }
    (best_exec, best)
}

/// Phase-2 allocator that picks `argmin_exec EFT` without duplication
/// (HEFT's allocation rule).
#[derive(Debug, Clone, Default)]
pub struct EftAllocator;

impl EftAllocator {
    pub fn new() -> Self {
        EftAllocator
    }
}

impl Allocator for EftAllocator {
    fn name(&self) -> String {
        "eft".to_string()
    }

    fn allocate(&self, state: &SimState, task: TaskRef) -> (Allocation, f64) {
        let (exec, finish) = best_eft(state, task);
        (Allocation::Direct { exec }, finish)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::dag::Job;
    use crate::sim::SimState;
    use crate::workload::Workload;

    fn state() -> SimState {
        let mut cluster = Cluster::homogeneous(2, 1.0, 10.0);
        cluster.executors[1].speed = 2.0;
        let job = Job::new(0, "chain", 0.0, vec![4.0, 6.0], &[(0, 1, 20.0)]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st
    }

    #[test]
    fn eft_prefers_fast_executor_for_entry() {
        let st = state();
        let t0 = TaskRef::new(0, 0);
        assert_eq!(eft(&st, t0, 0), 4.0);
        assert_eq!(eft(&st, t0, 1), 2.0);
        let (exec, f) = best_eft(&st, t0);
        assert_eq!(exec, 1);
        assert_eq!(f, 2.0);
    }

    #[test]
    fn eft_accounts_for_parent_location() {
        let mut st = state();
        st.apply(TaskRef::new(0, 0), crate::sim::Allocation::Direct { exec: 0 });
        let t1 = TaskRef::new(0, 1);
        // Same exec: start 4, run 6 → 10. Other exec: data 4+2=6, run 3 → 9.
        assert_eq!(eft(&st, t1, 0), 10.0);
        assert_eq!(eft(&st, t1, 1), 9.0);
        let (exec, f) = best_eft(&st, t1);
        assert_eq!((exec, f), (1, 9.0));
    }

    #[test]
    fn predicted_eft_matches_apply() {
        let mut st = state();
        let t0 = TaskRef::new(0, 0);
        let (exec, predicted) = best_eft(&st, t0);
        let actual = st.apply(t0, crate::sim::Allocation::Direct { exec });
        assert!((predicted - actual).abs() < 1e-12);
        let t1 = TaskRef::new(0, 1);
        let (exec, predicted) = best_eft(&st, t1);
        let actual = st.apply(t1, crate::sim::Allocation::Direct { exec });
        assert!((predicted - actual).abs() < 1e-12);
    }

    #[test]
    fn est_respects_wall_clock() {
        let mut st = state();
        st.wall = 50.0;
        assert_eq!(est(&st, TaskRef::new(0, 0), 0), 50.0);
        assert_eq!(eft(&st, TaskRef::new(0, 0), 0), 54.0);
    }
}
