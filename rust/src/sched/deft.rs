//! DEFT — Duplication-aware Earliest Finish Time (paper §4.2, Eq 9–11,
//! Algorithm 1).
//!
//! For a selected task `n_i`, DEFT evaluates, for every executor `r_j`:
//!
//! * **EFT** — run `n_i` on `r_j` directly (Eq 3), and
//! * **CPEFT** — *copy-parent* EFT (Eq 10): first re-execute one parent
//!   `n_p` on `r_j` (making its output local, saving the `e_pi / c_pj`
//!   transfer), then run `n_i` there.
//!
//! The minimum over all `(mode, parent, executor)` combinations wins
//! (Eq 11). Complexity is `O(P · M)` per task (`P` parents, `M`
//! executors) and `O(E · M)` for a whole workload, as analyzed in §5.1.

use super::eft::best_eft;
use super::Allocator;
use crate::dag::{NodeId, TaskRef};
use crate::sim::{Allocation, SimState};

/// CPEFT (Eq 10, with the duplicate's own execution modeled): finish time
/// of `task` on `exec` if parent `parent` is first duplicated onto `exec`.
///
/// The duplicated copy must wait for *its* input data on `exec` and for an
/// executor slot; the task then starts at
/// `max(duplicate finish, other parents' data-ready)` — parent data is
/// local after duplication (`AFTC` with zero transfer), and the executor is
/// serially occupied by the duplicate until it finishes. Both slots are
/// planned through [`SimState::plan_duplicate`], the same math `apply`
/// books, so the prediction is exact in both booking modes.
pub fn cpeft(state: &SimState, task: TaskRef, parent: NodeId, exec: usize) -> f64 {
    let (_, (_, finish)) = state.plan_duplicate(task, parent, exec);
    finish
}

/// DEFT (Eq 11, Algorithm 1): the minimum-finish-time allocation across
/// plain EFT and every (parent, executor) duplication, with the predicted
/// finish time. Deterministic tie-break: EFT preferred over duplication,
/// lower executor id preferred (avoids gratuitous copies).
pub fn deft(state: &SimState, task: TaskRef) -> (Allocation, f64) {
    let (exec, mut best) = best_eft(state, task);
    let mut alloc = Allocation::Direct { exec };
    let parents = &state.jobs[task.job].parents[task.node];
    if !parents.is_empty() {
        for e in 0..state.cluster.len() {
            if !state.exec_available(e) {
                continue; // never duplicate onto a down executor
            }
            for edge in parents {
                let f = cpeft(state, task, edge.other, e);
                if f + 1e-12 < best {
                    best = f;
                    alloc = Allocation::Duplicate {
                        exec: e,
                        parent: edge.other,
                    };
                }
            }
        }
    }
    (alloc, best)
}

/// Phase-2 allocator wrapping [`deft`] — the paper's executor-allocation
/// heuristic used by Lachesis and all `*-DEFT` baselines.
#[derive(Debug, Clone, Default)]
pub struct DeftAllocator;

impl DeftAllocator {
    pub fn new() -> Self {
        DeftAllocator
    }
}

impl Allocator for DeftAllocator {
    fn name(&self) -> String {
        "deft".to_string()
    }

    fn allocate(&self, state: &SimState, task: TaskRef) -> (Allocation, f64) {
        deft(state, task)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::dag::Job;
    use crate::sim::SimState;
    use crate::workload::Workload;

    /// Two executors (1 GHz, 2 GHz), slow 10 MB/s link, heavy 20 MB edge:
    /// duplication should beat shipping the data.
    fn dup_favorable() -> SimState {
        let mut cluster = Cluster::homogeneous(2, 1.0, 10.0);
        cluster.executors[1].speed = 2.0;
        let job = Job::new(0, "chain", 0.0, vec![4.0, 6.0], &[(0, 1, 20.0)]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st
    }

    #[test]
    fn cpeft_hand_computed() {
        let mut st = dup_favorable();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 }); // AFT 4 @ e0
        let t1 = TaskRef::new(0, 1);
        // Duplicate node 0 on e1: dup start 0, finish 2; child 2 + 3 = 5.
        assert_eq!(cpeft(&st, t1, 0, 1), 5.0);
        // Duplicate on e0 (same place it already ran): exec busy till 4,
        // dup 4..8, child 8..14.
        assert_eq!(cpeft(&st, t1, 0, 0), 14.0);
    }

    #[test]
    fn deft_chooses_duplication_when_it_wins() {
        let mut st = dup_favorable();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        let t1 = TaskRef::new(0, 1);
        let (alloc, finish) = deft(&st, t1);
        assert_eq!(
            alloc,
            Allocation::Duplicate { exec: 1, parent: 0 }
        );
        assert_eq!(finish, 5.0); // vs EFT best of 9.0
    }

    #[test]
    fn deft_predicted_finish_matches_apply() {
        let mut st = dup_favorable();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        let t1 = TaskRef::new(0, 1);
        let (alloc, predicted) = deft(&st, t1);
        let actual = st.apply(t1, alloc);
        assert!((predicted - actual).abs() < 1e-12);
        st.validate().unwrap();
    }

    #[test]
    fn deft_falls_back_to_eft_on_fast_network() {
        // 1 GB/s link: shipping 20 MB costs 0.02 s — duplication can't win.
        let mut cluster = Cluster::homogeneous(2, 1.0, 1000.0);
        cluster.executors[1].speed = 2.0;
        let job = Job::new(0, "chain", 0.0, vec![4.0, 6.0], &[(0, 1, 20.0)]);
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 1 });
        let (alloc, _) = deft(&st, TaskRef::new(0, 1));
        assert!(matches!(alloc, Allocation::Direct { .. }));
    }

    #[test]
    fn deft_entry_task_has_no_duplication() {
        let st = dup_favorable();
        let (alloc, finish) = deft(&st, TaskRef::new(0, 0));
        assert_eq!(alloc, Allocation::Direct { exec: 1 });
        assert_eq!(finish, 2.0);
    }

    /// DEFT never predicts a worse finish than plain EFT (Eq 11 is a min
    /// including EFT).
    #[test]
    fn deft_never_worse_than_eft() {
        let mut st = dup_favorable();
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
        let t1 = TaskRef::new(0, 1);
        let (_, eft_best) = best_eft(&st, t1);
        let (_, deft_best) = deft(&st, t1);
        assert!(deft_best <= eft_best);
    }

    /// Multi-parent case: duplicating one parent must still wait for the
    /// other parents' data.
    #[test]
    fn cpeft_waits_for_other_parents() {
        let mut cluster = Cluster::homogeneous(3, 1.0, 10.0);
        cluster.executors[2].speed = 2.0;
        // join: 0 -> 2, 1 -> 2; heavy edge from 0, light from 1.
        let job = Job::new(
            0,
            "join",
            0.0,
            vec![2.0, 8.0, 1.0],
            &[(0, 2, 40.0), (1, 2, 1.0)],
        );
        let mut st = SimState::new(cluster, Workload::new(vec![job]));
        st.mark_arrived(0);
        st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 }); // AFT 2
        st.apply(TaskRef::new(0, 1), Allocation::Direct { exec: 1 }); // AFT 8
        let t2 = TaskRef::new(0, 2);
        // Duplicate parent 0 on e2: dup 0..1; other parent 1's data at
        // 8 + 0.1 = 8.1; child starts 8.1, finish 8.6.
        let f = cpeft(&st, t2, 0, 2);
        assert!((f - 8.6).abs() < 1e-9, "f={f}");
    }
}
