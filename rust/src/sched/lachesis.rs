//! The learned schedulers: **Lachesis** (this paper) and **Decima-DEFT**
//! (baseline 5) — a policy-network task selector in phase 1 + DEFT in
//! phase 2.
//!
//! The selector encodes the scheduling state to fixed-shape tensors,
//! evaluates the MGNet policy (pure-rust or PJRT backend), and picks the
//! argmax (inference) or a softmax sample (training). During training it
//! records transitions — (encoded state, action slot, critic value,
//! horizon at decision time) — which the RL trainer turns into
//! advantage-weighted updates.

use super::{DeftAllocator, TaskSelector, TwoPhase};
use crate::dag::TaskRef;
use crate::obs::trace;
use crate::policy::features::FeatureMode;
use crate::policy::{EncodedState, EncoderCache, PolicyEval, PolicyNet};
use crate::sim::SimState;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

/// One recorded decision for RL training.
#[derive(Clone)]
pub struct Transition {
    pub enc: EncodedState,
    pub action_slot: usize,
    pub value: f32,
    /// Schedule horizon (max AFT) *before* this decision was applied; the
    /// trainer differences consecutive horizons to get the paper's
    /// makespan-increment penalty (Σ rewards = −makespan).
    pub horizon_before: f64,
    /// Simulation wall time of the decision (the paper's t_k).
    pub wall: f64,
}

/// How actions are drawn from the policy distribution (Eq 8).
pub enum SelectMode {
    /// Greedy argmax (evaluation).
    Greedy,
    /// Softmax sampling at a temperature (training exploration).
    Sample { temperature: f64, rng: Rng },
}

/// Phase-1 selector driven by the policy network. Encoding rides the
/// incremental [`EncoderCache`] — per decision the cache patches the
/// previous encoding from the sim's dirty-tracking log instead of
/// re-featurizing the whole state (bitwise-identical by the cache's
/// contract, so cached and fresh selectors take identical decisions).
pub struct PolicySelector {
    pub net: PolicyNet,
    pub feature_mode: FeatureMode,
    pub mode: SelectMode,
    /// When true, record transitions for the trainer.
    pub record: bool,
    pub transitions: Vec<Transition>,
    cache: EncoderCache,
    label: String,
}

impl PolicySelector {
    pub fn new(
        eval: Box<dyn PolicyEval>,
        feature_mode: FeatureMode,
        mode: SelectMode,
        label: &str,
    ) -> PolicySelector {
        PolicySelector {
            net: PolicyNet::new(eval),
            feature_mode,
            mode,
            record: false,
            transitions: Vec::new(),
            cache: EncoderCache::new(feature_mode),
            label: label.to_string(),
        }
    }

    /// Drain recorded transitions (trainer API).
    pub fn take_transitions(&mut self) -> Vec<Transition> {
        std::mem::take(&mut self.transitions)
    }
}

impl TaskSelector for PolicySelector {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn reset(&mut self) {
        self.transitions.clear();
        self.cache.reset();
    }

    fn select(&mut self, state: &SimState) -> Result<Option<TaskRef>> {
        if state.executable().is_empty() {
            return Ok(None);
        }
        let obs_on = crate::obs::enabled();
        let rebuilds_before = self.cache.rebuilds;
        // Clock reads only when telemetry is on (gated in CI by
        // bench_sim's obs_disabled_overhead_ratio).
        let t0 = obs_on.then(std::time::Instant::now);
        let enc = {
            let _sp = trace::span("policy", "encode");
            self.cache.refresh(state)
        };
        if let Some(t0) = t0 {
            let m = crate::obs::metrics::sim_metrics();
            m.encode_ms.record(t0.elapsed().as_secs_f64() * 1e3);
            if self.cache.rebuilds > rebuilds_before {
                m.encoder_rebuilds_total.inc();
            } else {
                m.encoder_reuses_total.inc();
            }
        }
        if enc.n_executable() == 0 {
            // All executable tasks were truncated out of the encoding —
            // fall back to the highest-rank_up executable task so the
            // schedule always completes.
            let t = *state
                .executable()
                .iter()
                .max_by(|a, b| {
                    state.rank_up[a.job][a.node]
                        .partial_cmp(&state.rank_up[b.job][b.node])
                        .unwrap()
                })
                .unwrap();
            return Ok(Some(t));
        }
        let t1 = obs_on.then(std::time::Instant::now);
        let _fwd = trace::span("policy", "forward");
        let (slot, value) = match &mut self.mode {
            SelectMode::Greedy => {
                let slot = self
                    .net
                    .argmax(enc)?
                    .ok_or_else(|| anyhow!("argmax over empty executable mask"))?;
                (slot, 0.0)
            }
            SelectMode::Sample { temperature, rng } => {
                let temp = *temperature;
                let (slot, value) = self
                    .net
                    .sample(enc, rng, temp)?
                    .ok_or_else(|| anyhow!("sample over empty executable mask"))?;
                (slot, value)
            }
        };
        drop(_fwd);
        if let Some(t1) = t1 {
            crate::obs::metrics::sim_metrics()
                .forward_ms
                .record(t1.elapsed().as_secs_f64() * 1e3);
        }
        let task = enc
            .slot_task(slot)
            .ok_or_else(|| anyhow!("selected padding slot {slot}"))?;
        debug_assert!(state.is_executable(task));
        if self.record {
            // The CSR encoding is compact (one u32 per edge/slot instead
            // of dense N²+J·N f32), so cloning it per transition is cheap.
            self.transitions.push(Transition {
                enc: enc.clone(),
                action_slot: slot,
                value,
                horizon_before: state.horizon,
                wall: state.wall,
            });
        }
        Ok(Some(task))
    }
}

/// Lachesis: policy selector (full heterogeneity-aware features) + DEFT.
pub type LachesisScheduler = TwoPhase<PolicySelector, DeftAllocator>;

impl LachesisScheduler {
    /// Greedy-inference Lachesis (evaluation mode).
    pub fn greedy(eval: Box<dyn PolicyEval>) -> LachesisScheduler {
        TwoPhase::named(
            PolicySelector::new(eval, FeatureMode::Full, SelectMode::Greedy, "lachesis"),
            DeftAllocator::new(),
            "Lachesis",
        )
    }

    /// Sampling Lachesis with transition recording (training mode).
    pub fn training(eval: Box<dyn PolicyEval>, temperature: f64, seed: u64) -> LachesisScheduler {
        let mut sel = PolicySelector::new(
            eval,
            FeatureMode::Full,
            SelectMode::Sample {
                temperature,
                rng: Rng::new(seed),
            },
            "lachesis",
        );
        sel.record = true;
        TwoPhase::named(sel, DeftAllocator::new(), "Lachesis")
    }
}

/// Decima-DEFT: the same architecture with heterogeneity-blind features
/// (Decima assumes homogeneous executors and no data transmission).
pub type DecimaScheduler = TwoPhase<PolicySelector, DeftAllocator>;

impl DecimaScheduler {
    pub fn greedy_decima(eval: Box<dyn PolicyEval>) -> DecimaScheduler {
        TwoPhase::named(
            PolicySelector::new(
                eval,
                FeatureMode::HomogeneousBlind,
                SelectMode::Greedy,
                "decima",
            ),
            DeftAllocator::new(),
            "Decima-DEFT",
        )
    }

    pub fn training_decima(
        eval: Box<dyn PolicyEval>,
        temperature: f64,
        seed: u64,
    ) -> DecimaScheduler {
        let mut sel = PolicySelector::new(
            eval,
            FeatureMode::HomogeneousBlind,
            SelectMode::Sample {
                temperature,
                rng: Rng::new(seed),
            },
            "decima",
        );
        sel.record = true;
        TwoPhase::named(sel, DeftAllocator::new(), "Decima-DEFT")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::{ClusterConfig, WorkloadConfig};
    use crate::policy::RustPolicy;
    use crate::sched::Scheduler;
    use crate::sim::Simulator;
    use crate::workload::WorkloadGenerator;

    #[test]
    fn greedy_lachesis_completes_schedule() {
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(6), 1);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(3), 1).generate();
        let mut sched = LachesisScheduler::greedy(Box::new(RustPolicy::random(7)));
        let mut sim = Simulator::new(cluster, w);
        let report = sim.run(&mut sched).unwrap();
        assert_eq!(report.algo, "Lachesis");
        assert!(report.makespan > 0.0);
        sim.state.validate().unwrap();
    }

    #[test]
    fn training_mode_records_transitions() {
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(4), 2);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(2), 2).generate();
        let n_tasks = w.n_tasks();
        let mut sched = LachesisScheduler::training(Box::new(RustPolicy::random(8)), 1.0, 3);
        let mut sim = Simulator::new(cluster, w);
        sim.run(&mut sched).unwrap();
        let trans = sched.selector.take_transitions();
        assert_eq!(trans.len(), n_tasks);
        // Horizons are non-decreasing over the episode.
        for w in trans.windows(2) {
            assert!(w[1].horizon_before >= w[0].horizon_before - 1e-9);
        }
    }

    #[test]
    fn reset_clears_transitions() {
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(4), 3);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(2), 3).generate();
        let mut sched = LachesisScheduler::training(Box::new(RustPolicy::random(9)), 1.0, 4);
        let mut sim = Simulator::new(cluster, w.clone());
        sim.run(&mut sched).unwrap();
        assert!(!sched.selector.transitions.is_empty());
        let mut sim2 = Simulator::new(
            Cluster::heterogeneous(&ClusterConfig::with_executors(4), 3),
            w,
        );
        sim2.run(&mut sched).unwrap(); // run() calls reset()
        let n = sim2.state.n_tasks_total();
        assert_eq!(sched.selector.transitions.len(), n);
    }

    #[test]
    fn decima_uses_blind_features() {
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(4), 4);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(2), 4).generate();
        let mut sched = DecimaScheduler::greedy_decima(Box::new(RustPolicy::random(10)));
        assert_eq!(sched.name(), "Decima-DEFT");
        let mut sim = Simulator::new(cluster, w);
        sim.run(&mut sched).unwrap();
        sim.state.validate().unwrap();
    }

    #[test]
    fn sampled_runs_differ_by_seed_but_not_within() {
        let cfg = ClusterConfig::with_executors(4);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(3), 5).generate();
        let run = |seed: u64| {
            let mut sched =
                LachesisScheduler::training(Box::new(RustPolicy::random(11)), 1.0, seed);
            let mut sim = Simulator::new(Cluster::heterogeneous(&cfg, 5), w.clone());
            let r = sim.run(&mut sched).unwrap();
            (
                r.makespan,
                sched
                    .selector
                    .take_transitions()
                    .iter()
                    .map(|t| t.action_slot)
                    .collect::<Vec<_>>(),
            )
        };
        let (m1, a1) = run(100);
        let (m1b, a1b) = run(100);
        assert_eq!(a1, a1b);
        assert_eq!(m1, m1b);
        let (_, a2) = run(101);
        // Usually differs; tolerate rare equality only if tiny episodes.
        if a1.len() > 5 {
            assert_ne!(a1, a2, "different sampling seeds should diverge");
        }
    }
}
