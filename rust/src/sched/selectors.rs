//! Phase-1 task selectors for the heuristic baselines (paper §5.2):
//! FIFO, SJF, HRRN, HighRankUp and a random control. Each pairs with the
//! DEFT allocator to form the `*-DEFT` baselines.

use super::{DeftAllocator, TaskSelector, TwoPhase};
use crate::dag::TaskRef;
use crate::sim::SimState;
use crate::util::rng::Rng;
use anyhow::Result;

/// Pick the executable task maximizing a score; deterministic tie-break on
/// (job, node).
fn argmax_by<F: Fn(&SimState, TaskRef) -> f64>(state: &SimState, score: F) -> Option<TaskRef> {
    let mut best: Option<(f64, TaskRef)> = None;
    for &t in state.executable() {
        let s = score(state, t);
        match best {
            None => best = Some((s, t)),
            Some((bs, bt)) => {
                if s > bs + 1e-12 || (s > bs - 1e-12 && t < bt) {
                    best = Some((s, t));
                }
            }
        }
    }
    best.map(|(_, t)| t)
}

// ---------------------------------------------------------------------------
// FIFO: ascending job arrival order (paper baseline 1).
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
pub struct FifoSelector;

impl TaskSelector for FifoSelector {
    fn name(&self) -> String {
        "fifo".to_string()
    }

    fn select(&mut self, state: &SimState) -> Result<Option<TaskRef>> {
        // Earlier arrival first; within a job, earlier topo position first
        // (the frontier is sorted, so negate job arrival/ids for argmax).
        Ok(argmax_by(state, |st, t| {
            -(st.jobs[t.job].arrival * 1e6 + t.job as f64)
        }))
    }
}

/// FIFO-DEFT baseline.
pub type FifoScheduler = TwoPhase<FifoSelector, DeftAllocator>;

impl FifoScheduler {
    pub fn new() -> FifoScheduler {
        TwoPhase::named(FifoSelector, DeftAllocator::new(), "FIFO-DEFT")
    }
}

impl Default for FifoScheduler {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// SJF: shortest job first (by remaining job work; paper baseline 2).
// `job_left_work` is an O(1) incremental counter, so this selector is
// O(|A_t|) per decision instead of O(|A_t| · tasks-per-job).
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
pub struct SjfSelector;

impl TaskSelector for SjfSelector {
    fn name(&self) -> String {
        "sjf".to_string()
    }

    fn select(&mut self, state: &SimState) -> Result<Option<TaskRef>> {
        Ok(argmax_by(state, |st, t| -st.job_left_work(t.job)))
    }
}

/// SJF-DEFT baseline.
pub type SjfScheduler = TwoPhase<SjfSelector, DeftAllocator>;

impl SjfScheduler {
    pub fn new() -> SjfScheduler {
        TwoPhase::named(SjfSelector, DeftAllocator::new(), "SJF-DEFT")
    }
}

impl Default for SjfScheduler {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// HRRN: highest response ratio next (paper baseline 7):
// ratio = t_wait / (t_wait + t_execution).
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
pub struct HrrnSelector;

impl TaskSelector for HrrnSelector {
    fn name(&self) -> String {
        "hrrn".to_string()
    }

    fn select(&mut self, state: &SimState) -> Result<Option<TaskRef>> {
        let v_avg = state.v_avg();
        Ok(argmax_by(state, |st, t| {
            let wait = (st.wall - st.jobs[t.job].arrival).max(0.0);
            let exec = st.task_compute(t) / v_avg;
            wait / (wait + exec).max(1e-12)
        }))
    }
}

/// HRRN-DEFT baseline.
pub type HrrnScheduler = TwoPhase<HrrnSelector, DeftAllocator>;

impl HrrnScheduler {
    pub fn new() -> HrrnScheduler {
        TwoPhase::named(HrrnSelector, DeftAllocator::new(), "HRRN-DEFT")
    }
}

impl Default for HrrnScheduler {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// HighRankUp: descending rank_up (paper baseline 6; also HEFT's phase 1).
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
pub struct RankUpSelector;

impl TaskSelector for RankUpSelector {
    fn name(&self) -> String {
        "rankup".to_string()
    }

    fn select(&mut self, state: &SimState) -> Result<Option<TaskRef>> {
        Ok(argmax_by(state, |st, t| st.rank_up[t.job][t.node]))
    }
}

/// HighRankUp-DEFT baseline.
pub type HighRankUpScheduler = TwoPhase<RankUpSelector, DeftAllocator>;

impl HighRankUpScheduler {
    pub fn new() -> HighRankUpScheduler {
        TwoPhase::named(RankUpSelector, DeftAllocator::new(), "HighRankUp-DEFT")
    }
}

impl Default for HighRankUpScheduler {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// Random selector (sanity-check control, not in the paper).
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct RandomSelector {
    rng: Rng,
    seed: u64,
}

impl RandomSelector {
    pub fn new(seed: u64) -> RandomSelector {
        RandomSelector {
            rng: Rng::new(seed),
            seed,
        }
    }
}

impl TaskSelector for RandomSelector {
    fn name(&self) -> String {
        "random".to_string()
    }

    fn reset(&mut self) {
        self.rng = Rng::new(self.seed);
    }

    fn select(&mut self, state: &SimState) -> Result<Option<TaskRef>> {
        let frontier = state.executable();
        if frontier.is_empty() {
            return Ok(None);
        }
        Ok(Some(*self.rng.choice(frontier)))
    }
}

/// Random-DEFT control.
pub type RandomScheduler = TwoPhase<RandomSelector, DeftAllocator>;

impl RandomScheduler {
    pub fn new(seed: u64) -> RandomScheduler {
        TwoPhase::named(RandomSelector::new(seed), DeftAllocator::new(), "Random-DEFT")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::dag::Job;
    use crate::sched::Scheduler;
    use crate::sim::SimState;
    use crate::workload::Workload;

    fn two_job_state() -> SimState {
        let cluster = Cluster::homogeneous(2, 1.0, 100.0);
        let j0 = Job::new(0, "big", 0.0, vec![100.0, 1.0], &[(0, 1, 1.0)]);
        let j1 = Job::new(1, "small", 5.0, vec![2.0], &[]);
        let mut st = SimState::new(cluster, Workload::new(vec![j0, j1]));
        st.mark_arrived(0);
        st.mark_arrived(1);
        st
    }

    #[test]
    fn fifo_prefers_earlier_arrival() {
        let st = two_job_state();
        let t = FifoSelector.select(&st).unwrap().unwrap();
        assert_eq!(t.job, 0);
    }

    #[test]
    fn sjf_prefers_lighter_job() {
        let st = two_job_state();
        let t = SjfSelector.select(&st).unwrap().unwrap();
        assert_eq!(t.job, 1); // 2.0 work vs 101.0
    }

    #[test]
    fn hrrn_prefers_long_waiters() {
        let mut st = two_job_state();
        st.wall = 100.0;
        // job0 waited 100s, job1 waited 95s; job0's task is huge though:
        // ratio0 = 100/(100+100), ratio1 = 95/(95+2) — job1 wins.
        let t = HrrnSelector.select(&st).unwrap().unwrap();
        assert_eq!(t.job, 1);
    }

    #[test]
    fn rankup_prefers_critical_task() {
        let st = two_job_state();
        let t = RankUpSelector.select(&st).unwrap().unwrap();
        // job0 node0 has rank_up ≈ 101 — the largest.
        assert_eq!((t.job, t.node), (0, 0));
    }

    #[test]
    fn random_is_reproducible_after_reset() {
        let st = two_job_state();
        let mut s = RandomSelector::new(9);
        let picks: Vec<TaskRef> = (0..5).map(|_| s.select(&st).unwrap().unwrap()).collect();
        s.reset();
        let picks2: Vec<TaskRef> = (0..5).map(|_| s.select(&st).unwrap().unwrap()).collect();
        assert_eq!(picks, picks2);
    }

    #[test]
    fn two_phase_name_composition() {
        let s = FifoScheduler::new();
        assert_eq!(s.name(), "FIFO-DEFT");
        let named = TwoPhase::named(RankUpSelector, crate::sched::EftAllocator::new(), "HEFT");
        assert_eq!(named.name(), "HEFT");
    }

    #[test]
    fn selectors_return_none_on_empty_frontier() {
        let cluster = Cluster::homogeneous(1, 1.0, 10.0);
        let j = Job::new(0, "late", 10.0, vec![1.0], &[]);
        let st = SimState::new(cluster, Workload::new(vec![j]));
        assert!(FifoSelector.select(&st).unwrap().is_none());
        assert!(SjfSelector.select(&st).unwrap().is_none());
        assert!(HrrnSelector.select(&st).unwrap().is_none());
        assert!(RankUpSelector.select(&st).unwrap().is_none());
        assert!(RandomSelector::new(1).select(&st).unwrap().is_none());
    }
}
