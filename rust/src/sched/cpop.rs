//! CPOP — Critical-Path-on-a-Processor (Topcuoglu et al. 2002; discussed
//! in the paper's related work as the companion of HEFT).
//!
//! Priority of a task is `rank_up + rank_down`; tasks on the critical path
//! (priority equal to the entry's, which is the CP length) are pinned to
//! the *critical-path processor* — the executor minimizing the path's
//! total execution time (for uniform-communication clusters, the fastest
//! executor). Off-path tasks fall back to best-EFT.

use super::eft::best_eft;
use super::Scheduler;
use crate::dag::TaskRef;
use crate::sim::{Allocation, SimState};
use anyhow::Result;

pub struct CpopScheduler {
    /// Per-job CP membership cache, keyed by job id.
    cp_member: Vec<Option<Vec<bool>>>,
}

impl CpopScheduler {
    pub fn new() -> CpopScheduler {
        CpopScheduler {
            cp_member: Vec::new(),
        }
    }

    fn ensure_job(&mut self, state: &SimState, job: usize) {
        if self.cp_member.len() < state.jobs.len() {
            self.cp_member.resize(state.jobs.len(), None);
        }
        if self.cp_member[job].is_some() {
            return;
        }
        let ju = &state.rank_up[job];
        let jd = &state.rank_down[job];
        let n = state.jobs[job].n_tasks();
        // CP length = max entry priority; members are nodes whose
        // rank_up + rank_down equals it (within tolerance).
        let cp_len = (0..n)
            .map(|i| ju[i] + jd[i])
            .fold(f64::NEG_INFINITY, f64::max);
        let members: Vec<bool> = (0..n)
            .map(|i| (ju[i] + jd[i]) >= cp_len * (1.0 - 1e-9))
            .collect();
        self.cp_member[job] = Some(members);
    }
}

impl Default for CpopScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for CpopScheduler {
    fn name(&self) -> String {
        "CPOP".to_string()
    }

    fn reset(&mut self) {
        self.cp_member.clear();
    }

    fn step(&mut self, state: &SimState) -> Result<Option<(TaskRef, Allocation)>> {
        if !state.any_executor_available() {
            return Ok(None); // wait out the outage
        }
        // Select by priority rank_up + rank_down.
        let mut best: Option<(f64, TaskRef)> = None;
        for &t in state.executable() {
            let p = state.rank_up[t.job][t.node] + state.rank_down[t.job][t.node];
            match best {
                None => best = Some((p, t)),
                Some((bp, bt)) => {
                    if p > bp + 1e-12 || (p > bp - 1e-12 && t < bt) {
                        best = Some((p, t));
                    }
                }
            }
        }
        let Some((_, task)) = best else {
            return Ok(None);
        };
        self.ensure_job(state, task.job);
        let on_cp = self.cp_member[task.job].as_ref().unwrap()[task.node];
        let exec = if on_cp {
            // Pin to the CP processor (fastest executor under the uniform
            // communication model).
            state.cluster.fastest()
        } else {
            best_eft(state, task).0
        };
        Ok(Some((task, Allocation::Direct { exec })))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::WorkloadConfig;
    use crate::sim::Simulator;
    use crate::workload::WorkloadGenerator;

    #[test]
    fn cpop_completes_and_validates() {
        let cfg = crate::config::ClusterConfig::with_executors(6);
        let cluster = Cluster::heterogeneous(&cfg, 1);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), 1).generate();
        let mut sim = Simulator::new(cluster, w);
        let report = sim.run(&mut CpopScheduler::new()).unwrap();
        assert!(report.makespan > 0.0);
        assert_eq!(report.n_duplicates, 0);
        sim.state.validate().unwrap();
    }

    #[test]
    fn critical_path_tasks_land_on_fastest_executor() {
        let mut cluster = Cluster::homogeneous(3, 1.0, 100.0);
        cluster.executors[2].speed = 3.0;
        // A pure chain: every node is on the critical path.
        let job = crate::dag::Job::new(
            0,
            "chain",
            0.0,
            vec![2.0, 2.0, 2.0],
            &[(0, 1, 0.1), (1, 2, 0.1)],
        );
        let w = crate::workload::Workload::new(vec![job]);
        let mut sim = Simulator::new(cluster, w);
        sim.run(&mut CpopScheduler::new()).unwrap();
        for node in 0..3 {
            assert_eq!(sim.state.placements[0][node][0].exec, 2);
        }
    }
}
