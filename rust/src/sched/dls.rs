//! DLS — Dynamic Level Scheduling (Sih & Lee 1993; paper §2 related
//! work). Unlike the two-phase algorithms, DLS jointly picks the
//! (task, executor) pair maximizing the *dynamic level*:
//!
//! ```text
//! DL(n, r) = SL(n) − max(data_ready(n, r), exec_ready(r)) + Δ(n, r)
//! ```
//!
//! where `SL` is the static level (rank_up with computation-only costs)
//! and `Δ(n, r) = w_n/v̄ − w_n/v_r` rewards placing a task on an executor
//! faster than average — the original paper's generalized dynamic level
//! for heterogeneous processors.

use super::Scheduler;
use crate::dag::TaskRef;
use crate::sim::{Allocation, SimState};
use anyhow::Result;

#[derive(Debug, Default)]
pub struct DlsScheduler {
    /// Static levels per job (computation-only rank_up), computed lazily.
    sl: Vec<Option<Vec<f64>>>,
}

impl DlsScheduler {
    pub fn new() -> DlsScheduler {
        DlsScheduler::default()
    }

    fn ensure_sl(&mut self, state: &SimState, job: usize) {
        if self.sl.len() < state.jobs.len() {
            self.sl.resize(state.jobs.len(), None);
        }
        if self.sl[job].is_some() {
            return;
        }
        // Static level: longest computation-only path to an exit, using
        // the mean execution time (no communication).
        let j = &state.jobs[job];
        let v_avg = state.v_avg();
        let n = j.n_tasks();
        let mut sl = vec![0.0f64; n];
        for &u in j.topo().iter().rev() {
            let mut best = 0.0f64;
            for e in &j.children[u] {
                if sl[e.other] > best {
                    best = sl[e.other];
                }
            }
            sl[u] = j.tasks[u].compute / v_avg + best;
        }
        self.sl[job] = Some(sl);
    }
}

impl Scheduler for DlsScheduler {
    fn name(&self) -> String {
        "DLS".to_string()
    }

    fn reset(&mut self) {
        self.sl.clear();
    }

    fn step(&mut self, state: &SimState) -> Result<Option<(TaskRef, Allocation)>> {
        if !state.any_executor_available() {
            return Ok(None); // wait out the outage
        }
        let v_avg = state.v_avg();
        let tasks: Vec<TaskRef> = state.executable().to_vec();
        let mut best: Option<(f64, TaskRef, usize)> = None;
        for t in tasks {
            self.ensure_sl(state, t.job);
            let sl = self.sl[t.job].as_ref().unwrap()[t.node];
            let w = state.task_compute(t);
            for r in 0..state.cluster.len() {
                if !state.exec_available(r) {
                    continue;
                }
                // Achievable start on r under the state's booking mode
                // (append tail or earliest feasible gap).
                let start = state.plan_direct(t, r).0;
                let delta = w / v_avg - w / state.cluster.speed(r);
                let dl = sl - start + delta;
                let better = match best {
                    None => true,
                    Some((b, bt, br)) => {
                        dl > b + 1e-12 || (dl > b - 1e-12 && (t, r) < (bt, br))
                    }
                };
                if better {
                    best = Some((dl, t, r));
                }
            }
        }
        Ok(best.map(|(_, t, r)| (t, Allocation::Direct { exec: r })))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::{ClusterConfig, WorkloadConfig};
    use crate::sim::Simulator;
    use crate::workload::WorkloadGenerator;

    #[test]
    fn dls_completes_and_validates() {
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(8), 5);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), 5).generate();
        let mut sim = Simulator::new(cluster, w);
        let report = sim.run(&mut DlsScheduler::new()).unwrap();
        assert!(report.makespan > 0.0);
        assert_eq!(report.n_duplicates, 0);
        sim.state.validate().unwrap();
    }

    #[test]
    fn dls_prefers_faster_executor_when_free() {
        let mut cluster = Cluster::homogeneous(2, 1.0, 100.0);
        cluster.executors[1].speed = 3.0;
        let job = crate::dag::Job::new(0, "one", 0.0, vec![6.0], &[]);
        let w = crate::workload::Workload::new(vec![job]);
        let mut sim = Simulator::new(cluster, w);
        sim.run(&mut DlsScheduler::new()).unwrap();
        assert_eq!(sim.state.placements[0][0][0].exec, 1);
    }

    #[test]
    fn dls_spreads_independent_tasks() {
        // Two equal independent tasks on two equal executors: DLS must use
        // both (the exec_ready term lowers the level of a busy executor).
        let cluster = Cluster::homogeneous(2, 2.0, 100.0);
        let job = crate::dag::Job::new(0, "par", 0.0, vec![4.0, 4.0], &[]);
        let w = crate::workload::Workload::new(vec![job]);
        let mut sim = Simulator::new(cluster, w);
        sim.run(&mut DlsScheduler::new()).unwrap();
        let e0 = sim.state.placements[0][0][0].exec;
        let e1 = sim.state.placements[0][1][0].exec;
        assert_ne!(e0, e1);
    }

    #[test]
    fn dls_continuous_mode() {
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(6), 6);
        let w = WorkloadGenerator::new(WorkloadConfig::continuous(5), 6).generate();
        let mut sim = Simulator::new(cluster, w);
        sim.run(&mut DlsScheduler::new()).unwrap();
        sim.state.validate().unwrap();
    }
}
