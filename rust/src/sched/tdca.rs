//! TDCA — Task-Duplication based Clustering Algorithm (He et al., TPDS
//! 2019; paper baseline 4). A batch-mode whole-DAG scheduler in four
//! phases:
//!
//! 1. **Cluster initialization** — walk up from each task to its *critical
//!    parent* (the parent with the latest data arrival), forming
//!    critical-parent chains; each chain becomes a cluster.
//! 2. **Cluster-to-executor mapping** — heaviest clusters (by total work)
//!    onto fastest executors; surplus clusters merge onto the least-loaded
//!    executors (TDCA's "processor merging").
//! 3. **Duplication** — when a cluster's head task reads a heavy edge from
//!    a parent placed elsewhere, re-execute the parent locally if that
//!    reduces the head's finish time (evaluated with the CPEFT math).
//! 4. **Task insertion** — emit tasks cluster-by-cluster in topological
//!    order; the simulator's append timeline realizes the schedule.
//!
//! TDCA is defined for batch workloads; under continuous arrivals it
//! re-plans over the arrived-but-unassigned set at each arrival event,
//! which matches how the paper could only run it in batch mode.

use super::deft::cpeft;
use super::eft::{best_eft, eft};
use super::Scheduler;
use crate::dag::TaskRef;
use crate::sim::{Allocation, SimState};
use anyhow::Result;
use std::collections::VecDeque;

pub struct TdcaScheduler {
    /// Planned decisions awaiting emission.
    plan: VecDeque<(TaskRef, usize)>, // (task, executor)
    /// Jobs already covered by a plan.
    planned_jobs: Vec<bool>,
}

impl TdcaScheduler {
    pub fn new() -> TdcaScheduler {
        TdcaScheduler {
            plan: VecDeque::new(),
            planned_jobs: Vec::new(),
        }
    }

    /// Build clusters for every arrived-but-unplanned job and append the
    /// placement plan.
    fn replan(&mut self, state: &SimState) {
        if self.planned_jobs.len() < state.jobs.len() {
            self.planned_jobs.resize(state.jobs.len(), false);
        }
        let n_exec = state.cluster.len();
        // Executor load accumulated by this planning round (work / speed),
        // seeded from the live timeline tails.
        let mut exec_load: Vec<f64> = (0..n_exec).map(|e| state.exec_ready(e)).collect();

        for (ji, job) in state.jobs.iter().enumerate() {
            if !state.arrived[ji] || self.planned_jobs[ji] {
                continue;
            }
            self.planned_jobs[ji] = true;
            let n = job.n_tasks();

            // --- Phase 1: critical-parent chains ---------------------------
            // critical parent of v = parent maximizing rank_down + edge
            // weight (the latest-arriving input).
            let rd = &state.rank_down[ji];
            let c_avg = state.c_avg();
            let v_avg = state.v_avg();
            let mut cluster_of: Vec<Option<usize>> = vec![None; n];
            let mut clusters: Vec<Vec<usize>> = Vec::new();
            // Walk nodes in reverse topological order; an unclustered node
            // starts a new cluster and pulls in its critical-parent chain.
            for &v in job.topo().iter().rev() {
                if cluster_of[v].is_some() {
                    continue;
                }
                let cid = clusters.len();
                clusters.push(Vec::new());
                let mut cur = v;
                loop {
                    cluster_of[cur] = Some(cid);
                    clusters[cid].push(cur);
                    // Find the critical parent not yet clustered.
                    let mut crit: Option<(f64, usize)> = None;
                    for e in &job.parents[cur] {
                        if cluster_of[e.other].is_some() {
                            continue;
                        }
                        let arrive = rd[e.other]
                            + job.tasks[e.other].compute / v_avg
                            + e.data / c_avg;
                        if crit.map(|(b, _)| arrive > b).unwrap_or(true) {
                            crit = Some((arrive, e.other));
                        }
                    }
                    match crit {
                        Some((_, p)) => cur = p,
                        None => break,
                    }
                }
                // The chain was built child→ancestor; reverse to topo order.
                clusters[cid].reverse();
            }

            // --- Phase 2: map clusters to executors ------------------------
            // Heaviest cluster first onto the executor with minimum
            // (load + cluster_work / speed) — merging happens naturally
            // when clusters outnumber executors.
            let mut order: Vec<usize> = (0..clusters.len()).collect();
            let work =
                |c: &Vec<usize>| -> f64 { c.iter().map(|&t| job.tasks[t].compute).sum() };
            order.sort_by(|&a, &b| {
                work(&clusters[b])
                    .partial_cmp(&work(&clusters[a]))
                    .unwrap()
            });
            let mut cluster_exec: Vec<usize> = vec![0; clusters.len()];
            for &cid in &order {
                let w = work(&clusters[cid]);
                // Down executors never receive a cluster; `step` guards
                // against the all-down case before replanning.
                let best = (0..n_exec)
                    .filter(|&e| state.exec_available(e))
                    .min_by(|&a, &b| {
                        let la = exec_load[a] + w / state.cluster.speed(a);
                        let lb = exec_load[b] + w / state.cluster.speed(b);
                        la.partial_cmp(&lb).unwrap()
                    })
                    .unwrap();
                cluster_exec[cid] = best;
                exec_load[best] += w / state.cluster.speed(best);
            }

            // --- Phases 3+4: emit in global topological order --------------
            // (duplication is decided at emission time in `step`, where the
            // live timeline is known).
            for &v in job.topo() {
                let cid = cluster_of[v].unwrap();
                self.plan
                    .push_back((TaskRef::new(ji, v), cluster_exec[cid]));
            }
        }
    }
}

impl Default for TdcaScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for TdcaScheduler {
    fn name(&self) -> String {
        "TDCA".to_string()
    }

    fn reset(&mut self) {
        self.plan.clear();
        self.planned_jobs.clear();
    }

    fn step(&mut self, state: &SimState) -> Result<Option<(TaskRef, Allocation)>> {
        if !state.any_executor_available() {
            return Ok(None); // wait out the outage before (re)planning
        }
        self.replan(state);
        // Emit the first plan entry that is currently executable (plans are
        // topo-ordered per job, so the head is almost always executable;
        // cross-job interleavings may require a scan).
        let idx = self
            .plan
            .iter()
            .position(|(t, _)| state.is_executable(*t));
        let Some(idx) = idx else {
            return Ok(None);
        };
        let (task, mut exec) = self.plan.remove(idx).unwrap();
        // The planned executor may have crashed since the plan was made:
        // fall back to the best available placement for this task.
        if !state.exec_available(exec) {
            exec = best_eft(state, task).0;
        }
        // Phase 3: duplicate the critical parent onto `exec` if it beats
        // the plain placement (TDCA's duplication rule, via CPEFT).
        let direct = eft(state, task, exec);
        let mut best = (Allocation::Direct { exec }, direct);
        for e in &state.jobs[task.job].parents[task.node] {
            let f = cpeft(state, task, e.other, exec);
            if f + 1e-12 < best.1 {
                best = (
                    Allocation::Duplicate {
                        exec,
                        parent: e.other,
                    },
                    f,
                );
            }
        }
        Ok(Some((task, best.0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::{ClusterConfig, WorkloadConfig};
    use crate::sim::Simulator;
    use crate::workload::WorkloadGenerator;

    #[test]
    fn tdca_completes_batch_and_validates() {
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(8), 2);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(5), 2).generate();
        let mut sim = Simulator::new(cluster, w);
        let report = sim.run(&mut TdcaScheduler::new()).unwrap();
        assert!(report.makespan > 0.0);
        sim.state.validate().unwrap();
    }

    #[test]
    fn tdca_handles_continuous_arrivals() {
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(8), 3);
        let w = WorkloadGenerator::new(WorkloadConfig::continuous(6), 3).generate();
        let mut sim = Simulator::new(cluster, w);
        let report = sim.run(&mut TdcaScheduler::new()).unwrap();
        assert!(report.makespan > 0.0);
        sim.state.validate().unwrap();
    }

    #[test]
    fn tdca_reset_allows_reuse() {
        let mut sched = TdcaScheduler::new();
        for seed in 0..2 {
            let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(4), seed);
            let w = WorkloadGenerator::new(WorkloadConfig::small_batch(2), seed).generate();
            let mut sim = Simulator::new(cluster, w);
            sim.run(&mut sched).unwrap();
            sim.state.validate().unwrap();
        }
    }

    #[test]
    fn clusters_colocate_chains() {
        // A pure chain should land entirely on one executor (single
        // cluster), eliminating all communication.
        let cluster = Cluster::homogeneous(4, 2.0, 10.0);
        let job = crate::dag::Job::new(
            0,
            "chain",
            0.0,
            vec![1.0, 1.0, 1.0, 1.0],
            &[(0, 1, 50.0), (1, 2, 50.0), (2, 3, 50.0)],
        );
        let w = crate::workload::Workload::new(vec![job]);
        let mut sim = Simulator::new(cluster, w);
        sim.run(&mut TdcaScheduler::new()).unwrap();
        let execs: Vec<usize> = (0..4)
            .map(|n| sim.state.placements[0][n][0].exec)
            .collect();
        assert!(
            execs.iter().all(|&e| e == execs[0]),
            "chain split across {execs:?}"
        );
    }
}
