//! HEFT (Topcuoglu et al. 2002; paper baseline 3): prioritize tasks by
//! descending `rank_up` and allocate with plain EFT — no duplication.
//!
//! In the two-phase framework HEFT is exactly `RankUpSelector +
//! EftAllocator`; because the engine invokes the scheduler on every event,
//! the classic batch behaviour emerges in batch mode (all tasks ranked up
//! front) while continuous mode degrades gracefully to list scheduling
//! over arrived jobs.

use super::eft::EftAllocator;
use super::selectors::RankUpSelector;
use super::TwoPhase;

/// The HEFT baseline.
pub type HeftScheduler = TwoPhase<RankUpSelector, EftAllocator>;

impl HeftScheduler {
    pub fn new() -> HeftScheduler {
        TwoPhase::named(RankUpSelector, EftAllocator::new(), "HEFT")
    }
}

impl Default for HeftScheduler {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::WorkloadConfig;
    use crate::sched::{FifoScheduler, Scheduler};
    use crate::sim::Simulator;
    use crate::workload::WorkloadGenerator;

    #[test]
    fn heft_never_duplicates() {
        let cluster = Cluster::heterogeneous(&crate::config::ClusterConfig::with_executors(8), 3);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), 3).generate();
        let mut sim = Simulator::new(cluster, w);
        let report = sim.run(&mut HeftScheduler::new()).unwrap();
        assert_eq!(report.n_duplicates, 0);
        assert_eq!(report.algo, "HEFT");
        sim.state.validate().unwrap();
    }

    #[test]
    fn gap_aware_heft_completes_and_validates() {
        use crate::config::SchedMode;
        // Insertion-based HEFT: same selector/allocator, gap-aware booking.
        for seed in 0..4 {
            let mut cfg = crate::config::ClusterConfig::with_executors(8);
            cfg.sched_mode = SchedMode::GapAware;
            let w = WorkloadGenerator::new(WorkloadConfig::small_batch(5), seed).generate();
            let mut sim = Simulator::new(Cluster::heterogeneous(&cfg, seed), w);
            let report = sim.run(&mut HeftScheduler::new()).unwrap();
            assert!(report.makespan.is_finite() && report.makespan > 0.0);
            assert_eq!(report.n_duplicates, 0);
            sim.state.validate().unwrap();
        }
    }

    #[test]
    fn heft_beats_fifo_on_average() {
        // Statistical sanity: across several seeds HEFT's rank_up ordering
        // should beat FIFO's arrival ordering (both using their allocators).
        let mut heft_wins = 0;
        let mut total = 0;
        for seed in 0..6 {
            let cfg = crate::config::ClusterConfig::with_executors(8);
            let w = WorkloadGenerator::new(WorkloadConfig::small_batch(6), seed).generate();
            let r_heft = Simulator::new(Cluster::heterogeneous(&cfg, seed), w.clone())
                .run(&mut HeftScheduler::new())
                .unwrap();
            let r_fifo = Simulator::new(Cluster::heterogeneous(&cfg, seed), w)
                .run(&mut FifoScheduler::new())
                .unwrap();
            if r_heft.makespan <= r_fifo.makespan * 1.02 {
                heft_wins += 1;
            }
            total += 1;
        }
        assert!(
            heft_wins * 2 >= total,
            "HEFT should be competitive with FIFO: {heft_wins}/{total}"
        );
    }
}
