//! Scheduling algorithms: the paper's two-phase framework (task selection →
//! executor allocation) plus every baseline it is evaluated against.
//!
//! * Phase 1 — [`TaskSelector`]: FIFO, SJF, HRRN, HighRankUp, random, or
//!   the learned policy (Lachesis / Decima-DEFT, in [`lachesis`]).
//! * Phase 2 — [`Allocator`]: EFT (Eq 2–3) or DEFT (Eq 9–11, Algorithm 1)
//!   which additionally considers duplicating one parent.
//! * Whole-schedule heuristics: HEFT, CPOP, TDCA.

pub mod cpop;
pub mod deft;
pub mod dls;
pub mod eft;
pub mod heft;
pub mod lachesis;
pub mod selectors;
pub mod tdca;

pub use cpop::CpopScheduler;
pub use dls::DlsScheduler;
pub use deft::DeftAllocator;
pub use eft::EftAllocator;
pub use heft::HeftScheduler;
pub use lachesis::{DecimaScheduler, LachesisScheduler};
pub use selectors::{
    FifoScheduler, HighRankUpScheduler, HrrnScheduler, RandomScheduler, SjfScheduler,
};
pub use tdca::TdcaScheduler;

use crate::dag::TaskRef;
use crate::sim::{Allocation, SimState};
use anyhow::Result;

/// A scheduling algorithm: called once per decision at each scheduling
/// event; returns `None` to pass (e.g. intentionally wait for a future
/// event even though executable tasks remain — none of the implemented
/// algorithms do, but the engine supports it).
pub trait Scheduler {
    fn name(&self) -> String;
    /// Reset internal state before a fresh simulation run.
    fn reset(&mut self) {}
    fn step(&mut self, state: &SimState) -> Result<Option<(TaskRef, Allocation)>>;
}

/// Boxed schedulers are schedulers too, so wrappers (tracing, recording,
/// composition) can be generic over `S: Scheduler` and still accept the
/// `Box<dyn Scheduler>` the builders hand out.
impl Scheduler for Box<dyn Scheduler> {
    fn name(&self) -> String {
        (**self).name()
    }

    fn reset(&mut self) {
        (**self).reset()
    }

    fn step(&mut self, state: &SimState) -> Result<Option<(TaskRef, Allocation)>> {
        (**self).step(state)
    }
}

/// Phase-1 policy: pick the next task from the executable set.
pub trait TaskSelector {
    fn name(&self) -> String;
    fn reset(&mut self) {}
    fn select(&mut self, state: &SimState) -> Result<Option<TaskRef>>;
}

/// Phase-2 policy: place a selected task on an executor, possibly
/// duplicating a parent. Returns the decision and its predicted finish
/// time (which must match what [`SimState::apply`] will produce).
pub trait Allocator {
    fn name(&self) -> String;
    fn allocate(&self, state: &SimState, task: TaskRef) -> (Allocation, f64);
}

/// The paper's two-phase composition: any selector + any allocator.
pub struct TwoPhase<S: TaskSelector, A: Allocator> {
    pub selector: S,
    pub allocator: A,
    rename: Option<String>,
}

impl<S: TaskSelector, A: Allocator> TwoPhase<S, A> {
    pub fn of(selector: S, allocator: A) -> Self {
        TwoPhase {
            selector,
            allocator,
            rename: None,
        }
    }

    /// Override the reported algorithm name (e.g. "HEFT" instead of
    /// "rankup-eft").
    pub fn named(selector: S, allocator: A, name: &str) -> Self {
        TwoPhase {
            selector,
            allocator,
            rename: Some(name.to_string()),
        }
    }
}

impl<S: TaskSelector, A: Allocator> Scheduler for TwoPhase<S, A> {
    fn name(&self) -> String {
        match &self.rename {
            Some(n) => n.clone(),
            None => format!("{}-{}", self.selector.name(), self.allocator.name()),
        }
    }

    fn reset(&mut self) {
        self.selector.reset();
    }

    fn step(&mut self, state: &SimState) -> Result<Option<(TaskRef, Allocation)>> {
        // Every executor down (fault outage): pass and wait for a
        // recovery event rather than booking onto a dead cluster.
        if !state.any_executor_available() {
            return Ok(None);
        }
        let selected = {
            let _sp = crate::obs::trace::span("sched", "select");
            self.selector.select(state)?
        };
        match selected {
            None => Ok(None),
            Some(task) => {
                // Clock read only when telemetry is on: the disabled
                // path pays one relaxed load and a branch, nothing more.
                let t0 = crate::obs::enabled().then(std::time::Instant::now);
                let alloc = {
                    let _sp = crate::obs::trace::span("sched", "allocate");
                    let (alloc, _eft) = self.allocator.allocate(state, task);
                    alloc
                };
                if let Some(t0) = t0 {
                    crate::obs::metrics::sim_metrics()
                        .allocate_ms
                        .record(t0.elapsed().as_secs_f64() * 1e3);
                }
                Ok(Some((task, alloc)))
            }
        }
    }
}
