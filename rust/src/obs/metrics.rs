//! Global, lock-light metrics registry: atomic counters, f64 gauges,
//! and fixed log-scale-bucket histograms that are deterministic and
//! mergeable across threads. Snapshots export to Prometheus
//! text-exposition format and to [`Json`].
//!
//! Registration (name + label set → instrument handle) takes a mutex;
//! hot paths hold the returned `Arc` (or reach it through a `OnceLock`
//! catalog like [`service_metrics`]) and touch only atomics. The same
//! (name, labels, kind) key always returns the same instrument, so
//! re-constructing a server or simulator keeps accumulating into the
//! process-wide series — exactly what a `/metrics` scrape should see.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

// ---------------------------------------------------------------------------
// Instruments
// ---------------------------------------------------------------------------

/// Monotone counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge (stored as bits in an `AtomicU64`).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

// Histogram bucketing: each power-of-two octave is split into
// 2^SUB_BITS sub-buckets by the top mantissa bits, so the bucket index
// is read straight off the float's bit pattern — exact, monotone in the
// value, and identical on every platform (no libm). Values are
// milliseconds by convention; the range [2^-10, 2^24) ms spans ~1 µs to
// ~4.7 h, with explicit underflow/overflow buckets outside it.
const SUB_BITS: u64 = 3;
const MIN_EXP: i32 = -10;
const MAX_EXP: i32 = 24;
const FIRST_KEY: u64 = ((1023 + MIN_EXP) as u64) << SUB_BITS;
const LAST_KEY: u64 = ((1023 + MAX_EXP) as u64) << SUB_BITS;
/// Total bucket count: underflow + log buckets + overflow.
pub const NBUCKETS: usize = (LAST_KEY - FIRST_KEY) as usize + 2;
/// Lower edge of the log range (values below land in the underflow bucket).
pub const HIST_MIN: f64 = 0.0009765625; // 2^-10
/// Upper edge of the log range (values at or above land in overflow).
pub const HIST_MAX: f64 = 16777216.0; // 2^24

/// Bucket index for a value. Deterministic pure bit arithmetic.
#[inline]
pub fn bucket_index(v: f64) -> usize {
    if !(v >= HIST_MIN) {
        // NaN, negatives, zero, subnormal-small: underflow bucket.
        return 0;
    }
    if v >= HIST_MAX {
        return NBUCKETS - 1;
    }
    let key = v.to_bits() >> (52 - SUB_BITS);
    (key - FIRST_KEY) as usize + 1
}

/// Inclusive upper edge of bucket `i` (`le` in Prometheus terms).
/// Underflow reports `HIST_MIN`, overflow `+Inf`.
pub fn bucket_upper(i: usize) -> f64 {
    if i == 0 {
        HIST_MIN
    } else if i >= NBUCKETS - 1 {
        f64::INFINITY
    } else {
        f64::from_bits((FIRST_KEY + i as u64) << (52 - SUB_BITS))
    }
}

/// Lower edge of bucket `i`. Underflow reports 0, overflow `HIST_MAX`.
pub fn bucket_lower(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else if i >= NBUCKETS - 1 {
        HIST_MAX
    } else {
        f64::from_bits((FIRST_KEY + (i as u64 - 1)) << (52 - SUB_BITS))
    }
}

/// Fixed log-scale-bucket histogram. Recording is two relaxed atomic
/// ops (bucket count + running sum); memory is a fixed ~2.2 KiB however
/// many samples arrive — the bounded replacement for hoarding every
/// sample in a [`crate::util::stats::Recorder`]. Two histograms filled
/// from interleaved streams merge into exactly the histogram of the
/// combined stream, so per-thread instances are safe to aggregate.
pub struct Histogram {
    counts: Vec<AtomicU64>,
    /// Running sum of recorded values, accumulated via CAS on f64 bits.
    sum_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    #[inline]
    pub fn record(&self, v: f64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        if v.is_finite() {
            let mut cur = self.sum_bits.load(Ordering::Relaxed);
            loop {
                let next = (f64::from_bits(cur) + v).to_bits();
                match self.sum_bits.compare_exchange_weak(
                    cur,
                    next,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            }
        }
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Sum of recorded values (merge order may perturb the last ulps).
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Raw per-bucket counts (index-aligned with [`bucket_upper`]).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Fold another histogram's counts into this one. Bucket counts are
    /// integers, so `merge ≡ recording every sample into one histogram`
    /// exactly (pinned by proptest in `tests/integration_obs.rs`).
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.counts.iter().zip(other.counts.iter()) {
            let n = theirs.load(Ordering::Relaxed);
            if n > 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
        let s = other.sum();
        if s != 0.0 {
            let mut cur = self.sum_bits.load(Ordering::Relaxed);
            loop {
                let next = (f64::from_bits(cur) + s).to_bits();
                match self.sum_bits.compare_exchange_weak(
                    cur,
                    next,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            }
        }
    }

    /// Percentile estimate: the upper edge of the bucket holding the
    /// nearest-rank sample. The true sample lies inside that bucket, so
    /// the estimate is within one bucket width (≤ 12.5% relative) of
    /// exact. Empty histograms return 0.0, matching `Recorder`.
    pub fn percentile(&self, p: f64) -> f64 {
        let counts = self.bucket_counts();
        let n: u64 = counts.iter().sum();
        if n == 0 {
            return 0.0;
        }
        let rank = ((p.clamp(0.0, 100.0) / 100.0) * ((n - 1) as f64)).ceil() as u64;
        let mut cum = 0u64;
        for (i, c) in counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                // Overflow bucket has no finite upper edge; report its
                // lower edge instead of +Inf.
                if i == NBUCKETS - 1 {
                    return HIST_MAX;
                }
                return bucket_upper(i);
            }
        }
        HIST_MAX
    }

    /// Batch percentiles, mirroring `Recorder::percentiles`.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        ps.iter().map(|&p| self.percentile(p)).collect()
    }

    /// Mean of recorded values (0.0 when empty, matching `Recorder`).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() / n as f64
        }
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

#[derive(Clone)]
enum Instrument {
    C(Arc<Counter>),
    G(Arc<Gauge>),
    H(Arc<Histogram>),
}

impl Instrument {
    fn kind(&self) -> &'static str {
        match self {
            Instrument::C(_) => "counter",
            Instrument::G(_) => "gauge",
            Instrument::H(_) => "histogram",
        }
    }
}

struct Entry {
    name: String,
    help: String,
    labels: Vec<(String, String)>,
    instrument: Instrument,
}

static REGISTRY: OnceLock<Mutex<Vec<Entry>>> = OnceLock::new();

fn registry() -> &'static Mutex<Vec<Entry>> {
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

fn intern(
    name: &str,
    help: &str,
    labels: &[(&str, &str)],
    make: impl FnOnce() -> Instrument,
) -> Instrument {
    let labels: Vec<(String, String)> = labels
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
    let mut entries = registry().lock().unwrap();
    for e in entries.iter() {
        if e.name == name && e.labels == labels {
            return e.instrument.clone();
        }
    }
    let instrument = make();
    entries.push(Entry {
        name: name.to_string(),
        help: help.to_string(),
        labels,
        instrument: instrument.clone(),
    });
    instrument
}

/// Register (or fetch) a counter series.
pub fn counter(name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
    match intern(name, help, labels, || Instrument::C(Arc::new(Counter::new()))) {
        Instrument::C(c) => c,
        other => panic!("metric {name} already registered as {}", other.kind()),
    }
}

/// Register (or fetch) a gauge series.
pub fn gauge(name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
    match intern(name, help, labels, || Instrument::G(Arc::new(Gauge::new()))) {
        Instrument::G(g) => g,
        other => panic!("metric {name} already registered as {}", other.kind()),
    }
}

/// Register (or fetch) a histogram series.
pub fn histogram(name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
    match intern(name, help, labels, || {
        Instrument::H(Arc::new(Histogram::new()))
    }) {
        Instrument::H(h) => h,
        other => panic!("metric {name} already registered as {}", other.kind()),
    }
}

/// Escape a label value per the Prometheus text exposition format:
/// backslash, double-quote and newline must be backslash-escaped.
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn label_block(labels: &[(String, String)], extra: Option<(&str, String)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// Render `le` edges the way Prometheus expects (finite decimals, +Inf).
fn fmt_le(v: f64) -> String {
    if v.is_infinite() {
        "+Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Snapshot the whole registry as Prometheus text exposition format.
/// Histograms emit cumulative `_bucket{le=...}` lines for non-empty
/// buckets (plus `+Inf`), `_sum`, and `_count`.
pub fn prometheus_text() -> String {
    let entries = registry().lock().unwrap();
    // Group series of the same name so # HELP/# TYPE appear once.
    let mut order: Vec<usize> = (0..entries.len()).collect();
    order.sort_by(|&a, &b| {
        (&entries[a].name, &entries[a].labels).cmp(&(&entries[b].name, &entries[b].labels))
    });
    let mut out = String::new();
    let mut last_name = "";
    for &i in &order {
        let e = &entries[i];
        if e.name != last_name {
            out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
            out.push_str(&format!("# TYPE {} {}\n", e.name, e.instrument.kind()));
            last_name = &e.name;
        }
        match &e.instrument {
            Instrument::C(c) => {
                out.push_str(&format!(
                    "{}{} {}\n",
                    e.name,
                    label_block(&e.labels, None),
                    c.get()
                ));
            }
            Instrument::G(g) => {
                out.push_str(&format!(
                    "{}{} {}\n",
                    e.name,
                    label_block(&e.labels, None),
                    g.get()
                ));
            }
            Instrument::H(h) => {
                let counts = h.bucket_counts();
                let mut cum = 0u64;
                for (b, &c) in counts.iter().enumerate() {
                    if c == 0 {
                        continue;
                    }
                    cum += c;
                    if b == NBUCKETS - 1 {
                        continue; // +Inf line below carries the total
                    }
                    out.push_str(&format!(
                        "{}_bucket{} {}\n",
                        e.name,
                        label_block(&e.labels, Some(("le", fmt_le(bucket_upper(b))))),
                        cum
                    ));
                }
                let total: u64 = counts.iter().sum();
                out.push_str(&format!(
                    "{}_bucket{} {}\n",
                    e.name,
                    label_block(&e.labels, Some(("le", "+Inf".to_string()))),
                    total
                ));
                out.push_str(&format!(
                    "{}_sum{} {}\n",
                    e.name,
                    label_block(&e.labels, None),
                    h.sum()
                ));
                out.push_str(&format!(
                    "{}_count{} {}\n",
                    e.name,
                    label_block(&e.labels, None),
                    total
                ));
            }
        }
    }
    out
}

/// Snapshot the whole registry as JSON: an array of series objects
/// (`name`, `kind`, `labels`, and a kind-specific `value`). Histograms
/// carry count/sum plus p50/p95/p99 estimates rather than raw buckets.
pub fn snapshot_json() -> Json {
    let entries = registry().lock().unwrap();
    let mut series: Vec<Json> = Vec::with_capacity(entries.len());
    let mut order: Vec<usize> = (0..entries.len()).collect();
    order.sort_by(|&a, &b| {
        (&entries[a].name, &entries[a].labels).cmp(&(&entries[b].name, &entries[b].labels))
    });
    for &i in &order {
        let e = &entries[i];
        let labels = Json::Obj(
            e.labels
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect(),
        );
        let value = match &e.instrument {
            Instrument::C(c) => Json::from(c.get()),
            Instrument::G(g) => Json::from(g.get()),
            Instrument::H(h) => {
                let ps = h.percentiles(&[50.0, 95.0, 99.0]);
                Json::from_pairs(vec![
                    ("count", Json::from(h.count())),
                    ("sum", Json::from(h.sum())),
                    ("p50", Json::from(ps[0])),
                    ("p95", Json::from(ps[1])),
                    ("p99", Json::from(ps[2])),
                ])
            }
        };
        series.push(Json::from_pairs(vec![
            ("name", Json::from(e.name.clone())),
            ("kind", Json::from(e.instrument.kind())),
            ("labels", labels),
            ("value", value),
        ]));
    }
    Json::from_pairs(vec![("series", Json::Arr(series))])
}

// ---------------------------------------------------------------------------
// Catalogs: one OnceLock per subsystem so hot paths pay a single atomic
// load to reach their handles. Metric names are documented in
// docs/observability.md — keep the two in sync.
// ---------------------------------------------------------------------------

/// Request-type label values, index-aligned with
/// [`crate::service::Request::kind_index`].
pub const REQUEST_KINDS: [&str; 7] = [
    "submit_job",
    "task_complete",
    "schedule",
    "report_failure",
    "status",
    "shutdown",
    "metrics",
];

/// Service-side instruments (server core loop, mailbox, journal).
pub struct ServiceMetrics {
    /// `lachesis_requests_total{type=...}` — requests dispatched.
    pub requests_total: [Arc<Counter>; 7],
    /// `lachesis_request_latency_ms{type=...}` — dispatch wall time.
    pub request_latency_ms: [Arc<Histogram>; 7],
    /// `lachesis_batch_size` — requests drained per mailbox batch.
    pub batch_size: Arc<Histogram>,
    /// `lachesis_mailbox_depth` — queue depth after the last enqueue/drain.
    pub mailbox_depth: Arc<Gauge>,
    /// `lachesis_requests_shed_total` — requests refused under overload.
    pub requests_shed_total: Arc<Counter>,
    /// `lachesis_requests_deduped_total` — retries answered from the window.
    pub requests_deduped_total: Arc<Counter>,
    /// `lachesis_heartbeats_coalesced_total` — heartbeats merged per batch.
    pub heartbeats_coalesced_total: Arc<Counter>,
    /// `lachesis_journal_append_ms` — write-ahead append wall time.
    pub journal_append_ms: Arc<Histogram>,
    /// `lachesis_journal_fsync_ms` — per-batch fsync wall time.
    pub journal_fsync_ms: Arc<Histogram>,
    /// `lachesis_journal_fsyncs_total` — fsync barrier count.
    pub journal_fsyncs_total: Arc<Counter>,
    /// `lachesis_snapshot_writes_total` — checkpoint files written.
    pub snapshot_writes_total: Arc<Counter>,
    /// `lachesis_snapshot_write_ms` — checkpoint write wall time.
    pub snapshot_write_ms: Arc<Histogram>,
}

/// Global service-metrics catalog.
pub fn service_metrics() -> &'static ServiceMetrics {
    static M: OnceLock<ServiceMetrics> = OnceLock::new();
    M.get_or_init(|| ServiceMetrics {
        requests_total: REQUEST_KINDS.map(|k| {
            counter(
                "lachesis_requests_total",
                "Requests dispatched by the scheduling service, by type.",
                &[("type", k)],
            )
        }),
        request_latency_ms: REQUEST_KINDS.map(|k| {
            histogram(
                "lachesis_request_latency_ms",
                "Service-side dispatch latency per request, by type (ms).",
                &[("type", k)],
            )
        }),
        batch_size: histogram(
            "lachesis_batch_size",
            "Requests drained from the mailbox per core-loop batch.",
            &[],
        ),
        mailbox_depth: gauge(
            "lachesis_mailbox_depth",
            "Mailbox depth observed at the last enqueue or drain.",
            &[],
        ),
        requests_shed_total: counter(
            "lachesis_requests_shed_total",
            "Mutating requests refused by the admission policy.",
            &[],
        ),
        requests_deduped_total: counter(
            "lachesis_requests_deduped_total",
            "Retried requests answered from the dedup window.",
            &[],
        ),
        heartbeats_coalesced_total: counter(
            "lachesis_heartbeats_coalesced_total",
            "Consecutive same-connection heartbeats merged inside a batch.",
            &[],
        ),
        journal_append_ms: histogram(
            "lachesis_journal_append_ms",
            "Write-ahead journal append wall time (ms).",
            &[],
        ),
        journal_fsync_ms: histogram(
            "lachesis_journal_fsync_ms",
            "Write-ahead journal fsync wall time per batch (ms).",
            &[],
        ),
        journal_fsyncs_total: counter(
            "lachesis_journal_fsyncs_total",
            "Durability barriers (fsync) executed.",
            &[],
        ),
        snapshot_writes_total: counter(
            "lachesis_snapshot_writes_total",
            "Periodic core snapshots written.",
            &[],
        ),
        snapshot_write_ms: histogram(
            "lachesis_snapshot_write_ms",
            "Core snapshot write wall time (ms).",
            &[],
        ),
    })
}

/// Simulator / policy decision-loop instruments.
pub struct SimMetrics {
    /// `lachesis_decisions_total` — scheduler decisions taken.
    pub decisions_total: Arc<Counter>,
    /// `lachesis_decision_ms` — whole `scheduler.step` wall time.
    pub decision_ms: Arc<Histogram>,
    /// `lachesis_apply_ms` — `SimState::apply` wall time.
    pub apply_ms: Arc<Histogram>,
    /// `lachesis_encode_ms` — graph encode (cache refresh) wall time.
    pub encode_ms: Arc<Histogram>,
    /// `lachesis_forward_ms` — sparse GNN forward wall time.
    pub forward_ms: Arc<Histogram>,
    /// `lachesis_allocate_ms` — phase-2 allocator wall time.
    pub allocate_ms: Arc<Histogram>,
    /// `lachesis_encoder_reuses_total` — incremental cache refreshes.
    pub encoder_reuses_total: Arc<Counter>,
    /// `lachesis_encoder_rebuilds_total` — full encode rebuilds.
    pub encoder_rebuilds_total: Arc<Counter>,
}

/// Global simulator/policy-metrics catalog.
pub fn sim_metrics() -> &'static SimMetrics {
    static M: OnceLock<SimMetrics> = OnceLock::new();
    M.get_or_init(|| SimMetrics {
        decisions_total: counter(
            "lachesis_decisions_total",
            "Scheduler decisions taken across all runs.",
            &[],
        ),
        decision_ms: histogram(
            "lachesis_decision_ms",
            "Wall time of one scheduler.step decision (ms).",
            &[],
        ),
        apply_ms: histogram(
            "lachesis_apply_ms",
            "Wall time of SimState::apply per decision (ms).",
            &[],
        ),
        encode_ms: histogram(
            "lachesis_encode_ms",
            "Wall time of graph encoding / encoder-cache refresh (ms).",
            &[],
        ),
        forward_ms: histogram(
            "lachesis_forward_ms",
            "Wall time of the policy network forward pass (ms).",
            &[],
        ),
        allocate_ms: histogram(
            "lachesis_allocate_ms",
            "Wall time of phase-2 executor allocation (ms).",
            &[],
        ),
        encoder_reuses_total: counter(
            "lachesis_encoder_reuses_total",
            "Encoder-cache refreshes that reused the incremental cache.",
            &[],
        ),
        encoder_rebuilds_total: counter(
            "lachesis_encoder_rebuilds_total",
            "Encoder-cache refreshes that rebuilt from scratch.",
            &[],
        ),
    })
}

/// Trainer instruments (per-episode phases and learning signals).
pub struct TrainMetrics {
    /// `lachesis_train_episodes_total` — episodes completed.
    pub episodes_total: Arc<Counter>,
    /// `lachesis_train_rollout_ms` — parallel rollout wall time.
    pub rollout_ms: Arc<Histogram>,
    /// `lachesis_train_update_ms` — backward + Adam wall time.
    pub update_ms: Arc<Histogram>,
    /// `lachesis_train_episode` — last completed episode index.
    pub episode: Arc<Gauge>,
    /// `lachesis_train_reward` — mean episode return.
    pub reward: Arc<Gauge>,
    /// `lachesis_train_entropy` — policy entropy.
    pub entropy: Arc<Gauge>,
    /// `lachesis_train_grad_norm` — L2 norm of the episode's parameter
    /// update (a gradient-scale proxy every backend can report).
    pub grad_norm: Arc<Gauge>,
}

/// Global trainer-metrics catalog.
pub fn train_metrics() -> &'static TrainMetrics {
    static M: OnceLock<TrainMetrics> = OnceLock::new();
    M.get_or_init(|| TrainMetrics {
        episodes_total: counter(
            "lachesis_train_episodes_total",
            "Training episodes completed.",
            &[],
        ),
        rollout_ms: histogram(
            "lachesis_train_rollout_ms",
            "Wall time of the parallel rollout phase per episode (ms).",
            &[],
        ),
        update_ms: histogram(
            "lachesis_train_update_ms",
            "Wall time of backward + Adam updates per episode (ms).",
            &[],
        ),
        episode: gauge(
            "lachesis_train_episode",
            "Index of the last completed training episode.",
            &[],
        ),
        reward: gauge(
            "lachesis_train_reward",
            "Mean episode return of the last training episode.",
            &[],
        ),
        entropy: gauge(
            "lachesis_train_entropy",
            "Policy entropy at the last update.",
            &[],
        ),
        grad_norm: gauge(
            "lachesis_train_grad_norm",
            "L2 norm of the parameter update applied by the last episode \
             (gradient-scale proxy).",
            &[],
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_edges_are_exact() {
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-1.0), 0);
        assert_eq!(bucket_index(f64::NAN), 0);
        assert_eq!(bucket_index(HIST_MIN / 2.0), 0);
        assert_eq!(bucket_index(HIST_MIN), 1);
        assert_eq!(bucket_index(HIST_MAX), NBUCKETS - 1);
        assert_eq!(bucket_index(f64::INFINITY), NBUCKETS - 1);
        let mut last = 0usize;
        let mut v = HIST_MIN;
        while v < HIST_MAX * 2.0 {
            let i = bucket_index(v);
            assert!(i >= last, "bucket index not monotone at {v}");
            last = i;
            v *= 1.037;
        }
    }

    #[test]
    fn bucket_bounds_bracket_their_values() {
        for &v in &[0.001, 0.01, 0.5, 1.0, 1.5, 7.0, 100.0, 12345.6] {
            let i = bucket_index(v);
            assert!(bucket_lower(i) <= v, "lower({i}) > {v}");
            assert!(v <= bucket_upper(i), "{v} > upper({i})");
            // Sub-bucket relative width is at most 2^-SUB_BITS.
            assert!(bucket_upper(i) <= bucket_lower(i) * (1.0 + 1.0 / 8.0) + 1e-12);
        }
    }

    #[test]
    fn histogram_percentiles_track_recorder_within_one_bucket() {
        use crate::util::stats::Recorder;
        let h = Histogram::new();
        let mut r = Recorder::new();
        // Dense log-spaced samples: adjacent samples sit within one
        // bucket width, so the histogram estimate must land within one
        // bucket width of the interpolated exact percentile.
        let mut v = 0.05f64;
        for _ in 0..4000 {
            h.record(v);
            r.push(v);
            v *= 1.002;
        }
        for &p in &[50.0, 95.0, 99.0] {
            let est = h.percentile(p);
            let exact = r.percentile(p);
            assert!(est >= exact - 1e-12, "p{p}: est {est} < exact {exact}");
            assert!(
                est <= exact * (1.0 + 0.13),
                "p{p}: est {est} beyond one bucket above exact {exact}"
            );
        }
    }

    #[test]
    fn histogram_merge_equals_single() {
        let a = Histogram::new();
        let b = Histogram::new();
        let all = Histogram::new();
        for i in 0..1000 {
            let v = 0.01 * (i as f64 + 1.0) * if i % 3 == 0 { 17.0 } else { 1.0 };
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge_from(&b);
        assert_eq!(a.bucket_counts(), all.bucket_counts());
        assert_eq!(a.count(), all.count());
        assert!((a.sum() - all.sum()).abs() < 1e-6 * all.sum().abs().max(1.0));
    }

    #[test]
    fn registry_interns_by_name_and_labels() {
        let c1 = counter("lachesis_test_interned_total", "h", &[("k", "a")]);
        let c2 = counter("lachesis_test_interned_total", "h", &[("k", "a")]);
        let c3 = counter("lachesis_test_interned_total", "h", &[("k", "b")]);
        c1.inc();
        c2.inc();
        c3.inc();
        assert_eq!(c1.get(), 2);
        assert_eq!(c3.get(), 1);
        let text = prometheus_text();
        assert!(text.contains("lachesis_test_interned_total{k=\"a\"} 2"));
        assert!(text.contains("lachesis_test_interned_total{k=\"b\"} 1"));
    }

    #[test]
    fn prometheus_escaping_handles_quotes_backslashes_newlines() {
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(escape_label_value("a\"b"), "a\\\"b");
        assert_eq!(escape_label_value("a\\b"), "a\\\\b");
        assert_eq!(escape_label_value("a\nb"), "a\\nb");
        let c = counter(
            "lachesis_test_escape_total",
            "h",
            &[("path", "C:\\tmp\n\"x\"")],
        );
        c.inc();
        let text = prometheus_text();
        assert!(
            text.contains("lachesis_test_escape_total{path=\"C:\\\\tmp\\n\\\"x\\\"\"} 1"),
            "escaped series missing in:\n{text}"
        );
    }

    #[test]
    fn prometheus_histogram_lines_are_cumulative_and_close_with_inf() {
        let h = histogram("lachesis_test_hist_ms", "h", &[("leg", "t")]);
        for v in [0.5, 0.5, 2.0, 1e-9, 1e12] {
            h.record(v);
        }
        let text = prometheus_text();
        assert!(text.contains("# TYPE lachesis_test_hist_ms histogram"));
        assert!(text.contains("lachesis_test_hist_ms_bucket{leg=\"t\",le=\"+Inf\"} 5"));
        assert!(text.contains("lachesis_test_hist_ms_count{leg=\"t\"} 5"));
        // Cumulative counts never decrease down the le ladder.
        let mut last = 0u64;
        for line in text.lines() {
            if line.starts_with("lachesis_test_hist_ms_bucket{leg=\"t\"") {
                let n: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(n >= last, "non-cumulative bucket line: {line}");
                last = n;
            }
        }
    }

    #[test]
    fn snapshot_json_parses_and_carries_series() {
        let c = counter("lachesis_test_json_total", "h", &[]);
        c.add(3);
        let j = snapshot_json();
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        let series = back.get("series").and_then(|s| s.as_arr()).unwrap();
        assert!(series.iter().any(|s| {
            s.get("name").and_then(|n| n.as_str()) == Some("lachesis_test_json_total")
        }));
    }
}
