//! Span/event tracer with per-thread ring buffers, exporting Chrome
//! `trace_event` JSON (load the file at <https://ui.perfetto.dev> or
//! `chrome://tracing`).
//!
//! Each thread records into its own fixed-capacity ring buffer behind a
//! thread-private mutex (uncontended except while dumping), so tracing
//! a hot loop never serializes threads against each other. When the
//! ring fills, the oldest events are overwritten and counted — a trace
//! is a bounded window onto the run, never an OOM.
//!
//! Disabled cost is one relaxed atomic load and a branch per site:
//! [`span`] returns an inert guard without reading the clock, and
//! [`instant`] returns immediately. Tracing never mutates anything the
//! scheduler math can see, so schedules stay bit-identical with tracing
//! on or off (pinned by `tests/integration_obs.rs`).

use std::cell::OnceCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Events kept per thread before the ring starts overwriting.
const RING_CAP: usize = 1 << 16;

static TRACING: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicUsize = AtomicUsize::new(1);
static BUFS: OnceLock<Mutex<Vec<Arc<Mutex<ThreadBuf>>>>> = OnceLock::new();

/// Is span recording on? Hot-path guard, intentionally `Relaxed`.
#[inline]
pub fn tracing() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// Turn span recording on (also flips the [`crate::obs`] master switch
/// so metric sites gated on it light up alongside the trace).
pub fn start_tracing() {
    EPOCH.get_or_init(Instant::now);
    crate::obs::set_enabled(true);
    TRACING.store(true, Ordering::Relaxed);
}

/// Stop recording (buffers are kept for [`dump_chrome_trace`]).
pub fn stop_tracing() {
    TRACING.store(false, Ordering::Relaxed);
}

#[derive(Clone, Copy)]
struct Ev {
    /// Chrome phase: b'X' (complete span) or b'i' (instant event).
    ph: u8,
    name: &'static str,
    cat: &'static str,
    ts_us: u64,
    dur_us: u64,
    arg: Option<(&'static str, f64)>,
}

struct ThreadBuf {
    tid: usize,
    thread_name: String,
    evs: Vec<Ev>,
    /// Next overwrite position once `evs` reached `RING_CAP`.
    head: usize,
    dropped: u64,
}

impl ThreadBuf {
    fn push(&mut self, ev: Ev) {
        if self.evs.len() < RING_CAP {
            self.evs.push(ev);
        } else {
            self.evs[self.head] = ev;
            self.head = (self.head + 1) % RING_CAP;
            self.dropped += 1;
        }
    }
}

fn bufs() -> &'static Mutex<Vec<Arc<Mutex<ThreadBuf>>>> {
    BUFS.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LOCAL: OnceCell<Arc<Mutex<ThreadBuf>>> = OnceCell::new();
}

fn register_thread() -> Arc<Mutex<ThreadBuf>> {
    let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    let thread_name = std::thread::current()
        .name()
        .map(|n| n.to_string())
        .unwrap_or_else(|| format!("thread-{tid}"));
    let buf = Arc::new(Mutex::new(ThreadBuf {
        tid,
        thread_name,
        evs: Vec::new(),
        head: 0,
        dropped: 0,
    }));
    bufs().lock().unwrap().push(buf.clone());
    buf
}

fn now_us() -> u64 {
    Instant::now()
        .saturating_duration_since(*EPOCH.get_or_init(Instant::now))
        .as_micros() as u64
}

fn push(ev: Ev) {
    LOCAL.with(|cell| {
        let buf = cell.get_or_init(register_thread);
        buf.lock().unwrap().push(ev);
    });
}

/// RAII span guard: records a complete (`ph:"X"`) event on drop. Inert
/// (no clock read, no allocation) when tracing is off.
pub struct Span {
    start: Option<Instant>,
    name: &'static str,
    cat: &'static str,
    arg: Option<(&'static str, f64)>,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(t0) = self.start.take() {
            let epoch = *EPOCH.get_or_init(Instant::now);
            let ts_us = t0.saturating_duration_since(epoch).as_micros() as u64;
            let dur_us = t0.elapsed().as_micros() as u64;
            push(Ev {
                ph: b'X',
                name: self.name,
                cat: self.cat,
                ts_us,
                dur_us,
                arg: self.arg,
            });
        }
    }
}

/// Open a span. `cat`/`name` must be static (they name code sites, not
/// data) so the hot path never allocates.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Span {
    Span {
        start: if tracing() { Some(Instant::now()) } else { None },
        name,
        cat,
        arg: None,
    }
}

/// Open a span carrying one numeric argument (e.g. a batch size).
#[inline]
pub fn span_with(cat: &'static str, name: &'static str, key: &'static str, val: f64) -> Span {
    Span {
        start: if tracing() { Some(Instant::now()) } else { None },
        name,
        cat,
        arg: Some((key, val)),
    }
}

/// Record an instant (`ph:"i"`) event, optionally with one argument.
#[inline]
pub fn instant(cat: &'static str, name: &'static str, arg: Option<(&'static str, f64)>) {
    if !tracing() {
        return;
    }
    push(Ev {
        ph: b'i',
        name,
        cat,
        ts_us: now_us(),
        dur_us: 0,
        arg,
    });
}

/// Number of events currently buffered across all threads.
pub fn buffered_events() -> usize {
    let bufs = bufs().lock().unwrap();
    bufs.iter().map(|b| b.lock().unwrap().evs.len()).sum()
}

/// Discard all buffered events (tests; a fresh `--trace-out` run).
pub fn clear() {
    let bufs = bufs().lock().unwrap();
    for b in bufs.iter() {
        let mut b = b.lock().unwrap();
        b.evs.clear();
        b.head = 0;
        b.dropped = 0;
    }
}

fn quote(s: &str) -> String {
    crate::util::json::Json::Str(s.to_string()).to_string()
}

/// Render every buffered event as Chrome `trace_event` JSON.
pub fn chrome_trace_json() -> String {
    let pid = std::process::id();
    let bufs = bufs().lock().unwrap();
    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    let mut emit = |s: String, first: &mut bool| {
        if !*first {
            out.push(',');
        }
        out.push('\n');
        out.push_str(&s);
        *first = false;
    };
    for b in bufs.iter() {
        let b = b.lock().unwrap();
        emit(
            format!(
                "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":{}}}}}",
                b.tid,
                quote(&b.thread_name)
            ),
            &mut first,
        );
        if b.dropped > 0 {
            emit(
                format!(
                    "{{\"ph\":\"i\",\"pid\":{pid},\"tid\":{},\"ts\":0,\"s\":\"t\",\
                     \"cat\":\"obs\",\"name\":\"ring_dropped\",\
                     \"args\":{{\"dropped\":{}}}}}",
                    b.tid, b.dropped
                ),
                &mut first,
            );
        }
        // Ring order: oldest first (head..end, then start..head).
        let n = b.evs.len();
        for k in 0..n {
            let ev = &b.evs[(b.head + k) % n.max(1)];
            let args = match ev.arg {
                Some((k, v)) if v.is_finite() => format!(",\"args\":{{\"{k}\":{v}}}"),
                _ => String::new(),
            };
            let line = match ev.ph {
                b'X' => format!(
                    "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{},\"ts\":{},\"dur\":{},\
                     \"cat\":\"{}\",\"name\":\"{}\"{args}}}",
                    b.tid, ev.ts_us, ev.dur_us, ev.cat, ev.name
                ),
                _ => format!(
                    "{{\"ph\":\"i\",\"pid\":{pid},\"tid\":{},\"ts\":{},\"s\":\"t\",\
                     \"cat\":\"{}\",\"name\":\"{}\"{args}}}",
                    b.tid, ev.ts_us, ev.cat, ev.name
                ),
            };
            emit(line, &mut first);
        }
    }
    out.push_str("\n]}\n");
    out
}

/// Write the Chrome trace to `path` (the `--trace-out FILE` sink).
pub fn dump_chrome_trace(path: &str) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace_json())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    // The tracing switch is process-global; serialize the tests that
    // toggle it so the parallel test harness can't interleave them.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_span_records_nothing() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        stop_tracing();
        let before = buffered_events();
        {
            let _s = span("test", "disabled_span");
        }
        instant("test", "disabled_instant", None);
        assert_eq!(buffered_events(), before);
    }

    #[test]
    fn trace_json_is_valid_and_carries_spans() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        start_tracing();
        {
            let _s = span_with("test", "unit_span", "n", 3.0);
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
        instant("test", "unit_instant", Some(("x", 1.0)));
        let t = std::thread::Builder::new()
            .name("obs-test-worker".into())
            .spawn(|| {
                let _s = span("test", "worker_span");
            })
            .unwrap();
        t.join().unwrap();
        stop_tracing();

        let text = chrome_trace_json();
        let parsed = Json::parse(&text).expect("chrome trace must be valid JSON");
        let events = parsed
            .get("traceEvents")
            .and_then(|e| e.as_arr())
            .expect("traceEvents array");
        let has = |name: &str, ph: &str| {
            events.iter().any(|e| {
                e.get("name").and_then(|n| n.as_str()) == Some(name)
                    && e.get("ph").and_then(|p| p.as_str()) == Some(ph)
            })
        };
        assert!(has("unit_span", "X"), "missing complete span");
        assert!(has("unit_instant", "i"), "missing instant event");
        assert!(has("worker_span", "X"), "missing cross-thread span");
        assert!(has("thread_name", "M"), "missing thread metadata");
        // Complete spans carry ts + dur in microseconds.
        let sp = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("unit_span"))
            .unwrap();
        assert!(sp.get("dur").and_then(|d| d.as_f64()).unwrap() >= 1.0);
        assert!(sp.get("ts").is_some() && sp.get("pid").is_some() && sp.get("tid").is_some());
        assert_eq!(
            sp.get("args").and_then(|a| a.get("n")).and_then(|v| v.as_f64()),
            Some(3.0)
        );
    }

    #[test]
    fn ring_overwrites_oldest_beyond_capacity() {
        let mut buf = ThreadBuf {
            tid: 0,
            thread_name: "t".into(),
            evs: Vec::new(),
            head: 0,
            dropped: 0,
        };
        for i in 0..(RING_CAP + 10) {
            buf.push(Ev {
                ph: b'i',
                name: "e",
                cat: "t",
                ts_us: i as u64,
                dur_us: 0,
                arg: None,
            });
        }
        assert_eq!(buf.evs.len(), RING_CAP);
        assert_eq!(buf.dropped, 10);
        // Oldest surviving event is ts=10 at the head.
        assert_eq!(buf.evs[buf.head].ts_us, 10);
    }
}
