//! Observability: a lock-light metrics registry ([`metrics`]) and a
//! span/event tracer with Chrome `trace_event` export ([`trace`]).
//!
//! Design constraints, in order:
//!
//! 1. **Telemetry never changes behavior.** Instrumentation only reads
//!    clocks and bumps atomics — it must never touch an RNG stream,
//!    event ordering, or any f64 that feeds a schedule. Golden tests
//!    pin every scheduler's output bitwise identical with telemetry on
//!    or off (`tests/integration_obs.rs`).
//! 2. **Near-zero disabled cost.** Every hot-path site degrades to one
//!    relaxed atomic load and a predictable branch when telemetry is
//!    off. `bench_sim` measures this as `obs_disabled_overhead_ratio`
//!    and CI gates it below 3%.
//! 3. **No dependencies.** Prometheus text exposition and Chrome trace
//!    JSON are both hand-rolled (the offline registry has no serde or
//!    tracing crates), reusing [`crate::util::json`] where convenient.
//!
//! The master switch [`enabled`] gates metric recording on the
//! simulator / policy / trainer hot paths; the service enables it at
//! server construction (a TCP round-trip dwarfs an atomic increment).
//! Span tracing has its own switch ([`trace::tracing`]) so `--trace-out`
//! can be turned on independently of metrics.

pub mod metrics;
pub mod trace;

use std::sync::atomic::{AtomicBool, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Master telemetry switch. Hot paths check this first; when false the
/// entire site is one relaxed load + branch.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn metric recording on (service startup, `--trace-out`,
/// `--metrics-*` flags). Never turned off implicitly: telemetry is
/// process-global.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}
