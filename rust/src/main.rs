//! `lachesis` — CLI for the DAG-scheduling system: workload generation,
//! single schedules, RL training, the plug-and-play service, and the
//! paper-reproduction harness (one subcommand per figure).

use anyhow::{bail, Context, Result};
use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, TrainConfig, WorkloadConfig};
use lachesis::exp::{self, PolicySource};
use lachesis::sim::Simulator;
use lachesis::util::cli::Args;
use lachesis::workload::{trace, WorkloadGenerator};

const USAGE: &str = "\
lachesis — learning to optimize DAG scheduling in heterogeneous environments

USAGE:
  lachesis workload  --jobs N [--mode batch|continuous] [--seed S] [--out trace.json]
  lachesis schedule  --algo NAME [--jobs N] [--trace trace.json] [--seed S]
                     [--executors M] [--validate] [--backend pjrt|rust]
                     [--trace-out spans.json]   (record telemetry spans,
                      write a Chrome trace viewable in ui.perfetto.dev)
                     [--net flat|tree:RxW|fat-tree:K]   (network topology;
                      flat reproduces the paper's uniform comm model)
                     [--fault-rate R]   (inject crashes/stragglers at R per exec/s)
                     [--rack-rate R]    (correlated whole-rack outages at R per rack/s)
  lachesis train     [--episodes N] [--agents A] [--seed S] [--decima]
                     [--threads N|auto] [--artifacts DIR]
                     [--out checkpoints/lachesis.bin]
                     (uses the AOT train_step when built with --features
                      pjrt and artifacts exist; otherwise the native CPU
                      gradient backend — no artifacts needed)
                     [--metrics-jsonl FILE]   (append one JSON line of
                      training metrics per episode)
                     [--trace-out spans.json]
  lachesis serve     [--addr 127.0.0.1:7654] [--algo NAME] [--executors M]
                     [--net flat|tree:RxW|fat-tree:K]
                     [--mode serial|batched]   (batched: mailbox core loop
                      + lock-free status snapshots — the default)
                     [--journal DIR] [--restore] [--snapshot-every N]
                     (write-ahead journal + periodic snapshots; --restore
                      rebuilds the core from disk before serving)
                     [--max-queue N] [--admission shed|block]
                     (bounded mailbox: refuse with `overloaded` or block)
                     [--metrics-addr 127.0.0.1:9464]   (serve the live
                      Prometheus text exposition over plain HTTP GET)
                     [--trace-out spans.json]
  lachesis soak      [--masters N] [--jobs J] [--mean-interval S]
                     [--executors M] [--algo NAME] [--seed S]
                     [--status-every K] [--monitors N] [--max-queue N]
                     [--journal DIR] [--snapshot-every N]
                     [--out BENCH_service.json] [--trace-out spans.json]
                     (sustained Poisson load over TCP: serial vs batched
                      vs batched+journal, with the journaling overhead
                      ratio CI gates on)
  lachesis soak --chaos
                     [--jobs J] [--kill-after R] [--executors M]
                     [--algo NAME] [--seed S] [--journal DIR]
                     [--snapshot-every N] [--out BENCH_chaos.json]
                     (SIGKILL a journaled server child mid-stream,
                      restore it, and require the final status to match
                      an uninterrupted reference byte-for-byte)
  lachesis repro     fig4|fig5|fig6|fig7|all [--quick] [--seeds K]
                     [--threads N|auto] [--backend pjrt|rust]
  lachesis ablate    [--seeds K] [--threads N|auto]
  lachesis faults    [--rates R1,R2,..] [--jobs N] [--seeds K]
                     [--threads N|auto]   (robustness sweep vs failure rate)
  lachesis locality  [--jobs N] [--seeds K] [--threads N|auto]
                     (sweep schedulers across flat vs tree vs fat-tree
                      topologies; reports makespan, duplicates and
                      cross-rack traffic per topology)
  lachesis info      [--artifacts DIR]

Algorithms: FIFO-DEFT SJF-DEFT HRRN-DEFT HighRankUp-DEFT HEFT CPOP DLS TDCA
            Random-DEFT Decima-DEFT Lachesis
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn policy_source(args: &Args) -> PolicySource {
    PolicySource {
        artifact_dir: args.opt_or("artifacts", "artifacts").to_string(),
        lachesis_params: args.opt("lachesis-params").map(str::to_string),
        decima_params: args.opt("decima-params").map(str::to_string),
        backend: args.opt_or("backend", "pjrt").to_string(),
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("workload") => cmd_workload(&args),
        Some("schedule") => cmd_schedule(&args),
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("soak") => cmd_soak(&args),
        Some("repro") => cmd_repro(&args),
        Some("ablate") => {
            let seeds = args.usize_opt("seeds", 3)?;
            let threads = args.threads_opt(1)?;
            let out = exp::ablate(&policy_source(&args), seeds, threads)?;
            println!("{out}");
            Ok(())
        }
        Some("faults") => cmd_faults(&args),
        Some("locality") => cmd_locality(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_workload(args: &Args) -> Result<()> {
    let n = args.usize_opt("jobs", 10)?;
    let seed = args.u64_opt("seed", 1)?;
    let mode = args.opt_or("mode", "batch");
    let cfg = match mode {
        "batch" => WorkloadConfig::small_batch(n),
        "continuous" => WorkloadConfig::continuous(n),
        other => bail!("unknown mode '{other}'"),
    };
    let w = WorkloadGenerator::new(cfg, seed).generate();
    println!(
        "generated {} jobs / {} tasks / {} edges (total work {:.1} GHz·s)",
        w.n_jobs(),
        w.n_tasks(),
        w.n_edges(),
        w.total_work()
    );
    if let Some(out) = args.opt("out") {
        trace::save(&w, out)?;
        println!("trace written to {out}");
    }
    Ok(())
}

/// Parse the `--net` flag into a cluster config's network model.
fn net_config(args: &Args) -> Result<lachesis::net::NetConfig> {
    lachesis::net::NetConfig::parse(args.opt_or("net", "flat"))
}

/// Honor `--trace-out FILE`: turn span tracing (and the metrics
/// registry) on and return the path the caller must dump to on exit.
/// (`--trace` was already taken by `schedule` for workload-trace
/// replay, hence the distinct name.)
fn trace_out_start(args: &Args) -> Option<String> {
    let path = args.opt("trace-out")?.to_string();
    lachesis::obs::trace::start_tracing();
    Some(path)
}

/// Write the Chrome trace accumulated since [`trace_out_start`].
fn trace_out_finish(path: Option<String>) -> Result<()> {
    if let Some(path) = path {
        lachesis::obs::trace::stop_tracing();
        lachesis::obs::trace::dump_chrome_trace(&path)
            .with_context(|| format!("writing chrome trace {path}"))?;
        println!("chrome trace written to {path} — load it at ui.perfetto.dev");
    }
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let algo = args.opt_or("algo", "Lachesis");
    let seed = args.u64_opt("seed", 1)?;
    let executors = args.usize_opt("executors", 50)?;
    let workload = match args.opt("trace") {
        Some(path) => trace::load(path)?,
        None => {
            let n = args.usize_opt("jobs", 10)?;
            WorkloadGenerator::new(WorkloadConfig::small_batch(n), seed).generate()
        }
    };
    let mut ccfg = ClusterConfig::with_executors(executors);
    ccfg.net = net_config(args)?;
    ccfg.validate()?;
    let cluster = Cluster::heterogeneous(&ccfg, seed);
    let src = policy_source(args);
    let mut sched = exp::build_scheduler(algo, &src, seed)?;
    let mut sim = Simulator::new(cluster, workload);
    let fault_rate = args.f64_opt("fault-rate", 0.0)?;
    let rack_rate = args.f64_opt("rack-rate", 0.0)?;
    if !fault_rate.is_finite() || fault_rate < 0.0 {
        bail!("--fault-rate must be finite and non-negative, got {fault_rate}");
    }
    if !rack_rate.is_finite() || rack_rate < 0.0 {
        bail!("--rack-rate must be finite and non-negative, got {rack_rate}");
    }
    if fault_rate > 0.0 || rack_rate > 0.0 {
        let mut fcfg = lachesis::config::FaultConfig::with_rate(fault_rate);
        fcfg.rack_rate = rack_rate;
        let plan = lachesis::fault::FaultPlan::generate_with_topology(
            &fcfg,
            &sim.state.cluster.net,
            seed,
        );
        println!(
            "fault plan: {} crashes, {} straggles (rate {fault_rate}/exec/s, \
             rack rate {rack_rate}/rack/s, seed {seed})",
            plan.n_crashes(),
            plan.n_straggles()
        );
        sim.inject_faults(&plan);
    }
    let tr = trace_out_start(args);
    let report = sim.run(sched.as_mut())?;
    trace_out_finish(tr)?;
    if args.flag("gantt") {
        println!("{}", lachesis::metrics::gantt::render(&sim.state, 100));
    }
    if args.flag("validate") {
        sim.state.validate().context("schedule validation")?;
        println!("schedule validated: dependency + executor-exclusivity invariants hold");
    }
    println!(
        "algo={} jobs={} tasks={}\n  makespan   {:.2}s\n  speedup    {:.2}x\n  avg SLR    {:.3}\n  avg JCT    {:.2}s\n  duplicates {}\n  utilization {:.1}%\n  decision p50/p98 {:.3}/{:.3} ms",
        report.algo,
        report.n_jobs,
        report.n_tasks,
        report.makespan,
        report.speedup,
        report.avg_slr,
        report.avg_jct,
        report.n_duplicates,
        100.0 * report.utilization,
        report.decision_ms.percentile(50.0),
        report.decision_ms.percentile(98.0),
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let tr = trace_out_start(args);
    let res = cmd_train_inner(args);
    trace_out_finish(tr)?;
    res
}

fn cmd_train_inner(args: &Args) -> Result<()> {
    let mut cfg = TrainConfig::default();
    cfg.episodes = args.usize_opt("episodes", cfg.episodes)?;
    cfg.agents = args.usize_opt("agents", cfg.agents)?;
    cfg.seed = args.u64_opt("seed", cfg.seed)?;
    cfg.jobs_per_episode = args.usize_opt("jobs-per-episode", cfg.jobs_per_episode)?;
    cfg.executors = args.usize_opt("executors", cfg.executors)?;
    cfg.imitation_epochs = args.usize_opt("imitation-epochs", cfg.imitation_epochs)?;
    cfg.threads = args.threads_opt(1)?;
    cfg.metrics_jsonl = args.opt("metrics-jsonl").map(str::to_string);
    let artifacts = args.opt_or("artifacts", "artifacts");
    let default_out = if args.flag("decima") {
        "checkpoints/decima.bin"
    } else {
        "checkpoints/lachesis.bin"
    };
    let out = args.opt_or("out", default_out);
    if args.flag("decima") {
        // Train the Decima-DEFT baseline (blind features). Prefers the
        // AOT train_step artifact; otherwise the native CPU backend.
        use lachesis::policy::features::FeatureMode;
        use lachesis::rl::trainer::Trainer;
        let init = lachesis::policy::params::load_expected(
            &format!("{artifacts}/params_init.bin"),
            lachesis::policy::net::param_len(),
        )
        .unwrap_or_else(|_| lachesis::policy::RustPolicy::random_params(cfg.seed));
        #[cfg(feature = "pjrt")]
        {
            use lachesis::rl::trainer::PjrtTrainBackend;
            match PjrtTrainBackend::new(artifacts, init.clone()) {
                Ok(backend) => {
                    let batch = backend.batch_size();
                    let trainer = Trainer::new(cfg, backend, FeatureMode::HomogeneousBlind);
                    return finish_decima_train(trainer, batch, out);
                }
                Err(e) => {
                    eprintln!("PJRT train backend unavailable ({e}); using the CPU backend")
                }
            }
        }
        let backend = lachesis::rl::CpuTrainBackend::new(init);
        let trainer = Trainer::new(cfg, backend, FeatureMode::HomogeneousBlind);
        finish_decima_train(trainer, lachesis::rl::cpu_backend::CPU_TRAIN_BATCH, out)
    } else {
        let summary = exp::fig4(&cfg, artifacts, out)?;
        println!("{summary}");
        Ok(())
    }
}

/// Shared tail of `train --decima`: run the loop, save the checkpoint,
/// print the summary. Generic over the gradient backend.
fn finish_decima_train<B: lachesis::rl::TrainBackend>(
    mut trainer: lachesis::rl::Trainer<B>,
    batch: usize,
    out: &str,
) -> Result<()> {
    let stats = trainer.train(batch)?;
    if let Some(dir) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    lachesis::policy::params::save_f32(out, trainer.backend.params())?;
    println!(
        "decima training done ({} backend): {} episodes, final makespan {:.1}s → {out}",
        trainer.backend.name(),
        stats.len(),
        stats.last().map(|s| s.makespan).unwrap_or(0.0)
    );
    Ok(())
}

/// The fault-robustness sweep (`exp::fault_sweep`): makespan degradation
/// and recovery counts per scheduler per failure rate.
fn cmd_faults(args: &Args) -> Result<()> {
    let seeds = args.usize_opt("seeds", 5)?;
    let jobs = args.usize_opt("jobs", 20)?;
    let threads = args.threads_opt(1)?;
    let rates: Vec<f64> = match args.opt("rates") {
        None => exp::FAULT_RATES.to_vec(),
        Some(spec) => spec
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("--rates expects numbers, got '{s}'"))
            })
            .collect::<Result<Vec<_>>>()?,
    };
    // Reject bad rates here with a CLI error instead of panicking inside
    // a sweep worker thread (FaultConfig::validate would `expect`).
    if let Some(bad) = rates.iter().find(|r| !r.is_finite() || **r < 0.0) {
        bail!("--rates must be finite and non-negative, got {bad}");
    }
    let out = exp::fault_sweep(&policy_source(args), &rates, jobs, seeds, threads)?;
    println!("{out}");
    Ok(())
}

/// The topology-locality sweep (`exp::locality`): schedulers × network
/// topologies on shared workloads — the figure showing where locality-
/// aware placement (duplication, rack-local sourcing) pays off.
fn cmd_locality(args: &Args) -> Result<()> {
    let seeds = args.usize_opt("seeds", 3)?;
    let jobs = args.usize_opt("jobs", 10)?;
    let threads = args.threads_opt(1)?;
    let out = exp::locality(&policy_source(args), jobs, seeds, threads)?;
    println!("{out}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use lachesis::service::{AdmissionPolicy, AgentServer, Durability, ServiceMode};
    let addr = args.opt_or("addr", "127.0.0.1:7654");
    let algo = args.opt_or("algo", "HighRankUp-DEFT");
    let executors = args.usize_opt("executors", 50)?;
    let seed = args.u64_opt("seed", 1)?;
    let mode = ServiceMode::parse(args.opt_or("mode", "batched"))?;
    let max_queue = args.usize_opt("max-queue", 0)?;
    let admission = AdmissionPolicy::parse(args.opt_or("admission", "shed"))?;
    let mut ccfg = ClusterConfig::with_executors(executors);
    ccfg.net = net_config(args)?;
    ccfg.validate()?;
    let cluster = Cluster::heterogeneous(&ccfg, seed);
    let src = policy_source(args);
    let sched = exp::build_send_scheduler(algo, &src, seed)?;
    let mut agent = AgentServer::with_mode(cluster, sched, mode);
    if max_queue > 0 {
        agent = agent.with_admission(max_queue, admission);
    }
    let mut durable = "";
    if let Some(dir) = args.opt("journal") {
        agent = agent.with_durability(Durability {
            dir: std::path::PathBuf::from(dir),
            snapshot_every: args.u64_opt("snapshot-every", 256)?,
            restore: args.flag("restore"),
        })?;
        durable = ", journaled";
    } else if args.flag("restore") {
        bail!("--restore needs --journal DIR to restore from");
    }
    println!(
        "lachesis agent ({algo}, {} engine{durable}) listening on {addr} — ctrl-c to stop",
        mode.name()
    );
    let tr = trace_out_start(args);
    let metrics_addr = args.opt("metrics-addr").map(str::to_string);
    let agent = &agent;
    std::thread::scope(|s| -> Result<()> {
        // The side listener polls the same shutdown flag the agent sets,
        // so the scope joins cleanly after a `shutdown` request.
        if let Some(maddr) = metrics_addr.as_deref() {
            s.spawn(move || {
                if let Err(e) = agent.serve_metrics_http(maddr, |bound| {
                    println!("metrics on http://{bound}/metrics")
                }) {
                    eprintln!("metrics listener failed: {e:#}");
                }
            });
        }
        agent.serve(addr, |bound| println!("bound {bound}"))
    })?;
    trace_out_finish(tr)?;
    Ok(())
}

/// Sustained-load soak: open-loop Poisson arrivals over N concurrent
/// master connections, run once per service engine (serial, batched,
/// batched+journal) and reported side by side (`results/soak.md` + a
/// bench JSON). `--chaos` runs the kill-and-restore drill instead.
fn cmd_soak(args: &Args) -> Result<()> {
    let tr = trace_out_start(args);
    let res = cmd_soak_inner(args);
    trace_out_finish(tr)?;
    res
}

fn cmd_soak_inner(args: &Args) -> Result<()> {
    let src = policy_source(args);
    if args.flag("chaos") {
        let mut cfg = lachesis::exp::soak::ChaosConfig::default();
        cfg.jobs = args.usize_opt("jobs", cfg.jobs)?;
        cfg.kill_after = args.usize_opt("kill-after", cfg.kill_after)?;
        cfg.executors = args.usize_opt("executors", cfg.executors)?;
        if let Some(algo) = args.opt("algo") {
            cfg.algo = algo.to_string();
        }
        cfg.seed = args.u64_opt("seed", cfg.seed)?;
        if let Some(dir) = args.opt("journal") {
            cfg.dir = std::path::PathBuf::from(dir);
        }
        cfg.snapshot_every = args.u64_opt("snapshot-every", cfg.snapshot_every)?;
        let out = args.opt_or("out", "BENCH_chaos.json");
        let report = lachesis::exp::soak::chaos(&cfg, &src, out)?;
        println!("{report}");
        return Ok(());
    }
    let mut cfg = lachesis::exp::soak::SoakConfig::default();
    cfg.masters = args.usize_opt("masters", cfg.masters)?;
    cfg.jobs = args.usize_opt("jobs", cfg.jobs)?;
    cfg.mean_interval = args.f64_opt("mean-interval", cfg.mean_interval)?;
    cfg.executors = args.usize_opt("executors", cfg.executors)?;
    if let Some(algo) = args.opt("algo") {
        cfg.algo = algo.to_string();
    }
    cfg.seed = args.u64_opt("seed", cfg.seed)?;
    cfg.status_every = args.usize_opt("status-every", cfg.status_every)?;
    cfg.monitors = args.usize_opt("monitors", cfg.monitors)?;
    cfg.max_queue = args.usize_opt("max-queue", cfg.max_queue)?;
    cfg.journal = args.opt("journal").map(std::path::PathBuf::from);
    cfg.snapshot_every = args.u64_opt("snapshot-every", cfg.snapshot_every)?;
    if !cfg.mean_interval.is_finite() || cfg.mean_interval <= 0.0 {
        bail!("--mean-interval must be finite and positive");
    }
    let out = args.opt_or("out", "BENCH_service.json");
    let report = lachesis::exp::soak::soak(&cfg, &src, out)?;
    println!("{report}");
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let which = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let quick = args.flag("quick");
    let seeds = args.usize_opt("seeds", if quick { 2 } else { 10 })?;
    let threads = args.threads_opt(1)?;
    let src = policy_source(args);
    match which {
        "fig4" => {
            let mut cfg = TrainConfig::default();
            cfg.episodes = args.usize_opt("episodes", if quick { 30 } else { cfg.episodes })?;
            cfg.threads = threads;
            let out = exp::fig4(&cfg, &src.artifact_dir, "checkpoints/lachesis.bin")?;
            println!("{out}");
        }
        "fig5" => println!("{}", exp::fig5(&src, quick, seeds, threads)?),
        "fig6" => println!("{}", exp::fig6(&src, quick, seeds, threads)?),
        "fig7" => println!("{}", exp::fig7(&src, quick, seeds, threads)?),
        "all" => {
            println!("{}", exp::fig5(&src, quick, seeds, threads)?);
            println!("{}", exp::fig6(&src, quick, seeds, threads)?);
            println!("{}", exp::fig7(&src, quick, seeds, threads)?);
        }
        other => bail!("unknown figure '{other}' (fig4|fig5|fig6|fig7|all)"),
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", "artifacts");
    println!("rust model contract:");
    println!("  param_len = {}", lachesis::policy::net::param_len());
    println!(
        "  F={} E={} K={} heads q=({},{},{}) v=({},{})",
        lachesis::policy::F,
        lachesis::policy::E,
        lachesis::policy::K,
        lachesis::policy::Q1,
        lachesis::policy::Q2,
        lachesis::policy::Q3,
        lachesis::policy::V1,
        lachesis::policy::V2
    );
    #[cfg(feature = "pjrt")]
    {
        match lachesis::runtime::Runtime::new(dir) {
            Ok(rt) => {
                println!("artifacts at {dir}: OK (platform {})", rt.platform());
                for (name, n, j) in &rt.meta.variants {
                    println!("  policy variant {name}: N={n} J={j}");
                }
                if let Some((name, b, n, j)) = &rt.meta.train {
                    println!("  train_step {name}: B={b} N={n} J={j}");
                }
            }
            Err(e) => println!("artifacts at {dir}: unavailable ({e})"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("artifacts at {dir}: PJRT disabled (build with --features pjrt)");
    Ok(())
}
