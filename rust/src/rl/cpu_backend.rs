//! Native CPU gradient backend: analytic backprop through the sparse
//! MGNet kernels, so `lachesis train` / `repro fig4` work without the
//! `pjrt` feature (no XLA, no artifacts).
//!
//! The forward pass rides [`PackedBatch`] — the whole batch is one
//! block-CSR graph, every dense layer runs once over the concatenated
//! rows. The loss mirrors `python/compile/model.py::_loss` exactly with
//! unit sample weights (no padding rows exist in the packed form):
//!
//! ```text
//! wsum    = B + 1e-8
//! pg      =  Σ_b −adv_b · logπ(a_b | s_b)            / wsum
//! entropy =  Σ_b −Σ_{i∈A_b} π_i logπ_i              / wsum
//! vloss   =  Σ_b (v_b − ret_b)²                      / wsum
//! total   = pg + vw·vloss − ew·entropy
//! ```
//!
//! followed by the same global-norm clip (‖g‖ capped at 5) and Adam step
//! (β₁ 0.9, β₂ 0.999, ε 1e-8, bias correction) the AOT `train_step`
//! applies. The backward pass is exact — gradient-checked against
//! central finite differences in the tests below — and reuses its tape
//! buffers across updates, so steady-state training does not allocate.
//!
//! Unlike the PJRT path this backend accepts batches that mix shape
//! variants (packing ignores the N/J capacities), which matters late in
//! an episode when states shrink from the n256 into the n64 variant.

use crate::policy::batch::PackedBatch;
use crate::policy::encode::EncodedState;
use crate::policy::net::{dense, param_len, LAYOUT};
use crate::policy::{E, F, H, K, Q1, Q2, Q3, V1, V2};
use crate::rl::trainer::{Row, TrainBackend};
use anyhow::Result;

/// Default minibatch size for CPU training (the PJRT artifact's compiled
/// B is fixed at build time; the CPU path is shape-free, this is just a
/// sensible throughput/variance trade-off).
pub const CPU_TRAIN_BATCH: usize = 64;

/// Offset and length of a named tensor in the flat vector.
fn span(name: &str) -> (usize, usize) {
    let mut off = 0;
    for (n, r, c) in LAYOUT {
        if *n == name {
            return (off, r * c);
        }
        off += r * c;
    }
    panic!("unknown parameter '{name}'");
}

/// A named tensor of a flat parameter (or gradient) vector.
fn ten<'a>(v: &'a [f32], name: &str) -> &'a [f32] {
    let (off, len) = span(name);
    &v[off..off + len]
}

/// Mutable (weight, bias) gradient pair. Relies on the LAYOUT invariant
/// that each bias immediately follows its weight tensor.
fn wb_mut<'a>(g: &'a mut [f32], w: &str, b: &str) -> (&'a mut [f32], &'a mut [f32]) {
    let (wo, wl) = span(w);
    let (bo, bl) = span(b);
    debug_assert_eq!(wo + wl, bo, "{b} must directly follow {w} in LAYOUT");
    let (ws, bs) = g[wo..bo + bl].split_at_mut(wl);
    (ws, bs)
}

/// Backward through one dense layer `out = act(input·W + b)` over m rows.
/// On entry `d_out` holds ∂L/∂out; it is rewritten in place to the
/// pre-activation gradient. Weight/bias gradients accumulate into
/// `dw`/`db`; ∂L/∂input is written (overwritten, not accumulated) into
/// `d_in` when given.
#[allow(clippy::too_many_arguments)]
fn dense_bwd(
    input: &[f32],
    out: &[f32],
    d_out: &mut [f32],
    w: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    mut d_in: Option<&mut [f32]>,
    m: usize,
    din: usize,
    dout: usize,
    tanh: bool,
) {
    if tanh {
        for (d, &o) in d_out[..m * dout].iter_mut().zip(&out[..m * dout]) {
            *d *= 1.0 - o * o;
        }
    }
    for r in 0..m {
        let irow = &input[r * din..(r + 1) * din];
        let drow = &d_out[r * dout..(r + 1) * dout];
        for (k, &iv) in irow.iter().enumerate() {
            if iv != 0.0 {
                let wrow = &mut dw[k * dout..(k + 1) * dout];
                for (o, &dv) in wrow.iter_mut().zip(drow) {
                    *o += iv * dv;
                }
            }
        }
        for (o, &dv) in db.iter_mut().zip(drow) {
            *o += dv;
        }
    }
    if let Some(d_in) = d_in.as_deref_mut() {
        for r in 0..m {
            let drow = &d_out[r * dout..(r + 1) * dout];
            let irow = &mut d_in[r * din..(r + 1) * din];
            for (k, o) in irow.iter_mut().enumerate() {
                let wrow = &w[k * dout..(k + 1) * dout];
                let mut acc = 0.0f32;
                for (&dv, &wv) in drow.iter().zip(wrow) {
                    acc += dv * wv;
                }
                *o = acc;
            }
        }
    }
}

/// Forward activations + backward scratch, reused across updates.
#[derive(Default)]
struct Tape {
    pack: PackedBatch,
    // Forward activations (aggregation inputs per K iteration kept for
    // the shared-weight g1/g2 gradients).
    e0: Vec<f32>,
    e: Vec<f32>,
    agg: Vec<f32>, // K × m × E
    h: Vec<f32>,   // K × m × H
    msg: Vec<f32>, // K × m × E
    jobsum: Vec<f32>,
    jh: Vec<f32>,
    y: Vec<f32>,
    gsum: Vec<f32>,
    gh: Vec<f32>,
    z: Vec<f32>,
    cat: Vec<f32>,
    q1: Vec<f32>,
    q2: Vec<f32>,
    q3: Vec<f32>,
    logits: Vec<f32>,
    vh1: Vec<f32>,
    vh2: Vec<f32>,
    values: Vec<f32>,
    logp: Vec<f32>,
    prob: Vec<f32>,
    // Backward scratch.
    d_e: Vec<f32>,
    d_e0: Vec<f32>,
    d_agg: Vec<f32>,
    d_h: Vec<f32>,
    d_jh: Vec<f32>,
    d_jobsum: Vec<f32>,
    d_y: Vec<f32>,
    d_gh: Vec<f32>,
    d_gsum: Vec<f32>,
    d_z: Vec<f32>,
    d_cat: Vec<f32>,
    d_q1: Vec<f32>,
    d_q2: Vec<f32>,
    d_q3: Vec<f32>,
    d_logits: Vec<f32>,
    d_vh1: Vec<f32>,
    d_vh2: Vec<f32>,
    d_values: Vec<f32>,
}

impl Tape {
    fn ensure(&mut self, m: usize, jobs: usize, b: usize) {
        self.e0.resize(m * E, 0.0);
        self.e.resize(m * E, 0.0);
        self.agg.resize(K * m * E, 0.0);
        self.h.resize(K * m * H, 0.0);
        self.msg.resize(K * m * E, 0.0);
        self.jobsum.resize(jobs * E, 0.0);
        self.jh.resize(jobs * H, 0.0);
        self.y.resize(jobs * E, 0.0);
        self.gsum.resize(b * E, 0.0);
        self.gh.resize(b * H, 0.0);
        self.z.resize(b * E, 0.0);
        self.cat.resize(m * 3 * E, 0.0);
        self.q1.resize(m * Q1, 0.0);
        self.q2.resize(m * Q2, 0.0);
        self.q3.resize(m * Q3, 0.0);
        self.logits.resize(m, 0.0);
        self.vh1.resize(b * V1, 0.0);
        self.vh2.resize(b * V2, 0.0);
        self.values.resize(b, 0.0);
        self.logp.resize(m, 0.0);
        self.prob.resize(m, 0.0);
        self.d_e.resize(m * E, 0.0);
        self.d_e0.resize(m * E, 0.0);
        self.d_agg.resize(m * E, 0.0);
        self.d_h.resize(m * H, 0.0);
        self.d_jh.resize(jobs * H, 0.0);
        self.d_jobsum.resize(jobs * E, 0.0);
        self.d_y.resize(jobs * E, 0.0);
        self.d_gh.resize(b * H, 0.0);
        self.d_gsum.resize(b * E, 0.0);
        self.d_z.resize(b * E, 0.0);
        self.d_cat.resize(m * 3 * E, 0.0);
        self.d_q1.resize(m * Q1, 0.0);
        self.d_q2.resize(m * Q2, 0.0);
        self.d_q3.resize(m * Q3, 0.0);
        self.d_logits.resize(m, 0.0);
        self.d_vh1.resize(b * V1, 0.0);
        self.d_vh2.resize(b * V2, 0.0);
        self.d_values.resize(b, 0.0);
    }
}

/// The CPU training backend: flat parameters + Adam moments + gradient
/// and tape buffers.
pub struct CpuTrainBackend {
    params: Vec<f32>,
    m_adam: Vec<f32>,
    v_adam: Vec<f32>,
    step: f32,
    grads: Vec<f32>,
    tape: Tape,
}

impl CpuTrainBackend {
    pub fn new(init_params: Vec<f32>) -> CpuTrainBackend {
        assert_eq!(
            init_params.len(),
            param_len(),
            "parameter vector length mismatch: got {}, layout wants {}",
            init_params.len(),
            param_len()
        );
        let p = init_params.len();
        CpuTrainBackend {
            params: init_params,
            m_adam: vec![0.0; p],
            v_adam: vec![0.0; p],
            step: 0.0,
            grads: vec![0.0; p],
            tape: Tape::default(),
        }
    }

    /// Forward pass over the packed batch, recording every activation.
    fn forward_tape(&self, t: &mut Tape, batch: &[Row]) {
        let refs: Vec<&EncodedState> = batch.iter().map(|r| &r.enc).collect();
        t.pack = PackedBatch::pack(&refs);
        let m = t.pack.n_rows();
        let jobs = t.pack.n_job_rows();
        let b = t.pack.n_states;
        t.ensure(m, jobs, b);
        let pp = &self.params[..];

        dense(&t.pack.x, ten(pp, "w_in"), ten(pp, "b_in"), &mut t.e0, m, F, E, true);
        t.e[..m * E].copy_from_slice(&t.e0[..m * E]);
        for k in 0..K {
            let agg = &mut t.agg[k * m * E..(k + 1) * m * E];
            agg.fill(0.0);
            for i in 0..m {
                let lo = t.pack.row_offsets[i] as usize;
                let hi = t.pack.row_offsets[i + 1] as usize;
                for &c in &t.pack.col_indices[lo..hi] {
                    let c = c as usize;
                    let erow = &t.e[c * E..(c + 1) * E];
                    let arow = &mut agg[i * E..(i + 1) * E];
                    for (o, &ev) in arow.iter_mut().zip(erow) {
                        *o += ev;
                    }
                }
            }
            dense(
                &t.agg[k * m * E..(k + 1) * m * E],
                ten(pp, "g1"),
                ten(pp, "bg1"),
                &mut t.h[k * m * H..(k + 1) * m * H],
                m,
                E,
                H,
                true,
            );
            dense(
                &t.h[k * m * H..(k + 1) * m * H],
                ten(pp, "g2"),
                ten(pp, "bg2"),
                &mut t.msg[k * m * E..(k + 1) * m * E],
                m,
                H,
                E,
                true,
            );
            for d in 0..m * E {
                t.e[d] = t.msg[k * m * E + d] + t.e0[d];
            }
        }

        t.jobsum[..jobs * E].fill(0.0);
        for (i, &js) in t.pack.slot_job.iter().enumerate() {
            let js = js as usize;
            for d in 0..E {
                t.jobsum[js * E + d] += t.e[i * E + d];
            }
        }
        dense(&t.jobsum, ten(pp, "fj1"), ten(pp, "bfj1"), &mut t.jh, jobs, E, H, true);
        dense(&t.jh, ten(pp, "fj2"), ten(pp, "bfj2"), &mut t.y, jobs, H, E, true);

        t.gsum[..b * E].fill(0.0);
        for bi in 0..b {
            for j in t.pack.job_base[bi]..t.pack.job_base[bi + 1] {
                for d in 0..E {
                    t.gsum[bi * E + d] += t.y[j * E + d];
                }
            }
        }
        dense(&t.gsum, ten(pp, "fg1"), ten(pp, "bfg1"), &mut t.gh, b, E, H, true);
        dense(&t.gh, ten(pp, "fg2"), ten(pp, "bfg2"), &mut t.z, b, H, E, true);

        for bi in 0..b {
            let zrow = &t.z[bi * E..(bi + 1) * E];
            for i in t.pack.row_base[bi]..t.pack.row_base[bi + 1] {
                let js = t.pack.slot_job[i] as usize;
                let cat = &mut t.cat[i * 3 * E..(i + 1) * 3 * E];
                cat[..E].copy_from_slice(&t.e[i * E..(i + 1) * E]);
                cat[E..2 * E].copy_from_slice(&t.y[js * E..(js + 1) * E]);
                cat[2 * E..].copy_from_slice(zrow);
            }
        }
        dense(&t.cat, ten(pp, "q1"), ten(pp, "bq1"), &mut t.q1, m, 3 * E, Q1, true);
        dense(&t.q1, ten(pp, "q2"), ten(pp, "bq2"), &mut t.q2, m, Q1, Q2, true);
        dense(&t.q2, ten(pp, "q3"), ten(pp, "bq3"), &mut t.q3, m, Q2, Q3, true);
        dense(&t.q3, ten(pp, "q4"), ten(pp, "bq4"), &mut t.logits, m, Q3, 1, false);

        dense(&t.z, ten(pp, "v1"), ten(pp, "bv1"), &mut t.vh1, b, E, V1, true);
        dense(&t.vh1, ten(pp, "v2"), ten(pp, "bv2"), &mut t.vh2, b, V1, V2, true);
        dense(&t.vh2, ten(pp, "v3"), ten(pp, "bv3"), &mut t.values, b, V2, 1, false);
    }

    /// Losses (total, pg, value, entropy) from the recorded tape; when
    /// `want_grads`, also seeds ∂L/∂logits and ∂L/∂values.
    fn losses_from_tape(t: &mut Tape, batch: &[Row], ew: f32, vw: f32, want_grads: bool) -> [f32; 4] {
        let m = t.pack.n_rows();
        let wsum = batch.len() as f32 + 1e-8;
        let (mut pg, mut ent, mut vl) = (0.0f64, 0.0f64, 0.0f64);
        if want_grads {
            t.d_logits[..m].fill(0.0);
        }
        for (bi, row) in batch.iter().enumerate() {
            let lo = t.pack.row_base[bi];
            let hi = t.pack.row_base[bi + 1];
            // Masked log-softmax over the state's executable slots —
            // identical to the python reference's −1e9 masking in the
            // limit (excluded slots simply don't enter the logsumexp).
            let mut maxl = f32::NEG_INFINITY;
            for i in lo..hi {
                if t.pack.exec_mask[i] > 0.0 && t.logits[i] > maxl {
                    maxl = t.logits[i];
                }
            }
            let verr = t.values[bi] - row.ret;
            vl += (verr * verr) as f64;
            if want_grads {
                t.d_values[bi] = 2.0 * vw * verr / wsum;
            }
            if !maxl.is_finite() {
                // No executable slot survived encoding; the row carries
                // no policy-gradient signal (cannot happen for sampled
                // transitions, guarded for arbitrary callers).
                continue;
            }
            let mut sum = 0.0f32;
            for i in lo..hi {
                if t.pack.exec_mask[i] > 0.0 {
                    sum += (t.logits[i] - maxl).exp();
                }
            }
            let lse = maxl + sum.ln();
            let mut hent = 0.0f32;
            for i in lo..hi {
                if t.pack.exec_mask[i] > 0.0 {
                    let lp = t.logits[i] - lse;
                    let p = lp.exp();
                    t.logp[i] = lp;
                    t.prob[i] = p;
                    hent -= p * lp;
                } else {
                    t.logp[i] = 0.0;
                    t.prob[i] = 0.0;
                }
            }
            let a = lo + row.action as usize;
            debug_assert!(
                a < hi && t.pack.exec_mask[a] > 0.0,
                "action {} not executable in its state",
                row.action
            );
            pg += (-row.adv * t.logp[a]) as f64;
            ent += hent as f64;
            if want_grads {
                for i in lo..hi {
                    if t.pack.exec_mask[i] > 0.0 {
                        let delta = if i == a { 1.0 } else { 0.0 };
                        // d pg/dl + d(−ew·entropy)/dl, both already /wsum.
                        t.d_logits[i] = (row.adv / wsum) * (t.prob[i] - delta)
                            + (ew / wsum) * t.prob[i] * (t.logp[i] + hent);
                    }
                }
            }
        }
        let pg = (pg / wsum as f64) as f32;
        let ent = (ent / wsum as f64) as f32;
        let vl = (vl / wsum as f64) as f32;
        [pg + vw * vl - ew * ent, pg, vl, ent]
    }

    /// Backward pass: tape + loss seeds → flat gradient vector.
    fn backward_pass(params: &[f32], g: &mut [f32], t: &mut Tape) {
        let m = t.pack.n_rows();
        let jobs = t.pack.n_job_rows();
        let b = t.pack.n_states;
        g.fill(0.0);

        // Policy head (q4 is linear, q1–q3 tanh).
        {
            let (dw, db) = wb_mut(g, "q4", "bq4");
            dense_bwd(&t.q3, &t.logits, &mut t.d_logits, ten(params, "q4"), dw, db, Some(&mut t.d_q3), m, Q3, 1, false);
        }
        {
            let (dw, db) = wb_mut(g, "q3", "bq3");
            dense_bwd(&t.q2, &t.q3, &mut t.d_q3, ten(params, "q3"), dw, db, Some(&mut t.d_q2), m, Q2, Q3, true);
        }
        {
            let (dw, db) = wb_mut(g, "q2", "bq2");
            dense_bwd(&t.q1, &t.q2, &mut t.d_q2, ten(params, "q2"), dw, db, Some(&mut t.d_q1), m, Q1, Q2, true);
        }
        {
            let (dw, db) = wb_mut(g, "q1", "bq1");
            dense_bwd(&t.cat, &t.q1, &mut t.d_q1, ten(params, "q1"), dw, db, Some(&mut t.d_cat), m, 3 * E, Q1, true);
        }

        // Value head — lands its input gradient in d_z (overwritten, so
        // run it before the cat-split accumulates into d_z).
        {
            let (dw, db) = wb_mut(g, "v3", "bv3");
            dense_bwd(&t.vh2, &t.values, &mut t.d_values, ten(params, "v3"), dw, db, Some(&mut t.d_vh2), b, V2, 1, false);
        }
        {
            let (dw, db) = wb_mut(g, "v2", "bv2");
            dense_bwd(&t.vh1, &t.vh2, &mut t.d_vh2, ten(params, "v2"), dw, db, Some(&mut t.d_vh1), b, V1, V2, true);
        }
        {
            let (dw, db) = wb_mut(g, "v1", "bv1");
            dense_bwd(&t.z, &t.vh1, &mut t.d_vh1, ten(params, "v1"), dw, db, Some(&mut t.d_z), b, E, V1, true);
        }

        // Split the concat gradient: [e_i ; y_job(i) ; z_state(i)].
        t.d_y[..jobs * E].fill(0.0);
        for bi in 0..b {
            for i in t.pack.row_base[bi]..t.pack.row_base[bi + 1] {
                let js = t.pack.slot_job[i] as usize;
                let dcat = &t.d_cat[i * 3 * E..(i + 1) * 3 * E];
                t.d_e[i * E..(i + 1) * E].copy_from_slice(&dcat[..E]);
                for d in 0..E {
                    t.d_y[js * E + d] += dcat[E + d];
                    t.d_z[bi * E + d] += dcat[2 * E + d];
                }
            }
        }

        // Global summary: z = f(gsum), gsum_b = Σ_{j∈b} y_j.
        {
            let (dw, db) = wb_mut(g, "fg2", "bfg2");
            dense_bwd(&t.gh, &t.z, &mut t.d_z, ten(params, "fg2"), dw, db, Some(&mut t.d_gh), b, H, E, true);
        }
        {
            let (dw, db) = wb_mut(g, "fg1", "bfg1");
            dense_bwd(&t.gsum, &t.gh, &mut t.d_gh, ten(params, "fg1"), dw, db, Some(&mut t.d_gsum), b, E, H, true);
        }
        for bi in 0..b {
            for j in t.pack.job_base[bi]..t.pack.job_base[bi + 1] {
                for d in 0..E {
                    t.d_y[j * E + d] += t.d_gsum[bi * E + d];
                }
            }
        }

        // Job summaries: y = f(jobsum), jobsum_j = Σ_{i∈j} e_i.
        {
            let (dw, db) = wb_mut(g, "fj2", "bfj2");
            dense_bwd(&t.jh, &t.y, &mut t.d_y, ten(params, "fj2"), dw, db, Some(&mut t.d_jh), jobs, H, E, true);
        }
        {
            let (dw, db) = wb_mut(g, "fj1", "bfj1");
            dense_bwd(&t.jobsum, &t.jh, &mut t.d_jh, ten(params, "fj1"), dw, db, Some(&mut t.d_jobsum), jobs, E, H, true);
        }
        for (i, &js) in t.pack.slot_job.iter().enumerate() {
            let js = js as usize;
            for d in 0..E {
                t.d_e[i * E + d] += t.d_jobsum[js * E + d];
            }
        }

        // K message-passing iterations, reversed. Iteration k computed
        // e_{k+1} = msg_k(agg(e_k)) + e0; d_e enters holding ∂L/∂e_{k+1}
        // and leaves holding ∂L/∂e_k. The g1/g2 gradients accumulate
        // across iterations (shared weights).
        t.d_e0[..m * E].fill(0.0);
        for k in (0..K).rev() {
            for d in 0..m * E {
                t.d_e0[d] += t.d_e[d]; // skip connection
            }
            {
                let (dw, db) = wb_mut(g, "g2", "bg2");
                dense_bwd(
                    &t.h[k * m * H..(k + 1) * m * H],
                    &t.msg[k * m * E..(k + 1) * m * E],
                    &mut t.d_e,
                    ten(params, "g2"),
                    dw,
                    db,
                    Some(&mut t.d_h),
                    m,
                    H,
                    E,
                    true,
                );
            }
            {
                let (dw, db) = wb_mut(g, "g1", "bg1");
                dense_bwd(
                    &t.agg[k * m * E..(k + 1) * m * E],
                    &t.h[k * m * H..(k + 1) * m * H],
                    &mut t.d_h,
                    ten(params, "g1"),
                    dw,
                    db,
                    Some(&mut t.d_agg),
                    m,
                    E,
                    H,
                    true,
                );
            }
            // agg_i = Σ_{c∈children(i)} e_c  →  d_e_c += d_agg_i.
            t.d_e[..m * E].fill(0.0);
            for i in 0..m {
                let lo = t.pack.row_offsets[i] as usize;
                let hi = t.pack.row_offsets[i + 1] as usize;
                for &c in &t.pack.col_indices[lo..hi] {
                    let c = c as usize;
                    for d in 0..E {
                        t.d_e[c * E + d] += t.d_agg[i * E + d];
                    }
                }
            }
        }

        // Input embedding: e0 = tanh(x·W_in + b_in).
        for d in 0..m * E {
            t.d_e0[d] += t.d_e[d];
        }
        {
            let (dw, db) = wb_mut(g, "w_in", "b_in");
            dense_bwd(&t.pack.x, &t.e0, &mut t.d_e0, ten(params, "w_in"), dw, db, None, m, F, E, true);
        }
    }

    /// Forward + loss only — no gradient, no optimizer-state mutation.
    /// The finite-difference probe the gradient tests drive.
    pub fn loss(&mut self, batch: &[Row], entropy_w: f32, vw: f32) -> [f32; 4] {
        if batch.is_empty() {
            return [0.0; 4];
        }
        let mut t = std::mem::take(&mut self.tape);
        self.forward_tape(&mut t, batch);
        let losses = Self::losses_from_tape(&mut t, batch, entropy_w, vw, false);
        self.tape = t;
        losses
    }

    /// Forward + backward: fills the internal (pre-clip) gradient buffer
    /// and returns the losses. Does not touch parameters or Adam state.
    pub fn backward(&mut self, batch: &[Row], entropy_w: f32, vw: f32) -> [f32; 4] {
        let mut t = std::mem::take(&mut self.tape);
        self.forward_tape(&mut t, batch);
        let losses = Self::losses_from_tape(&mut t, batch, entropy_w, vw, true);
        let mut g = std::mem::take(&mut self.grads);
        Self::backward_pass(&self.params, &mut g, &mut t);
        self.grads = g;
        self.tape = t;
        losses
    }

    /// The gradient buffer filled by the last [`CpuTrainBackend::backward`]
    /// (pre-clip, flat LAYOUT order).
    pub fn grads(&self) -> &[f32] {
        &self.grads
    }
}

impl TrainBackend for CpuTrainBackend {
    fn update(&mut self, batch: &[Row], lr: f32, entropy_w: f32, vw: f32) -> Result<[f32; 4]> {
        if batch.is_empty() {
            return Ok([0.0; 4]);
        }
        let losses = self.backward(batch, entropy_w, vw);
        // Global-norm clip at 5.0 + Adam — the exact sequence (and
        // constants) of python/compile/model.py::train_step.
        let mut norm2 = 0.0f64;
        for &gv in &self.grads {
            norm2 += gv as f64 * gv as f64;
        }
        let gnorm = (norm2 + 1e-12).sqrt() as f32;
        let clip = (5.0 / gnorm).min(1.0);
        self.step += 1.0;
        let bc1 = 1.0 - 0.9f32.powf(self.step);
        let bc2 = 1.0 - 0.999f32.powf(self.step);
        for i in 0..self.params.len() {
            let gv = self.grads[i] * clip;
            self.m_adam[i] = 0.9 * self.m_adam[i] + 0.1 * gv;
            self.v_adam[i] = 0.999 * self.v_adam[i] + 0.001 * gv * gv;
            let mhat = self.m_adam[i] / bc1;
            let vhat = self.v_adam[i] / bc2;
            self.params[i] -= lr * mhat / (vhat.sqrt() + 1e-8);
        }
        Ok(losses)
    }

    fn params(&self) -> &[f32] {
        &self.params
    }

    fn params_mut(&mut self) -> &mut Vec<f32> {
        &mut self.params
    }

    fn name(&self) -> &'static str {
        "cpu"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::{ClusterConfig, WorkloadConfig};
    use crate::policy::features::FeatureMode;
    use crate::policy::RustPolicy;
    use crate::rl::trainer::RecordingExpert;
    use crate::sched::HeftScheduler;
    use crate::sim::Simulator;
    use crate::workload::WorkloadGenerator;

    /// Expert-collected rows with synthetic advantages/returns so every
    /// loss term (pg, value, entropy) carries gradient.
    fn test_batch(n_jobs: usize, seed: u64, take: usize) -> Vec<Row> {
        let mut expert = RecordingExpert::new(HeftScheduler::new(), FeatureMode::Full);
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(5), seed);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(n_jobs), seed).generate();
        let mut sim = Simulator::new(cluster, w);
        sim.run(&mut expert).unwrap();
        let advs = [1.0f32, -0.7, 0.4, -1.2, 0.9];
        let rets = [0.3f32, -0.5, 0.8, 0.1, -0.9];
        let mut rows: Vec<Row> = expert.rows.drain(..).collect();
        rows.truncate(take);
        for (i, r) in rows.iter_mut().enumerate() {
            r.adv = advs[i % advs.len()];
            r.ret = rets[i % rets.len()];
        }
        assert!(!rows.is_empty());
        rows
    }

    #[test]
    fn update_is_finite_and_moves_params() {
        let batch = test_batch(2, 3, 8);
        let init = RustPolicy::random_params(7);
        let mut be = CpuTrainBackend::new(init.clone());
        for _ in 0..3 {
            let l = be.update(&batch, 1e-3, 0.01, 0.5).unwrap();
            for v in l {
                assert!(v.is_finite(), "{l:?}");
            }
        }
        assert_ne!(be.params(), &init[..], "parameters must move");
        assert!(be.params().iter().all(|p| p.is_finite()));
    }

    #[test]
    fn update_is_deterministic() {
        let batch = test_batch(2, 4, 6);
        let init = RustPolicy::random_params(8);
        let mut a = CpuTrainBackend::new(init.clone());
        let mut b = CpuTrainBackend::new(init);
        for _ in 0..4 {
            let la = a.update(&batch, 1e-3, 0.01, 0.5).unwrap();
            let lb = b.update(&batch, 1e-3, 0.01, 0.5).unwrap();
            assert_eq!(la, lb);
        }
        assert_eq!(a.params(), b.params());
    }

    #[test]
    fn imitation_cross_entropy_decreases() {
        // adv 1, vw 0, ew 0 → pure cross-entropy toward the expert's
        // choices; 8 Adam steps on a fixed batch must reduce it.
        let mut batch = test_batch(2, 5, 12);
        for r in batch.iter_mut() {
            r.adv = 1.0;
            r.ret = 0.0;
        }
        let mut be = CpuTrainBackend::new(RustPolicy::random_params(9));
        let mut losses = Vec::new();
        for _ in 0..8 {
            losses.push(be.update(&batch, 1e-3, 0.0, 0.0).unwrap()[0]);
        }
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "imitation CE should fall: {losses:?}"
        );
    }

    #[test]
    fn value_loss_decreases_toward_targets() {
        let mut batch = test_batch(2, 6, 6);
        for r in batch.iter_mut() {
            r.adv = 0.0;
            r.ret = 0.5;
        }
        let mut be = CpuTrainBackend::new(RustPolicy::random_params(10));
        let first = be.update(&batch, 1e-3, 0.0, 1.0).unwrap()[2];
        for _ in 0..15 {
            be.update(&batch, 1e-3, 0.0, 1.0).unwrap();
        }
        let last = be.update(&batch, 1e-3, 0.0, 1.0).unwrap()[2];
        assert!(last < first, "value loss should fall: {first} → {last}");
    }

    #[test]
    fn mixed_variant_batch_updates() {
        use crate::policy::encode::encode;
        use crate::sim::SimState;
        let mut rows = test_batch(2, 11, 3); // n64 variant
        // RecordingExpert only keeps n64-variant rows; build an n256 row
        // directly from a large all-arrived state (14 jobs overflow the
        // n64 variant — same setup the policy bench uses).
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(5), 12);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(14), 12).generate();
        let mut st = SimState::new(cluster, w);
        for j in 0..14 {
            st.mark_arrived(j);
        }
        let enc = encode(&st, FeatureMode::Full);
        let slot = (0..enc.n_used())
            .find(|&i| enc.exec_mask[i] > 0.0)
            .expect("some executable slot");
        rows.push(Row {
            enc,
            action: slot as i32,
            adv: -0.3,
            ret: 0.2,
        });
        let variants: std::collections::HashSet<usize> =
            rows.iter().map(|r| r.enc.variant.n).collect();
        assert!(variants.len() > 1, "batch must mix variants");
        let mut be = CpuTrainBackend::new(RustPolicy::random_params(13));
        let l = be.update(&rows, 1e-3, 0.01, 0.5).unwrap();
        for v in l {
            assert!(v.is_finite());
        }
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let init = RustPolicy::random_params(14);
        let mut be = CpuTrainBackend::new(init.clone());
        let l = be.update(&[], 1e-3, 0.01, 0.5).unwrap();
        assert_eq!(l, [0.0; 4]);
        assert_eq!(be.params(), &init[..]);
    }

    #[test]
    fn analytic_gradient_matches_finite_differences() {
        // Central finite differences in f32 carry ~1e-3 absolute noise at
        // h=1e-3 (loss is O(1) with ~1e-6 rounding), so the checks are
        // (a) a directional derivative along sign(g) — large signal, all
        // parameters at once — and (b) per-tensor spot checks at each
        // tensor's largest-|g| coordinate, skipping coordinates whose
        // gradient is too small to measure above the noise floor.
        let batch = test_batch(2, 20, 6);
        let (ew, vw) = (0.01f32, 0.5f32);
        let mut be = CpuTrainBackend::new(RustPolicy::random_params(21));
        be.backward(&batch, ew, vw);
        let g = be.grads().to_vec();
        assert!(g.iter().all(|v| v.is_finite()));
        assert!(g.iter().any(|&v| v != 0.0), "gradient must be nonzero");

        // (a) directional: d/dh L(p + h·sign(g)) = Σ|g| = ‖g‖₁.
        let h = 1e-3f32;
        let base = be.params().to_vec();
        let l1: f64 = g.iter().map(|&v| v.abs() as f64).sum();
        let probe = |delta: f32, be: &mut CpuTrainBackend| -> f64 {
            for (p, &gv) in be.params_mut().iter_mut().zip(&g) {
                *p += delta * gv.signum();
            }
            let l = be.loss(&batch, ew, vw)[0] as f64;
            be.params_mut().copy_from_slice(&base);
            l
        };
        let lp = probe(h, &mut be);
        let lm = probe(-h, &mut be);
        let fd = (lp - lm) / (2.0 * h as f64);
        let rel = (fd - l1).abs() / l1.max(1e-6);
        assert!(
            rel < 2e-2,
            "directional derivative mismatch: fd={fd:.6} analytic={l1:.6} rel={rel:.4}"
        );

        // (b) per-tensor spot checks at the largest-|g| coordinate.
        let mut checked = 0;
        for name in ["w_in", "g1", "g2", "fj1", "fj2", "fg1", "fg2", "q1", "q4", "v1", "v3"] {
            let (off, len) = super::span(name);
            let (best, mag) = (off..off + len)
                .map(|i| (i, g[i].abs()))
                .fold((off, 0.0f32), |acc, x| if x.1 > acc.1 { x } else { acc });
            if mag < 5e-3 {
                continue; // below the FD noise floor at this h
            }
            let hc = 2.5e-3f32;
            be.params_mut()[best] = base[best] + hc;
            let lp = be.loss(&batch, ew, vw)[0] as f64;
            be.params_mut()[best] = base[best] - hc;
            let lm = be.loss(&batch, ew, vw)[0] as f64;
            be.params_mut()[best] = base[best];
            let fd = ((lp - lm) / (2.0 * hc as f64)) as f32;
            let err = (fd - g[best]).abs();
            assert!(
                err <= 1e-3 + 0.15 * g[best].abs(),
                "{name}[{}]: fd={fd:.6} analytic={:.6}",
                best - off,
                g[best]
            );
            checked += 1;
        }
        assert!(checked >= 3, "too few tensors above the FD noise floor ({checked})");
    }

    #[test]
    fn loss_matches_backward_losses() {
        let batch = test_batch(2, 22, 5);
        let mut be = CpuTrainBackend::new(RustPolicy::random_params(23));
        let a = be.loss(&batch, 0.01, 0.5);
        let b = be.backward(&batch, 0.01, 0.5);
        assert_eq!(a, b);
    }
}
