//! The training loop (Algorithm 2): synchronous actor–critic with parallel
//! reward-collection agents (fanned over scoped worker threads, bit-
//! deterministic w.r.t. thread count), curriculum over workload size, an
//! optional imitation warm start toward HEFT, and Adam updates executed by
//! a [`TrainBackend`] — the native CPU backprop backend or the AOT
//! `train_step` artifact.

use crate::cluster::Cluster;
use crate::config::{ClusterConfig, TrainConfig, WorkloadConfig};
use crate::obs::trace;
use crate::policy::encode::EncodedState;
use crate::policy::features::FeatureMode;
use crate::policy::RustPolicy;
#[cfg(feature = "pjrt")]
use crate::policy::F;
use crate::rl::episode;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::sched::lachesis::{LachesisScheduler, Transition};
use crate::sched::{HeftScheduler, Scheduler};
use crate::sim::Simulator;
use crate::util::par;
use crate::util::rng::{Rng, STREAM_AGENT};
use crate::workload::WorkloadGenerator;
#[cfg(feature = "pjrt")]
use anyhow::{bail, Context};
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

/// One batch row fed to train_step.
pub struct Row {
    pub enc: EncodedState,
    pub action: i32,
    pub adv: f32,
    pub ret: f32,
}

/// Backend executing one gradient step. The production implementation
/// drives the `train_step` HLO artifact; tests may substitute a fake.
pub trait TrainBackend {
    /// Apply one Adam step on a batch. Returns (total, pg, value, entropy)
    /// losses. `vw` is the value-loss weight (0 for imitation batches).
    fn update(&mut self, batch: &[Row], lr: f32, entropy_w: f32, vw: f32) -> Result<[f32; 4]>;
    fn params(&self) -> &[f32];
    fn params_mut(&mut self) -> &mut Vec<f32>;
    /// Short tag for logs and result files ("cpu", "pjrt", "fake").
    fn name(&self) -> &'static str {
        "backend"
    }
}

/// PJRT-backed trainer state: parameters + Adam moments + step counter.
/// Requires the `pjrt` cargo feature (drives the AOT `train_step`
/// artifact); offline builds train through the native
/// [`crate::rl::CpuTrainBackend`] instead.
#[cfg(feature = "pjrt")]
pub struct PjrtTrainBackend {
    runtime: Runtime,
    stem: String,
    b: usize,
    n: usize,
    j: usize,
    params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    step: f32,
}

#[cfg(feature = "pjrt")]
impl PjrtTrainBackend {
    pub fn new(artifact_dir: &str, init_params: Vec<f32>) -> Result<PjrtTrainBackend> {
        let runtime = Runtime::new(artifact_dir)?;
        let (stem, b, n, j) = runtime
            .meta
            .train
            .clone()
            .context("artifacts were built without a train_step (rerun make artifacts)")?;
        if init_params.len() != runtime.meta.param_len {
            bail!("init params length mismatch");
        }
        let p = init_params.len();
        Ok(PjrtTrainBackend {
            runtime,
            stem,
            b,
            n,
            j,
            params: init_params,
            m: vec![0.0; p],
            v: vec![0.0; p],
            step: 0.0,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.b
    }
}

#[cfg(feature = "pjrt")]
impl TrainBackend for PjrtTrainBackend {
    fn update(&mut self, batch: &[Row], lr: f32, entropy_w: f32, vw: f32) -> Result<[f32; 4]> {
        let (b, n, j) = (self.b, self.n, self.j);
        assert!(batch.len() <= b, "batch of {} exceeds compiled B={b}", batch.len());
        // Pad by repeating the last row (with zero advantage and zero
        // sample weight below, so padding rows produce zero gradient) and
        // materialize the whole batch's dense tensors in a single pass —
        // transitions carry the compact CSR encoding, the train_step
        // artifact wants dense [B, …] tensors.
        let padded: Vec<&EncodedState> = (0..b)
            .map(|i| &batch[i.min(batch.len() - 1)].enc)
            .collect();
        let mut x = vec![0.0f32; b * n * F];
        let mut adj = vec![0.0f32; b * n * n];
        let mut jobmat = vec![0.0f32; b * j * n];
        let mut node_mask = vec![0.0f32; b * n];
        let mut exec_mask = vec![0.0f32; b * n];
        crate::policy::batch::write_dense_batch(
            &padded,
            n,
            j,
            &mut x,
            &mut adj,
            &mut jobmat,
            &mut node_mask,
            &mut exec_mask,
        )?;
        let mut action = vec![0i32; b];
        let mut adv = vec![0.0f32; b];
        let mut ret = vec![0.0f32; b];
        let mut sample_w = vec![0.0f32; b];
        for i in 0..b {
            let row = &batch[i.min(batch.len() - 1)];
            let pad = i >= batch.len();
            action[i] = row.action;
            adv[i] = if pad { 0.0 } else { row.adv };
            ret[i] = row.ret;
            sample_w[i] = if pad { 0.0 } else { 1.0 };
        }
        self.step += 1.0;
        let p = self.params.len() as i64;
        let inputs = [
            Runtime::lit_f32(&self.params, &[p])?,
            Runtime::lit_f32(&self.m, &[p])?,
            Runtime::lit_f32(&self.v, &[p])?,
            Runtime::lit_f32(&[self.step], &[1])?,
            Runtime::lit_f32(&x, &[b as i64, n as i64, F as i64])?,
            Runtime::lit_f32(&adj, &[b as i64, n as i64, n as i64])?,
            Runtime::lit_f32(&jobmat, &[b as i64, j as i64, n as i64])?,
            Runtime::lit_f32(&node_mask, &[b as i64, n as i64])?,
            Runtime::lit_f32(&exec_mask, &[b as i64, n as i64])?,
            Runtime::lit_i32(&action, &[b as i64])?,
            Runtime::lit_f32(&adv, &[b as i64])?,
            Runtime::lit_f32(&ret, &[b as i64])?,
            Runtime::lit_f32(&sample_w, &[b as i64])?,
            Runtime::lit_f32(&[lr], &[1])?,
            Runtime::lit_f32(&[entropy_w], &[1])?,
            Runtime::lit_f32(&[vw], &[1])?,
        ];
        let out = self.runtime.execute(&self.stem, &inputs)?;
        if out.len() != 7 {
            bail!("train_step returned {} outputs, expected 7", out.len());
        }
        self.params = Runtime::read_f32(&out[0])?;
        self.m = Runtime::read_f32(&out[1])?;
        self.v = Runtime::read_f32(&out[2])?;
        let total = Runtime::read_f32(&out[3])?[0];
        let pg = Runtime::read_f32(&out[4])?[0];
        let vl = Runtime::read_f32(&out[5])?[0];
        let ent = Runtime::read_f32(&out[6])?[0];
        Ok([total, pg, vl, ent])
    }

    fn params(&self) -> &[f32] {
        &self.params
    }

    fn params_mut(&mut self) -> &mut Vec<f32> {
        &mut self.params
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Per-episode training statistics (the Fig 4 learning-curve series).
#[derive(Debug, Clone)]
pub struct EpisodeStat {
    pub episode: usize,
    pub makespan: f64,
    pub ep_return: f64,
    pub loss: f64,
    pub pg_loss: f64,
    pub value_loss: f64,
    pub entropy: f64,
    pub n_jobs: usize,
    pub n_transitions: usize,
    /// Greedy-policy makespan on a fixed held-out workload set, measured
    /// every few episodes (NaN otherwise) — the cleanest Fig 4 signal
    /// since the curriculum changes the training distribution.
    pub eval_makespan: f64,
}

impl EpisodeStat {
    pub fn csv_header() -> &'static str {
        "episode,makespan,return,loss,pg_loss,value_loss,entropy,n_jobs,n_transitions,eval_makespan"
    }

    pub fn csv_row(&self) -> String {
        format!(
            "{},{:.4},{:.4},{:.6},{:.6},{:.6},{:.6},{},{},{:.4}",
            self.episode,
            self.makespan,
            self.ep_return,
            self.loss,
            self.pg_loss,
            self.value_loss,
            self.entropy,
            self.n_jobs,
            self.n_transitions,
            self.eval_makespan
        )
    }
}

/// Trainer: owns the backend and the training configuration.
pub struct Trainer<B: TrainBackend> {
    pub cfg: TrainConfig,
    pub backend: B,
    /// Which feature mode the trained policy uses (Full for Lachesis,
    /// HomogeneousBlind for the Decima-DEFT baseline).
    pub feature_mode: FeatureMode,
    /// Returns scale for value targets (running estimate).
    ret_scale: f64,
}

/// Fixed learning hyper-parameters (paper Appendix C: Adam, lr 1e-3).
const LR: f32 = 1e-3;
const ENTROPY_W: f32 = 0.01;
const VALUE_W: f32 = 0.5;

impl<B: TrainBackend> Trainer<B> {
    pub fn new(cfg: TrainConfig, backend: B, feature_mode: FeatureMode) -> Trainer<B> {
        Trainer {
            cfg,
            backend,
            feature_mode,
            ret_scale: 100.0,
        }
    }

    /// Curriculum: episode index → number of jobs (grows from 1 to the
    /// configured max over the first half of training; Algorithm 2's
    /// τ_mean ← τ_mean + ε, adapted to whole-episode rollouts).
    fn jobs_for_episode(&self, ep: usize) -> usize {
        let max = self.cfg.jobs_per_episode.max(1);
        let ramp = (self.cfg.episodes / 2).max(1);
        (1 + ep * (max - 1) / ramp).min(max)
    }

    /// Convert one episode into batch rows with advantages and targets.
    fn episode_rows(&mut self, transitions: Vec<Transition>, makespan: f64) -> Vec<Row> {
        let rewards = episode::rewards_from_transitions(&transitions, makespan);
        let rets = episode::returns(&rewards, self.cfg.gamma);
        let values: Vec<f32> = transitions.iter().map(|t| t.value).collect();
        // Update the running return scale (value targets stay O(1)).
        if let Some(&r0) = rets.first() {
            self.ret_scale = 0.95 * self.ret_scale + 0.05 * r0.abs().max(1.0);
        }
        let scaled: Vec<f64> = rets.iter().map(|r| r / self.ret_scale).collect();
        let adv = episode::advantages(&scaled, &values);
        transitions
            .into_iter()
            .zip(adv)
            .zip(scaled)
            .map(|((t, a), r)| Row {
                action: t.action_slot as i32,
                adv: a as f32,
                ret: r as f32,
                enc: t.enc,
            })
            .collect()
    }

    fn update_batches(
        &mut self,
        mut rows: Vec<Row>,
        rng: &mut Rng,
        batch: usize,
        vw: f32,
    ) -> Result<[f64; 4]> {
        rng.shuffle(&mut rows);
        let mut losses = [0.0f64; 4];
        let mut n_batches = 0;
        for chunk in rows.chunks(batch) {
            let l = self.backend.update(chunk, LR, ENTROPY_W, vw)?;
            for i in 0..4 {
                losses[i] += l[i] as f64;
            }
            n_batches += 1;
        }
        if n_batches > 0 {
            for l in &mut losses {
                *l /= n_batches as f64;
            }
        }
        Ok(losses)
    }

    /// Greedy evaluation on a fixed held-out workload set (3 seeds × the
    /// full jobs_per_episode) — the Fig 4 y-axis. One parameter snapshot
    /// is shared by all evaluation actors.
    fn eval_greedy(&self, threads: usize) -> Result<f64> {
        let seeds = [990_001u64, 990_002, 990_003];
        let params = Arc::new(self.backend.params().to_vec());
        let executors = self.cfg.executors;
        let n_jobs = self.cfg.jobs_per_episode;
        let mode = self.feature_mode;
        let makespans = par::par_indexed(&seeds, threads, |&seed| {
            let cluster =
                Cluster::heterogeneous(&ClusterConfig::with_executors(executors), seed);
            let w = WorkloadGenerator::new(training_workload_cfg(n_jobs), seed).generate();
            let policy = RustPolicy::shared(params.clone());
            let mut sched = match mode {
                FeatureMode::Full => LachesisScheduler::greedy(Box::new(policy)),
                FeatureMode::HomogeneousBlind => {
                    crate::sched::DecimaScheduler::greedy_decima(Box::new(policy))
                }
            };
            let mut sim = Simulator::new(cluster, w);
            Ok(sim.run(&mut sched)?.makespan)
        })?;
        Ok(crate::util::stats::mean(&makespans))
    }

    /// Imitation warm start: collect (state, HEFT-choice) pairs and train
    /// with cross-entropy (advantage 1, value weight 0). See DESIGN.md.
    pub fn imitation_warmstart(&mut self, batch: usize) -> Result<()> {
        let mut rng = Rng::new(self.cfg.seed ^ 0x1111);
        for epoch in 0..self.cfg.imitation_epochs {
            let mut rows: Vec<Row> = Vec::new();
            for k in 0..8 {
                let seed = self.cfg.seed ^ (epoch as u64 * 131 + k + 7);
                let n_jobs = 1 + (k as usize % self.cfg.jobs_per_episode.max(1));
                let cluster = Cluster::heterogeneous(
                    &ClusterConfig::with_executors(self.cfg.executors),
                    seed,
                );
                let w = WorkloadGenerator::new(training_workload_cfg(n_jobs), seed).generate();
                let mut expert = RecordingExpert::new(HeftScheduler::new(), self.feature_mode);
                let mut sim = Simulator::new(cluster, w);
                sim.run(&mut expert)?;
                rows.extend(expert.rows.drain(..));
            }
            self.update_batches(rows, &mut rng, batch, 0.0)?;
        }
        Ok(())
    }

    /// The main loop: `episodes` iterations × `agents` parallel rollouts,
    /// fanned over `cfg.threads` scoped worker threads (0 = all cores).
    /// Returns the learning-curve series (Fig 4).
    ///
    /// The trajectory is bit-deterministic w.r.t. the thread count: the
    /// driver rng is drawn exactly twice per episode regardless of agent
    /// or thread count, each agent's sampling stream is derived purely
    /// from (sample master, agent index), the actors only *read* the
    /// shared parameter snapshot, and rollout results come back in agent
    /// order (so the order-sensitive return-scale EMA sees the same
    /// sequence a sequential run produces).
    pub fn train(&mut self, batch: usize) -> Result<Vec<EpisodeStat>> {
        if self.cfg.imitation_epochs > 0 {
            self.imitation_warmstart(batch)?;
        }
        let threads = par::effective_threads(self.cfg.threads);
        let mut rng = Rng::new(self.cfg.seed);
        let mut stats = Vec::with_capacity(self.cfg.episodes);
        // Telemetry never touches the trajectory: it reads wall clocks
        // and publishes gauges, while every RNG draw and update below is
        // identical with it on or off (integration_obs pins trainer-side
        // determinism indirectly through the shared sim/policy paths).
        let obs = if crate::obs::enabled() {
            Some(crate::obs::metrics::train_metrics())
        } else {
            None
        };
        let track_norm = obs.is_some() || self.cfg.metrics_jsonl.is_some();
        for ep in 0..self.cfg.episodes {
            let n_jobs = self.jobs_for_episode(ep);
            // All agents share the job sequence (paper Appendix C) and
            // differ only in sampling seed, each on its own named stream
            // of the per-episode master draw.
            let workload_seed = rng.next_u64();
            let sample_master = rng.next_u64();
            let agents = self.cfg.agents.max(1);
            let seeds: Vec<u64> = (0..agents)
                .map(|a| Rng::stream_seed(sample_master, STREAM_AGENT, a as u64))
                .collect();
            // One parameter snapshot per episode, shared by every actor.
            let params = Arc::new(self.backend.params().to_vec());
            let executors = self.cfg.executors;
            let temperature = self.cfg.temperature;
            let mode = self.feature_mode;
            let t_roll = Instant::now();
            let rollouts = {
                let _sp = trace::span("train", "rollout");
                par::par_indexed(&seeds, threads, |&sample_seed| {
                    rollout_once(
                        executors,
                        temperature,
                        mode,
                        params.clone(),
                        workload_seed,
                        sample_seed,
                        n_jobs,
                    )
                })?
            };
            let rollout_ms = t_roll.elapsed().as_secs_f64() * 1e3;
            let mut all_rows: Vec<Row> = Vec::new();
            let mut makespans = Vec::new();
            let mut n_trans = 0;
            for (transitions, makespan) in rollouts {
                makespans.push(makespan);
                n_trans += transitions.len();
                all_rows.extend(self.episode_rows(transitions, makespan));
            }
            let ep_return = -crate::util::stats::mean(&makespans);
            let params_before = if track_norm {
                Some(self.backend.params().to_vec())
            } else {
                None
            };
            let t_upd = Instant::now();
            let losses = {
                let _sp = trace::span("train", "update");
                self.update_batches(all_rows, &mut rng, batch, VALUE_W)?
            };
            let update_ms = t_upd.elapsed().as_secs_f64() * 1e3;
            let update_norm = params_before.map(|p0| {
                p0.iter()
                    .zip(self.backend.params())
                    .map(|(a, b)| {
                        let d = (*b - *a) as f64;
                        d * d
                    })
                    .sum::<f64>()
                    .sqrt()
            });
            let eval_makespan = if ep % 5 == 0 || ep + 1 == self.cfg.episodes {
                let _sp = trace::span("train", "eval");
                self.eval_greedy(threads)?
            } else {
                f64::NAN
            };
            stats.push(EpisodeStat {
                episode: ep,
                makespan: crate::util::stats::mean(&makespans),
                ep_return,
                loss: losses[0],
                pg_loss: losses[1],
                value_loss: losses[2],
                entropy: losses[3],
                n_jobs,
                n_transitions: n_trans,
                eval_makespan,
            });
            if let Some(m) = &obs {
                m.episodes_total.inc();
                m.rollout_ms.record(rollout_ms);
                m.update_ms.record(update_ms);
                m.episode.set(ep as f64);
                m.reward.set(ep_return);
                m.entropy.set(losses[3]);
                if let Some(n) = update_norm {
                    m.grad_norm.set(n);
                }
            }
            if let Some(path) = &self.cfg.metrics_jsonl {
                let s = stats.last().expect("episode just pushed");
                if let Err(e) =
                    append_metrics_jsonl(path, s, rollout_ms, update_ms, update_norm)
                {
                    crate::log_warn!("metrics jsonl append to {path} failed: {e}");
                }
            }
            if ep % 10 == 0 {
                crate::log_info!(
                    "episode {ep}: jobs={n_jobs} makespan={:.1}s loss={:.4} entropy={:.3}",
                    stats.last().unwrap().makespan,
                    losses[0],
                    losses[3]
                );
            }
        }
        Ok(stats)
    }
}

/// Append one episode's telemetry as a JSON line (the `--metrics-jsonl`
/// monitoring stream). NaN fields (skipped evals) serialize as `null`.
fn append_metrics_jsonl(
    path: &str,
    s: &EpisodeStat,
    rollout_ms: f64,
    update_ms: f64,
    update_norm: Option<f64>,
) -> std::io::Result<()> {
    use crate::util::json::Json;
    use std::io::Write as _;
    let line = Json::from_pairs(vec![
        ("episode", Json::from(s.episode)),
        ("makespan", Json::from(s.makespan)),
        ("return", Json::from(s.ep_return)),
        ("loss", Json::from(s.loss)),
        ("pg_loss", Json::from(s.pg_loss)),
        ("value_loss", Json::from(s.value_loss)),
        ("entropy", Json::from(s.entropy)),
        ("n_jobs", Json::from(s.n_jobs)),
        ("n_transitions", Json::from(s.n_transitions)),
        ("eval_makespan", Json::from(s.eval_makespan)),
        ("rollout_ms", Json::from(rollout_ms)),
        ("update_ms", Json::from(update_ms)),
        (
            "update_norm",
            match update_norm {
                Some(n) => Json::from(n),
                None => Json::Null,
            },
        ),
    ])
    .to_string();
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    writeln!(f, "{line}")
}

/// Workload used for training episodes and held-out evaluation: small
/// scale factors keep the per-episode task count within the N=64
/// training variant.
pub(crate) fn training_workload_cfg(n_jobs: usize) -> WorkloadConfig {
    let mut cfg = WorkloadConfig::small_batch(n_jobs);
    cfg.sizes_gb = vec![2.0, 5.0, 10.0];
    cfg
}

/// Roll out one sampled episode against a shared parameter snapshot;
/// returns (transitions, makespan). A free function (no trainer borrow)
/// so parallel actors can run it on scoped worker threads.
fn rollout_once(
    executors: usize,
    temperature: f64,
    feature_mode: FeatureMode,
    params: Arc<Vec<f32>>,
    workload_seed: u64,
    sample_seed: u64,
    n_jobs: usize,
) -> Result<(Vec<Transition>, f64)> {
    let cluster =
        Cluster::heterogeneous(&ClusterConfig::with_executors(executors), workload_seed);
    let w = WorkloadGenerator::new(training_workload_cfg(n_jobs), workload_seed).generate();
    let policy = RustPolicy::shared(params);
    let mut sched = match feature_mode {
        FeatureMode::Full => {
            LachesisScheduler::training(Box::new(policy), temperature, sample_seed)
        }
        FeatureMode::HomogeneousBlind => {
            crate::sched::DecimaScheduler::training_decima(
                Box::new(policy),
                temperature,
                sample_seed,
            )
        }
    };
    let mut sim = Simulator::new(cluster, w);
    let report = sim.run(&mut sched)?;
    Ok((sched.selector.take_transitions(), report.makespan))
}

/// Wraps any scheduler and records (encoding, chosen slot) pairs — the
/// imitation-learning data collector.
pub struct RecordingExpert<S: Scheduler> {
    pub inner: S,
    pub feature_mode: FeatureMode,
    pub rows: Vec<Row>,
}

impl<S: Scheduler> RecordingExpert<S> {
    pub fn new(inner: S, feature_mode: FeatureMode) -> Self {
        RecordingExpert {
            inner,
            feature_mode,
            rows: Vec::new(),
        }
    }
}

impl<S: Scheduler> Scheduler for RecordingExpert<S> {
    fn name(&self) -> String {
        format!("expert-{}", self.inner.name())
    }

    fn reset(&mut self) {
        self.inner.reset();
        self.rows.clear();
    }

    fn step(
        &mut self,
        state: &crate::sim::SimState,
    ) -> Result<Option<(crate::dag::TaskRef, crate::sim::Allocation)>> {
        let decision = self.inner.step(state)?;
        if let Some((task, _)) = decision {
            let enc = crate::policy::encode::encode(state, self.feature_mode);
            if let Some(slot) = enc.task_slot(task) {
                // Only keep states that fit the training variant.
                if enc.variant.n == crate::policy::encode::VARIANTS[0].n {
                    self.rows.push(Row {
                        enc,
                        action: slot as i32,
                        adv: 1.0,
                        ret: 0.0,
                    });
                }
            }
        }
        Ok(decision)
    }
}

/// A fake backend for engine-level tests (no artifacts needed): applies a
/// tiny perturbation so "training" visibly changes parameters.
pub struct FakeBackend {
    pub params: Vec<f32>,
    pub updates: usize,
}

impl FakeBackend {
    pub fn new(seed: u64) -> FakeBackend {
        FakeBackend {
            params: RustPolicy::random_params(seed),
            updates: 0,
        }
    }
}

impl TrainBackend for FakeBackend {
    fn update(&mut self, batch: &[Row], _lr: f32, _ew: f32, _vw: f32) -> Result<[f32; 4]> {
        self.updates += 1;
        let delta = 1e-5 * batch.len() as f32;
        for p in self.params.iter_mut().take(16) {
            *p += delta;
        }
        Ok([1.0 / self.updates as f32, 0.0, 0.0, 1.0])
    }

    fn params(&self) -> &[f32] {
        &self.params
    }

    fn params_mut(&mut self) -> &mut Vec<f32> {
        &mut self.params
    }

    fn name(&self) -> &'static str {
        "fake"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> TrainConfig {
        TrainConfig {
            episodes: 3,
            agents: 2,
            jobs_per_episode: 2,
            executors: 4,
            imitation_epochs: 0,
            ..Default::default()
        }
    }

    #[test]
    fn trainer_runs_with_fake_backend() {
        let mut tr = Trainer::new(quick_cfg(), FakeBackend::new(1), FeatureMode::Full);
        let stats = tr.train(8).unwrap();
        assert_eq!(stats.len(), 3);
        assert!(tr.backend.updates > 0);
        for s in &stats {
            assert!(s.makespan > 0.0);
            assert!(s.n_transitions > 0);
            assert!((s.ep_return + s.makespan).abs() < 1e-9);
        }
    }

    #[test]
    fn curriculum_grows_jobs() {
        let mut cfg = quick_cfg();
        cfg.episodes = 100;
        cfg.jobs_per_episode = 4;
        let tr = Trainer::new(cfg, FakeBackend::new(2), FeatureMode::Full);
        assert_eq!(tr.jobs_for_episode(0), 1);
        assert!(tr.jobs_for_episode(99) >= tr.jobs_for_episode(0));
        assert_eq!(tr.jobs_for_episode(99), 4);
    }

    #[test]
    fn recording_expert_collects_rows() {
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(4), 5);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(2), 5).generate();
        let n = w.n_tasks();
        let mut expert = RecordingExpert::new(HeftScheduler::new(), FeatureMode::Full);
        let mut sim = Simulator::new(cluster, w);
        sim.run(&mut expert).unwrap();
        assert_eq!(expert.rows.len(), n);
        for r in &expert.rows {
            let t = r.enc.slot_task(r.action as usize).unwrap();
            // The recorded action must have been executable in its state.
            assert!(r.enc.exec_mask[r.action as usize] > 0.0, "{t:?}");
        }
    }

    #[test]
    fn train_is_thread_count_invariant() {
        // Same config, different thread counts → identical stat series
        // and parameters (the full-fidelity CpuTrainBackend variant lives
        // in tests/integration_train.rs; this pins the engine plumbing).
        let run = |threads: usize| {
            let mut cfg = quick_cfg();
            cfg.threads = threads;
            let mut tr = Trainer::new(cfg, FakeBackend::new(7), FeatureMode::Full);
            let stats = tr.train(8).unwrap();
            let series: Vec<(f64, f64, usize)> = stats
                .iter()
                .map(|s| (s.makespan, s.ep_return, s.n_transitions))
                .collect();
            (series, tr.backend.params().to_vec())
        };
        let (s1, p1) = run(1);
        let (s4, p4) = run(4);
        assert_eq!(s1, s4);
        assert_eq!(p1, p4);
    }

    #[test]
    fn fake_backend_changes_params() {
        let mut tr = Trainer::new(quick_cfg(), FakeBackend::new(3), FeatureMode::Full);
        let before = tr.backend.params().to_vec();
        tr.train(8).unwrap();
        assert_ne!(before, tr.backend.params());
    }

    #[test]
    fn episode_stat_csv_shape() {
        let s = EpisodeStat {
            episode: 1,
            makespan: 2.0,
            ep_return: -2.0,
            loss: 0.5,
            pg_loss: 0.1,
            value_loss: 0.2,
            entropy: 1.5,
            n_jobs: 2,
            n_transitions: 10,
            eval_makespan: f64::NAN,
        };
        assert_eq!(
            s.csv_row().split(',').count(),
            EpisodeStat::csv_header().split(',').count()
        );
    }
}
