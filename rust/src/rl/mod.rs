//! Reinforcement-learning training (paper §4.3, Algorithm 2): episode
//! collection in the simulator, makespan-increment rewards, discounted
//! returns with a learned value baseline, and parameter updates through
//! the AOT-compiled `train_step` artifact (forward + backward + Adam, all
//! inside one XLA program — python is only involved at build time).

pub mod episode;
pub mod trainer;

pub use episode::{advantages, returns, rewards_from_transitions};
pub use trainer::{EpisodeStat, TrainBackend, Trainer};
