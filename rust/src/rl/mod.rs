//! Reinforcement-learning training (paper §4.3, Algorithm 2): episode
//! collection in the simulator, makespan-increment rewards, discounted
//! returns with a learned value baseline, and parameter updates through
//! either the native CPU backend ([`CpuTrainBackend`] — analytic backprop
//! through the sparse kernels, no python anywhere) or the AOT-compiled
//! `train_step` artifact (forward + backward + Adam inside one XLA
//! program — python is only involved at build time).

pub mod cpu_backend;
pub mod episode;
pub mod trainer;

pub use cpu_backend::CpuTrainBackend;
pub use episode::{advantages, returns, rewards_from_transitions};
pub use trainer::{EpisodeStat, TrainBackend, Trainer};
