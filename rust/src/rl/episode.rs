//! Episode post-processing: rewards, discounted returns, advantages.
//!
//! The paper penalizes each action by the wall-clock increment
//! `r_k = -(t_k - t_{k-1})`, whose episode sum is `-t_T` — the makespan.
//! Because our engine (like any list scheduler) may assign many tasks at
//! a single event time, we use the equivalent *schedule-horizon*
//! increment: `r_k = -(horizon_{k+1} - horizon_k)` where `horizon` is the
//! running max AFT. The episode return is still exactly `-makespan`, but
//! credit is assigned to the decision that actually extended the
//! schedule (denser, better-conditioned signal; see DESIGN.md).

use crate::sched::lachesis::Transition;

/// Per-step rewards from the recorded horizons and the final makespan.
pub fn rewards_from_transitions(transitions: &[Transition], final_makespan: f64) -> Vec<f64> {
    let n = transitions.len();
    let mut rewards = Vec::with_capacity(n);
    for k in 0..n {
        let next_h = if k + 1 < n {
            transitions[k + 1].horizon_before
        } else {
            final_makespan
        };
        rewards.push(-(next_h - transitions[k].horizon_before));
    }
    rewards
}

/// Discounted reward-to-go.
pub fn returns(rewards: &[f64], gamma: f64) -> Vec<f64> {
    let mut out = vec![0.0; rewards.len()];
    let mut acc = 0.0;
    for k in (0..rewards.len()).rev() {
        acc = rewards[k] + gamma * acc;
        out[k] = acc;
    }
    out
}

/// Advantage = return − critic value, normalized to zero mean / unit std
/// across the batch (stabilizes the policy gradient; standard practice).
pub fn advantages(returns: &[f64], values: &[f32]) -> Vec<f64> {
    assert_eq!(returns.len(), values.len());
    let raw: Vec<f64> = returns
        .iter()
        .zip(values)
        .map(|(r, &v)| r - v as f64)
        .collect();
    let n = raw.len().max(1) as f64;
    let mean = raw.iter().sum::<f64>() / n;
    let var = raw.iter().map(|a| (a - mean).powi(2)).sum::<f64>() / n;
    let std = var.sqrt().max(1e-6);
    raw.iter().map(|a| (a - mean) / std).collect()
}

/// Normalize returns for the value-regression target (same scale the
/// critic is trained in; keeps value magnitudes O(1) across workloads).
pub fn normalize_returns(returns: &[f64], scale: f64) -> Vec<f32> {
    returns.iter().map(|&r| (r / scale) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::encode::EncodedState;
    use crate::policy::features::FeatureMode;

    fn fake_transition(horizon: f64) -> Transition {
        // Build a tiny valid encoding from a 1-task state.
        let cluster = crate::cluster::Cluster::homogeneous(1, 1.0, 10.0);
        let job = crate::dag::Job::new(0, "t", 0.0, vec![1.0], &[]);
        let mut st =
            crate::sim::SimState::new(cluster, crate::workload::Workload::new(vec![job]));
        st.mark_arrived(0);
        let enc: EncodedState = crate::policy::encode::encode(&st, FeatureMode::Full);
        Transition {
            enc,
            action_slot: 0,
            value: 0.0,
            horizon_before: horizon,
            wall: horizon,
        }
    }

    #[test]
    fn rewards_sum_to_negative_makespan() {
        let ts = vec![
            fake_transition(0.0),
            fake_transition(3.0),
            fake_transition(3.0),
            fake_transition(7.0),
        ];
        let r = rewards_from_transitions(&ts, 10.0);
        assert_eq!(r, vec![-3.0, 0.0, -4.0, -3.0]);
        assert!((r.iter().sum::<f64>() + 10.0).abs() < 1e-12);
    }

    #[test]
    fn undiscounted_returns_are_suffix_sums() {
        let r = returns(&[-1.0, -2.0, -3.0], 1.0);
        assert_eq!(r, vec![-6.0, -5.0, -3.0]);
    }

    #[test]
    fn discounting_shrinks_tail() {
        let r = returns(&[-1.0, -1.0, -1.0], 0.5);
        assert!((r[0] - (-1.75)).abs() < 1e-12);
        assert!((r[1] - (-1.5)).abs() < 1e-12);
        assert!((r[2] - (-1.0)).abs() < 1e-12);
    }

    #[test]
    fn advantages_are_standardized() {
        let adv = advantages(&[-10.0, -20.0, -30.0], &[0.0, 0.0, 0.0]);
        let mean: f64 = adv.iter().sum::<f64>() / 3.0;
        assert!(mean.abs() < 1e-9);
        let var: f64 = adv.iter().map(|a| (a - mean).powi(2)).sum::<f64>() / 3.0;
        assert!((var - 1.0).abs() < 1e-9);
        // Better (less negative) return ⇒ larger advantage.
        assert!(adv[0] > adv[1] && adv[1] > adv[2]);
    }

    #[test]
    fn empty_inputs_are_fine() {
        assert!(rewards_from_transitions(&[], 5.0).is_empty());
        assert!(returns(&[], 0.9).is_empty());
        assert!(advantages(&[], &[]).is_empty());
    }
}
