//! Heterogeneous executor cluster model (paper §3, constraints 2–3).
//!
//! Executors differ in processing speed `v_k` (sampled from an Intel CPU
//! frequency table, 2.1–3.6 GHz, per §5.2). Data transmission between
//! *distinct* executors is priced by a [`NetworkModel`]: the default
//! `flat` topology reproduces the paper's uniform speed `c` bitwise,
//! while `tree`/`fat-tree` topologies give rack-local pairs more
//! bandwidth than cross-rack ones (see `rust/src/net/`). Transfers
//! within one executor are free in every topology.

use crate::config::{ClusterConfig, SchedMode};
use crate::net::{NetConfig, NetworkModel};
use crate::util::rng::{Rng, STREAM_CLUSTER};

/// One computing executor.
#[derive(Debug, Clone)]
pub struct Executor {
    pub id: usize,
    /// Processing speed `v_k` in GHz; task `n_i` takes `w_i / v_k` seconds.
    pub speed: f64,
    /// Whether the executor is currently up. Flipped by the fault
    /// subsystem (crash / recovery); allocators skip down executors and
    /// the simulator refuses to book work onto them.
    pub available: bool,
}

/// The cluster: executor set + communication model.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub executors: Vec<Executor>,
    /// Base inter-executor transmission speed in MB/s (the uniform
    /// speed under `flat`; the reference link rate other topologies
    /// scale from).
    pub comm_mbps: f64,
    /// How executor time is booked by the simulator (append-compat vs
    /// gap-aware insertion); threaded from [`ClusterConfig::sched_mode`].
    pub sched_mode: SchedMode,
    /// Compiled per-pair bandwidth/latency lookups; rebuilt on cluster
    /// change via [`Cluster::with_net`].
    pub net: NetworkModel,
}

impl Cluster {
    /// Sample a heterogeneous cluster per the paper: speeds drawn uniformly
    /// from the config's frequency table.
    pub fn heterogeneous(cfg: &ClusterConfig, seed: u64) -> Cluster {
        cfg.validate().expect("invalid cluster config");
        let mut rng = Rng::stream(seed, STREAM_CLUSTER);
        let executors = (0..cfg.n_executors)
            .map(|id| Executor {
                id,
                speed: *rng.choice(&cfg.freq_table),
                available: true,
            })
            .collect();
        Cluster {
            executors,
            comm_mbps: cfg.comm_mbps,
            sched_mode: cfg.sched_mode,
            net: NetworkModel::build(&cfg.net, cfg.comm_mbps, cfg.n_executors),
        }
    }

    /// A homogeneous cluster (Decima's setting; used in ablations/tests).
    /// Always flat — topology-aware tests go through [`Cluster::with_net`].
    pub fn homogeneous(n: usize, speed: f64, comm_mbps: f64) -> Cluster {
        assert!(n > 0 && speed > 0.0 && comm_mbps > 0.0);
        Cluster {
            executors: (0..n)
                .map(|id| Executor {
                    id,
                    speed,
                    available: true,
                })
                .collect(),
            comm_mbps,
            sched_mode: SchedMode::Append,
            net: NetworkModel::build(&NetConfig::flat(), comm_mbps, n),
        }
    }

    /// Builder-style override of the booking mode (used by tests and the
    /// gap-aware bench comparisons).
    pub fn with_sched_mode(mut self, mode: SchedMode) -> Cluster {
        self.sched_mode = mode;
        self
    }

    /// Builder-style topology override: recompiles the per-pair lookup
    /// matrices for this cluster's size and base speed.
    pub fn with_net(mut self, cfg: &NetConfig) -> Cluster {
        self.net = NetworkModel::build(cfg, self.comm_mbps, self.len());
        self
    }

    pub fn len(&self) -> usize {
        self.executors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.executors.is_empty()
    }

    pub fn speed(&self, k: usize) -> f64 {
        self.executors[k].speed
    }

    /// Whether executor `k` is currently up.
    pub fn available(&self, k: usize) -> bool {
        self.executors[k].available
    }

    /// Flip executor `k`'s availability (fault subsystem hook).
    pub fn set_available(&mut self, k: usize, up: bool) {
        self.executors[k].available = up;
    }

    /// Number of executors currently up.
    pub fn n_available(&self) -> usize {
        self.executors.iter().filter(|e| e.available).count()
    }

    /// Is at least one executor up?
    pub fn any_available(&self) -> bool {
        self.executors.iter().any(|e| e.available)
    }

    /// Mean executor speed `v̄` over the *available* executors (used by
    /// rank_up/rank_down, Eq 6–7). Falls back to the all-executor mean if
    /// every executor is down (so the ratio features never divide by
    /// zero); with no faults this is the historical mean, bit-identical.
    pub fn v_avg(&self) -> f64 {
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for e in self.executors.iter().filter(|e| e.available) {
            sum += e.speed;
            n += 1;
        }
        if n == 0 {
            return self.executors.iter().map(|e| e.speed).sum::<f64>() / self.len() as f64;
        }
        sum / n as f64
    }

    /// Fastest executor speed (speedup numerator and SLR denominator use
    /// the fastest executor, Eq 13–14).
    pub fn v_max(&self) -> f64 {
        self.executors
            .iter()
            .map(|e| e.speed)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Index of the fastest *available* executor (falls back to the
    /// all-executor argmax when everything is down, matching `v_avg`).
    /// Ties keep the historical resolution (last maximum wins), so the
    /// zero-fault answer is unchanged.
    pub fn fastest(&self) -> usize {
        // total_cmp: speeds are validated finite, but a NaN smuggled in
        // through a hand-built cluster must not panic the scheduler
        // (same hardening as the event-queue ordering).
        (0..self.len())
            .filter(|&k| self.executors[k].available)
            .max_by(|&a, &b| self.speed(a).total_cmp(&self.speed(b)))
            .unwrap_or_else(|| {
                (0..self.len())
                    .max_by(|&a, &b| self.speed(a).total_cmp(&self.speed(b)))
                    .unwrap()
            })
    }

    /// Transmission speed `c_ij` between executors (MB/s); infinite within
    /// a single executor (data already local, paper constraint 3). Under
    /// `flat` this is the uniform `comm_mbps`; other topologies return
    /// the pair's effective bandwidth.
    pub fn comm_speed(&self, from: usize, to: usize) -> f64 {
        self.net.bandwidth(from, to)
    }

    /// Average inter-executor transmission speed `c̄` (for the rank
    /// features): the topology's mean off-diagonal bandwidth, which is
    /// exactly `comm_mbps` under the paper's uniform (`flat`) model.
    pub fn c_avg(&self) -> f64 {
        self.net.c_avg()
    }

    /// Transfer time of `data` MB from executor `from` to `to` (Eq 2's
    /// `e_pi / c_pj` term): zero when co-located, otherwise latency +
    /// size over the pair's effective bandwidth.
    pub fn transfer_time(&self, data: f64, from: usize, to: usize) -> f64 {
        self.net.transfer_time(data, from, to)
    }

    /// Rack id of executor `k` (0 for every executor under `flat`).
    pub fn rack_of(&self, k: usize) -> usize {
        self.net.rack_of(k)
    }

    /// Number of racks in the topology (1 under `flat`).
    pub fn n_racks(&self) -> usize {
        self.net.n_racks()
    }

    /// Do two executors share a rack (always true under `flat`)?
    pub fn same_rack(&self, a: usize, b: usize) -> bool {
        self.net.same_rack(a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    #[test]
    fn heterogeneous_speeds_from_table() {
        let cfg = ClusterConfig::default();
        let c = Cluster::heterogeneous(&cfg, 7);
        assert_eq!(c.len(), 50);
        for e in &c.executors {
            assert!(
                cfg.freq_table.iter().any(|&f| (f - e.speed).abs() < 1e-9),
                "speed {} not in table",
                e.speed
            );
        }
        // With 50 draws from 16 values we should see heterogeneity.
        let distinct: std::collections::BTreeSet<u64> = c
            .executors
            .iter()
            .map(|e| (e.speed * 10.0).round() as u64)
            .collect();
        assert!(distinct.len() > 3);
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = ClusterConfig::with_executors(10);
        let a = Cluster::heterogeneous(&cfg, 42);
        let b = Cluster::heterogeneous(&cfg, 42);
        for (x, y) in a.executors.iter().zip(&b.executors) {
            assert_eq!(x.speed, y.speed);
        }
    }

    #[test]
    fn comm_model() {
        let c = Cluster::homogeneous(3, 2.0, 100.0);
        assert_eq!(c.transfer_time(500.0, 0, 1), 5.0);
        assert_eq!(c.transfer_time(500.0, 1, 1), 0.0);
        assert_eq!(c.transfer_time(0.0, 0, 1), 0.0);
        assert!(c.comm_speed(0, 0).is_infinite());
        assert_eq!(c.c_avg(), 100.0);
        assert_eq!(c.n_racks(), 1);
        assert!(c.same_rack(0, 2));
    }

    #[test]
    fn with_net_compiles_topology() {
        let c = Cluster::homogeneous(8, 2.0, 100.0).with_net(&NetConfig::tree(2, 4));
        assert_eq!(c.n_racks(), 2);
        assert_eq!(c.rack_of(3), 0);
        assert_eq!(c.rack_of(4), 1);
        assert!(c.transfer_time(100.0, 0, 1) < c.transfer_time(100.0, 0, 4));
        // Intra-executor transfers stay free in every topology.
        assert_eq!(c.transfer_time(100.0, 5, 5), 0.0);
        // c̄ reflects the topology mix, not the scalar base.
        assert_ne!(c.c_avg(), 100.0);
        assert!(c.c_avg().is_finite() && c.c_avg() > 0.0);
    }

    #[test]
    fn fastest_survives_nan_speed() {
        // A NaN speed must not panic fastest(); total_cmp orders NaN
        // above every finite value, so the finite argmax still wins
        // when the NaN executor is filtered out by availability.
        let mut c = Cluster::homogeneous(3, 2.0, 10.0);
        c.executors[1].speed = f64::NAN;
        c.set_available(1, false);
        assert_eq!(c.fastest(), 2, "ties keep last-max resolution");
        // Even with the NaN executor live the call must not panic.
        c.set_available(1, true);
        let _ = c.fastest();
    }

    #[test]
    fn aggregates() {
        let mut c = Cluster::homogeneous(2, 2.0, 10.0);
        c.executors[1].speed = 4.0;
        assert!((c.v_avg() - 3.0).abs() < 1e-12);
        assert_eq!(c.v_max(), 4.0);
        assert_eq!(c.fastest(), 1);
    }

    #[test]
    fn availability_skews_aggregates_but_never_empties_them() {
        let mut c = Cluster::homogeneous(3, 2.0, 10.0);
        c.executors[1].speed = 4.0;
        c.executors[2].speed = 3.0;
        assert!(c.any_available());
        assert_eq!(c.n_available(), 3);
        // Down the fastest: fastest() moves to the next-best live one.
        c.set_available(1, false);
        assert!(!c.available(1));
        assert_eq!(c.n_available(), 2);
        assert_eq!(c.fastest(), 2);
        assert!((c.v_avg() - 2.5).abs() < 1e-12);
        // v_max stays the nameplate maximum (report metrics keep a
        // stable denominator across fault runs).
        assert_eq!(c.v_max(), 4.0);
        // All down: aggregates fall back to the full set instead of
        // panicking / dividing by zero.
        c.set_available(0, false);
        c.set_available(2, false);
        assert!(!c.any_available());
        assert_eq!(c.fastest(), 1);
        assert!((c.v_avg() - 3.0).abs() < 1e-12);
    }
}
