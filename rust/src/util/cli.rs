//! A small command-line argument parser (the offline registry has no clap).
//!
//! Supports `binary <subcommand> [positionals] [--flag] [--key value|--key=value]`.
//! Typed accessors return `anyhow` errors with the offending flag named, and
//! unknown-flag detection catches typos in experiment scripts.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (e.g. `repro`, `train`, `serve`).
    pub subcommand: Option<String>,
    /// Remaining non-flag tokens in order.
    pub positionals: Vec<String>,
    /// `--key value` and `--key=value` options.
    opts: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.opts.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the process's own argv.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        // A `--key value` where the value was actually intended as a flag
        // still counts via opts lookup of "true"/"false".
        self.flags.iter().any(|f| f == name)
            || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn usize_opt(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn u64_opt(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Worker-thread count for parallel sweeps: `--threads N`, where
    /// `--threads auto` (or `0`) means one worker per available core.
    pub fn threads_opt(&self, default: usize) -> Result<usize> {
        let auto = || {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        };
        match self.opt("threads") {
            None => Ok(default.max(1)),
            Some("auto") => Ok(auto()),
            Some(v) => {
                let n = v.parse::<usize>().map_err(|_| {
                    anyhow!("--threads expects an integer or 'auto', got '{v}'")
                })?;
                Ok(if n == 0 { auto() } else { n })
            }
        }
    }

    pub fn f64_opt(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Validate that every provided option/flag is in the allowed set
    /// (catches typos like `--episods`).
    pub fn check_known(&self, allowed: &[&str]) -> Result<()> {
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !allowed.contains(&k.as_str()) {
                bail!(
                    "unknown option '--{k}' (allowed: {})",
                    allowed
                        .iter()
                        .map(|a| format!("--{a}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["repro", "fig5", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("repro"));
        assert_eq!(a.positionals, vec!["fig5", "extra"]);
    }

    #[test]
    fn options_both_syntaxes() {
        let a = parse(&["train", "--episodes", "100", "--seed=7"]);
        assert_eq!(a.usize_opt("episodes", 0).unwrap(), 100);
        assert_eq!(a.u64_opt("seed", 0).unwrap(), 7);
    }

    #[test]
    fn flags() {
        let a = parse(&["repro", "--quick", "--out", "x.csv"]);
        assert!(a.flag("quick"));
        assert!(!a.flag("slow"));
        assert_eq!(a.opt("out"), Some("x.csv"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b"]);
        assert!(a.flag("a"));
        assert!(a.flag("b"));
    }

    #[test]
    fn typed_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.usize_opt("n", 0).is_err());
        assert!(a.f64_opt("n", 0.0).is_err());
    }

    #[test]
    fn unknown_detection() {
        let a = parse(&["x", "--good", "1", "--bad", "2"]);
        assert!(a.check_known(&["good"]).is_err());
        assert!(a.check_known(&["good", "bad"]).is_ok());
    }

    #[test]
    fn threads_option() {
        assert_eq!(parse(&["x", "--threads", "3"]).threads_opt(1).unwrap(), 3);
        assert_eq!(parse(&["x"]).threads_opt(2).unwrap(), 2);
        // 'auto' and 0 resolve to the machine's parallelism (≥ 1).
        assert!(parse(&["x", "--threads", "auto"]).threads_opt(1).unwrap() >= 1);
        assert!(parse(&["x", "--threads", "0"]).threads_opt(1).unwrap() >= 1);
        assert!(parse(&["x", "--threads", "lots"]).threads_opt(1).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.usize_opt("n", 5).unwrap(), 5);
        assert_eq!(a.f64_opt("r", 1.5).unwrap(), 1.5);
        assert_eq!(a.opt_or("name", "d"), "d");
    }
}
