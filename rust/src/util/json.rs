//! Minimal JSON implementation (parser + writer).
//!
//! Used for the config system, workload traces, artifact metadata
//! (`artifacts/meta.json`) and the plug-and-play service protocol. The
//! offline registry has no serde, so this is a from-scratch substrate:
//! a complete RFC 8259 parser (strings with escapes and `\uXXXX`, numbers,
//! nested containers) and a writer with compact and pretty modes.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a BTreeMap for deterministic
/// serialization (stable diffs in committed reports).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for debuggability.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---- accessors --------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(idx),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Fetch a required field, with a useful error for config validation.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing required field '{key}'"),
            offset: 0,
        })
    }

    /// Typed fetch helpers returning `anyhow`-friendly errors.
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?.as_f64().ok_or_else(|| JsonError {
            msg: format!("field '{key}' is not a number"),
            offset: 0,
        })
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?.as_usize().ok_or_else(|| JsonError {
            msg: format!("field '{key}' is not a non-negative integer"),
            offset: 0,
        })
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?.as_str().ok_or_else(|| JsonError {
            msg: format!("field '{key}' is not a string"),
            offset: 0,
        })
    }

    /// Insert into an object value (no-op on non-objects).
    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    // ---- parsing ----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // ---- writing ----------------------------------------------------------

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(|x| x.into()).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; emit null (matches common lenient writers).
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            match c {
                                Some(c) => s.push(c),
                                None => return Err(self.err("invalid \\u escape")),
                            }
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 codepoint.
                    let start = self.pos;
                    let rest = &self.bytes[start..];
                    let len = utf8_len(rest[0]);
                    if rest.len() < len {
                        return Err(self.err("truncated UTF-8"));
                    }
                    match std::str::from_utf8(&rest[..len]) {
                        Ok(chunk) => {
                            s.push_str(chunk);
                            self.pos += len;
                        }
                        Err(_) => return Err(self.err("invalid UTF-8")),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid hex"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid hex"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xE0 {
        2
    } else if b < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().at(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            v.get("a").unwrap().at(2).unwrap().get("b"),
            Some(&Json::Null)
        );
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_structured() {
        let mut obj = Json::obj();
        obj.set("n", Json::from(42usize));
        obj.set("xs", Json::from(vec![1.5f64, 2.5]));
        obj.set("s", Json::from("quote\"backslash\\"));
        let text = obj.to_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, obj);
    }

    #[test]
    fn req_helpers() {
        let v = Json::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req_f64("missing").is_err());
        assert!(v.req_usize("s").is_err());
    }

    #[test]
    fn deterministic_key_order() {
        let v = Json::parse(r#"{"b": 1, "a": 2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"b":1}"#);
    }
}
